//! Policy hot-path micro-benchmarks: per-slot decision latency and
//! throughput for every policy, plus the WindowScan primitive.
//!
//! The deterministic policy's O(1)-amortized window bookkeeping is the
//! §Perf L3 target: ≥10 M policy-steps/s (vs the naive O(τ) rescan).

use cloudreserve::algos::baselines::{AllOnDemand, AllReserved, Separate};
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::algos::window::{NaiveScan, WindowScan};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::util::bench::{sink, Bencher};
use cloudreserve::util::rng::Rng;
use cloudreserve::Policy;

fn main() {
    let pricing = ec2_small_compressed(); // tau = 8760 — the real window
    let slots = 50_000usize;
    let mut rng = Rng::new(42);
    // a group-2-like demand curve
    let demand: Vec<u32> = (0..slots)
        .map(|t| {
            let base = 4.0 + 3.0 * ((t as f64) / 720.0).sin();
            (base * (1.0 + 0.3 * rng.normal()).max(0.0)).round() as u32
        })
        .collect();

    let b = Bencher::default();

    // Full-trace runs (policy-steps/s is the headline number).
    let policies: Vec<(&str, Box<dyn Fn() -> Box<dyn Policy>>)> = vec![
        ("all_on_demand", Box::new(move || Box::new(AllOnDemand::new()))),
        ("all_reserved", Box::new(move || Box::new(AllReserved::new(pricing)))),
        ("separate", Box::new(move || Box::new(Separate::new(pricing)))),
        ("deterministic_beta", Box::new(move || Box::new(Deterministic::online(pricing)))),
        (
            "deterministic_w720",
            Box::new(move || Box::new(Deterministic::with_window(pricing, 720))),
        ),
        ("randomized", Box::new(move || Box::new(Randomized::online(pricing, 7)))),
    ];
    println!("== policy step throughput (tau=8760, {slots} slots, group-2 demand) ==");
    for (name, factory) in &policies {
        let r = b.run(&format!("policy/{name}/full_trace"), || {
            let mut p = factory();
            let mut acc = 0u32;
            for &d in &demand {
                let dec = p.decide(d, &[]);
                acc = acc.wrapping_add(dec.total_reserved() + dec.on_demand);
            }
            acc
        });
        r.report();
        println!(
            "  -> {:.2} M policy-steps/s",
            r.throughput(slots as f64) / 1e6
        );
    }

    // WindowScan primitive vs the literal O(tau) rescan.
    println!("\n== window-scan primitive (the Algorithm-1 inner loop) ==");
    let r_fast = b.run("window_scan/incremental/50k_slots", || {
        let mut scan = WindowScan::new();
        let tau = 8760usize;
        let mut acc = 0u32;
        for (t, &d) in demand.iter().enumerate() {
            scan.expire_before((t + 1).saturating_sub(tau));
            scan.insert(t, d, 0);
            acc = acc.wrapping_add(scan.violations());
        }
        acc
    });
    r_fast.report();
    println!("  -> {:.2} M slots/s", r_fast.throughput(slots as f64) / 1e6);

    let naive_slots = 2_000usize; // the naive scan is ~tau x slower
    let quick = Bencher::quick();
    let r_naive = quick.run("window_scan/naive_rescan/2k_slots", || {
        let tau = 8760usize;
        let mut scan = NaiveScan::new(tau);
        let mut acc = 0u32;
        for (t, &d) in demand[..naive_slots].iter().enumerate() {
            scan.insert(d);
            acc = acc.wrapping_add(scan.violations(t));
        }
        acc
    });
    r_naive.report();
    let speedup = (r_naive.median_ns() / naive_slots as f64) / (r_fast.median_ns() / slots as f64);
    println!("  -> incremental scan speedup over naive O(tau) rescan: {speedup:.0}x");

    sink(());
}
