//! Fig. 2 regeneration bench: measured worst-case competitive ratios over
//! the α grid (deterministic adversary exact; randomized Monte-Carlo),
//! with wall-time accounting. `cargo bench` prints the same series the
//! figure plots.

use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::offline;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;
use cloudreserve::util::bench::fmt_ns;

fn main() {
    let p = 0.004;
    let samples = 800u64;
    println!("== Fig. 2 series: competitive ratio vs alpha ==");
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>12}",
        "alpha", "2-a", "det(meas)", "e/(e-1+a)", "rand(meas@beta)"
    );
    let t0 = std::time::Instant::now();
    for i in 0..10 {
        let alpha = i as f64 / 10.0;
        let pricing = Pricing::normalized(p, alpha, 10_000_000);
        let beta = pricing.beta();

        // deterministic adversary: demand just past break-even
        let pulses = (beta / p).ceil() as usize + 1;
        let mut demands = vec![1u32; pulses];
        demands.extend(vec![0u32; 5]);
        let mut det = Deterministic::online(pricing);
        let det_cost = run_policy(&mut det, &demands, pricing).unwrap().total;
        let det_ratio = det_cost / offline::optimal_single(&demands, &pricing).cost;

        // randomized at x = beta (the tight point of Prop. 3)
        let at_beta = vec![1u32; (beta / p).floor() as usize];
        let opt = offline::optimal_single(&at_beta, &pricing).cost;
        let mean: f64 = (0..samples)
            .map(|s| {
                let mut a = Randomized::online(pricing, s * 31 + 7);
                run_policy(&mut a, &at_beta, pricing).unwrap().total
            })
            .sum::<f64>()
            / samples as f64;
        println!(
            "{alpha:>6.2} {:>10.4} {det_ratio:>12.4} {:>12.4} {:>12.4}",
            pricing.deterministic_ratio(),
            pricing.randomized_ratio(),
            mean / opt
        );
    }
    println!(
        "bench fig2/ratio_sweep total {}",
        fmt_ns(t0.elapsed().as_nanos() as f64)
    );
}
