//! Fig. 6 / Fig. 7 regeneration bench: prediction-window sweep (1/2/3
//! compressed months) for the deterministic and randomized policies,
//! normalized to their online (w = 0) counterparts, on a scaled-down
//! population. Also times the oracle-window runs (the prediction window
//! adds scan-bookkeeping work — this bench quantifies the overhead).

use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::pricing::Market;
use cloudreserve::sim::fleet::{run_fleet, PolicySpec};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::bench::fmt_ns;

fn main() {
    let cfg = SynthConfig { users: 200, slots: 20_000, seed: 2013, ..Default::default() };
    let pop = generate(&cfg);
    let market = Market::single(ec2_small_compressed());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let month = 8760 / 12;

    for (fig, randomized) in [("Fig. 6 deterministic", false), ("Fig. 7 randomized", true)] {
        println!("== {fig}: mean cost normalized to the online (w=0) algorithm ==");
        let base_spec = if randomized {
            PolicySpec::Randomized { window: 0, seed: 1 }
        } else {
            PolicySpec::Deterministic { z: None, window: 0 }
        };
        let t0 = std::time::Instant::now();
        let base = run_fleet(&pop, &market, &base_spec, threads);
        let base_dt = t0.elapsed();
        println!(
            "{:<16} {:>12} {:>12} {:>12}",
            "window", "mean(norm)", "wall", "vs w=0 wall"
        );
        println!(
            "{:<16} {:>12.4} {:>12} {:>12}",
            "w=0",
            1.0,
            fmt_ns(base_dt.as_nanos() as f64),
            "1.00x"
        );
        for m in 1..=3usize {
            let w = m * month;
            let spec = if randomized {
                PolicySpec::Randomized { window: w, seed: 1 }
            } else {
                PolicySpec::Deterministic { z: None, window: w }
            };
            let t0 = std::time::Instant::now();
            let res = run_fleet(&pop, &market, &spec, threads);
            let dt = t0.elapsed();
            // normalize per user against the online run
            let mut sum = 0.0;
            let mut n = 0usize;
            for (a, b) in res.per_user.iter().zip(&base.per_user) {
                if b.absolute_cost > 0.0 {
                    sum += a.absolute_cost / b.absolute_cost;
                    n += 1;
                }
            }
            println!(
                "{:<16} {:>12.4} {:>12} {:>11.2}x",
                format!("w={w} ({m}mo)"),
                sum / n.max(1) as f64,
                fmt_ns(dt.as_nanos() as f64),
                dt.as_secs_f64() / base_dt.as_secs_f64()
            );
        }
        println!();
    }
}
