//! Fig. 5 / Table II regeneration bench: the Sec. VII suite over a
//! scaled-down population (full scale lives in `examples/fig5_cost_cdf`),
//! reporting both the Table II rows and the wall-time per policy.

use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::pricing::Market;
use cloudreserve::sim::fleet::{run_fleet, PolicySpec};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::bench::fmt_ns;

fn main() {
    let cfg = SynthConfig { users: 300, slots: 20_000, seed: 2013, ..Default::default() };
    let pop = generate(&cfg);
    let market = Market::single(ec2_small_compressed());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    println!(
        "== Table II / Fig. 5 bench: {} users x {} slots, {threads} threads ==",
        cfg.users, cfg.slots
    );
    println!(
        "{:<28} {:>10} {:>8} {:>8} {:>8} {:>12} {:>14}",
        "Algorithm", "All", "G1", "G2", "G3", "wall", "user-slots/s"
    );
    let specs = [
        PolicySpec::AllOnDemand,
        PolicySpec::AllReserved,
        PolicySpec::Separate,
        PolicySpec::Deterministic { z: None, window: 0 },
        PolicySpec::Randomized { window: 0, seed: 1 },
    ];
    for spec in &specs {
        let t0 = std::time::Instant::now();
        let result = run_fleet(&pop, &market, spec, threads);
        let dt = t0.elapsed();
        let row = result.table2_row();
        let slots_total = (cfg.users * cfg.slots) as f64;
        println!(
            "{:<28} {:>10.2} {:>8.2} {:>8.2} {:>8.2} {:>12} {:>11.1} M/s",
            result.policy,
            row[0],
            row[1],
            row[2],
            row[3],
            fmt_ns(dt.as_nanos() as f64),
            slots_total / dt.as_secs_f64() / 1e6
        );
    }
}
