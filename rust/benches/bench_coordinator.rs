//! Coordinator throughput bench: demand events/s through the sharded
//! broker (the L3 service hot path), swept over shard counts, plus the
//! snapshot (analytics cut) latency.

use cloudreserve::coordinator::{Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::bench::fmt_ns;

fn main() {
    let users = 256usize;
    let slots = 3000usize;
    let pop = generate(&SynthConfig { users, slots, seed: 9, ..Default::default() });
    let pricing = ec2_small_compressed();
    let events = (users * slots) as f64;

    println!("== broker throughput: {users} users x {slots} slots ==");
    println!(
        "{:<12} {:>14} {:>16} {:>16}",
        "shards", "wall", "events/s", "snapshot lat."
    );
    for shards in [1usize, 2, 4, 8] {
        let cfg = BrokerConfig { pricing, shards, queue_capacity: 16384, window: 64 };
        let broker = Broker::start(cfg, PolicyKind::Deterministic { z: None });
        let t0 = std::time::Instant::now();
        for t in 0..slots {
            for u in &pop.users {
                broker
                    .submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })
                    .unwrap();
            }
        }
        // measure a snapshot after the stream (queues drained by the marker)
        let s0 = std::time::Instant::now();
        let rows = broker.snapshot().unwrap();
        let snap = s0.elapsed();
        assert_eq!(rows.len(), users);
        let dt = t0.elapsed();
        broker.finish().unwrap();
        println!(
            "{:<12} {:>14} {:>13.2} M/s {:>16}",
            shards,
            fmt_ns(dt.as_nanos() as f64),
            events / dt.as_secs_f64() / 1e6,
            fmt_ns(snap.as_nanos() as f64)
        );
    }

    // forecaster-backed prediction policy (heavier per-event work)
    println!("\n== broker with AR(8)-forecast prediction policy (w=120) ==");
    let cfg = BrokerConfig { pricing, shards: 8, queue_capacity: 16384, window: 64 };
    let broker = Broker::start(cfg, PolicyKind::DeterministicForecast { window: 120, ar_order: 8 });
    let t0 = std::time::Instant::now();
    let fslots = 600usize;
    for t in 0..fslots {
        for u in &pop.users {
            broker
                .submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })
                .unwrap();
        }
    }
    broker.finish().unwrap();
    let dt = t0.elapsed();
    println!(
        "8 shards: {} for {} events -> {:.2} M events/s",
        fmt_ns(dt.as_nanos() as f64),
        users * fslots,
        (users * fslots) as f64 / dt.as_secs_f64() / 1e6
    );
}
