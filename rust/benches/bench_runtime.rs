//! PJRT runtime bench: latency of the AOT artifacts from the Rust side —
//! the fleet_step analytics tick at each catalog variant and the AR
//! forecaster, plus per-user amortized cost. Skips (exit 0) when
//! artifacts are absent.

use cloudreserve::runtime::Runtime;
use cloudreserve::util::bench::Bencher;
use cloudreserve::util::rng::Rng;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(dir).expect("load artifacts");
    println!("platform: {}; artifacts: {:?}", rt.platform(), rt.names());
    let b = Bencher::default();
    let mut rng = Rng::new(3);

    for (users, window, k) in [(8usize, 64usize, 8usize), (32, 1024, 32), (128, 8760, 64)] {
        let demand: Vec<f32> = (0..users * window).map(|_| rng.below(6) as f32).collect();
        let reserved: Vec<f32> = (0..users * window).map(|_| rng.below(6) as f32).collect();
        let z_grid: Vec<f32> = (0..k).map(|i| i as f32 * 0.03).collect();
        let r = b.run(&format!("runtime/fleet_step/b{users}_w{window}_k{k}"), || {
            rt.fleet_step(0.00116, &demand, &reserved, users, window, &z_grid).unwrap()
        });
        r.report();
        println!(
            "  -> {:.1} us/user/tick, {:.2} M window-slots/s",
            r.median_ns() / 1e3 / users as f64,
            r.throughput((users * window) as f64) / 1e6
        );
    }

    // AR forecast artifact
    let (users, len, k) = (128usize, 128usize, 4usize);
    let history: Vec<f32> = (0..users * len).map(|_| rng.below(20) as f32).collect();
    let coef: Vec<f32> = (0..users * (k + 1)).map(|_| rng.f64() as f32 * 0.3).collect();
    let r = b.run("runtime/ar_forecast/b128_l128_k4_h60", || {
        rt.ar_forecast(&history, &coef, users, len).unwrap()
    });
    r.report();
    println!(
        "  -> {:.1} us/user for a 60-step forecast",
        r.median_ns() / 1e3 / users as f64
    );
}
