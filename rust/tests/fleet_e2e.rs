//! End-to-end fleet test: synthesize a small population, stream it through
//! the broker coordinator, and cross-check against the sequential fleet
//! simulator — the two execution paths must produce identical billing.

use cloudreserve::coordinator::{Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::{Market, Pricing};
use cloudreserve::sim::fleet::{run_fleet, PolicySpec};
use cloudreserve::trace::synth::{generate, SynthConfig};

fn pricing() -> Pricing {
    Pricing::normalized(0.08 / 69.0, 0.4875, 2000)
}

#[test]
fn broker_matches_fleet_simulator_deterministic() {
    let pop = generate(&SynthConfig { users: 20, slots: 2500, seed: 11, ..Default::default() });
    let pricing = pricing();

    // Path 1: sequential fleet simulator.
    let spec = PolicySpec::Deterministic { z: None, window: 0 };
    let sim = run_fleet(&pop, &Market::single(pricing), &spec, 4);

    // Path 2: streaming broker (slot-major event order, as in production).
    let cfg = BrokerConfig { pricing, shards: 4, queue_capacity: 1024, window: 32 };
    let broker = Broker::start(cfg, PolicyKind::Deterministic { z: None });
    for t in 0..2500usize {
        for u in &pop.users {
            broker
                .submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })
                .unwrap();
        }
    }
    let report = broker.finish().unwrap();

    assert_eq!(report.per_user.len(), sim.per_user.len());
    for ((uid, got), want) in report.per_user.iter().zip(&sim.per_user) {
        assert_eq!(*uid, want.user_id);
        assert!(
            (got.total - want.absolute_cost).abs() < 1e-9,
            "user {uid}: broker {} vs sim {}",
            got.total,
            want.absolute_cost
        );
    }
    let m = broker_metrics_note();
    eprintln!("{m}");
}

fn broker_metrics_note() -> &'static str {
    "broker/simulator billing cross-check complete"
}

#[test]
fn broker_matches_fleet_simulator_randomized() {
    let pop = generate(&SynthConfig { users: 12, slots: 1500, seed: 13, ..Default::default() });
    let pricing = pricing();
    let seed = 99u64;

    let spec = PolicySpec::Randomized { window: 0, seed };
    let sim = run_fleet(&pop, &Market::single(pricing), &spec, 3);

    let cfg = BrokerConfig { pricing, shards: 3, queue_capacity: 1024, window: 16 };
    let broker = Broker::start(cfg, PolicyKind::Randomized { seed });
    for t in 0..1500usize {
        for u in &pop.users {
            broker
                .submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })
                .unwrap();
        }
    }
    let report = broker.finish().unwrap();
    for ((uid, got), want) in report.per_user.iter().zip(&sim.per_user) {
        assert_eq!(*uid, want.user_id);
        assert!(
            (got.total - want.absolute_cost).abs() < 1e-9,
            "user {uid}: broker {} vs sim {} (same per-user seed derivation)",
            got.total,
            want.absolute_cost
        );
    }
}

#[test]
fn broker_metrics_reflect_stream() {
    let pricing = pricing();
    let cfg = BrokerConfig { pricing, shards: 2, queue_capacity: 64, window: 8 };
    let broker = Broker::start(cfg, PolicyKind::AllOnDemand);
    for t in 0..100u32 {
        for u in 0..5u32 {
            broker.submit(DemandEvent { user_id: u, slot: t, demand: 2 }).unwrap();
        }
    }
    // metrics race with queue draining; finish() synchronizes.
    let metrics_events = broker.metrics().events.load(std::sync::atomic::Ordering::Relaxed);
    assert!(metrics_events <= 500);
    let report = broker.finish().unwrap();
    assert_eq!(report.per_user.len(), 5);
    let total_demand: u64 = report.per_user.iter().map(|(_, r)| r.demand_slots).sum();
    assert_eq!(total_demand, 1000);
    // All-on-demand: cost = p * demand
    let expect = pricing.p * 1000.0;
    assert!((report.total_cost() - expect).abs() < 1e-9);
}
