//! Property tests for the `cloudreserve-trace/v2` chunked columnar format:
//! random fleets round-trip bit-exactly through `ChunkedWriter` →
//! `ChunkedPopulation` for arbitrary chunk sizes, the streaming generator
//! matches the in-RAM one byte-for-byte, and damaged files (flipped bytes,
//! truncation, wrong magic) are rejected rather than silently misread.

use cloudreserve::trace::io::{ChunkedPopulation, ChunkedWriter};
use cloudreserve::trace::synth::{generate, generate_chunked, SynthConfig};
use cloudreserve::trace::FlatPopulation;
use cloudreserve::util::rng::Rng;

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cloudreserve_test_{tag}_{}.bin", std::process::id()))
}

fn write_flat_chunked(flat: &FlatPopulation, path: &std::path::Path, chunk_users: u32) {
    let mut w = ChunkedWriter::create(path, chunk_users).expect("create");
    for i in 0..flat.len() {
        w.push_user(flat.user_id(i), flat.demand(i)).expect("push");
    }
    w.finish().expect("finish");
}

/// Random fleet with RLE-friendly and RLE-hostile users mixed in.
fn random_flat(rng: &mut Rng, users: usize, slots: usize) -> FlatPopulation {
    let mut flat = FlatPopulation::with_capacity(users, slots);
    for u in 0..users {
        let demand: Vec<u32> = match rng.below(3) {
            0 => vec![rng.below(5) as u32; slots], // constant: one run
            1 => (0..slots).map(|_| rng.below(4) as u32).collect(), // noisy
            _ => {
                // piecewise-constant plateaus, the realistic shape
                let mut d = Vec::with_capacity(slots);
                let mut level = rng.below(6) as u32;
                while d.len() < slots {
                    let run = 1 + rng.below(20) as usize;
                    for _ in 0..run.min(slots - d.len()) {
                        d.push(level);
                    }
                    level = rng.below(6) as u32;
                }
                d
            }
        };
        flat.push_user(u as u32 * 3 + 1, &demand); // non-contiguous ids
    }
    flat
}

fn read_all(chunked: &mut ChunkedPopulation) -> FlatPopulation {
    let mut all = FlatPopulation::default();
    for i in 0..chunked.n_chunks() {
        let chunk = chunked.read_chunk(i).expect("chunk reads back");
        for u in 0..chunk.len() {
            all.push_user(chunk.user_id(u), chunk.demand(u));
        }
    }
    all
}

fn assert_same_fleet(a: &FlatPopulation, b: &FlatPopulation, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: user count");
    assert_eq!(a.total_slots(), b.total_slots(), "{what}: total slots");
    for i in 0..a.len() {
        assert_eq!(a.user_id(i), b.user_id(i), "{what}: user index {i}");
        assert_eq!(a.demand(i), b.demand(i), "{what}: demand of user index {i}");
    }
}

#[test]
fn random_fleets_round_trip_across_chunk_sizes() {
    let mut rng = Rng::new(0xC4A2);
    for case in 0..20 {
        let users = 1 + rng.below(60) as usize;
        let slots = 1 + rng.below(300) as usize;
        let flat = random_flat(&mut rng, users, slots);
        // chunk sizes straddling the fleet: 1, a random interior size, and
        // one larger than the whole fleet (single chunk).
        for chunk_users in [1, 1 + rng.below(users as u64) as u32, users as u32 + 7] {
            let what = format!("case {case} ({users}x{slots}, chunks of {chunk_users})");
            let path = tmp_path(&format!("roundtrip_{case}_{chunk_users}"));
            write_flat_chunked(&flat, &path, chunk_users);
            let mut chunked = ChunkedPopulation::open(&path).expect("open");
            assert_eq!(chunked.n_users(), users, "{what}");
            assert_eq!(chunked.total_slots(), flat.total_slots() as u64, "{what}");
            let expected_chunks = users.div_ceil(chunk_users as usize);
            assert_eq!(chunked.n_chunks(), expected_chunks, "{what}");
            let back = read_all(&mut chunked);
            assert_same_fleet(&flat, &back, &what);
            std::fs::remove_file(&path).ok();
        }
    }
}

#[test]
fn streaming_generator_matches_in_ram_generation() {
    for (users, slots, seed) in [(17, 120, 2013u64), (64, 77, 9), (5, 1000, 0x5EED)] {
        let cfg = SynthConfig { users, slots, seed, ..Default::default() };
        let in_ram = FlatPopulation::from(&generate(&cfg));
        let path = tmp_path(&format!("synth_{users}_{slots}"));
        generate_chunked(&cfg, &path, 7).expect("stream-generate");
        let mut chunked = ChunkedPopulation::open(&path).expect("open");
        let streamed = read_all(&mut chunked);
        assert_same_fleet(&in_ram, &streamed, &format!("synth {users}x{slots} seed {seed}"));
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn every_corrupted_payload_byte_is_detected() {
    // Flip each byte of the first chunk's payload in turn: the FNV-1a
    // checksum must reject every single-byte corruption (it has full
    // avalanche over the payload; no byte is slack).
    let mut rng = Rng::new(0xBAD);
    let flat = random_flat(&mut rng, 6, 24);
    let path = tmp_path("corrupt");
    write_flat_chunked(&flat, &path, 3);
    let clean = std::fs::read(&path).expect("read back");
    let meta = ChunkedPopulation::open(&path).expect("open clean").chunk_meta(0);
    let (start, len) = (meta.offset as usize, meta.byte_len as usize);

    for off in 0..len {
        let mut bytes = clean.clone();
        bytes[start + off] ^= 0x40;
        std::fs::write(&path, &bytes).expect("write corrupted");
        // the index itself is untouched, so open() still succeeds…
        let mut c = ChunkedPopulation::open(&path).expect("open corrupted");
        // …but the damaged chunk must fail its checksum, and chunk 1 must
        // still read fine (corruption is contained per chunk).
        let err = c.read_chunk(0).expect_err("corruption must be detected");
        assert!(format!("{err:#}").contains("checksum"), "byte {off}: {err:#}");
        c.read_chunk(1).expect("other chunks unaffected");
    }
    std::fs::write(&path, &clean).expect("restore");
    ChunkedPopulation::open(&path).expect("clean file still opens").read_chunk(0).expect("ok");
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_and_mislabeled_files_are_rejected() {
    let mut rng = Rng::new(0x7EAE);
    let flat = random_flat(&mut rng, 5, 30);
    let path = tmp_path("truncate");
    write_flat_chunked(&flat, &path, 2);
    let clean = std::fs::read(&path).expect("read back");

    // every strict prefix must fail to open (header, payload, or index cut)
    for keep in [0, 4, 31, clean.len() / 2, clean.len() - 1] {
        std::fs::write(&path, &clean[..keep]).expect("write truncated");
        assert!(
            ChunkedPopulation::open(&path).is_err(),
            "truncation to {keep} of {} bytes must be rejected",
            clean.len()
        );
    }

    // wrong magic (a v1 flat file is not a v2 chunked file)
    let mut bytes = clean.clone();
    bytes[0] ^= 0xFF;
    std::fs::write(&path, &bytes).expect("write bad magic");
    assert!(ChunkedPopulation::open(&path).is_err(), "bad magic must be rejected");
    std::fs::remove_file(&path).ok();
}
