//! End-to-end tests of the committed multi-contract scenario specs
//! (`examples/scenarios/table1_two_term.json`,
//! `examples/scenarios/table1_two_term_window.json`, and the learned-policy
//! `examples/scenarios/table1_ucb.json`): parse → run through
//! the batched engine → verify the acceptance contract — two Table I terms
//! on the menu, every policy feasible, the joint multi-contract offline DP
//! solved (and under the restricted DP), and the deterministic menu
//! policies (windowless and Sec. VI windowed) within `2 − α_max` of it.

use cloudreserve::sim::scenario::{self, ScenarioSpec};
use cloudreserve::util::json::parse;

fn load_spec(name: &str) -> ScenarioSpec {
    let path = format!("{}/../examples/scenarios/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(path).expect("committed scenario spec readable");
    ScenarioSpec::from_json(&parse(&text).expect("spec is valid JSON")).expect("spec parses")
}

#[test]
fn committed_two_term_scenario_meets_the_ratio_bound() {
    let spec = load_spec("table1_two_term.json");
    assert_eq!(spec.market.len(), 2, "two Table I terms on the menu");
    assert_eq!(spec.pruned_contracts, 0);
    assert!((spec.market.alpha_max() - 0.4875).abs() < 1e-12);
    assert!(spec.offline);

    let report = scenario::run(&spec, 2).expect("scenario runs end-to-end");
    assert_eq!(report.users, 1);
    assert_eq!(report.slots, 120);
    assert_eq!(report.policies.len(), 5);

    // All-on-demand is the normalization anchor.
    let od = &report.policies[0];
    assert!(od.name.contains("on-demand"));
    assert!((od.mean_normalized - 1.0).abs() < 1e-9);

    // The deterministic menu policy must commit and save versus on-demand.
    let det = report
        .policies
        .iter()
        .find(|p| p.name.starts_with("Deterministic"))
        .expect("deterministic policy in the suite");
    assert!(det.reservations >= 1, "stable demand must trigger reservations");
    assert!(det.mean_normalized < 1.0, "deterministic saves vs on-demand: {}", det.mean_normalized);

    // The offline comparator is the joint DP here (terms 4 + 12 at unit
    // demand), cross-checked against the restricted per-contract DP.
    let offline = report.offline.as_ref().expect("single-user trace solves the offline DP");
    assert!(offline.cost > 0.0);
    assert!(offline.joint, "compressed menu must be joint-DP tractable");
    assert!(
        offline.cost <= offline.restricted_cost + 1e-9,
        "joint {} must not exceed restricted {}",
        offline.cost,
        offline.restricted_cost
    );
    assert_eq!(offline.skipped, 0, "both compressed terms are DP-tractable");

    // Acceptance: deterministic cost <= (2 - alpha_max) * joint DP cost.
    let ratio = report.deterministic_ratio.expect("ratio computed");
    assert!((report.ratio_bound - (2.0 - 0.4875)).abs() < 1e-12);
    assert!(
        ratio <= report.ratio_bound + 1e-9,
        "deterministic/offline ratio {ratio} exceeds 2 - alpha_max = {}",
        report.ratio_bound
    );

    // On stable unit demand the restricted optimum commits to the deeper
    // (better steady-state) 3-year contract.
    assert_eq!(offline.contract, Some(1));
}

#[test]
fn committed_window_scenario_meets_the_bound_and_beats_the_online_variant() {
    let spec = load_spec("table1_two_term_window.json");
    assert_eq!(spec.market.len(), 2);
    assert!(spec.offline);

    let report = scenario::run(&spec, 2).expect("scenario runs end-to-end");
    assert_eq!(report.users, 1);
    assert_eq!(report.policies.len(), 4);

    let offline = report.offline.as_ref().expect("offline comparator solved");
    assert!(offline.joint, "window scenario pins the Sec. VI ratio against the joint DP");

    // Sec. VI: with w = 3 slots of reliable prediction on stable demand,
    // the windowed deterministic policy pays no more than the windowless
    // one, and both respect the 2 - alpha_max comparison bound.
    let ratio = report.deterministic_ratio.expect("windowless ratio");
    let ratio_w = report.deterministic_window_ratio.expect("windowed ratio");
    assert!(
        ratio_w <= ratio + 1e-9,
        "windowed ratio {ratio_w} must not exceed online ratio {ratio}"
    );
    assert!(ratio <= report.ratio_bound + 1e-9, "online ratio {ratio} over the bound");
    assert!(ratio_w <= report.ratio_bound + 1e-9, "windowed ratio {ratio_w} over the bound");

    // The windowed policies actually commit (and the randomized windowed
    // entry bills feasibly end to end — run() would have errored).
    let det_w = report
        .policies
        .iter()
        .find(|p| p.name.contains("w=3") && p.name.starts_with("Deterministic"))
        .expect("windowed deterministic in the suite");
    assert!(det_w.reservations >= 1);
    assert!(det_w.mean_normalized < 1.0);
}

#[test]
fn committed_ucb_scenario_reports_regret_against_the_joint_dp() {
    let spec = load_spec("table1_ucb.json");
    assert_eq!(spec.market.len(), 2);
    assert!(spec.offline);

    let report = scenario::run(&spec, 2).expect("scenario runs end-to-end");
    assert_eq!(report.users, 1);
    assert_eq!(report.slots, 240);
    assert_eq!(report.policies.len(), 4);

    let offline = report.offline.as_ref().expect("single-user trace solves the offline DP");
    assert!(offline.joint, "compressed menu must be joint-DP tractable");
    assert!(offline.cost > 0.0);

    let bound = (2.0 - spec.market.alpha_max()) * offline.cost;
    for p in &report.policies {
        // joint <= every online policy, learned included
        let regret = p.regret_vs_joint.expect("regret filled when offline solved");
        assert!(regret >= -1e-9, "{}: beat the offline DP by {regret}", p.name);
        assert!((p.total_cost - offline.cost - regret).abs() < 1e-12, "{}", p.name);
        let per_slot = p.per_slot_regret.expect("per-slot regret filled");
        assert!((per_slot - regret / 240.0).abs() < 1e-12, "{}", p.name);
        // learned policies: within the 2 - alpha_max comparison bound, or
        // the excess is reported honestly through the regret fields —
        // either way the report must carry the evidence
        if p.name.contains("UCB") || p.name.contains("AdaptiveWindow") {
            assert!(
                p.total_cost <= bound + 1e-9 || regret > 0.0,
                "{}: over the bound without reporting excess",
                p.name
            );
        }
    }

    // JSON carries the additive regret fields for every policy
    let doc = report.to_json();
    for p in doc.get("policies").as_arr().expect("policies array") {
        assert!(p.get("regret_vs_joint").as_f64().is_some());
        assert!(p.get("per_slot_regret").as_f64().is_some());
    }
}

#[test]
fn spec_rejection_paths_name_the_offender() {
    let base = |policies: &str| {
        format!(
            r#"{{
          "name": "bad",
          "market": {{"on_demand": 0.08, "contracts": [
            {{"upfront": 0.1333, "rate": 0.039, "term": 4}},
            {{"upfront": 0.3, "rate": 0.031, "term": 12}}
          ]}},
          "trace": {{"kind": "constant", "users": 1, "level": 1, "slots": 20}},
          "policies": {policies}
        }}"#
        )
    };
    let err_of = |policies: &str| {
        format!(
            "{:#}",
            ScenarioSpec::from_json(&parse(&base(policies)).unwrap()).unwrap_err()
        )
    };

    // unknown policy name: expected_one_of style with the full name list
    let err = err_of(r#"["magic"]"#);
    assert!(err.contains("unknown name 'magic'"), "{err}");
    assert!(err.contains("ucb") && err.contains("adaptive_window"), "{err}");

    // window on a policy that ignores it, naming policy + valid takers
    let err = err_of(r#"[{"policy": "ucb", "window": 2}]"#);
    assert!(err.contains("policy 'ucb'") && err.contains("'window'"), "{err}");
    assert!(err.contains("deterministic|randomized"), "{err}");

    // z on a policy that ignores it
    let err = err_of(r#"[{"policy": "adaptive_window", "z": 0.4}]"#);
    assert!(err.contains("policy 'adaptive_window'") && err.contains("'z'"), "{err}");

    // w >= min tau names the policy and the offending term
    let err = err_of(r#"[{"policy": "deterministic", "window": 4}]"#);
    assert!(err.contains("policy 'Deterministic(w=4)'"), "{err}");
    assert!(err.contains("shortest") && err.contains("(4)"), "{err}");
}

#[test]
fn scenario_json_report_shape_is_stable() {
    let spec = load_spec("table1_two_term.json");
    let report = scenario::run(&spec, 1).expect("scenario runs");
    let doc = report.to_json();
    assert_eq!(doc.get("schema").as_str(), Some("cloudreserve-scenario/v2"));
    assert_eq!(doc.get("market_contracts").as_usize(), Some(2));
    assert_eq!(doc.get("policies").as_arr().map(|a| a.len()), Some(5));
    assert!(doc.get("deterministic_ratio").as_f64().is_some());
    assert!(doc.get("ratio_bound").as_f64().is_some());
    assert!(doc.get("offline").get("cost").as_f64().is_some());
    assert!(doc.get("offline").get("restricted_cost").as_f64().is_some());
    assert!(matches!(
        *doc.get("offline").get("joint"),
        cloudreserve::util::json::Json::Bool(true)
    ));
    // serialized text re-parses
    let text = doc.dump_pretty();
    let back = parse(&text).unwrap();
    assert_eq!(&back, &doc);
}
