//! End-to-end test of the committed multi-contract scenario spec
//! (`examples/scenarios/table1_two_term.json`): parse → run through the
//! batched engine → verify the acceptance contract — two Table I terms on
//! the menu, every policy feasible, and the deterministic menu policy's
//! cost within `2 − α_max` of the restricted offline DP on the same trace.

use cloudreserve::sim::scenario::{self, ScenarioSpec};
use cloudreserve::util::json::parse;

fn load_spec() -> ScenarioSpec {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/scenarios/table1_two_term.json"
    );
    let text = std::fs::read_to_string(path).expect("committed scenario spec readable");
    ScenarioSpec::from_json(&parse(&text).expect("spec is valid JSON")).expect("spec parses")
}

#[test]
fn committed_two_term_scenario_meets_the_ratio_bound() {
    let spec = load_spec();
    assert_eq!(spec.market.len(), 2, "two Table I terms on the menu");
    assert_eq!(spec.pruned_contracts, 0);
    assert!((spec.market.alpha_max() - 0.4875).abs() < 1e-12);
    assert!(spec.offline);

    let report = scenario::run(&spec, 2).expect("scenario runs end-to-end");
    assert_eq!(report.users, 1);
    assert_eq!(report.slots, 120);
    assert_eq!(report.policies.len(), 5);

    // All-on-demand is the normalization anchor.
    let od = &report.policies[0];
    assert!(od.name.contains("on-demand"));
    assert!((od.mean_normalized - 1.0).abs() < 1e-9);

    // The deterministic menu policy must commit and save versus on-demand.
    let det = report
        .policies
        .iter()
        .find(|p| p.name.starts_with("Deterministic"))
        .expect("deterministic policy in the suite");
    assert!(det.reservations >= 1, "stable demand must trigger reservations");
    assert!(det.mean_normalized < 1.0, "deterministic saves vs on-demand: {}", det.mean_normalized);

    // Acceptance: deterministic cost <= (2 - alpha_max) * offline DP cost.
    let offline = report.offline.as_ref().expect("single-user trace solves the offline DP");
    assert!(offline.cost > 0.0);
    assert_eq!(offline.skipped, 0, "both compressed terms are DP-tractable");
    let ratio = report.deterministic_ratio.expect("ratio computed");
    assert!((report.ratio_bound - (2.0 - 0.4875)).abs() < 1e-12);
    assert!(
        ratio <= report.ratio_bound + 1e-9,
        "deterministic/offline ratio {ratio} exceeds 2 - alpha_max = {}",
        report.ratio_bound
    );

    // On stable unit demand the offline optimum commits to the deeper
    // (better steady-state) 3-year contract.
    assert_eq!(offline.contract, Some(1));
}

#[test]
fn scenario_json_report_shape_is_stable() {
    let spec = load_spec();
    let report = scenario::run(&spec, 1).expect("scenario runs");
    let doc = report.to_json();
    assert_eq!(doc.get("schema").as_str(), Some("cloudreserve-scenario/v1"));
    assert_eq!(doc.get("market_contracts").as_usize(), Some(2));
    assert_eq!(doc.get("policies").as_arr().map(|a| a.len()), Some(5));
    assert!(doc.get("deterministic_ratio").as_f64().is_some());
    assert!(doc.get("ratio_bound").as_f64().is_some());
    assert!(doc.get("offline").get("cost").as_f64().is_some());
    // serialized text re-parses
    let text = doc.dump_pretty();
    let back = parse(&text).unwrap();
    assert_eq!(&back, &doc);
}
