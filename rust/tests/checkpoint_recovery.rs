//! Crash-recovery property tests for the checkpointed chunked fleet path:
//! a run killed at ANY chunk boundary and resumed from its checkpoint must
//! be bit-identical to an uninterrupted run (for every policy, including
//! randomized ones, on both markets); a torn checkpoint write must fall
//! back to the previous generation; corrupt chunks must either abort with
//! full context or be quarantined with a structured report — never folded
//! in silently; and transient read errors must be retried to success.

use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::sim::engine::{for_each_user_chunked_recoverable, OnCorrupt, RecoveryOptions};
use cloudreserve::sim::fleet::{FleetAggregate, PolicySpec, UserResult};
use cloudreserve::trace::io::ChunkedPopulation;
use cloudreserve::trace::synth::{generate_chunked, SynthConfig};
use cloudreserve::util::faults::{site, Fault, FaultPlan, KillPoint};
use std::path::{Path, PathBuf};

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cloudreserve_ckpt_{tag}_{}.bin", std::process::id()))
}

/// `<path>.prev` — the fallback generation kept by `Checkpoint::write_atomic`.
fn prev_of(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".prev");
    PathBuf::from(s)
}

fn make_trace(tag: &str, users: usize, slots: usize, seed: u64, chunk_users: u32) -> PathBuf {
    let path = tmp_path(tag);
    let cfg = SynthConfig { users, slots, seed, ..Default::default() };
    generate_chunked(&cfg, &path, chunk_users).expect("generate chunked trace");
    path
}

fn markets() -> Vec<(&'static str, Market)> {
    vec![
        ("single", Market::single(Pricing::normalized(0.08 / 69.0, 0.4875, 1000))),
        (
            "menu2",
            Market::new(
                0.01,
                vec![
                    Contract { upfront: 1.0, rate: 0.004, term: 600 },
                    Contract { upfront: 1.5, rate: 0.002, term: 1800 },
                ],
            ),
        ),
    ]
}

fn specs() -> Vec<PolicySpec> {
    vec![
        PolicySpec::AllOnDemand,
        PolicySpec::AllReserved,
        PolicySpec::Separate,
        PolicySpec::Deterministic { z: None, window: 32 },
        PolicySpec::Randomized { window: 16, seed: 7 },
        // learned policies: UCB arm statistics and the adaptive window's
        // forecaster state must survive kill/resume bit-identically too
        PolicySpec::Ucb { seed: 7 },
        PolicySpec::AdaptiveWindow,
    ]
}

/// Exact-bit view of the aggregate (f64s compared as raw bits, not approx).
fn agg_bits(a: &FleetAggregate) -> (u64, u64, u64, u64) {
    (a.mean_normalized().to_bits(), a.total_cost().to_bits(), a.total_reservations(), a.users())
}

/// Exact-bit view of one sink delivery.
fn user_bits(u: &UserResult) -> (u32, u64, u64, u64) {
    (u.user_id, u.normalized_cost.to_bits(), u.absolute_cost.to_bits(), u.reservations)
}

fn cleanup(paths: &[&Path]) {
    for p in paths {
        std::fs::remove_file(p).ok();
    }
}

/// The core acceptance property: kill at EVERY chunk boundary, resume, and
/// demand the final aggregate AND the concatenated sink stream bit-identical
/// to an uninterrupted run — for every policy spec on both markets.
#[test]
fn resume_at_every_chunk_boundary_is_bit_identical() {
    for (mname, market) in markets() {
        for (si, spec) in specs().into_iter().enumerate() {
            let trace = make_trace(&format!("resume_{mname}_{si}"), 21, 400, 0xFEED, 4);
            let mut chunked = ChunkedPopulation::open(&trace).expect("open trace");
            let n_chunks = chunked.n_chunks();
            assert_eq!(n_chunks, 6, "21 users in chunks of 4");

            let mut clean_users = Vec::new();
            let clean = for_each_user_chunked_recoverable(
                &mut chunked,
                &market,
                &spec,
                3,
                &RecoveryOptions::default(),
                |u| clean_users.push(user_bits(u)),
            )
            .expect("clean run");

            for kill in 0..n_chunks {
                let what = format!("{mname}/{} kill after chunk {kill}", spec.name());
                let ckpt = tmp_path(&format!("resume_{mname}_{si}_k{kill}"));
                let plan = FaultPlan::new().script(
                    site::FLEET_AFTER_CHUNK,
                    kill as u64,
                    u32::MAX,
                    Fault::Kill,
                );

                let mut first_users = Vec::new();
                let opts = RecoveryOptions {
                    checkpoint_path: Some(&ckpt),
                    checkpoint_every: 1,
                    faults: Some(&plan),
                    ..Default::default()
                };
                let err = for_each_user_chunked_recoverable(
                    &mut chunked,
                    &market,
                    &spec,
                    3,
                    &opts,
                    |u| first_users.push(user_bits(u)),
                )
                .expect_err(&what);
                let kp = err
                    .downcast_ref::<KillPoint>()
                    .unwrap_or_else(|| panic!("{what}: expected a kill-point, got {err:#}"));
                assert_eq!(kp.key, kill as u64, "{what}");

                let opts = RecoveryOptions {
                    checkpoint_path: Some(&ckpt),
                    checkpoint_every: 1,
                    resume: true,
                    ..Default::default()
                };
                let mut rest_users = Vec::new();
                let out = for_each_user_chunked_recoverable(
                    &mut chunked,
                    &market,
                    &spec,
                    3,
                    &opts,
                    |u| rest_users.push(user_bits(u)),
                )
                .unwrap_or_else(|e| panic!("{what}: resume failed: {e:#}"));

                assert_eq!(out.resumed_from_chunk, Some(kill as u64 + 1), "{what}");
                assert!(!out.used_fallback_checkpoint, "{what}");
                assert_eq!(agg_bits(&out.aggregate), agg_bits(&clean.aggregate), "{what}");
                // The killed run's deliveries plus the resumed run's
                // deliveries must reproduce the clean stream exactly: no
                // user replayed, none dropped, every f64 bit-identical.
                let mut combined = first_users.clone();
                combined.extend_from_slice(&rest_users);
                assert_eq!(combined, clean_users, "{what}: sink stream");

                cleanup(&[&ckpt, &prev_of(&ckpt)]);
            }
            cleanup(&[&trace]);
        }
    }
}

/// A torn checkpoint write (crash mid-write) leaves the newest generation
/// unreadable; resume must fall back to `<path>.prev` and still converge to
/// the clean answer, merely replaying one extra chunk.
#[test]
fn torn_checkpoint_write_falls_back_to_previous_generation() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::Randomized { window: 16, seed: 7 };
    let trace = make_trace("torn", 21, 400, 0xFEED, 4);
    let mut chunked = ChunkedPopulation::open(&trace).expect("open trace");

    let clean = for_each_user_chunked_recoverable(
        &mut chunked,
        &market,
        &spec,
        2,
        &RecoveryOptions::default(),
        |_| {},
    )
    .expect("clean run");

    // Checkpoints land after chunks 0..=3 with next_chunk 1..=4; tear the
    // one keyed next_chunk=4 (written after chunk 3), then kill.
    let ckpt = tmp_path("torn_ckpt");
    let plan = FaultPlan::new()
        .script(site::CKPT_WRITE, 4, u32::MAX, Fault::TornWrite { keep: 10 })
        .script(site::FLEET_AFTER_CHUNK, 3, u32::MAX, Fault::Kill);
    let opts = RecoveryOptions {
        checkpoint_path: Some(&ckpt),
        checkpoint_every: 1,
        faults: Some(&plan),
        ..Default::default()
    };
    let err = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect_err("kill after torn write");
    assert!(err.downcast_ref::<KillPoint>().is_some(), "expected kill-point, got {err:#}");
    assert!(ckpt.exists() && prev_of(&ckpt).exists(), "both generations on disk");

    let opts = RecoveryOptions {
        checkpoint_path: Some(&ckpt),
        checkpoint_every: 1,
        resume: true,
        ..Default::default()
    };
    let out = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect("resume via fallback");
    assert!(out.used_fallback_checkpoint, "newest is torn, .prev must be used");
    // .prev was written after chunk 2 (next_chunk=3): chunk 3 is replayed
    // a second time, which is safe — its users were never folded twice
    // because the torn generation's aggregate was discarded with it.
    assert_eq!(out.resumed_from_chunk, Some(3));
    assert_eq!(agg_bits(&out.aggregate), agg_bits(&clean.aggregate));

    cleanup(&[&trace, &ckpt, &prev_of(&ckpt)]);
}

/// On-disk corruption under the default policy: abort, naming the chunk and
/// the checksum failure — never a silent wrong answer.
#[test]
fn corrupt_chunk_aborts_by_default_with_chunk_context() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::Deterministic { z: None, window: 0 };
    let trace = make_trace("corrupt_fail", 21, 200, 3, 4);
    let meta = ChunkedPopulation::open(&trace).expect("open").chunk_meta(2);
    let mut bytes = std::fs::read(&trace).expect("read");
    bytes[meta.offset as usize + 5] ^= 0x10;
    std::fs::write(&trace, &bytes).expect("corrupt chunk 2 on disk");

    let mut chunked = ChunkedPopulation::open(&trace).expect("index still intact");
    let err = for_each_user_chunked_recoverable(
        &mut chunked,
        &market,
        &spec,
        2,
        &RecoveryOptions::default(),
        |_| {},
    )
    .expect_err("corruption must abort under OnCorrupt::Fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("chunk 2"), "error names the chunk: {msg}");
    assert!(msg.contains("checksum"), "error names the cause: {msg}");

    cleanup(&[&trace]);
}

/// The same corruption under `--on-corrupt skip`: the run completes, the
/// chunk is quarantined with offsets/counts/cause, and the aggregate covers
/// exactly the surviving users.
#[test]
fn corrupt_chunk_skip_quarantines_with_structured_report() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::Deterministic { z: None, window: 0 };
    let trace = make_trace("corrupt_skip", 21, 200, 3, 4);
    let meta = ChunkedPopulation::open(&trace).expect("open").chunk_meta(2);
    let mut bytes = std::fs::read(&trace).expect("read");
    bytes[meta.offset as usize + 5] ^= 0x10;
    std::fs::write(&trace, &bytes).expect("corrupt chunk 2 on disk");

    let mut chunked = ChunkedPopulation::open(&trace).expect("index still intact");
    let opts = RecoveryOptions { on_corrupt: OnCorrupt::Skip, ..Default::default() };
    let out = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect("skip mode completes");

    assert_eq!(out.quarantined.len(), 1);
    let q = &out.quarantined[0];
    assert_eq!(q.chunk, 2);
    assert_eq!(q.offset, meta.offset);
    assert_eq!(q.byte_len, meta.byte_len);
    assert_eq!(q.users_skipped, meta.users_in_chunk);
    assert!(q.error.contains("checksum"), "quarantine records the cause: {}", q.error);
    assert_eq!(out.aggregate.users(), 21 - meta.users_in_chunk as u64);
    assert_eq!(out.chunks_replayed, chunked.n_chunks() as u64 - 1);

    cleanup(&[&trace]);
}

/// An injected bit flip is deterministic, so it must NOT be retried: one
/// injection, straight to quarantine as a checksum failure.
#[test]
fn injected_bit_flip_is_quarantined_without_retry() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::Separate;
    let trace = make_trace("bitflip", 21, 200, 3, 4);
    let mut chunked = ChunkedPopulation::open(&trace).expect("open");

    let plan = FaultPlan::new().script(
        site::TRACE_READ,
        1,
        u32::MAX,
        Fault::BitFlip { byte: 3, bit: 2 },
    );
    let opts = RecoveryOptions {
        on_corrupt: OnCorrupt::Skip,
        faults: Some(&plan),
        ..Default::default()
    };
    let out = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect("skip mode completes");

    assert_eq!(out.quarantined.len(), 1);
    assert_eq!(out.quarantined[0].chunk, 1);
    assert!(out.quarantined[0].error.contains("checksum"));
    let injected = plan.injected();
    assert_eq!(injected.len(), 1, "deterministic corruption is not retried");
    assert_eq!(injected[0].kind, "bit_flip");

    cleanup(&[&trace]);
}

/// Transient read errors recover within the retry budget: the run succeeds,
/// nothing is quarantined, and the result is bit-identical to a fault-free
/// run. The injection log shows exactly the two failed attempts.
#[test]
fn transient_read_errors_are_retried_to_success() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::Randomized { window: 0, seed: 11 };
    let trace = make_trace("transient", 21, 200, 3, 4);
    let mut chunked = ChunkedPopulation::open(&trace).expect("open");

    let clean = for_each_user_chunked_recoverable(
        &mut chunked,
        &market,
        &spec,
        2,
        &RecoveryOptions::default(),
        |_| {},
    )
    .expect("clean run");

    // Attempts 0 and 1 on chunk 0 fail; attempt 2 (the last allowed by
    // max_read_retries=2) reads clean.
    let plan = FaultPlan::new().script(site::TRACE_READ, 0, 1, Fault::ReadError);
    let opts = RecoveryOptions { retry_base_ms: 1, faults: Some(&plan), ..Default::default() };
    let out = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect("retries absorb the transient errors");

    assert!(out.quarantined.is_empty());
    assert_eq!(out.chunks_replayed, chunked.n_chunks() as u64);
    assert_eq!(agg_bits(&out.aggregate), agg_bits(&clean.aggregate));
    let injected = plan.injected();
    assert_eq!(injected.len(), 2);
    assert!(injected.iter().all(|f| f.kind == "read_error"));

    cleanup(&[&trace]);
}

/// A read error that outlives the retry budget surfaces: abort under Fail,
/// structured quarantine under Skip — in both cases naming the injected
/// transient error, never a silent omission.
#[test]
fn exhausted_read_retries_fail_or_quarantine() {
    let (_, market) = markets().remove(0);
    let spec = PolicySpec::AllReserved;
    let trace = make_trace("exhausted", 21, 200, 3, 4);
    let mut chunked = ChunkedPopulation::open(&trace).expect("open");

    let plan = FaultPlan::new().script(site::TRACE_READ, 2, u32::MAX, Fault::ReadError);
    let opts = RecoveryOptions {
        max_read_retries: 1,
        retry_base_ms: 1,
        faults: Some(&plan),
        ..Default::default()
    };
    let err = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect_err("persistent read error must abort under Fail");
    assert!(format!("{err:#}").contains("injected transient read error"), "{err:#}");

    let plan = FaultPlan::new().script(site::TRACE_READ, 2, u32::MAX, Fault::ReadError);
    let opts = RecoveryOptions {
        max_read_retries: 1,
        retry_base_ms: 1,
        on_corrupt: OnCorrupt::Skip,
        faults: Some(&plan),
        ..Default::default()
    };
    let out = for_each_user_chunked_recoverable(&mut chunked, &market, &spec, 2, &opts, |_| {})
        .expect("skip mode completes");
    assert_eq!(out.quarantined.len(), 1);
    assert_eq!(out.quarantined[0].chunk, 2);
    assert!(out.quarantined[0].error.contains("injected transient read error"));

    cleanup(&[&trace]);
}

/// A checkpoint is bound to its (trace, market, policy) by fingerprints:
/// resuming against anything else is rejected, naming the component.
#[test]
fn resume_rejects_mismatched_trace_market_or_policy() {
    let markets = markets();
    let spec = PolicySpec::Deterministic { z: None, window: 32 };
    let trace = make_trace("mismatch_a", 21, 200, 3, 4);
    let ckpt = tmp_path("mismatch_ckpt");

    let mut chunked = ChunkedPopulation::open(&trace).expect("open");
    let opts = RecoveryOptions { checkpoint_path: Some(&ckpt), ..Default::default() };
    let out =
        for_each_user_chunked_recoverable(&mut chunked, &markets[0].1, &spec, 2, &opts, |_| {})
            .expect("checkpointed run");
    assert_eq!(out.checkpoints_written, 1, "checkpoint_every=0 still writes the final one");

    let resume = RecoveryOptions {
        checkpoint_path: Some(&ckpt),
        resume: true,
        ..Default::default()
    };

    let err = for_each_user_chunked_recoverable(
        &mut chunked,
        &markets[0].1,
        &PolicySpec::Randomized { window: 32, seed: 1 },
        2,
        &resume,
        |_| {},
    )
    .expect_err("different policy must be rejected");
    assert!(format!("{err:#}").contains("policy spec"), "{err:#}");

    let err =
        for_each_user_chunked_recoverable(&mut chunked, &markets[1].1, &spec, 2, &resume, |_| {})
            .expect_err("different market must be rejected");
    assert!(format!("{err:#}").contains("market"), "{err:#}");

    let trace_b = make_trace("mismatch_b", 21, 200, 4, 4);
    let mut other = ChunkedPopulation::open(&trace_b).expect("open other");
    let err =
        for_each_user_chunked_recoverable(&mut other, &markets[0].1, &spec, 2, &resume, |_| {})
            .expect_err("different trace must be rejected");
    assert!(format!("{err:#}").contains("trace"), "{err:#}");

    cleanup(&[&trace, &trace_b, &ckpt, &prev_of(&ckpt)]);
}
