//! Failure injection and edge-case hardening across the stack: degenerate
//! pricing, pathological demand, malformed inputs, and broker misuse.

use cloudreserve::algos::baselines::{AllOnDemand, AllReserved, Separate};
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::coordinator::{Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;
use cloudreserve::Policy;

fn policies(pricing: Pricing) -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(AllOnDemand::new()),
        Box::new(AllReserved::new(pricing)),
        Box::new(Separate::new(pricing)),
        Box::new(Deterministic::online(pricing)),
        Box::new(Deterministic::with_threshold(pricing, 0.0)),
        Box::new(Deterministic::with_window(pricing, pricing.tau - 1)),
        Box::new(Randomized::online(pricing, 3)),
    ]
}

#[test]
fn alpha_zero_and_one_edges() {
    for alpha in [0.0, 1.0] {
        let pricing = Pricing::normalized(0.1, alpha, 10);
        let demands: Vec<u32> = (0..100).map(|t| (t % 5) as u32).collect();
        for mut p in policies(pricing) {
            let rep = run_policy(p.as_mut(), &demands, pricing)
                .unwrap_or_else(|e| panic!("{} at alpha={alpha}: {e}", p.name()));
            assert!(rep.identity_holds(&pricing, 1e-9), "{} alpha={alpha}", p.name());
        }
    }
}

#[test]
fn tau_one_everywhere() {
    let pricing = Pricing::normalized(0.5, 0.5, 1);
    let demands = vec![3u32; 50];
    for mut p in policies(pricing) {
        // window variant invalid for tau=1 (w < tau forces w=0) — skip it
        if p.window() >= pricing.tau {
            continue;
        }
        run_policy(p.as_mut(), &demands, pricing)
            .unwrap_or_else(|e| panic!("{}: {e}", p.name()));
    }
}

#[test]
fn demand_spike_beyond_everything() {
    // one slot of a million instances between zeros
    let pricing = Pricing::normalized(0.001, 0.5, 20);
    let mut demands = vec![0u32; 50];
    demands[25] = 1_000_000;
    for mut p in policies(pricing) {
        let rep = run_policy(p.as_mut(), &demands, pricing)
            .unwrap_or_else(|e| panic!("{}: {e}", p.name()));
        assert!(rep.total.is_finite());
    }
}

#[test]
fn empty_and_all_zero_traces() {
    let pricing = Pricing::normalized(0.1, 0.4, 5);
    for mut p in policies(pricing) {
        let rep = run_policy(p.as_mut(), &[], pricing).unwrap();
        assert_eq!(rep.total, 0.0);
    }
    for mut p in policies(pricing) {
        let rep = run_policy(p.as_mut(), &[0; 200], pricing).unwrap();
        assert_eq!(rep.total, 0.0, "{} charged for zero demand", p.name());
    }
}

#[test]
fn sawtooth_demand_full_coverage() {
    // rapid oscillation between 0 and high demand stresses expiry paths
    let pricing = Pricing::normalized(0.05, 0.3, 7);
    let demands: Vec<u32> = (0..300).map(|t| if t % 2 == 0 { 9 } else { 0 }).collect();
    for mut p in policies(pricing) {
        let rep = run_policy(p.as_mut(), &demands, pricing)
            .unwrap_or_else(|e| panic!("{}: {e}", p.name()));
        assert!(rep.identity_holds(&pricing, 1e-9), "{}", p.name());
    }
}

#[test]
fn broker_survives_interleaved_users_and_gaps() {
    let pricing = Pricing::normalized(0.01, 0.5, 50);
    let cfg = BrokerConfig { pricing, shards: 3, queue_capacity: 8, window: 4 };
    let broker = Broker::start(cfg, PolicyKind::Deterministic { z: None });
    // users report at wildly different cadences; tiny queue forces
    // backpressure on the submitter
    for t in 0..200u32 {
        for u in 0..10u32 {
            if (t + u) % (u + 1) == 0 {
                broker.submit(DemandEvent { user_id: u, slot: t, demand: u % 4 }).unwrap();
            }
        }
    }
    let report = broker.finish().unwrap();
    assert_eq!(report.per_user.len(), 10);
}

#[test]
fn broker_rejects_use_after_worker_death() {
    let pricing = Pricing::normalized(0.01, 0.5, 50);
    let cfg = BrokerConfig { pricing, shards: 1, queue_capacity: 8, window: 4 };
    let broker = Broker::start(cfg, PolicyKind::AllOnDemand);
    broker.submit(DemandEvent { user_id: 0, slot: 10, demand: 1 }).unwrap();
    // slot regression kills the worker
    broker.submit(DemandEvent { user_id: 0, slot: 2, demand: 1 }).unwrap();
    // subsequent operations must error, not hang
    let mut failed = false;
    for t in 0..64u32 {
        if broker.submit(DemandEvent { user_id: 0, slot: 20 + t, demand: 1 }).is_err() {
            failed = true;
            break;
        }
    }
    assert!(failed || broker.finish().is_err());
}

#[test]
fn trace_io_rejects_truncated_binary() {
    let dir = std::env::temp_dir().join("cloudreserve_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("trunc_{}.bin", std::process::id()));
    // valid magic, then garbage length fields
    let mut bytes = b"CLDRSV01".to_vec();
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    assert!(cloudreserve::trace::io::read_bin(&path).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn runtime_missing_artifacts_is_clean_error() {
    let err = cloudreserve::runtime::Runtime::load("/nonexistent/artifacts");
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("make artifacts"), "actionable message, got: {msg}");
}

#[test]
fn forecaster_handles_constant_zero_history() {
    use cloudreserve::forecast::{ArForecaster, Forecaster};
    let mut f = ArForecaster::new(4, 8, 64);
    for _ in 0..100 {
        f.observe(0);
    }
    assert!(f.predict(10).iter().all(|&x| x == 0));
}

#[test]
fn prediction_window_with_short_tail_horizons() {
    // near the trace end, the available future shrinks below w; policies
    // must accept shorter slices without panicking
    let pricing = Pricing::normalized(0.1, 0.2, 30);
    let demands = vec![2u32; 40];
    let mut p = Deterministic::with_window(pricing, 20);
    for t in 0..demands.len() {
        let hi = (t + 1 + 20).min(demands.len());
        let _ = p.decide(demands[t], &demands[t + 1..hi]);
    }
}
