//! Integration tests over the real PJRT runtime: load the AOT artifacts
//! built by `make artifacts` and validate the Rust↔HLO contract end to end
//! (numerics against pure-Rust references, padding, the coordinator's
//! analytics tick).
//!
//! Skipped (with a loud message) when artifacts are absent.

use cloudreserve::coordinator::{AnalyticsEngine, Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::Pricing;
use cloudreserve::runtime::Runtime;
use cloudreserve::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Load only the small test variants for fast compile.
fn small_runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    Some(
        Runtime::load_filtered(dir, |name| {
            name.contains("b8_") || name.contains("_b8")
        })
        .expect("load small artifacts"),
    )
}

#[test]
fn runtime_loads_and_lists_artifacts() {
    let Some(rt) = small_runtime() else { return };
    let names = rt.names();
    assert!(names.iter().any(|n| n.starts_with("fleet_step_b8")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("ar_forecast_b8")), "{names:?}");
    assert!(!rt.platform().is_empty());
}

#[test]
fn fleet_step_matches_rust_reference() {
    let Some(rt) = small_runtime() else { return };
    let mut rng = Rng::new(42);
    let (users, window) = (8usize, 64usize);
    let p = 0.08 / 69.0;
    let demand: Vec<f32> = (0..users * window).map(|_| rng.below(5) as f32).collect();
    let reserved: Vec<f32> = (0..users * window).map(|_| rng.below(5) as f32).collect();
    let z_grid: Vec<f32> = (0..8).map(|i| i as f32 * 0.002).collect();

    let out = rt.fleet_step(p, &demand, &reserved, users, window, &z_grid).unwrap();

    for u in 0..users {
        let expect: f32 = (0..window)
            .map(|t| f32::from(demand[u * window + t] > reserved[u * window + t]))
            .sum();
        assert_eq!(out.counts[u], expect, "user {u}");
        for (k, &z) in z_grid.iter().enumerate() {
            let want = (p as f32) * expect > z;
            assert_eq!(out.decided(u, k), want, "user {u} z={z}");
        }
    }
}

#[test]
fn fleet_step_pads_small_batches() {
    let Some(rt) = small_runtime() else { return };
    // 3 users, window 10 — artifact is 8x64; padding must not leak
    let users = 3;
    let window = 10;
    let demand = vec![1.0f32; users * window];
    let reserved = vec![0.0f32; users * window];
    let out = rt.fleet_step(0.1, &demand, &reserved, users, window, &[0.5]).unwrap();
    assert_eq!(out.counts.len(), users);
    for u in 0..users {
        assert_eq!(out.counts[u], window as f32);
        assert!(out.decided(u, 0)); // 0.1*10 = 1.0 > 0.5
    }
}

#[test]
fn fleet_step_strict_inequality_boundary() {
    let Some(rt) = small_runtime() else { return };
    // cost exactly z must not fire (Algorithm 1 uses strict >)
    let users = 8;
    let window = 10;
    let demand = vec![1.0f32; users * window];
    let reserved = vec![0.0f32; users * window];
    // p=0.1, V=10 -> cost=1.0 exactly
    let out = rt.fleet_step(0.1, &demand, &reserved, users, window, &[1.0]).unwrap();
    for u in 0..users {
        assert!(!out.decided(u, 0), "boundary must not fire");
    }
}

#[test]
fn ar_forecast_matches_rust_forecaster() {
    let Some(rt) = small_runtime() else { return };
    use cloudreserve::forecast::{ArForecaster, Forecaster};

    let users = 4usize;
    let len = 32usize;
    let k = 2usize;
    let mut histories = Vec::new();
    let mut coefs = Vec::new();
    let mut rust_preds = Vec::new();
    for u in 0..users {
        let hist: Vec<u32> = (0..len as u32).map(|t| (t + u as u32) % 7).collect();
        let mut f = ArForecaster::new(k, 1, len + 1);
        for &d in &hist {
            f.observe(d);
        }
        rust_preds.push(f.predict_f64(8));
        coefs.extend(f.coefficients().iter().map(|&c| c as f32));
        histories.extend(hist.iter().map(|&d| d as f32));
    }
    let (fc, h) = rt.ar_forecast(&histories, &coefs, users, len).unwrap();
    assert_eq!(h, 8);
    for u in 0..users {
        for i in 0..h {
            let got = fc[u * h + i] as f64;
            let want = rust_preds[u][i];
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "user {u} step {i}: artifact {got} vs rust {want}"
            );
        }
    }
}

#[test]
fn coordinator_analytics_tick_end_to_end() {
    let Some(rt) = small_runtime() else { return };
    let pricing = Pricing::normalized(0.05, 0.4, 100);
    let cfg = BrokerConfig { pricing, shards: 3, queue_capacity: 256, window: 64 };
    let broker = Broker::start(cfg, PolicyKind::AllOnDemand);

    // user 0: persistent unmet demand (All-on-demand covers nothing via
    // reservations -> violations accumulate). user 1: idle.
    for t in 0..50u32 {
        broker.submit(DemandEvent { user_id: 0, slot: t, demand: 2 }).unwrap();
        broker.submit(DemandEvent { user_id: 1, slot: t, demand: 0 }).unwrap();
    }
    let engine = AnalyticsEngine::new(rt, pricing, 8, 8);
    let posture = engine.tick(&broker).unwrap();
    assert_eq!(posture.users.len(), 2);
    let u0 = posture.users.iter().find(|u| u.user_id == 0).unwrap();
    let u1 = posture.users.iter().find(|u| u.user_id == 1).unwrap();
    assert_eq!(u0.violations, 50.0);
    assert_eq!(u1.violations, 0.0);
    assert!(u0.reserve_pressure > u1.reserve_pressure);
    // p*V = 0.05*50 = 2.5 > beta=1.667 -> over break-even
    assert!(u0.breakeven_frac > 1.0);
    assert_eq!(posture.over_breakeven(), vec![0]);
    assert_eq!(broker.metrics().analytics_ticks.load(std::sync::atomic::Ordering::Relaxed), 1);
    broker.finish().unwrap();
}

#[test]
fn fleet_step_rejects_wrong_sizes() {
    let Some(rt) = small_runtime() else { return };
    let err = rt.fleet_step(0.1, &[0.0; 10], &[0.0; 10], 2, 4, &[0.5]);
    assert!(err.is_err());
}
