//! Property tests (via `util::prop`) for the v2 [`Market`] invariants:
//!
//! * dominance pruning never changes the optimal fixed-horizon commitment
//!   cost (for any usage length `h`, pruned and unpruned menus price it
//!   identically),
//! * the break-even `β` is monotone in the discount factor `α` (deeper
//!   discount ⇒ later break-even) and anchored at `β(α=0) = upfront`,
//! * a single-contract `Market` reproduces classic `Pricing` costs
//!   **bit-identically** across the policy + ledger stack.

use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::market::{MarketDeterministic, MarketRandomized};
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::sim::{run_policy, run_policy_market};
use cloudreserve::util::prop::{check, check_no_shrink, shrink_demand, Config};
use cloudreserve::util::rng::Rng;

fn gen_contract(rng: &mut Rng, p: f64) -> Contract {
    Contract {
        upfront: 0.05 + rng.f64() * 2.0,
        rate: rng.f64() * p,
        term: 1 + rng.below(30) as usize,
    }
}

#[test]
fn prop_dominance_pruning_preserves_min_horizon_cost() {
    let cfg = Config { cases: 200, ..Default::default() };
    check_no_shrink(
        &cfg,
        "pruning-preserves-min-horizon-cost",
        |rng| {
            let p = 0.02 + rng.f64() * 0.5;
            let k = 1 + rng.below(4) as usize;
            let contracts: Vec<Contract> = (0..k).map(|_| gen_contract(rng, p)).collect();
            (p, contracts)
        },
        |(p, contracts)| {
            let pruned = Market::new(*p, contracts.clone());
            let raw = Market::new_unpruned(*p, contracts.clone());
            let max_term = contracts.iter().map(|c| c.term).max().unwrap_or(0);
            for h in 0..=(max_term as u64 + 2) {
                let a = pruned.min_horizon_cost(h);
                let b = raw.min_horizon_cost(h);
                if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                    return Err(format!(
                        "h={h}: pruned {a} vs raw {b} (menu {} -> {})",
                        raw.len(),
                        pruned.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_beta_monotone_in_alpha() {
    let cfg = Config { cases: 200, ..Default::default() };
    check_no_shrink(
        &cfg,
        "beta-monotone-in-alpha",
        |rng| {
            let p = 0.02 + rng.f64() * 0.5;
            let upfront = 0.05 + rng.f64() * 2.0;
            let term = 1 + rng.below(50) as usize;
            let mut a1 = rng.f64();
            let mut a2 = rng.f64();
            if a1 > a2 {
                std::mem::swap(&mut a1, &mut a2);
            }
            (p, upfront, term, a1, a2)
        },
        |&(p, upfront, term, a1, a2)| {
            let c1 = Contract { upfront, rate: a1 * p, term };
            let c2 = Contract { upfront, rate: a2 * p, term };
            let (b0, b1, b2) = (
                Contract { upfront, rate: 0.0, term }.beta_at(p),
                c1.beta_at(p),
                c2.beta_at(p),
            );
            if (b0 - upfront).abs() > 1e-9 * (1.0 + upfront) {
                return Err(format!("beta(alpha=0) = {b0}, want upfront {upfront}"));
            }
            // rate = alpha * p loses a few ulps, so compare with slack
            if b1 > b2 * (1.0 + 1e-9) {
                return Err(format!("alpha {a1} <= {a2} but beta {b1} > {b2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_market_reproduces_pricing_bit_identically() {
    // Classic Deterministic through run_policy (Pricing convenience) vs
    // the menu policy over Market::single through run_policy_market:
    // decisions and billing must agree to the bit.
    let cfg = Config { cases: 60, ..Default::default() };
    check(
        &cfg,
        "single-market-bit-identical",
        |rng| {
            let tau = 2 + rng.below(40) as usize;
            let p = 0.01 + rng.f64() * 0.3;
            let alpha = rng.f64();
            let demands: Vec<u32> = (0..150).map(|_| rng.below(5) as u32).collect();
            (p, alpha, tau, demands)
        },
        |(p, alpha, tau, demands)| {
            let pricing = Pricing::normalized(*p, *alpha, *tau);
            let market = Market::single(pricing);
            let classic = run_policy(&mut Deterministic::online(pricing), demands, pricing)
                .map_err(|e| e.to_string())?;
            let menu =
                run_policy_market(&mut MarketDeterministic::new(market.clone()), demands, &market)
                    .map_err(|e| e.to_string())?;
            if classic.total.to_bits() != menu.total.to_bits() {
                return Err(format!("total: classic {} vs menu {}", classic.total, menu.total));
            }
            if classic.reservations != menu.reservations {
                return Err(format!(
                    "reservations: classic {} vs menu {}",
                    classic.reservations, menu.reservations
                ));
            }
            // randomized pair on a seed derived from the case (so shrunken
            // counterexamples replay deterministically)
            let seed = demands
                .iter()
                .fold(*tau as u64, |a, &d| a.wrapping_mul(31).wrapping_add(d as u64 + 1));
            let rc = run_policy(&mut Randomized::online(pricing, seed), demands, pricing)
                .map_err(|e| e.to_string())?;
            let rm = run_policy_market(
                &mut MarketRandomized::new(market.clone(), seed),
                demands,
                &market,
            )
            .map_err(|e| e.to_string())?;
            if rc.total.to_bits() != rm.total.to_bits() {
                return Err(format!(
                    "randomized(seed {seed}): classic {} vs menu {}",
                    rc.total, rm.total
                ));
            }
            Ok(())
        },
        |(p, alpha, tau, demands)| {
            shrink_demand(demands)
                .into_iter()
                .map(|d| (*p, *alpha, *tau, d))
                .collect()
        },
    );
}
