//! Property tests (via `util::prop`) for the v2 [`Market`] invariants:
//!
//! * dominance pruning never changes the optimal fixed-horizon commitment
//!   cost (for any usage length `h`, pruned and unpruned menus price it
//!   identically),
//! * the break-even `β` is monotone in the discount factor `α` (deeper
//!   discount ⇒ later break-even) and anchored at `β(α=0) = upfront`,
//! * a single-contract `Market` reproduces classic `Pricing` costs
//!   **bit-identically** across the policy + ledger stack,
//! * **no permanent shadowing**: under the cross-tier spend accounting, a
//!   deeper contract whose window spans enough cheap-purchase cycles is
//!   eventually purchased under sustained demand (the pre-fix accounting
//!   reset the deep scan on every shallow purchase and never committed),
//! * **spend conservation** (windowless policies): each scan's
//!   uncompensated violation count is backed by real billing — it never
//!   exceeds the number of window slots that either billed on-demand
//!   instances or made a purchase (a purchase can cover its own trigger
//!   slot, which is why purchase slots count). With a prediction window
//!   the bound gains up to `w` lookahead slots per purchase by design
//!   (see the `algos::market` module docs), so the property is pinned at
//!   `w = 0` where it is exact.

use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::market::{MarketDeterministic, MarketRandomized};
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::ledger::Ledger;
use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::sim::{run_policy, run_policy_market};
use cloudreserve::util::prop::{check, check_no_shrink, shrink_demand, Config};
use cloudreserve::util::rng::Rng;

fn gen_contract(rng: &mut Rng, p: f64) -> Contract {
    Contract {
        upfront: 0.05 + rng.f64() * 2.0,
        rate: rng.f64() * p,
        term: 1 + rng.below(30) as usize,
    }
}

#[test]
fn prop_dominance_pruning_preserves_min_horizon_cost() {
    let cfg = Config { cases: 200, ..Default::default() };
    check_no_shrink(
        &cfg,
        "pruning-preserves-min-horizon-cost",
        |rng| {
            let p = 0.02 + rng.f64() * 0.5;
            let k = 1 + rng.below(4) as usize;
            let contracts: Vec<Contract> = (0..k).map(|_| gen_contract(rng, p)).collect();
            (p, contracts)
        },
        |(p, contracts)| {
            let pruned = Market::new(*p, contracts.clone());
            let raw = Market::new_unpruned(*p, contracts.clone());
            let max_term = contracts.iter().map(|c| c.term).max().unwrap_or(0);
            for h in 0..=(max_term as u64 + 2) {
                let a = pruned.min_horizon_cost(h);
                let b = raw.min_horizon_cost(h);
                if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                    return Err(format!(
                        "h={h}: pruned {a} vs raw {b} (menu {} -> {})",
                        raw.len(),
                        pruned.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_beta_monotone_in_alpha() {
    let cfg = Config { cases: 200, ..Default::default() };
    check_no_shrink(
        &cfg,
        "beta-monotone-in-alpha",
        |rng| {
            let p = 0.02 + rng.f64() * 0.5;
            let upfront = 0.05 + rng.f64() * 2.0;
            let term = 1 + rng.below(50) as usize;
            let mut a1 = rng.f64();
            let mut a2 = rng.f64();
            if a1 > a2 {
                std::mem::swap(&mut a1, &mut a2);
            }
            (p, upfront, term, a1, a2)
        },
        |&(p, upfront, term, a1, a2)| {
            let c1 = Contract { upfront, rate: a1 * p, term };
            let c2 = Contract { upfront, rate: a2 * p, term };
            let (b0, b1, b2) = (
                Contract { upfront, rate: 0.0, term }.beta_at(p),
                c1.beta_at(p),
                c2.beta_at(p),
            );
            if (b0 - upfront).abs() > 1e-9 * (1.0 + upfront) {
                return Err(format!("beta(alpha=0) = {b0}, want upfront {upfront}"));
            }
            // rate = alpha * p loses a few ulps, so compare with slack
            if b1 > b2 * (1.0 + 1e-9) {
                return Err(format!("alpha {a1} <= {a2} but beta {b1} > {b2}"));
            }
            Ok(())
        },
    );
}

/// Shadowing regime by construction: the shallow contract triggers every
/// `g_s + τ_s` slots under constant unit demand, and the deep contract's
/// window spans at least `m ≥ 3` such cycles while its break-even needs at
/// most `(m−1)·g_s − 1` violating slots — so with cross-tier accounting
/// (shallow purchases do *not* compensate the deeper scan, `β_d > β_s`)
/// the deep contract must fire. Returns `(market, total_slots)`; the deep
/// contract is id 1 after term-sorting.
fn gen_shadowing_menu(rng: &mut Rng) -> (Market, usize) {
    let p = 0.05 + rng.f64() * 0.2;
    let tau_s = 4 + rng.below(5) as usize; // 4..=8
    let g_s = 2 + rng.below(tau_s as u64 - 1) as usize; // 2..=tau_s
    let alpha_s = 0.05 + rng.f64() * 0.65;
    // trigger at exactly V = g_s: p*(g_s-1) < beta_s < p*g_s
    let beta_s = p * (g_s as f64 - 1.0 + 0.1 + rng.f64() * 0.8);
    let cycle = g_s + tau_s;
    let m = 3 + rng.below(2) as usize; // 3..=4
    let tau_d = m * cycle + rng.below(cycle as u64) as usize;
    let alpha_d = rng.f64() * alpha_s; // <= alpha_s keeps upfront_d > upfront_s
    let hi = 0.95 * p * ((m - 1) * g_s - 1) as f64;
    let beta_d = beta_s + (hi - beta_s) * (0.1 + rng.f64() * 0.9);
    assert!(beta_d > beta_s && beta_d < hi + 1e-12);
    let market = Market::new(
        p,
        vec![
            Contract { upfront: beta_s * (1.0 - alpha_s), rate: alpha_s * p, term: tau_s },
            Contract { upfront: beta_d * (1.0 - alpha_d), rate: alpha_d * p, term: tau_d },
        ],
    );
    (market, 2 * tau_d)
}

#[test]
fn prop_no_permanent_shadowing() {
    let cfg = Config { cases: 60, ..Default::default() };
    check_no_shrink(&cfg, "no-permanent-shadowing", gen_shadowing_menu, |(market, t_len)| {
        if market.len() != 2 {
            return Err(format!("generator must keep both tiers, got {}", market.len()));
        }
        let mut policy = MarketDeterministic::new(market.clone());
        let mut ledger = Ledger::new(market.clone());
        let mut per_contract = [0u64; 2];
        for _ in 0..*t_len {
            let dec = policy.decide(1, &[]);
            for &(cid, n) in dec.reservations {
                per_contract[cid] += n as u64;
            }
            ledger.bill(1, &dec).map_err(|e| e.to_string())?;
        }
        if per_contract[1] == 0 {
            return Err(format!(
                "deep contract (beta {:.4}, term {}) was never purchased; shallow bought {} times",
                market.beta(1),
                market.contract(1).term,
                per_contract[0]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_spend_conservation() {
    // p * V_j (the scan's uncompensated spend) never exceeds the billed
    // on-demand spend in contract j's window plus p per purchase slot in
    // it: every counted violation slot either billed >= 1 on-demand
    // instance or was a purchase slot (the purchase covered its own
    // trigger slot). Purely a property of the new accounting — checked at
    // every slot, for every scan, on random two-tier menus. Windowless
    // (w = 0) policies only: a prediction window adds up to w
    // later-covered lookahead slots per purchase by design.
    let cfg = Config { cases: 60, ..Default::default() };
    check_no_shrink(
        &cfg,
        "spend-conservation",
        |rng| {
            let p = 0.05 + rng.f64() * 0.3;
            let tau_s = 3 + rng.below(6) as usize;
            let tau_d = tau_s + 2 + rng.below(10) as usize;
            let market = Market::new(
                p,
                vec![
                    Contract {
                        upfront: 0.05 + rng.f64() * 0.8,
                        rate: rng.f64() * 0.8 * p,
                        term: tau_s,
                    },
                    Contract {
                        upfront: 0.2 + rng.f64() * 1.5,
                        rate: rng.f64() * 0.6 * p,
                        term: tau_d,
                    },
                ],
            );
            let demands: Vec<u32> = (0..120)
                .map(|_| if rng.chance(0.3) { 0 } else { rng.below(4) as u32 })
                .collect();
            (market, demands)
        },
        |(market, demands)| {
            let k = market.len();
            let mut policy = MarketDeterministic::new(market.clone());
            let mut ledger = Ledger::new(market.clone());
            // per slot: did it bill on-demand instances / make purchases?
            let mut od_slots: Vec<bool> = Vec::new();
            let mut buy_slots: Vec<bool> = Vec::new();
            for (t, &d) in demands.iter().enumerate() {
                let (on_demand, bought) = {
                    let dec = policy.decide(d, &[]);
                    let bought = dec.total_reserved();
                    ledger.bill(d, &dec).map_err(|e| e.to_string())?;
                    (dec.on_demand, bought)
                };
                od_slots.push(on_demand > 0);
                buy_slots.push(bought > 0);
                for j in 0..k {
                    let tau = market.contract(j).term;
                    let lo = (t + 1).saturating_sub(tau);
                    let backing = (lo..=t)
                        .filter(|&i| od_slots[i] || buy_slots[i])
                        .count() as u32;
                    let v = policy.scan_violations(j);
                    if v > backing {
                        return Err(format!(
                            "t={t} contract {j} (tau {tau}): {v} violations > {backing} \
                             backed slots"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_market_reproduces_pricing_bit_identically() {
    // Classic Deterministic through run_policy (Pricing convenience) vs
    // the menu policy over Market::single through run_policy_market:
    // decisions and billing must agree to the bit.
    let cfg = Config { cases: 60, ..Default::default() };
    check(
        &cfg,
        "single-market-bit-identical",
        |rng| {
            let tau = 2 + rng.below(40) as usize;
            let p = 0.01 + rng.f64() * 0.3;
            let alpha = rng.f64();
            let demands: Vec<u32> = (0..150).map(|_| rng.below(5) as u32).collect();
            (p, alpha, tau, demands)
        },
        |(p, alpha, tau, demands)| {
            let pricing = Pricing::normalized(*p, *alpha, *tau);
            let market = Market::single(pricing);
            let classic = run_policy(&mut Deterministic::online(pricing), demands, pricing)
                .map_err(|e| e.to_string())?;
            let menu =
                run_policy_market(&mut MarketDeterministic::new(market.clone()), demands, &market)
                    .map_err(|e| e.to_string())?;
            if classic.total.to_bits() != menu.total.to_bits() {
                return Err(format!("total: classic {} vs menu {}", classic.total, menu.total));
            }
            if classic.reservations != menu.reservations {
                return Err(format!(
                    "reservations: classic {} vs menu {}",
                    classic.reservations, menu.reservations
                ));
            }
            // randomized pair on a seed derived from the case (so shrunken
            // counterexamples replay deterministically)
            let seed = demands
                .iter()
                .fold(*tau as u64, |a, &d| a.wrapping_mul(31).wrapping_add(d as u64 + 1));
            let rc = run_policy(&mut Randomized::online(pricing, seed), demands, pricing)
                .map_err(|e| e.to_string())?;
            let rm = run_policy_market(
                &mut MarketRandomized::new(market.clone(), seed),
                demands,
                &market,
            )
            .map_err(|e| e.to_string())?;
            if rc.total.to_bits() != rm.total.to_bits() {
                return Err(format!(
                    "randomized(seed {seed}): classic {} vs menu {}",
                    rc.total, rm.total
                ));
            }
            Ok(())
        },
        |(p, alpha, tau, demands)| {
            shrink_demand(demands)
                .into_iter()
                .map(|d| (*p, *alpha, *tau, d))
                .collect()
        },
    );
}
