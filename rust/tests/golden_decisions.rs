//! Golden decision-stream fixture: per-slot `(on_demand, reservations)`
//! sequences recorded from the pre-rewrite bookkeeping (hash-map excess
//! histogram, one queue entry per purchased instance) for every
//! [`PolicySpec`] on four committed markets — the two paper-scale menus
//! plus two short-term markets whose reservations expire inside the trace.
//!
//! The flat hot-path rewrite (dense rotating-base `WindowScan`, coalesced
//! `RunQueue` runs, SoA market sweeps) must reproduce every stream
//! bit-exactly. Regenerate with `python3 tests/fixtures/gen_golden.py`,
//! which re-derives the streams from its own port of the old layout and
//! cross-checks them against a port of the flat structures first.
//!
//! The learned `Ucb` policy is pinned here too (its arm machinery is pure
//! integer/f64 arithmetic over the same structures); `AdaptiveWindow` is
//! deliberately excluded — its AR ridge fit is not float-portable enough
//! to pin bit-exactly across toolchains (see PERF.md §"Learned policies").

use cloudreserve::sim::fleet::PolicySpec;
use cloudreserve::util::json::{parse, Json};
use cloudreserve::{Contract, Market, Policy, Pricing};

const FIXTURE: &str = include_str!("fixtures/golden_decisions.json");

fn market_from(desc: &Json) -> Market {
    let p = desc.get("p").as_f64().unwrap();
    match desc.get("kind").as_str().unwrap() {
        "single" => {
            let alpha = desc.get("alpha").as_f64().unwrap();
            let tau = desc.get("tau").as_usize().unwrap();
            Market::single(Pricing::normalized(p, alpha, tau))
        }
        "menu" => {
            let contracts = desc
                .get("contracts")
                .as_arr()
                .unwrap()
                .iter()
                .map(|c| {
                    let c = c.as_arr().unwrap();
                    Contract {
                        upfront: c[0].as_f64().unwrap(),
                        rate: c[1].as_f64().unwrap(),
                        term: c[2].as_usize().unwrap(),
                    }
                })
                .collect();
            Market::new(p, contracts)
        }
        other => panic!("unknown market kind {other}"),
    }
}

fn spec_from(spec: &Json) -> PolicySpec {
    match spec.get("kind").as_str().unwrap() {
        "AllOnDemand" => PolicySpec::AllOnDemand,
        "AllReserved" => PolicySpec::AllReserved,
        "Separate" => PolicySpec::Separate,
        "Deterministic" => {
            PolicySpec::Deterministic { z: None, window: spec.get("window").as_usize().unwrap() }
        }
        "Randomized" => PolicySpec::Randomized {
            window: spec.get("window").as_usize().unwrap(),
            seed: spec.get("seed").as_usize().unwrap() as u64,
        },
        "Ucb" => PolicySpec::Ucb { seed: spec.get("seed").as_usize().unwrap() as u64 },
        other => panic!("unknown spec kind {other}"),
    }
}

#[test]
fn every_policy_reproduces_the_recorded_streams() {
    let fixture = parse(FIXTURE).expect("fixture parses");
    let user_id = fixture.get("user_id").as_usize().unwrap() as u32;
    let markets = fixture.get("markets").as_obj().unwrap();
    let demands_of = |name: &str| -> Vec<u32> {
        let (_, desc) = markets.iter().find(|(k, _)| k == name).unwrap();
        desc.get("demands")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap() as u32)
            .collect()
    };

    let cases = fixture.get("cases").as_arr().unwrap();
    assert!(cases.len() >= 32, "fixture unexpectedly small: {} cases", cases.len());
    let mut pinned_reservations = 0u32;
    for case in cases {
        let mname = case.get("market").as_str().unwrap();
        let (_, desc) = markets.iter().find(|(k, _)| k == mname).unwrap();
        let market = market_from(desc);
        let spec = spec_from(case.get("spec"));
        let demands = demands_of(mname);
        let want_od: Vec<u32> = case
            .get("od")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        let want_res: Vec<(usize, usize, u32)> = case
            .get("reservations")
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                let r = r.as_arr().unwrap();
                (
                    r[0].as_usize().unwrap(),
                    r[1].as_usize().unwrap(),
                    r[2].as_usize().unwrap() as u32,
                )
            })
            .collect();
        assert_eq!(want_od.len(), demands.len(), "{mname}/{}", spec.name());

        let mut policy = spec.build(&market, user_id);
        let w = policy.window();
        let mut got_res = Vec::new();
        for (t, &d) in demands.iter().enumerate() {
            let hi = (t + 1 + w).min(demands.len());
            let fut = if w == 0 { &[][..] } else { &demands[t + 1..hi] };
            let dec = policy.decide(d, fut);
            assert_eq!(
                dec.on_demand,
                want_od[t],
                "on-demand diverged: market={mname} spec={} t={t}",
                spec.name()
            );
            for &(cid, n) in dec.reservations {
                got_res.push((t, cid, n));
                pinned_reservations += n;
            }
        }
        assert_eq!(got_res, want_res, "reservations diverged: market={mname} spec={}", spec.name());
    }
    // the fixture must genuinely exercise the reservation machinery
    assert!(pinned_reservations > 50, "only {pinned_reservations} reservations replayed");
}
