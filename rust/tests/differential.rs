//! Differential harness for the multi-contract market stack: random menus
//! and demand traces, three independent cost oracles, one sandwich.
//!
//! For every generated `(menu, trace)` case the suite asserts
//!
//! ```text
//! joint DP  ≤  restricted per-contract DP
//! joint DP  ≤  every online policy (billed through the Ledger)
//! deterministic (z = β, w = 0)  ≤  (2 − α_max) · joint DP
//! ```
//!
//! plus engine wiring: each policy's cost is computed twice — through the
//! boxed `run_policy_market` replay and through the batched zero-allocation
//! fleet engine (`run_fleet_flat` over a single-user population) — and the
//! two must agree **bit-identically**. Single-contract menus are further
//! pinned bit-identically to the classic Algorithm 1/2 (and 3/4 with
//! windows) policies.
//!
//! Soundness of the sandwich: the joint DP searches a superset of every
//! restricted schedule and of every feasible decision sequence under the
//! exact billing convention the `Ledger` uses (serve `min(d, active)` on
//! reservations, cheapest rate first), so the first two inequalities are
//! theorems of the implementation. The third is the paper's Prop. 1 bound
//! with `α_max = max_j α_j`, checked *empirically* over the menu family
//! generated here — the paper leaves multi-contract competitive theory
//! open (see `PAPERS.md`: Wu et al. 1607.05178, Zhang et al. 1611.07379).

use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::market::{MarketDeterministic, MarketRandomized};
use cloudreserve::algos::offline;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::sim::engine::run_fleet_flat;
use cloudreserve::sim::fleet::{suite_specs, PolicySpec};
use cloudreserve::sim::{run_policy, run_policy_market};
use cloudreserve::trace::{Population, UserTrace};
use cloudreserve::util::rng::Rng;

/// Random two-tier menu in the regime the harness certifies: every
/// surviving contract's break-even is reachable inside its own window
/// (`β < p·τ`, which dominance pruning guarantees anyway), discounts are
/// moderate (`α ≤ 0.55`), and the deeper contract has the longer term and
/// the higher break-even.
fn gen_menu(rng: &mut Rng) -> Market {
    let p = 0.1 + rng.f64() * 0.3;
    let tau_s = 3 + rng.below(2) as usize; // 3..=4
    let tau_d = (tau_s + 2) + rng.below(7 - (tau_s + 2) as u64) as usize; // ..=6
    let alpha_s = 0.05 + rng.f64() * 0.5;
    let alpha_d = 0.05 + rng.f64() * 0.5;
    let beta_s = p * (1.0 + rng.f64() * (tau_s as f64 - 1.0));
    let beta_d = beta_s + rng.f64() * (0.9 * p * tau_d as f64 - beta_s).max(0.0);
    Market::new(
        p,
        vec![
            Contract { upfront: beta_s * (1.0 - alpha_s), rate: alpha_s * p, term: tau_s },
            Contract { upfront: beta_d * (1.0 - alpha_d), rate: alpha_d * p, term: tau_d },
        ],
    )
}

fn gen_trace(rng: &mut Rng, t_len: usize) -> Vec<u32> {
    match rng.below(3) {
        0 => vec![1u32; t_len],
        1 => (0..t_len).map(|_| rng.below(3) as u32).collect(),
        _ => (0..t_len)
            .map(|_| if rng.chance(0.35) { 0 } else { 1 + rng.below(2) as u32 })
            .collect(),
    }
}

/// Menu policy set under test: the Sec. VII suite plus windowed variants
/// and the learned policies (UCB threshold selection, forecast-driven
/// adaptive windows).
fn policy_specs(market: &Market, seed: u64, rng: &mut Rng) -> Vec<PolicySpec> {
    let mut specs = suite_specs(seed).to_vec();
    if let Some(min_term) = market.contracts().iter().map(|c| c.term).min() {
        if min_term > 1 {
            let w = 1 + rng.below(min_term as u64 - 1) as usize;
            specs.push(PolicySpec::Deterministic { z: None, window: w });
            specs.push(PolicySpec::Randomized { window: w, seed });
        }
    }
    specs.push(PolicySpec::Ucb { seed });
    specs.push(PolicySpec::AdaptiveWindow);
    specs
}

/// One policy's ledger-billed total, computed through both the boxed
/// replay and the batched engine — asserted bit-identical.
fn billed_total(demands: &[u32], market: &Market, spec: &PolicySpec, what: &str) -> f64 {
    let mut policy = spec.build(market, 0);
    let report = run_policy_market(policy.as_mut(), demands, market)
        .unwrap_or_else(|e| panic!("{what}: {}: infeasible decision: {e}", spec.name()));
    let pop = Population { users: vec![UserTrace::new(0, demands.to_vec())] };
    let fleet = run_fleet_flat(&pop.flatten(), market, spec, 2);
    assert_eq!(fleet.per_user.len(), 1, "{what}: {}", spec.name());
    assert_eq!(
        fleet.per_user[0].absolute_cost.to_bits(),
        report.total.to_bits(),
        "{what}: {}: engine vs boxed replay diverge ({} vs {})",
        spec.name(),
        fleet.per_user[0].absolute_cost,
        report.total
    );
    assert_eq!(fleet.per_user[0].reservations, report.reservations, "{what}: {}", spec.name());
    report.total
}

#[test]
fn cost_sandwich_on_random_menus() {
    let mut rng = Rng::new(0xD1FF);
    for case in 0..40 {
        let market = gen_menu(&mut rng);
        let demands = gen_trace(&mut rng, 40);
        let what = format!("case {case} (menu k={})", market.len());
        let d_max = demands.iter().copied().max().unwrap_or(0);
        let terms: Vec<usize> = market.contracts().iter().map(|c| c.term).collect();
        assert!(
            offline::dp_joint_tractable(d_max, &terms),
            "{what}: generator must stay inside the joint envelope"
        );
        let joint = offline::optimal_market_joint(&demands, &market).expect("tractable");

        // joint <= restricted (superset search space, same billing)
        let restricted = offline::optimal_market(&demands, &market);
        if let Some((_, best)) = restricted.best {
            assert!(
                joint.cost <= best.cost + 1e-9 * (1.0 + best.cost),
                "{what}: joint {} > restricted {}",
                joint.cost,
                best.cost
            );
        }

        // joint <= every online policy, through both replay paths
        let mut det_total: Option<f64> = None;
        for spec in policy_specs(&market, 0xA5 ^ case as u64, &mut rng) {
            let total = billed_total(&demands, &market, &spec, &what);
            assert!(
                joint.cost <= total + 1e-9 * (1.0 + total),
                "{what}: joint {} > {} cost {total}",
                joint.cost,
                spec.name()
            );
            if matches!(spec, PolicySpec::Deterministic { z: None, window: 0 }) {
                det_total = Some(total);
            }
        }

        // deterministic (z = beta, online) <= (2 - alpha_max) * joint
        let det = det_total.expect("suite contains the deterministic policy");
        let bound = (2.0 - market.alpha_max()) * joint.cost;
        assert!(
            det <= bound + 1e-9 * (1.0 + bound),
            "{what}: deterministic {det} > (2 - alpha_max) * joint = {bound} \
             (alpha_max {}, joint {})",
            market.alpha_max(),
            joint.cost
        );
    }
}

#[test]
fn single_contract_menus_stay_bit_identical_to_the_classic_policies() {
    let mut rng = Rng::new(0x51D3);
    for case in 0..25 {
        let tau = 3 + rng.below(30) as usize;
        let p = 0.02 + rng.f64() * 0.3;
        let alpha = rng.f64() * 0.95;
        let pricing = Pricing::normalized(p, alpha, tau);
        let market = Market::single(pricing);
        let w = rng.below(tau as u64) as usize; // 0..tau-1
        let demands: Vec<u32> = (0..200)
            .map(|_| if rng.chance(0.4) { 0 } else { rng.below(4) as u32 })
            .collect();
        let seed = 77 + case as u64;

        let menu_det = run_policy_market(
            &mut MarketDeterministic::with_window(market.clone(), w),
            &demands,
            &market,
        )
        .unwrap();
        let classic_det =
            run_policy(&mut Deterministic::new(pricing, pricing.beta(), w), &demands, pricing)
                .unwrap();
        assert_eq!(
            menu_det.total.to_bits(),
            classic_det.total.to_bits(),
            "case {case} w={w}: menu det {} vs Algorithm {} {}",
            menu_det.total,
            if w == 0 { 1 } else { 3 },
            classic_det.total
        );
        assert_eq!(menu_det.reservations, classic_det.reservations);
        assert_eq!(menu_det.on_demand_slots, classic_det.on_demand_slots);

        let menu_rand = run_policy_market(
            &mut MarketRandomized::with_window(market.clone(), w, seed),
            &demands,
            &market,
        )
        .unwrap();
        let classic_rand =
            run_policy(&mut Randomized::with_window(pricing, w, seed), &demands, pricing).unwrap();
        assert_eq!(
            menu_rand.total.to_bits(),
            classic_rand.total.to_bits(),
            "case {case} w={w}: menu randomized vs Algorithm {}",
            if w == 0 { 2 } else { 4 },
        );
    }
}

#[test]
fn sandwich_holds_per_user_through_the_batched_engine() {
    // Multi-user population through the chunked-shard engine: every
    // per-user ledger total must dominate that user's joint DP, across
    // thread counts (which must not change results at all).
    let mut rng = Rng::new(0xF1EE7);
    let market = Market::new(
        0.2,
        vec![
            Contract { upfront: 0.35, rate: 0.03, term: 4 },
            Contract { upfront: 0.8, rate: 0.015, term: 7 },
        ],
    );
    assert_eq!(market.len(), 2);
    let users: Vec<UserTrace> = (0..6)
        .map(|u| UserTrace::new(u as u32, gen_trace(&mut rng, 40)))
        .collect();
    let pop = Population { users };
    let flat = pop.flatten();
    let joints: Vec<f64> = pop
        .users
        .iter()
        .map(|u| offline::optimal_market_joint(&u.demand, &market).expect("tractable").cost)
        .collect();
    for spec in policy_specs(&market, 0x77, &mut rng) {
        let one = run_fleet_flat(&flat, &market, &spec, 1);
        let many = run_fleet_flat(&flat, &market, &spec, 3);
        for ((a, b), joint) in one.per_user.iter().zip(&many.per_user).zip(&joints) {
            assert_eq!(a.user_id, b.user_id);
            assert_eq!(
                a.absolute_cost.to_bits(),
                b.absolute_cost.to_bits(),
                "{}: thread-count changed user {}",
                spec.name(),
                a.user_id
            );
            assert!(
                *joint <= a.absolute_cost + 1e-9 * (1.0 + a.absolute_cost),
                "{}: user {}: joint {} > billed {}",
                spec.name(),
                a.user_id,
                joint,
                a.absolute_cost
            );
        }
    }
}

#[test]
fn ucb_per_slot_regret_decreases_on_stationary_traces() {
    // On a stationary trace the UCB threshold learner should converge to a
    // fixed arm, so its total excess cost over hindsight is dominated by a
    // bounded exploration transient — per-slot regret must not grow as the
    // horizon doubles, and must end below where it started.
    use cloudreserve::trace::synth::{regime_user, Regime};
    let market = Market::single(Pricing::normalized(0.2, 0.3, 6));
    let mut rng = Rng::new(0x57A7);
    // cap demand so the joint DP stays tractable at every horizon
    let full: Vec<u32> =
        regime_user(Regime::Stationary, 4096, 6, &mut rng).into_iter().map(|d| d.min(2)).collect();
    let mut per_slot = Vec::new();
    for &t_len in &[512usize, 1024, 2048, 4096] {
        let demands = &full[..t_len];
        let joint = offline::optimal_market_joint(demands, &market).expect("tractable");
        let total =
            billed_total(demands, &market, &PolicySpec::Ucb { seed: 9 }, &format!("T={t_len}"));
        assert!(
            joint.cost <= total + 1e-9 * (1.0 + total),
            "T={t_len}: joint {} > UCB {total}",
            joint.cost
        );
        per_slot.push((total - joint.cost) / t_len as f64);
    }
    let first = per_slot[0];
    let last = *per_slot.last().unwrap();
    assert!(
        last <= first + 1e-9,
        "per-slot regret failed to decrease across horizon doublings: {per_slot:?}"
    );
}

#[test]
fn adversarial_regime_keeps_the_deterministic_bound() {
    // Bursts held just below break-even then long idle gaps — the
    // worst-case shape for reservation triggers. The deterministic policy
    // must still meet its (2 − α) competitive bound (Prop. 1 holds for
    // arbitrary traces), and the joint DP must still floor the learned
    // policies.
    use cloudreserve::trace::synth::{regime_user, Regime};
    let market = Market::single(Pricing::normalized(0.25, 0.4, 8));
    let mut rng = Rng::new(0xAD5E);
    for case in 0..8 {
        let demands: Vec<u32> = regime_user(Regime::Adversarial, 400, 8, &mut rng)
            .into_iter()
            .map(|d| d.min(2))
            .collect();
        let what = format!("adversarial case {case}");
        let joint = offline::optimal_market_joint(&demands, &market).expect("tractable");
        let det = billed_total(
            &demands,
            &market,
            &PolicySpec::Deterministic { z: None, window: 0 },
            &what,
        );
        let bound = (2.0 - market.alpha_max()) * joint.cost;
        assert!(
            det <= bound + 1e-9 * (1.0 + bound),
            "{what}: deterministic {det} > (2 - alpha) * joint = {bound}"
        );
        for spec in [PolicySpec::Ucb { seed: 0xAD5E + case as u64 }, PolicySpec::AdaptiveWindow] {
            let total = billed_total(&demands, &market, &spec, &what);
            assert!(
                joint.cost <= total + 1e-9 * (1.0 + total),
                "{what}: joint {} > {} cost {total}",
                joint.cost,
                spec.name()
            );
        }
    }
}

#[test]
fn capped_joint_dp_is_bit_identical_on_constant_traces() {
    // `optimal_market_joint` takes a needed-capped fast path when the trace
    // is constant (d_t ≡ L): per-contract actives are pruned at L, which is
    // provably exact there (dropping the purchase that lifts a_j above L
    // leaves every cheapest-first take unchanged and strictly removes an
    // upfront fee). The *cost* must match the uncapped search to the bit.
    // Reservation COUNT may legitimately differ on exact cost ties (the
    // frontier keeps the incumbent), so only cost bits are asserted.
    let mut rng = Rng::new(0xCA9ED);
    let mut engaged = 0;
    for case in 0..30 {
        let market = gen_menu(&mut rng);
        let level = rng.below(4) as u32; // 0..=3
        let t_len = 10 + rng.below(41) as usize; // 10..=50
        let terms: Vec<usize> = market.contracts().iter().map(|c| c.term).collect();
        if !offline::dp_joint_tractable(level, &terms) {
            continue;
        }
        let demands = vec![level; t_len];
        let capped = offline::optimal_market_joint(&demands, &market).expect("tractable");
        let uncapped =
            offline::optimal_market_joint_uncapped(&demands, &market).expect("tractable");
        assert_eq!(
            capped.cost.to_bits(),
            uncapped.cost.to_bits(),
            "case {case} (L={level}, T={t_len}): capped {} vs uncapped {}",
            capped.cost,
            uncapped.cost
        );
        engaged += 1;

        // Non-constant traces must be untouched by the cap plumbing: a
        // single perturbed slot makes both entry points the same search.
        let mut bumped = demands.clone();
        bumped[t_len / 2] = level + 1;
        if offline::dp_joint_tractable(level + 1, &terms) {
            let a = offline::optimal_market_joint(&bumped, &market).expect("tractable");
            let b = offline::optimal_market_joint_uncapped(&bumped, &market).expect("tractable");
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "case {case}: perturbed trace");
            assert_eq!(a.reservations, b.reservations, "case {case}: perturbed trace");
        }
    }
    assert!(engaged >= 10, "fast path exercised only {engaged} times");
}

#[test]
fn joint_dp_is_exact_against_brute_force_menus() {
    // Independent exactness oracle: exhaustive search over all per-slot
    // purchase vectors (each contract 0..=D per slot), billed exactly like
    // the ledger. Tiny instances only.
    fn brute(demands: &[u32], market: &Market) -> f64 {
        fn rec(
            t: usize,
            demands: &[u32],
            hist: &mut [Vec<u32>],
            market: &Market,
            d_max: u32,
        ) -> f64 {
            if t == demands.len() {
                return 0.0;
            }
            let k = market.len();
            let d = demands[t];
            let base = d_max as usize + 1;
            let mut best = f64::INFINITY;
            for combo in 0..base.pow(k as u32) {
                let mut digits = combo;
                let mut fees = 0.0;
                for h in hist.iter_mut() {
                    h.push((digits % base) as u32);
                    digits /= base;
                }
                let avail: Vec<u32> = (0..k)
                    .map(|j| {
                        let lo = hist[j].len().saturating_sub(market.contract(j).term);
                        hist[j][lo..].iter().sum::<u32>()
                    })
                    .collect();
                for j in 0..k {
                    fees += *hist[j].last().unwrap() as f64 * market.contract(j).upfront;
                }
                let total: u32 = avail.iter().sum();
                let usage = d.min(total);
                let mut step = fees + market.p() * (d - usage) as f64;
                let mut rem = usage;
                for &cid in market.rate_order() {
                    let take = rem.min(avail[cid]);
                    step += market.contract(cid).rate * take as f64;
                    rem -= take;
                }
                best = best.min(step + rec(t + 1, demands, hist, market, d_max));
                for h in hist.iter_mut() {
                    h.pop();
                }
            }
            best
        }
        let d_max = demands.iter().copied().max().unwrap_or(0);
        let mut hist: Vec<Vec<u32>> = vec![Vec::new(); market.len()];
        rec(0, demands, &mut hist, market, d_max)
    }

    let mut rng = Rng::new(0xB00F);
    for case in 0..12 {
        let market = gen_menu(&mut rng);
        let demands: Vec<u32> = (0..6).map(|_| rng.below(2) as u32).collect();
        let joint = offline::optimal_market_joint(&demands, &market).expect("tractable");
        let bf = brute(&demands, &market);
        assert!(
            (joint.cost - bf).abs() < 1e-9,
            "case {case}: joint {} vs brute force {bf}",
            joint.cost
        );
    }
}
