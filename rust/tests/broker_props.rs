//! Property suite for the shared-portfolio broker (`cloudreserve::broker`).
//!
//! Three families of invariants:
//!
//! 1. **Settlement conserves cost bit-exactly.** For any realized total and
//!    any usage vector, Σ bills reconstructs the total to the bit — summed
//!    forward, backward, or in any other order (every bill is a multiple of
//!    one power-of-two quantum `q = total / mantissa`, and all partial sums
//!    stay ≤ 2⁵³·q, so f64 addition of bills is exact). The od-capped
//!    scheme additionally never bills a user above their standalone
//!    all-on-demand cost.
//!
//! 2. **The cost sandwich on sampled fleets.** Rotating-burst fleets are
//!    generated in a regime where the broker provably wins: `n` users take
//!    one-slot turns (the aggregate is a constant 1), the contract term
//!    spans two full rotations (`τ = 2n`) so no user ever accumulates the
//!    2.5-slot break-even inside a window alone (standalone = pure
//!    on-demand), while the broker's constant aggregate re-reserves
//!    profitably every `⌈β/p⌉ + τ` slots. On every sampled fleet:
//!    `joint DP on aggregate ≤ broker aggregate cost < Σ standalone
//!    deterministic costs` — the offline floor is a theorem of the
//!    implementation (the DP searches a superset of the policy's feasible
//!    schedules), the ceiling is the multiplexing gain the subsystem
//!    exists to capture.
//!
//! 3. **Streaming == in-RAM.** The chunk-at-a-time broker pipeline over a
//!    v2 trace is bit-identical to the in-RAM run for every chunk size
//!    (aggregation is pure integer addition; the standalone baseline is
//!    per-user independent), mirroring `tests/engine_parity.rs`.

use cloudreserve::algos::offline;
use cloudreserve::broker::{
    BrokerRun, OnDemandCapped, ProportionalUsage, Settlement, UserUsage, STANDALONE_SPEC,
};
use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::trace::io::{write_chunked, ChunkedPopulation};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::trace::{FlatPopulation, Population};
use cloudreserve::util::prop::{check_no_shrink, Config};
use cloudreserve::util::rng::Rng;

/// Assert Σ `bills` reconstructs `total` to the bit in several summation
/// orders (forward, reverse, sorted ascending by amount).
fn assert_conserves(bills: &[f64], total: f64, what: &str) {
    let fwd: f64 = bills.iter().sum();
    assert_eq!(fwd.to_bits(), total.to_bits(), "{what}: forward sum {fwd} vs total {total}");
    let rev: f64 = bills.iter().rev().sum();
    assert_eq!(rev.to_bits(), total.to_bits(), "{what}: reverse sum {rev} vs total {total}");
    let mut sorted = bills.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let asc: f64 = sorted.iter().sum();
    assert_eq!(asc.to_bits(), total.to_bits(), "{what}: sorted sum {asc} vs total {total}");
}

// ---------------------------------------------------------------------------
// 1. Settlement invariants on raw (total, usage) inputs
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct SettleCase {
    total: f64,
    p: f64,
    usage: Vec<UserUsage>,
}

fn gen_settle_case(rng: &mut Rng) -> SettleCase {
    let n = 1 + rng.below(40) as usize;
    let p = 0.01 + rng.f64() * 0.4;
    let usage: Vec<UserUsage> = (0..n)
        .map(|i| UserUsage {
            user_id: i as u32,
            // Include zero-usage users; span six orders of magnitude.
            demand_slots: rng.below(1_000_000),
            peak: 1,
        })
        .collect();
    let od_total: f64 = usage.iter().map(|u| p * u.demand_slots as f64).sum();
    // Keep the total under the on-demand ceiling so od-capped is feasible
    // (a broker whose realized cost exceeds Σ on-demand has no cap-respecting
    // split — that rejection path is pinned in the settlement unit tests).
    let total = rng.f64() * 0.8 * od_total;
    SettleCase { total, p, usage }
}

#[test]
fn settlement_conserves_cost_bit_exactly() {
    let schemes: [&dyn Settlement; 2] = [&ProportionalUsage, &OnDemandCapped];
    check_no_shrink(
        &Config { cases: 96, ..Config::default() },
        "settlement-conserves",
        gen_settle_case,
        |case| {
            for scheme in schemes {
                let bills = scheme
                    .settle(case.total, &case.usage, case.p)
                    .map_err(|e| format!("{}: settle failed: {e}", scheme.name()))?;
                if bills.len() != case.usage.len() {
                    return Err(format!("{}: {} bills for {} users", scheme.name(), bills.len(), case.usage.len()));
                }
                if bills.iter().any(|&b| !(b >= 0.0)) {
                    return Err(format!("{}: negative or NaN bill in {bills:?}", scheme.name()));
                }
                assert_conserves(&bills, case.total, scheme.name());
                if scheme.name() == "od-capped" {
                    for (u, &b) in case.usage.iter().zip(&bills) {
                        let od = case.p * u.demand_slots as f64;
                        if b > od {
                            return Err(format!(
                                "od-capped billed user {} {b} above its on-demand cost {od}",
                                u.user_id
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn settlement_degenerate_inputs() {
    let schemes: [&dyn Settlement; 2] = [&ProportionalUsage, &OnDemandCapped];
    for scheme in schemes {
        // Zero total: everyone owes exactly zero.
        let usage = vec![
            UserUsage { user_id: 0, demand_slots: 5, peak: 1 },
            UserUsage { user_id: 1, demand_slots: 0, peak: 0 },
        ];
        let bills = scheme.settle(0.0, &usage, 0.1).unwrap();
        assert_eq!(bills, vec![0.0, 0.0], "{}", scheme.name());

        // Single user: the whole total lands on them, to the bit.
        let one = vec![UserUsage { user_id: 7, demand_slots: 400, peak: 3 }];
        let total = 12.3456789;
        let bills = scheme.settle(total, &one, 0.5).unwrap();
        assert_eq!(bills.len(), 1);
        assert_eq!(bills[0].to_bits(), total.to_bits(), "{}", scheme.name());
    }
}

// ---------------------------------------------------------------------------
// 2. The cost sandwich on rotating-burst fleets
// ---------------------------------------------------------------------------

/// Parameters of one rotating-burst fleet (see module docs): everything
/// the broker run needs, in plain numbers so failures replay trivially.
#[derive(Debug, Clone)]
struct RotatingCase {
    n_users: usize,
    p: f64,
    alpha: f64,
    cycles: usize,
}

fn gen_rotating_case(rng: &mut Rng) -> RotatingCase {
    RotatingCase {
        n_users: 4 + rng.below(3) as usize,       // 4..=6
        p: 0.05 + rng.f64() * 0.2,                // 0.05..0.25
        alpha: 0.2 + rng.f64() * 0.4,             // 0.2..0.6
        cycles: 12 + rng.below(9) as usize,       // 12..=20 rotations
    }
}

impl RotatingCase {
    /// Single contract with term `2n` and break-even at 2.5 on-demand
    /// slots: a lone user sees at most 2 demanded slots per window (below
    /// break-even), the aggregate sees all `2n`.
    fn market(&self) -> Market {
        let beta = 2.5 * self.p;
        Market::new(
            self.p,
            vec![Contract {
                upfront: beta * (1.0 - self.alpha),
                rate: self.alpha * self.p,
                term: 2 * self.n_users,
            }],
        )
    }

    /// User `u` is busy on slots `t ≡ u (mod n)`; the aggregate is 1
    /// everywhere.
    fn fleet(&self) -> FlatPopulation {
        let slots = self.n_users * self.cycles;
        let mut flat = FlatPopulation::default();
        for u in 0..self.n_users {
            let demand: Vec<u32> =
                (0..slots).map(|t| u32::from(t % self.n_users == u)).collect();
            flat.push_user(u as u32, &demand);
        }
        flat
    }
}

#[test]
fn broker_cost_is_sandwiched_on_rotating_fleets() {
    check_no_shrink(
        &Config { cases: 48, ..Config::default() },
        "broker-sandwich",
        gen_rotating_case,
        |case| {
            let market = case.market();
            let flat = case.fleet();
            let outcome = BrokerRun {
                market: &market,
                policy: STANDALONE_SPEC,
                settlement: &ProportionalUsage,
                threads: 2,
                offline: true,
            }
            .run_flat(&flat)
            .map_err(|e| format!("broker run failed: {e}"))?;

            let broker = outcome.aggregate.report.total;
            let standalone = outcome.standalone_total;

            // Ceiling: aggregate broker cost < Σ standalone deterministic
            // costs — the multiplexing gain this regime guarantees.
            if !(outcome.multiplexing_gain > 0.0) {
                return Err(format!(
                    "no multiplexing gain: broker {broker} vs standalone {standalone}"
                ));
            }

            // Floor: the joint DP on the aggregate curve (searches a
            // superset of the policy's feasible schedules under identical
            // ledger billing).
            let floor = outcome
                .offline
                .as_ref()
                .ok_or("offline floor missing on a tractable aggregate")?;
            if floor.cost > broker + 1e-9 * (1.0 + broker) {
                return Err(format!("offline floor {} above broker cost {broker}", floor.cost));
            }

            // The floor is independently reproducible from the constant
            // aggregate curve.
            let curve = vec![1u32; case.n_users * case.cycles];
            let direct = offline::optimal_market_joint(&curve, &market)
                .ok_or("constant unit curve must be joint-tractable")?;
            if direct.cost.to_bits() != floor.cost.to_bits() {
                return Err(format!(
                    "offline floor {} diverges from direct joint DP {}",
                    floor.cost, direct.cost
                ));
            }

            assert_conserves(
                &outcome.bills.iter().map(|b| b.amount).collect::<Vec<_>>(),
                broker,
                "proportional",
            );
            Ok(())
        },
    );
}

#[test]
fn od_capped_broker_never_bills_above_on_demand_on_rotating_fleets() {
    check_no_shrink(
        &Config { cases: 32, ..Config::default() },
        "broker-od-capped",
        gen_rotating_case,
        |case| {
            let market = case.market();
            let flat = case.fleet();
            // Feasible by construction: the broker beats Σ standalone here,
            // and standalone is pure on-demand in this regime.
            let outcome = BrokerRun {
                market: &market,
                policy: STANDALONE_SPEC,
                settlement: &OnDemandCapped,
                threads: 2,
                offline: false,
            }
            .run_flat(&flat)
            .map_err(|e| format!("broker run failed: {e}"))?;
            for b in &outcome.bills {
                if b.amount > b.on_demand_cost {
                    return Err(format!(
                        "user {} billed {} above its on-demand cost {}",
                        b.user_id, b.amount, b.on_demand_cost
                    ));
                }
            }
            assert_conserves(
                &outcome.bills.iter().map(|b| b.amount).collect::<Vec<_>>(),
                outcome.aggregate.report.total,
                "od-capped",
            );
            Ok(())
        },
    );
}

#[test]
fn single_user_broker_is_the_standalone_policy_exactly() {
    // With one user the aggregate curve IS the user's curve, so the broker
    // degenerates to the standalone deterministic run bit-for-bit, the one
    // bill is the whole total, and the multiplexing gain is exactly zero.
    let mut flat = FlatPopulation::default();
    let demand: Vec<u32> = (0..200).map(|t| ((t / 13) % 3) as u32).collect();
    flat.push_user(0, &demand);
    let market = Market::single(Pricing::normalized(0.1, 0.45, 8));
    let outcome = BrokerRun {
        market: &market,
        policy: STANDALONE_SPEC,
        settlement: &ProportionalUsage,
        threads: 1,
        offline: false,
    }
    .run_flat(&flat)
    .unwrap();
    assert_eq!(outcome.users, 1);
    assert_eq!(
        outcome.aggregate.report.total.to_bits(),
        outcome.standalone_total.to_bits(),
        "one-user broker must equal the standalone run"
    );
    assert_eq!(outcome.multiplexing_gain, 0.0);
    assert_eq!(outcome.bills.len(), 1);
    assert_eq!(outcome.bills[0].amount.to_bits(), outcome.aggregate.report.total.to_bits());
}

// ---------------------------------------------------------------------------
// 3. Streaming chunked pipeline == in-RAM pipeline, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn chunked_broker_pipeline_is_bit_identical_to_in_ram() {
    let pop = generate(&SynthConfig { users: 23, slots: 400, seed: 11, ..Default::default() });
    let flat = pop.flatten();
    let market = Market::single(Pricing::normalized(0.1, 0.4, 60));
    let run = |settlement: &dyn Settlement| BrokerRun {
        market: &market,
        policy: STANDALONE_SPEC,
        settlement,
        threads: 3,
        offline: false,
    };
    let in_ram = run(&ProportionalUsage).run_flat(&flat).unwrap();
    assert_conserves(
        &in_ram.bills.iter().map(|b| b.amount).collect::<Vec<_>>(),
        in_ram.aggregate.report.total,
        "in-ram",
    );

    let dir = std::env::temp_dir();
    for chunk_users in [1u32, 4, 23, 64] {
        let path =
            dir.join(format!("cloudreserve_broker_props_{chunk_users}_{}.bin", std::process::id()));
        write_chunked(&pop, &path, chunk_users).unwrap();
        let mut chunked = ChunkedPopulation::open(&path).unwrap();
        let streamed = run(&ProportionalUsage).run_chunked(&mut chunked).unwrap();
        std::fs::remove_file(&path).ok();

        let what = format!("chunk_users={chunk_users}");
        assert_eq!(streamed.users, in_ram.users, "{what}");
        assert_eq!(streamed.slots, in_ram.slots, "{what}");
        assert_eq!(streamed.aggregate.report, in_ram.aggregate.report, "{what}");
        assert_eq!(
            streamed.standalone_total.to_bits(),
            in_ram.standalone_total.to_bits(),
            "{what}: standalone baseline"
        );
        assert_eq!(
            streamed.multiplexing_gain.to_bits(),
            in_ram.multiplexing_gain.to_bits(),
            "{what}: gain"
        );
        assert_eq!(streamed.bills.len(), in_ram.bills.len(), "{what}");
        for (a, b) in streamed.bills.iter().zip(&in_ram.bills) {
            assert_eq!(a.user_id, b.user_id, "{what}");
            assert_eq!(a.usage_slots, b.usage_slots, "{what}: user {}", a.user_id);
            assert_eq!(
                a.amount.to_bits(),
                b.amount.to_bits(),
                "{what}: bill of user {}",
                a.user_id
            );
            assert_eq!(
                a.standalone_cost.to_bits(),
                b.standalone_cost.to_bits(),
                "{what}: standalone of user {}",
                a.user_id
            );
        }
    }
}
