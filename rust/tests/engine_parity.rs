//! Golden-parity property test: the batched zero-allocation fleet engine
//! must produce **bit-identical** `CostReport`-derived results to the seed
//! per-user `run_policy` path — across random populations, seeds, thread
//! counts, and every Sec. VII policy (plus prediction-window variants and
//! multi-contract menus).
//!
//! Three independent oracles are compared:
//! 1. `run_fleet` — the batched engine over the columnar store,
//! 2. `run_fleet_reference` — the seed strided `mpsc` + `Box<dyn Policy>`
//!    runner, kept verbatim,
//! 3. a direct single-user `run_policy_market` replay (no fleet machinery
//!    at all).
//!
//! The single-contract market here is `Market::single(...)` — the v2 fast
//! path whose arithmetic must stay bit-identical to the pre-redesign
//! `Pricing` path (same ops, same order; pinned by the exact-constant
//! ledger/policy unit tests).

use cloudreserve::pricing::{Contract, Market, Pricing};
use cloudreserve::sim::engine::run_fleet_chunked;
use cloudreserve::sim::fleet::{
    run_fleet, run_fleet_reference, suite_specs, FleetResult, PolicySpec,
};
use cloudreserve::sim::run_policy_market;
use cloudreserve::trace::io::{write_chunked, ChunkedPopulation};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::trace::Population;

fn market() -> Market {
    // compressed EC2 small, tau sized to the short test traces
    Market::single(Pricing::normalized(0.08 / 69.0, 0.4875, 1000))
}

fn menu_market() -> Market {
    // two-term menu with break-evens that fire inside the short traces
    let m = Market::new(
        0.01,
        vec![
            Contract { upfront: 1.0, rate: 0.004, term: 600 },
            Contract { upfront: 1.5, rate: 0.002, term: 1800 },
        ],
    );
    assert_eq!(m.len(), 2);
    m
}

fn assert_bit_identical(a: &FleetResult, b: &FleetResult, what: &str) {
    assert_eq!(a.per_user.len(), b.per_user.len(), "{what}: user count");
    for (x, y) in a.per_user.iter().zip(&b.per_user) {
        assert_eq!(x.user_id, y.user_id, "{what}");
        assert_eq!(x.group, y.group, "{what}: user {}", x.user_id);
        assert_eq!(
            x.normalized_cost.to_bits(),
            y.normalized_cost.to_bits(),
            "{what}: user {} normalized {} vs {}",
            x.user_id,
            x.normalized_cost,
            y.normalized_cost
        );
        assert_eq!(
            x.absolute_cost.to_bits(),
            y.absolute_cost.to_bits(),
            "{what}: user {} absolute",
            x.user_id
        );
        assert_eq!(x.reservations, y.reservations, "{what}: user {} reservations", x.user_id);
    }
}

fn specs_under_test(seed: u64) -> Vec<PolicySpec> {
    let mut specs: Vec<PolicySpec> = suite_specs(seed).to_vec();
    // prediction-window variants exercise the borrowed future slices
    specs.push(PolicySpec::Deterministic { z: None, window: 60 });
    specs.push(PolicySpec::Deterministic { z: Some(0.3), window: 200 });
    specs.push(PolicySpec::Randomized { window: 90, seed });
    specs
}

#[test]
fn engine_matches_reference_across_populations_seeds_and_threads() {
    // Sized for debug-mode CI: 2 random populations x 8 policy specs x
    // 2 thread counts, engine vs reference compared pairwise plus a
    // thread-count-invariance check against the single-thread engine run.
    for (pop_seed, users, slots) in [(1u64, 10usize, 1500usize), (2013, 14, 1000)] {
        let pop = generate(&SynthConfig { users, slots, seed: pop_seed, ..Default::default() });
        for spec in specs_under_test(pop_seed ^ 0xA5) {
            let engine_1t = run_fleet(&pop, &market(), &spec, 1);
            for threads in [4usize, 11] {
                let engine = run_fleet(&pop, &market(), &spec, threads);
                let reference = run_fleet_reference(&pop, &market(), &spec, threads);
                let what = format!("{} pop_seed={pop_seed} threads={threads}", spec.name());
                assert_bit_identical(&engine, &reference, &what);
                assert_bit_identical(&engine, &engine_1t, &format!("{what} vs 1-thread"));
            }
        }
    }
}

/// Menu specs under parity test: the Sec. VII suite plus the windowed menu
/// variants (the cross-tier accounting runs on both paths; `menu_market`'s
/// break-evens are inverted versus its terms — β₀ ≈ 1.67 < β₁ = 1.875 —
/// so shallow purchases leave the deep scan uncompensated, exercising the
/// cross-tier path rather than the uniform-compensation one).
fn menu_specs_under_test(seed: u64) -> Vec<PolicySpec> {
    let mut specs = suite_specs(seed).to_vec();
    specs.push(PolicySpec::Deterministic { z: None, window: 200 });
    specs.push(PolicySpec::Randomized { window: 90, seed });
    specs
}

#[test]
fn engine_matches_reference_on_multi_contract_menus() {
    // The menu policies (MarketDeterministic / MarketRandomized / pinned
    // baselines) must replay identically through the monomorphic engine
    // and the boxed reference path, across thread counts — including the
    // prediction-window variants over the borrowed future slices.
    let mkt = menu_market();
    let pop = generate(&SynthConfig { users: 12, slots: 1500, seed: 7, ..Default::default() });
    for spec in menu_specs_under_test(0x51) {
        let engine_1t = run_fleet(&pop, &mkt, &spec, 1);
        for threads in [3usize, 9] {
            let engine = run_fleet(&pop, &mkt, &spec, threads);
            let reference = run_fleet_reference(&pop, &mkt, &spec, threads);
            let what = format!("menu {} threads={threads}", spec.name());
            assert_bit_identical(&engine, &reference, &what);
            assert_bit_identical(&engine, &engine_1t, &format!("{what} vs 1-thread"));
        }
    }
    // sanity: the menu deterministic policy actually commits on these
    // traces (the parity above is not vacuously about zero reservations)
    let det = run_fleet(&pop, &mkt, &PolicySpec::Deterministic { z: None, window: 0 }, 4);
    assert!(
        det.per_user.iter().any(|u| u.reservations > 0),
        "expected at least one menu reservation across the population"
    );
}

#[test]
fn engine_matches_direct_run_policy_per_user() {
    let pop = generate(&SynthConfig { users: 12, slots: 2000, seed: 5, ..Default::default() });
    for (mkt, specs) in [
        (market(), specs_under_test(9)),
        (menu_market(), menu_specs_under_test(9)),
    ] {
        for spec in specs {
            let fleet = run_fleet(&pop, &mkt, &spec, 4);
            for (u, got) in pop.users.iter().zip(&fleet.per_user) {
                let mut policy = spec.build(&mkt, u.user_id);
                let want = run_policy_market(policy.as_mut(), &u.demand, &mkt).unwrap();
                assert_eq!(got.user_id, u.user_id);
                assert_eq!(
                    got.absolute_cost.to_bits(),
                    want.total.to_bits(),
                    "{}: user {} (menu k={})",
                    spec.name(),
                    u.user_id,
                    mkt.len()
                );
                assert_eq!(got.reservations, want.reservations);
            }
        }
    }
}

#[test]
fn chunked_streaming_replay_is_bit_identical_to_in_ram() {
    // The bounded-memory chunked path (stream chunks from disk, rewind one
    // ShardRunner per shard) must reproduce the in-RAM engine to the bit —
    // across every policy under test, chunk sizes that split users at
    // awkward boundaries, both markets, and several thread counts. This is
    // the correctness contract that lets `bench --fleet-scale` replay a
    // million users without holding them resident.
    let dir = std::env::temp_dir();
    for (mkt, specs, tag) in [
        (market(), specs_under_test(0xC1), "single"),
        (menu_market(), menu_specs_under_test(0xC1), "menu"),
    ] {
        let pop = generate(&SynthConfig { users: 23, slots: 900, seed: 11, ..Default::default() });
        for chunk_users in [1u32, 4, 23, 64] {
            let path = dir.join(format!(
                "cloudreserve_parity_{tag}_{chunk_users}_{}.bin",
                std::process::id()
            ));
            write_chunked(&pop, &path, chunk_users).unwrap();
            for spec in &specs {
                let in_ram = run_fleet(&pop, &mkt, spec, 4);
                for threads in [1usize, 3, 9] {
                    let mut chunked = ChunkedPopulation::open(&path).unwrap();
                    let streamed = run_fleet_chunked(&mut chunked, &mkt, spec, threads).unwrap();
                    let what = format!(
                        "{tag} {} chunk_users={chunk_users} threads={threads}",
                        spec.name()
                    );
                    assert_bit_identical(&in_ram, &streamed, &what);
                }
            }
            std::fs::remove_file(&path).ok();
        }
    }
}

#[test]
fn engine_handles_degenerate_populations() {
    // zero users, zero-demand users, and single-slot traces
    let empty = Population::default();
    let r = run_fleet(&empty, &market(), &PolicySpec::AllOnDemand, 8);
    assert!(r.per_user.is_empty());

    let degenerate = Population {
        users: vec![
            cloudreserve::trace::UserTrace::new(0, vec![0; 500]),
            cloudreserve::trace::UserTrace::new(1, vec![3]),
            cloudreserve::trace::UserTrace::new(2, vec![]),
        ],
    };
    for mkt in [market(), menu_market()] {
        for spec in suite_specs(3) {
            let engine = run_fleet(&degenerate, &mkt, &spec, 2);
            let reference = run_fleet_reference(&degenerate, &mkt, &spec, 2);
            assert_bit_identical(&engine, &reference, &spec.name());
            // zero-demand users normalize to exactly 1.0 on both paths
            assert_eq!(engine.per_user[0].normalized_cost, 1.0, "{}", spec.name());
            assert_eq!(engine.per_user[2].normalized_cost, 1.0, "{}", spec.name());
        }
    }
}
