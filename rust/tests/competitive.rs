//! Property-based verification of the paper's theory against the exact
//! offline DP on small instances:
//!
//! * coverage feasibility for every policy (problem (1)'s constraint),
//! * Lemma 2: `n_β ≤ n_OPT`,
//! * Proposition 1: `C_{A_β} ≤ (2−α)·C_OPT`,
//! * Proposition 3: `E[C_{A_z}] ≤ e/(e−1+α)·C_OPT` (Monte-Carlo),
//! * Proposition 5: the prediction-window variants keep the same bounds,
//! * the cost identity `C = n + (1−α)·Od + α·S` (Eq. 34).

use cloudreserve::algos::baselines::{AllOnDemand, AllReserved, Separate};
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::offline;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;
use cloudreserve::util::prop::{check, shrink_demand, Config};
use cloudreserve::util::rng::Rng;

/// Random small instance: (demands, pricing) suitable for the exact DP.
fn gen_instance(rng: &mut Rng) -> (Vec<u32>, Pricing) {
    let tau = 2 + rng.below(4) as usize; // 2..=5
    let p = 0.05 + rng.f64() * 0.5;
    let alpha = rng.f64() * 0.95;
    let t_len = 5 + rng.below(20) as usize;
    let demands: Vec<u32> = (0..t_len)
        .map(|_| if rng.chance(0.3) { 0 } else { rng.below(4) as u32 })
        .collect();
    (demands, Pricing::normalized(p, alpha, tau))
}

#[test]
fn lemma2_deterministic_reserves_at_most_opt() {
    let cfg = Config { cases: 120, ..Default::default() };
    let mut rng = Rng::new(0xBEEF);
    check(
        &cfg,
        "lemma2: n_beta <= n_opt",
        move |r| gen_instance(&mut rng.fork(r.next_u64())),
        |(demands, pricing)| {
            let mut a = Deterministic::online(*pricing);
            let rep = run_policy(&mut a, demands, *pricing).map_err(|e| e.to_string())?;
            let opt = offline::optimal(demands, pricing);
            if rep.reservations <= opt.reservations {
                Ok(())
            } else {
                Err(format!(
                    "n_beta={} > n_opt={} (opt cost {})",
                    rep.reservations, opt.reservations, opt.cost
                ))
            }
        },
        |(d, pr)| shrink_demand(d).into_iter().map(|d2| (d2, *pr)).collect(),
    );
}

#[test]
fn prop1_deterministic_within_2_minus_alpha() {
    let cfg = Config { cases: 150, ..Default::default() };
    let mut rng = Rng::new(0xCAFE);
    check(
        &cfg,
        "prop1: C_A <= (2-alpha) C_OPT",
        move |r| gen_instance(&mut rng.fork(r.next_u64())),
        |(demands, pricing)| {
            let mut a = Deterministic::online(*pricing);
            let rep = run_policy(&mut a, demands, *pricing).map_err(|e| e.to_string())?;
            let opt = offline::optimal(demands, pricing).cost;
            let bound = pricing.deterministic_ratio() * opt + 1e-9;
            if rep.total <= bound {
                Ok(())
            } else {
                Err(format!(
                    "C_A={} > (2-a)*OPT={} (alpha={}, opt={})",
                    rep.total, bound, pricing.alpha, opt
                ))
            }
        },
        |(d, pr)| shrink_demand(d).into_iter().map(|d2| (d2, *pr)).collect(),
    );
}

#[test]
fn prop5_prediction_window_keeps_bound() {
    let cfg = Config { cases: 100, ..Default::default() };
    let mut rng = Rng::new(0xD00D);
    check(
        &cfg,
        "prop5: A^w_beta is (2-alpha)-competitive",
        move |r| {
            let mut rr = rng.fork(r.next_u64());
            let (d, pr) = gen_instance(&mut rr);
            let w = rr.below(pr.tau as u64 - 1) as usize;
            (d, pr, w)
        },
        |(demands, pricing, w)| {
            let mut a = Deterministic::with_window(*pricing, *w);
            let rep = run_policy(&mut a, demands, *pricing).map_err(|e| e.to_string())?;
            let opt = offline::optimal(demands, pricing).cost;
            let bound = pricing.deterministic_ratio() * opt + 1e-9;
            if rep.total <= bound {
                Ok(())
            } else {
                Err(format!("C={} > bound={} (w={w})", rep.total, bound))
            }
        },
        |(d, pr, w)| shrink_demand(d).into_iter().map(|d2| (d2, *pr, *w)).collect(),
    );
}

#[test]
fn prop3_randomized_expected_cost_bound() {
    // Monte-Carlo over the threshold draw: expectation within the bound
    // plus a sampling tolerance.
    let mut rng = Rng::new(0x5EED);
    for case in 0..25u64 {
        let (demands, pricing) = gen_instance(&mut rng);
        let opt = offline::optimal(&demands, &pricing).cost;
        if opt <= 0.0 {
            continue;
        }
        let n = 400;
        let mean: f64 = (0..n)
            .map(|s| {
                let mut a = Randomized::online(pricing, s as u64 * 7 + case);
                run_policy(&mut a, &demands, pricing).unwrap().total
            })
            .sum::<f64>()
            / n as f64;
        let bound = pricing.randomized_ratio() * opt;
        // 5% Monte-Carlo tolerance
        assert!(
            mean <= bound * 1.05 + 1e-9,
            "case {case}: E[C]={mean} > e/(e-1+a)*OPT={bound} (alpha={}, demands={demands:?})",
            pricing.alpha
        );
    }
}

#[test]
fn randomized_beats_deterministic_in_expectation_on_adversarial_input() {
    // The classic bad input for A_beta: demand stops right after the
    // break-even point. Deterministic pays ~ (2-alpha) OPT; randomized
    // does strictly better in expectation.
    //
    // KNOWN DEVIATION (PERF.md §Known deviations): on demand stopping at
    // x = beta + eps, the density's atom at z = beta fires its reservation
    // and pays the fee for epsilon of discounted use, adding
    // alpha(1-alpha)/(e-1+alpha) to the expected ratio:
    //   r(beta+eps) = (e + alpha(1-alpha)) / (e-1+alpha)  >  e/(e-1+alpha).
    // The paper's claimed bound (Prop. 3) holds at x = beta exactly (see
    // the next test) but not on this boundary family; the inequality chain
    // (30)->(32) drops the atom's fee. We assert the *corrected* bound.
    let p = 0.005;
    let alpha = 0.3;
    let pricing = Pricing::normalized(p, alpha, 100_000);
    let beta = pricing.beta();
    let n_slots = (beta / p).ceil() as usize + 1; // just past break-even
    let mut demands = vec![1u32; n_slots];
    demands.extend(vec![0u32; 30]);

    let mut det = Deterministic::online(pricing);
    let det_cost = run_policy(&mut det, &demands, pricing).unwrap().total;

    let n = 2000;
    let rand_mean: f64 = (0..n)
        .map(|s| {
            let mut a = Randomized::online(pricing, s as u64);
            run_policy(&mut a, &demands, pricing).unwrap().total
        })
        .sum::<f64>()
        / n as f64;

    let opt = offline::optimal_single(&demands, &pricing).cost;
    assert!(
        rand_mean < det_cost,
        "E[C_rand]={rand_mean} should beat C_det={det_cost} (OPT={opt})"
    );
    let e = std::f64::consts::E;
    let corrected = (e + alpha * (1.0 - alpha)) / (e - 1.0 + alpha);
    let ratio = rand_mean / opt;
    assert!(
        ratio <= corrected * 1.02,
        "E[C]/OPT={ratio} vs corrected bound {corrected}"
    );
    // and the deviation is real: the ratio *exceeds* the paper's bound here
    assert!(
        ratio > pricing.randomized_ratio() * 1.02,
        "expected the boundary family to exceed the paper bound ({} vs {})",
        ratio,
        pricing.randomized_ratio()
    );
}

#[test]
fn prop3_randomized_bound_tight_at_exact_breakeven() {
    // At x = beta exactly the atom never fires (strict >) and the expected
    // ratio equals e/(e-1+alpha) — the paper's bound, tight.
    for &alpha in &[0.0, 0.3, 0.4875] {
        let p = 0.002;
        let pricing = Pricing::normalized(p, alpha, 1_000_000);
        let beta = pricing.beta();
        let n_slots = (beta / p).floor() as usize; // spend = beta (<= atom)
        let demands = vec![1u32; n_slots];
        let n = 4000;
        let rand_mean: f64 = (0..n)
            .map(|s| {
                let mut a = Randomized::online(pricing, s as u64 * 13 + 1);
                run_policy(&mut a, &demands, pricing).unwrap().total
            })
            .sum::<f64>()
            / n as f64;
        let opt = offline::optimal_single(&demands, &pricing).cost;
        let ratio = rand_mean / opt;
        let bound = pricing.randomized_ratio();
        assert!(
            (ratio - bound).abs() < 0.03 * bound + 3.0 * p,
            "alpha={alpha}: ratio {ratio} should be ~= bound {bound}"
        );
    }
}

#[test]
fn coverage_and_identity_for_all_policies() {
    let cfg = Config { cases: 60, ..Default::default() };
    let mut rng = Rng::new(0xF00D);
    check(
        &cfg,
        "coverage + Eq.34 identity",
        move |r| gen_instance(&mut rng.fork(r.next_u64())),
        |(demands, pricing)| {
            let policies: Vec<Box<dyn cloudreserve::Policy>> = vec![
                Box::new(AllOnDemand::new()),
                Box::new(AllReserved::new(*pricing)),
                Box::new(Separate::new(*pricing)),
                Box::new(Deterministic::online(*pricing)),
                Box::new(Deterministic::with_threshold(*pricing, 0.0)),
                Box::new(Randomized::online(*pricing, 7)),
            ];
            for mut p in policies {
                let name = p.name();
                // run_policy errors on any coverage violation
                let rep = run_policy(p.as_mut(), demands, *pricing)
                    .map_err(|e| format!("{name}: {e}"))?;
                if !rep.identity_holds(pricing, 1e-9) {
                    return Err(format!("{name}: Eq.34 identity violated: {rep:?}"));
                }
            }
            Ok(())
        },
        |(d, pr)| shrink_demand(d).into_iter().map(|d2| (d2, *pr)).collect(),
    );
}

#[test]
fn deterministic_ratio_is_tight_on_bahncard_adversary() {
    // Fig. 2 verification: the adversarial sequence drives A_beta's ratio
    // toward 2-alpha as p -> 0. Demand for just past break-even then
    // silence: A_beta pays ~2*beta while OPT pays ~beta.
    for &alpha in &[0.0, 0.3, 0.4875, 0.7] {
        let p = 0.01;
        let pricing = Pricing::normalized(p, alpha, 100_000);
        let beta = pricing.beta();
        let pulses = (beta / p).ceil() as usize + 1;
        let mut demands = vec![1u32; pulses];
        demands.extend(vec![0u32; 10]);
        let mut a = Deterministic::online(pricing);
        let cost = run_policy(&mut a, &demands, pricing).unwrap().total;
        let opt = offline::optimal_single(&demands, &pricing).cost;
        let ratio = cost / opt;
        let bound = pricing.deterministic_ratio();
        assert!(ratio <= bound + 1e-9, "alpha={alpha}: ratio {ratio} > bound {bound}");
        assert!(
            ratio >= bound - 0.05,
            "alpha={alpha}: adversarial ratio {ratio} should approach {bound}"
        );
    }
}

#[test]
fn separate_never_beats_joint_on_level_shifting_load() {
    // Sec. II-D: joint reservation dominates Separate when demand levels
    // alternate (Separate cannot time-multiplex reservations).
    let pricing = Pricing::normalized(0.1, 0.0, 40); // beta = 1
    let mut demands = Vec::new();
    for block in 0..8 {
        let level = 1 + (block % 2) as u32;
        demands.extend(std::iter::repeat(level).take(15));
    }
    let mut sep = Separate::new(pricing);
    let mut det = Deterministic::online(pricing);
    let c_sep = run_policy(&mut sep, &demands, pricing).unwrap().total;
    let c_det = run_policy(&mut det, &demands, pricing).unwrap().total;
    assert!(c_det <= c_sep + 1e-9, "joint {c_det} must not exceed separate {c_sep}");
}
