#!/usr/bin/env python3
"""Cross-validation of the shared-portfolio broker against an independent
Python port.

The Rust toolchain is not always available in the environments this repo
grows in, so the broker subsystem's key invariants are re-derived here on
top of the policy/market ports in ``gen_golden.py`` (which are pinned
bit-identical to the Rust decision streams by ``tests/golden_decisions.rs``):

* a faithful port of ``ledger::Ledger::bill`` (same float-op order, so
  costs agree to the bit with the Rust replay);
* ports of the settlement machinery in ``broker/settlement.rs``
  (mantissa-quantum decomposition, exact-integer Hamilton apportionment,
  od-capped water-fill);
* the broker pipeline itself: aggregate fold, shared-portfolio replay,
  standalone baseline, settlement.

It then checks, in plain IEEE-754 Python floats:

1. the committed ``examples/scenarios/broker_table1.json`` fleet has a
   positive multiplexing gain (aggregate broker cost < Σ standalone
   deterministic costs) and bills that conserve the broker cost bit-exactly;
2. the exact rotating-burst case streams sampled by
   ``tests/broker_props.rs`` (same xoshiro256** stream, same parameters)
   satisfy gain > 0, bit-exact conservation in several summation orders,
   and — for the od-capped scheme — the per-user on-demand ceiling.

Run:  python3 rust/tests/fixtures/validate_broker.py
"""

import json
import math
import os
import struct
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_golden import Contract, Market, Rng, RunQueue, build_policy  # noqa: E402

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "..")
)

PROP_SEED = 0xC10D_5EED  # util::prop Config::default()


def bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


# --------------------------------------------------- ledger/mod.rs port


class Ledger:
    """Port of Ledger::bill — identical float-op order."""

    def __init__(self, market):
        self.market = market
        self.rate_order = sorted(
            range(len(market.contracts)),
            key=lambda i: (market.contracts[i].rate, i),
        )
        self.active = [RunQueue() for _ in market.contracts]
        self.t = 0
        self.total = 0.0
        self.reservations = 0

    def active_now(self):
        total = 0
        for q in self.active:
            q.expire_before(self.t + 1)
            total += q.total()
        return total

    def bill(self, demand, on_demand, reservations):
        t = self.t
        assert on_demand <= demand, f"slot {t}: on-demand {on_demand} > demand {demand}"
        active = self.active_now() + sum(n for _, n in reservations)
        reserved_use = demand - on_demand
        assert reserved_use <= active, f"slot {t}: underprovisioned"
        fees = 0.0
        for cid, n in reservations:
            c = self.market.contracts[cid]
            self.active[cid].push_n(t + c.term, n)
            fees += n * c.upfront
            self.reservations += n
        p = self.market.p
        od = on_demand * p
        ru = 0.0
        rem = reserved_use
        for cid in self.rate_order:
            if rem == 0:
                break
            take = min(rem, self.active[cid].total())
            ru += self.market.contracts[cid].rate * take
            rem -= take
        self.total += fees + od + ru
        self.t += 1


def billed_replay(market, spec, demands, user_id=0):
    """run_policy_market: drive a policy over a trace, bill every slot."""
    policy = build_policy(spec, market, user_id, True)
    w = policy.window
    ledger = Ledger(market)
    for t, d in enumerate(demands):
        fut = demands[t + 1 : min(t + 1 + w, len(demands))] if w > 0 else []
        od, res = policy.decide(d, fut)
        ledger.bill(d, od, res)
    return ledger


# --------------------------------------------- broker/settlement.rs port


def quantum(total):
    b = bits(total)
    exp = (b >> 52) & 0x7FF
    frac = b & ((1 << 52) - 1)
    m = frac if exp == 0 else frac | (1 << 52)
    return m, total / float(m)


def apportion(m, weights):
    w_total = sum(weights)
    units = [0] * len(weights)
    if m == 0 or w_total == 0:
        return units
    assigned = 0
    rema = []
    for i, w in enumerate(weights):
        prod = m * w
        units[i] = prod // w_total
        assigned += units[i]
        rema.append((prod % w_total, i))
    rema.sort(key=lambda e: (-e[0], e[1]))
    for _, i in rema[: m - assigned]:
        units[i] += 1
    return units


def settle_proportional(total, usage_slots, p):
    if total == 0.0:
        return [0.0] * len(usage_slots)
    m, q = quantum(total)
    weights = list(usage_slots)
    if all(w == 0 for w in weights):
        weights = [1] * len(weights)
    return [u * q for u in apportion(m, weights)]


def saturating_quanta(c):
    """Mirror of settlement::saturating_quanta (Rust `as`-cast semantics).

    `u64::MAX as f64` rounds UP to 2^64, so the saturation boundary is
    2^64 itself: every float >= 2^64 maps to u64::MAX, and the largest
    float BELOW the boundary (2^64 - 2048) converts losslessly.  NaN and
    non-positive inputs map to zero, as Rust's saturating cast does.
    """
    if math.isnan(c) or c <= 0.0:
        return 0
    if c >= 2.0**64:
        return 2**64 - 1
    return int(c)


def settle_od_capped(total, usage_slots, p):
    if total == 0.0:
        return [0.0] * len(usage_slots)
    m, q = quantum(total)
    n = len(usage_slots)
    caps = [saturating_quanta(math.floor((p * float(d)) / q)) for d in usage_slots]
    # Exact integer cap total (the Rust side folds into a u128); the float
    # ceiling in the error message is derived from it so the reported sum
    # cannot itself overflow or drift from the true cap.
    cap_total = sum(caps)
    assert m <= cap_total, (
        f"total exceeds the on-demand ceiling {float(cap_total) * q!r}"
    )
    units = [0] * n
    capped = [False] * n
    remaining = m
    while remaining > 0:
        ws = [0] * n
        for i in range(n):
            if not capped[i]:
                ws[i] = usage_slots[i]
        if not any(ws):
            for i in range(n):
                if not capped[i]:
                    ws[i] = caps[i] - units[i]
        share = apportion(remaining, ws)
        violated = False
        for i in range(n):
            if not capped[i] and share[i] > caps[i]:
                units[i] = caps[i]
                capped[i] = True
                remaining -= caps[i]
                violated = True
        if not violated:
            for i in range(n):
                if not capped[i]:
                    units[i] = share[i]
            break
    return [u * q for u in units]


# ------------------------------------------------------ broker pipeline


STANDALONE_SPEC = {"kind": "Deterministic", "window": 0}


def run_broker(market, users, settle):
    """Port of BrokerRun::run_flat: (uid, demand) list -> outcome dict."""
    slots = max(len(d) for _, d in users)
    curve = [0] * slots
    usage = []
    for _, demand in users:
        for t, d in enumerate(demand):
            curve[t] += d
        usage.append(sum(demand))
    portfolio = billed_replay(market, STANDALONE_SPEC, curve)
    standalone = [
        billed_replay(market, STANDALONE_SPEC, demand, uid).total for uid, demand in users
    ]
    standalone_total = 0.0
    for c in standalone:
        standalone_total += c
    bills = settle(portfolio.total, usage, market.p)
    return {
        "total": portfolio.total,
        "reservations": portfolio.reservations,
        "standalone_total": standalone_total,
        "gain": standalone_total - portfolio.total,
        "usage": usage,
        "bills": bills,
    }


def assert_conserves(bills, total, what):
    for name, order in [
        ("forward", bills),
        ("reverse", list(reversed(bills))),
        ("sorted", sorted(bills)),
    ]:
        s = 0.0
        for b in order:
            s += b
        assert bits(s) == bits(total), f"{what}: {name} sum {s!r} != total {total!r}"


# ----------------------------------------------------------- the checks


def check_broker_table1():
    path = os.path.join(REPO_ROOT, "examples", "scenarios", "broker_table1.json")
    spec = json.load(open(path))
    assert spec["mode"] == "broker", "broker_table1.json must be a broker-mode spec"
    mj = spec["market"]
    market = Market(
        mj["on_demand"],
        [Contract(c["upfront"], c["rate"], c["term"]) for c in mj["contracts"]],
    )
    assert len(market) == len(mj["contracts"]), "no contract may be pruned"
    users = list(enumerate(spec["trace"]["demands"]))
    out = run_broker(market, users, settle_proportional)
    assert out["reservations"] >= 1, "the aggregate curve must trigger reservations"
    assert out["gain"] > 0.0, (
        f"broker_table1 must show multiplexing gain: aggregate {out['total']} "
        f"vs standalone {out['standalone_total']}"
    )
    assert_conserves(out["bills"], out["total"], "broker_table1")
    print(
        f"  broker_table1: {len(users)} users, aggregate {out['total']:.6f} "
        f"<= standalone {out['standalone_total']:.6f} "
        f"(gain {out['gain']:.6f}, {out['reservations']} reservations) OK"
    )


def gen_rotating_case(rng):
    """Mirror of gen_rotating_case in tests/broker_props.rs (field order!)."""
    n_users = 4 + rng.below(3)
    p = 0.05 + rng.f64() * 0.2
    alpha = 0.2 + rng.f64() * 0.4
    cycles = 12 + rng.below(9)
    return n_users, p, alpha, cycles


def rotating_market_and_fleet(n_users, p, alpha, cycles):
    beta = 2.5 * p
    market = Market(
        p,
        [Contract(beta * (1.0 - alpha), alpha * p, 2 * n_users)],
    )
    assert len(market) == 1, "the rotating contract must survive pruning"
    slots = n_users * cycles
    users = [
        (u, [1 if t % n_users == u else 0 for t in range(slots)]) for u in range(n_users)
    ]
    return market, users


def check_rotating_props():
    # Same stream as `broker_cost_is_sandwiched_on_rotating_fleets`.
    rng = Rng(PROP_SEED)
    for case in range(48):
        n_users, p, alpha, cycles = gen_rotating_case(rng)
        market, users = rotating_market_and_fleet(n_users, p, alpha, cycles)
        out = run_broker(market, users, settle_proportional)
        what = f"sandwich case {case} (n={n_users}, p={p:.4f}, a={alpha:.4f}, c={cycles})"
        assert out["gain"] > 0.0, f"{what}: no gain ({out['total']} vs {out['standalone_total']})"
        assert_conserves(out["bills"], out["total"], what)
    print("  rotating sandwich: 48 prop cases show gain > 0 and conserve OK")

    # Same stream as `od_capped_broker_never_bills_above_on_demand_...`.
    rng = Rng(PROP_SEED)
    for case in range(32):
        n_users, p, alpha, cycles = gen_rotating_case(rng)
        market, users = rotating_market_and_fleet(n_users, p, alpha, cycles)
        out = run_broker(market, users, settle_od_capped)
        what = f"od-capped case {case} (n={n_users}, p={p:.4f}, a={alpha:.4f}, c={cycles})"
        for d, b in zip(out["usage"], out["bills"]):
            od = p * float(d)
            assert b <= od, f"{what}: bill {b!r} above on-demand cost {od!r}"
        assert_conserves(out["bills"], out["total"], what)
    print("  rotating od-capped: 32 prop cases respect caps and conserve OK")


def check_settlement_unit_cases():
    # Single user takes the whole total, to the bit.
    total = 12.3456789
    for settle in (settle_proportional, settle_od_capped):
        b = settle(total, [400], 0.5)
        assert len(b) == 1 and bits(b[0]) == bits(total), settle.__name__
    # Zero-usage fleets still conserve under the proportional fallback.
    b = settle_proportional(1.25, [0, 0, 0], 0.1)
    assert_conserves(b, 1.25, "zero-usage fallback")
    # The saturation boundary sits exactly at 2^64 (u64::MAX as f64 rounds
    # up), mirroring rust/src/broker/settlement.rs::saturating_quanta.
    below = 18_446_744_073_709_549_568.0  # 2^64 - 2048, largest f64 < 2^64
    assert saturating_quanta(below) == 18_446_744_073_709_549_568
    assert saturating_quanta(2.0**64) == 2**64 - 1
    assert saturating_quanta(float("inf")) == 2**64 - 1
    assert saturating_quanta(float("nan")) == 0
    assert saturating_quanta(-1.0) == 0
    assert saturating_quanta(0.75) == 0
    # Saturated caps still settle: one user pinned at the cap ceiling.
    b = settle_od_capped(1.0, [2**63, 4], 1e6)
    assert_conserves(b, 1.0, "saturated caps")
    print("  settlement unit cases OK")


def main():
    print("cross-validating the shared-portfolio broker against the Python port…")
    check_settlement_unit_cases()
    check_broker_table1()
    check_rotating_props()
    print("validate_broker.py: all checks passed")


if __name__ == "__main__":
    main()
