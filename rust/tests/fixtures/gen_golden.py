#!/usr/bin/env python3
"""Generator for golden_decisions.json — and the cross-validation harness
for the flat hot-path rewrite.

This script ports BOTH generations of the policy bookkeeping to Python:

* "old":  the pre-rewrite layout — dict-based excess histogram in the
          break-even scan, one VecDeque entry per purchased instance in
          every reservation queue;
* "flat": a line-by-line port of the current Rust structures — the dense
          rotating-base WindowScan (rust/src/algos/window.rs) and the
          coalesced-run RunQueue (rust/src/algos/mod.rs).

Every policy (Deterministic/Randomized/AllReserved/Separate/AllOnDemand,
plus the menu generalizations MarketDeterministic/MarketRandomized, the
PinnedSingle adapter, and the learned UcbThreshold wrapper from
algos/learned.rs) is implemented once, parameterized over the two
structure families.  The harness:

1. stress-tests flat-vs-old-vs-naive WindowScan and RunQueue behaviour on
   randomized operation streams (including histogram growth and base
   rotation far past the capacity);
2. replays every fixture case under both families and asserts the decision
   streams are identical;
3. emits rust/tests/fixtures/golden_decisions.json, pinning the per-slot
   (on_demand, reservations) streams for every PolicySpec on four
   committed markets.  rust/tests/golden_decisions.rs replays the fixture
   through the public PolicySpec::build API.

The RNG (xoshiro256** / SplitMix64), the Eq. 24 threshold sampler, and all
seed-derivation arithmetic mirror the Rust implementations exactly, so the
recorded streams are bit-exact expectations for the Rust side.
"""

import json
import math
import os

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15

# ---------------------------------------------------------------- RNG port


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


def _splitmix64(state):
    state = (state + GOLDEN) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, z ^ (z >> 31)


class Rng:
    """xoshiro256** seeded via SplitMix64 — port of util/rng.rs."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, w = _splitmix64(sm)
            s.append(w)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return self.next_u64() % n

    def chance(self, p):
        return self.f64() < p


# ---------------------------------------------------------- pricing / menu


class Pricing:
    def __init__(self, p, alpha, tau):
        self.p = p
        self.alpha = alpha
        self.tau = tau

    def beta(self):
        if self.alpha >= 1.0:
            return math.inf
        return 1.0 / (1.0 - self.alpha)


class Contract:
    def __init__(self, upfront, rate, term):
        self.upfront = upfront
        self.rate = rate
        self.term = term

    def alpha_at(self, p):
        return self.rate / p

    def beta_at(self, p):
        a = self.alpha_at(p)
        if a >= 1.0:
            return math.inf
        return self.upfront / (1.0 - a)

    def steady_cost(self):
        return self.upfront / float(self.term) + self.rate


class Market:
    """Port of pricing/market.rs: sort, dominance-prune, derive."""

    def __init__(self, p, contracts):
        idx = sorted(
            range(len(contracts)),
            key=lambda i: (contracts[i].term, contracts[i].upfront, contracts[i].rate),
        )
        entries = [contracts[i] for i in idx]

        def dominated(i, c):
            if (p - c.rate) * c.term <= c.upfront:
                return True
            for j, o in enumerate(entries):
                if j == i:
                    continue
                weakly = o.term >= c.term and o.upfront <= c.upfront and o.rate <= c.rate
                strictly = o.term > c.term or o.upfront < c.upfront or o.rate < c.rate
                if weakly and (strictly or j < i):
                    return True
            return False

        kept = [c for i, c in enumerate(entries) if not dominated(i, c)]
        self.p = p
        self.contracts = kept
        self.alphas = [c.alpha_at(p) for c in kept]
        self.betas = [c.beta_at(p) for c in kept]
        self._single = False
        self._derive()

    @classmethod
    def single(cls, pricing):
        m = cls.__new__(cls)
        m.p = pricing.p
        m.contracts = [Contract(1.0, pricing.alpha * pricing.p, pricing.tau)]
        m.alphas = [pricing.alpha]
        m.betas = [pricing.beta()]
        m._single = True
        m._derive()
        return m

    def _derive(self):
        n = len(self.contracts)
        self.steady_best = None
        if n:
            self.steady_best = min(range(n), key=lambda i: (self.contracts[i].steady_cost(), i))

    def __len__(self):
        return len(self.contracts)

    def is_single(self):
        return len(self.contracts) == 1

    def beta(self, cid):
        return self.betas[cid]

    def contract_pricing(self, cid):
        c = self.contracts[cid]
        return Pricing(self.p / c.upfront, self.alphas[cid], c.term)


def sample_z(pricing, rng):
    """Eq. 24 inverse-CDF draw — port of algos/density.rs."""
    alpha = pricing.alpha
    if alpha >= 1.0:
        return math.inf
    e = math.e
    u = rng.f64()
    if u >= (e - 1.0) / (e - 1.0 + alpha):
        return pricing.beta()
    return math.log(1.0 + u * (e - 1.0 + alpha)) / (1.0 - alpha)


# ------------------------------------------------- break-even window scans


class OldWindowScan:
    """Pre-rewrite layout: FIFO of (slot, e) + dict excess histogram."""

    def __init__(self):
        self.g = 0
        self.entries = []  # (slot, e), FIFO; list with start index
        self.start = 0
        self.hist = {}
        self.v = 0

    def violations(self):
        return self.v

    def insert(self, slot, demand, x_at_insert):
        e = demand - x_at_insert + self.g
        if e > self.g:
            self.hist[e] = self.hist.get(e, 0) + 1
            self.v += 1
            self.entries.append((slot, e))

    def expire_before(self, oldest_kept):
        while self.start < len(self.entries) and self.entries[self.start][0] < oldest_kept:
            _, e = self.entries[self.start]
            self.start += 1
            if e > self.g:
                self.hist[e] -= 1
                if self.hist[e] == 0:
                    del self.hist[e]
                self.v -= 1
        if self.start > 64 and self.start * 2 > len(self.entries):
            self.entries = self.entries[self.start :]
            self.start = 0

    def reserve(self):
        self.g += 1
        self.v -= self.hist.pop(self.g, 0)

    def buffered(self):
        return len(self.entries) - self.start


RING_MIN = 8
DENSE_MIN = 16


def _next_pow2(n):
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class FlatWindowScan:
    """Line-by-line port of the flat rust/src/algos/window.rs."""

    def __init__(self):
        self.g = 0
        self.ring_slot = []
        self.ring_e = []
        self.head = 0
        self.len = 0
        self.dense = []
        self.v = 0

    def violations(self):
        return self.v

    def insert(self, slot, demand, x_at_insert):
        e = demand - x_at_insert + self.g
        if e > self.g:
            self._push_violation(slot, e)

    def _push_violation(self, slot, e):
        off = e - self.g
        if off >= len(self.dense):
            self._grow_dense(off)
        self.dense[e & (len(self.dense) - 1)] += 1
        self.v += 1
        if self.len == len(self.ring_slot):
            self._grow_ring()
        idx = (self.head + self.len) & (len(self.ring_slot) - 1)
        self.ring_slot[idx] = slot
        self.ring_e[idx] = e
        self.len += 1

    def _grow_dense(self, min_off):
        cap = max(_next_pow2(min_off + 1), DENSE_MIN, len(self.dense) * 2)
        dense = [0] * cap
        ring_mask = len(self.ring_slot) - 1
        for i in range(self.len):
            e = self.ring_e[(self.head + i) & ring_mask]
            if e > self.g:
                dense[e & (cap - 1)] += 1
        self.dense = dense

    def _grow_ring(self):
        old_cap = len(self.ring_slot)
        cap = max(old_cap * 2, RING_MIN)
        slots = [0] * cap
        es = [0] * cap
        for i in range(self.len):
            j = (self.head + i) & (old_cap - 1)
            slots[i] = self.ring_slot[j]
            es[i] = self.ring_e[j]
        self.ring_slot = slots
        self.ring_e = es
        self.head = 0

    def expire_before(self, oldest_kept):
        while self.len > 0:
            mask = len(self.ring_slot) - 1
            if self.ring_slot[self.head] >= oldest_kept:
                break
            e = self.ring_e[self.head]
            self.head = (self.head + 1) & mask
            self.len -= 1
            if e > self.g:
                self.dense[e & (len(self.dense) - 1)] -= 1
                self.v -= 1

    def reserve(self):
        self.g += 1
        if self.dense:
            idx = self.g & (len(self.dense) - 1)
            self.v -= self.dense[idx]
            self.dense[idx] = 0

    def buffered(self):
        return self.len


class NaiveScan:
    """Literal Algorithm-1 bookkeeping with explicit x arrays (reference)."""

    def __init__(self, tau):
        self.d = []
        self.x = []
        self.tau = tau

    def insert(self, demand):
        self.d.append(demand)
        if len(self.x) < len(self.d) + self.tau:
            self.x.extend([0] * (len(self.d) + self.tau - len(self.x)))

    def violations(self, end):
        lo = max(0, end + 1 - self.tau)
        hi = min(end + 1, len(self.d))
        return sum(1 for i in range(lo, hi) if self.d[i] > self.x[i])

    def reserve(self, t):
        lo = max(0, t + 1 - self.tau)
        hi = t + self.tau - 1
        if len(self.x) <= hi:
            self.x.extend([0] * (hi + 1 - len(self.x)))
        for i in range(lo, hi + 1):
            self.x[i] += 1


# ------------------------------------------------------ reservation queues


class OldQueue:
    """Pre-rewrite layout: one deque entry per purchased instance."""

    def __init__(self):
        self.keys = []
        self.start = 0

    def push_n(self, key, n):
        self.keys.extend([key] * n)

    def push(self, key):
        self.keys.append(key)

    def expire_before(self, min_keep):
        while self.start < len(self.keys) and self.keys[self.start] < min_keep:
            self.start += 1
        if self.start > 64 and self.start * 2 > len(self.keys):
            self.keys = self.keys[self.start :]
            self.start = 0

    def active_at(self, t, tau):
        self.expire_before(max(0, t + 1 - tau))
        return self.total()

    def total(self):
        return len(self.keys) - self.start

    def count_after(self, s):
        return sum(1 for k in self.keys[self.start :] if k > s)


class RunQueue:
    """Port of the coalesced-run queue in rust/src/algos/mod.rs."""

    def __init__(self):
        self.runs = []  # (key, count); nondecreasing keys
        self.start = 0
        self._total = 0

    def push_n(self, key, n):
        if n == 0:
            return
        live = self.runs[self.start :] if self.start else self.runs
        assert not live or live[-1][0] <= key, "keys must be nondecreasing"
        if self.runs and self.start < len(self.runs) and self.runs[-1][0] == key:
            self.runs[-1] = (key, self.runs[-1][1] + n)
        else:
            self.runs.append((key, n))
        self._total += n

    def push(self, key):
        self.push_n(key, 1)

    def expire_before(self, min_keep):
        while self.start < len(self.runs) and self.runs[self.start][0] < min_keep:
            self._total -= self.runs[self.start][1]
            self.start += 1
        if self.start > 64 and self.start * 2 > len(self.runs):
            self.runs = self.runs[self.start :]
            self.start = 0

    def active_at(self, t, tau):
        self.expire_before(max(0, t + 1 - tau))
        return self._total

    def total(self):
        return self._total

    def count_after(self, s):
        n = 0
        for k, c in reversed(self.runs[self.start :]):
            if k <= s:
                break
            n += c
        return n


# ------------------------------------------------------------ the policies


class AllOnDemand:
    window = 0

    def decide(self, demand, future):
        return demand, []


class AllReserved:
    window = 0

    def __init__(self, pricing, flat):
        self.pricing = pricing
        self.cover = RunQueue() if flat else OldQueue()
        self.t = 0

    def decide(self, demand, future):
        t = self.t
        self.t += 1
        active = self.cover.active_at(t, self.pricing.tau)
        reserve = max(0, demand - active)
        self.cover.push_n(t, reserve)
        return 0, ([(0, reserve)] if reserve > 0 else [])


class Deterministic:
    """Port of algos/deterministic.rs decide()."""

    def __init__(self, pricing, z, w, flat):
        assert w < pricing.tau
        self.pricing = pricing
        self.z = z
        self.window = w
        mk_scan = FlatWindowScan if flat else OldWindowScan
        mk_q = RunQueue if flat else OldQueue
        self.scan = mk_scan()
        self.cover = mk_q()
        self.scan_res = mk_q()
        self.t = 0
        self.next_scan_slot = 0

    def decide(self, demand, future):
        t = self.t
        self.t += 1
        tau = self.pricing.tau
        p = self.pricing.p
        right = t + self.window
        self.scan.expire_before(max(0, right + 1 - tau))
        visible_end = t + min(self.window, len(future))
        while self.next_scan_slot <= visible_end:
            s = self.next_scan_slot
            d_s = demand if s == t else future[s - t - 1]
            x_ins = self.scan_res.active_at(s, tau)
            self.scan.insert(s, d_s, x_ins)
            self.next_scan_slot += 1
        reserve = 0
        while True:
            if p * self.scan.violations() <= self.z + 1e-12:
                break
            if self.window > 0 and self.cover.active_at(t, tau) >= demand:
                break
            self.scan.reserve()
            self.cover.push(t)
            self.scan_res.push(t)
            reserve += 1
        covered = self.cover.active_at(t, tau)
        on_demand = max(0, demand - covered)
        return on_demand, ([(0, reserve)] if reserve > 0 else [])


def randomized(pricing, w, seed, flat):
    """Port of Randomized::with_window — draw z, clamp, run A^w_z."""
    rng = Rng(seed)
    z = sample_z(pricing, rng)
    z_eff = z if math.isfinite(z) else 1.7976931348623157e308 / 4.0
    return Deterministic(pricing, z_eff, w, flat)


class Separate:
    """Port of baselines.rs Separate (per-level virtual users)."""

    window = 0

    class Level:
        def __init__(self, flat):
            mk_scan = FlatWindowScan if flat else OldWindowScan
            mk_q = RunQueue if flat else OldQueue
            self.scan = mk_scan()
            self.cover = mk_q()
            self.scan_res = mk_q()

    def __init__(self, pricing, flat):
        self.pricing = pricing
        self.flat = flat
        self.levels = []

        self.t = 0

    def _step_level(self, level, t, demand01):
        tau = self.pricing.tau
        beta = self.pricing.beta()
        level.scan.expire_before(max(0, t + 1 - tau))
        x_ins = level.scan_res.active_at(t, tau)
        level.scan.insert(t, demand01, x_ins)
        reserve = 0
        while self.pricing.p * level.scan.violations() > beta + 1e-12:
            level.scan.reserve()
            level.cover.push(t)
            level.scan_res.push(t)
            reserve += 1
        covered = level.cover.active_at(t, tau)
        return reserve, max(0, demand01 - min(covered, demand01))

    def decide(self, demand, future):
        t = self.t
        self.t += 1
        while len(self.levels) < demand:
            self.levels.append(Separate.Level(self.flat))
        reserve = 0
        on_demand = 0
        for k, level in enumerate(self.levels):
            d_k = 1 if k < demand else 0
            if d_k == 0 and level.scan.violations() == 0:
                continue
            r, od = self._step_level(level, t, d_k)
            reserve += r
            on_demand += od
        return on_demand, ([(0, reserve)] if reserve > 0 else [])


class MarketDeterministic:
    """Port of algos/market.rs decide() with the kernels sweeps inlined."""

    def __init__(self, market, thresholds, w, flat):
        k = len(market)
        assert w == 0 or all(w < c.term for c in market.contracts)
        self.market = market
        self.thresholds = thresholds
        self.window = w
        self.terms = [c.term for c in market.contracts]
        self.betas = [market.beta(j) for j in range(k)]
        self.steady = [c.steady_cost() for c in market.contracts]
        mk_scan = FlatWindowScan if flat else OldWindowScan
        mk_q = RunQueue if flat else OldQueue
        self.scans = [mk_scan() for _ in range(k)]
        self.res_times = [mk_q() for _ in range(k)]
        self.cover = [mk_q() for _ in range(k)]
        self.t = 0
        self.next_scan_slot = 0

    @classmethod
    def with_window(cls, market, w, flat):
        th = [market.beta(j) for j in range(len(market))]
        return cls(market, th, w, flat)

    def _pick_triggered(self, p, viol):
        best = None
        best_cost = math.inf
        for j in range(len(viol)):
            triggered = p * viol[j] > self.thresholds[j] + 1e-12
            if triggered and self.steady[j] < best_cost:
                best = j
                best_cost = self.steady[j]
        return best

    def decide(self, demand, future):
        t = self.t
        self.t += 1
        k = len(self.market)
        p = self.market.p

        covered_now = 0
        for q in self.cover:
            q.expire_before(t + 1)
            covered_now += q.total()
        right = t + self.window
        for scan, term in zip(self.scans, self.terms):
            scan.expire_before(max(0, right + 1 - term))
        visible_end = t + min(self.window, len(future))
        while self.next_scan_slot <= visible_end:
            s = self.next_scan_slot
            d_s = demand if s == t else future[s - t - 1]
            cov_s = covered_now if s == t else sum(q.count_after(s) for q in self.cover)
            for j in range(k):
                own = self.res_times[j].active_at(s, self.terms[j])
                x_ins = max(own, cov_s)
                self.scans[j].insert(s, d_s, x_ins)
            self.next_scan_slot += 1

        counts = [0] * k
        cov = covered_now
        viol = [s.violations() for s in self.scans]
        while True:
            j = self._pick_triggered(p, viol)
            if j is None:
                break
            if self.window > 0 and cov >= demand:
                break
            self.cover[j].push(t + self.terms[j])
            cov += 1
            counts[j] += 1
            cap = self.betas[j]
            for i in range(k):
                if self.betas[i] <= cap:
                    self.scans[i].reserve()
                    self.res_times[i].push(t)
            viol = [s.violations() for s in self.scans]

        out = [(j, counts[j]) for j in range(k) if counts[j] > 0]
        return max(0, demand - cov), out


def market_randomized(market, w, seed, flat):
    """Port of MarketRandomized::with_window threshold derivation."""
    thresholds = []
    for cid in range(len(market)):
        rng = Rng(seed ^ ((cid * GOLDEN) & MASK))
        z = sample_z(market.contract_pricing(cid), rng)
        if math.isfinite(z):
            z_abs = z * market.contracts[cid].upfront
        else:
            z_abs = 1.7976931348623157e308 / 4.0
        thresholds.append(z_abs)
    return MarketDeterministic(market, thresholds, w, flat)


# ------------------------------------------------- learned.rs (UCB) port

ARM_MULTIPLIERS = [0.5, 0.75, 1.0, 1.25, 1.5]
ARMS = len(ARM_MULTIPLIERS)
SEED_ARM = 2  # the multiplier-1.0 arm: plain Algorithm 1 on the menu
EPOCH_MIN = 8
EPOCH_MAX = 256


def per_user_seed(base, user_id):
    """Port of sim/mod.rs per_user_seed — the one seed-derivation formula."""
    return (base ^ (user_id << 17)) & MASK


def exploration_order(seed):
    """UcbThreshold::exploration_order: seed arm first, rest seed-shuffled
    with the util/rng.rs Fisher-Yates loop (high index down, below(i+1))."""
    rest = [a for a in range(ARMS) if a != SEED_ARM]
    rng = Rng(seed)
    for i in range(len(rest) - 1, 0, -1):
        j = rng.below(i + 1)
        rest[i], rest[j] = rest[j], rest[i]
    return [SEED_ARM] + rest


class UcbThreshold:
    """Port of algos/learned.rs UcbThreshold over MarketDeterministic."""

    window = 0

    def __init__(self, market, seed, flat):
        terms = [c.term for c in market.contracts]
        self.epoch_len = min(max(min(terms) if terms else EPOCH_MAX, EPOCH_MIN), EPOCH_MAX)
        self.market = market
        self.p = market.p
        self.upfronts = [c.upfront for c in market.contracts]
        rates = [c.rate for c in market.contracts]
        self.min_rate = min(min(rates) if rates else math.inf, market.p)
        self.flat = flat
        self.reseed(seed)

    def reseed(self, seed):
        self.seed = seed
        self.order = exploration_order(seed)
        self.arm = self.order[0]
        self.pulls = [0] * ARMS
        self.reward_sum = [0.0] * ARMS
        self.epochs_done = 0
        self.slot_in_epoch = 0
        self.epoch_cost = 0.0
        self.epoch_od_cost = 0.0
        # Rust resets the inner policy in place; rebuilding is the same
        # state by the reset-equals-fresh invariant its tests pin.
        self.inner = MarketDeterministic.with_window(self.market, 0, self.flat)
        self.apply_arm()

    def apply_arm(self):
        mult = ARM_MULTIPLIERS[self.arm]
        for j in range(len(self.market)):
            self.inner.thresholds[j] = mult * self.market.beta(j)

    def select_arm(self):
        for a in self.order:
            if self.pulls[a] == 0:
                return a
        ln_n = math.log(float(self.epochs_done))
        best, best_idx = 0, -math.inf
        for a in range(ARMS):
            mean = self.reward_sum[a] / float(self.pulls[a])
            idx = mean + math.sqrt(2.0 * ln_n / float(self.pulls[a]))
            if idx > best_idx:
                best_idx = idx
                best = a
        return best

    def finish_epoch(self):
        if self.epoch_od_cost > 0.0:
            reward = max(-1.0, min(1.0, 1.0 - self.epoch_cost / self.epoch_od_cost))
        else:
            reward = 0.0
        self.pulls[self.arm] += 1
        self.reward_sum[self.arm] += reward
        self.epochs_done += 1
        self.epoch_cost = 0.0
        self.epoch_od_cost = 0.0
        self.slot_in_epoch = 0

    def decide(self, demand, future):
        if self.slot_in_epoch == 0:
            self.arm = self.select_arm()
            self.apply_arm()
        od, res = self.inner.decide(demand, [])
        fees = 0.0
        for j, n in res:
            fees += self.upfronts[j] * float(n)
        served_reserved = max(0, demand - od)
        self.epoch_cost += fees + self.p * float(od) + self.min_rate * float(served_reserved)
        self.epoch_od_cost += self.p * float(demand)
        self.slot_in_epoch += 1
        if self.slot_in_epoch == self.epoch_len:
            self.finish_epoch()
        return od, res


class PinnedSingle:
    def __init__(self, inner, cid):
        self.inner = inner
        self.cid = cid
        self.window = inner.window

    def decide(self, demand, future):
        od, res = self.inner.decide(demand, future)
        reserve = sum(n for _, n in res)
        return od, ([(self.cid, reserve)] if reserve > 0 else [])


# ------------------------------------------------------ PolicySpec::build


def build_policy(spec, market, user_id, flat):
    """Port of sim/fleet.rs PolicySpec::build."""
    kind = spec["kind"]
    if kind == "Ucb":
        # learned policies dispatch on the full market, single or menu
        return UcbThreshold(market, per_user_seed(spec["seed"], user_id), flat)
    if market.is_single():
        pricing = market.contract_pricing(0)
        if kind == "AllOnDemand":
            return AllOnDemand()
        if kind == "AllReserved":
            return AllReserved(pricing, flat)
        if kind == "Separate":
            return Separate(pricing, flat)
        if kind == "Deterministic":
            return Deterministic(pricing, pricing.beta(), spec["window"], flat)
        if kind == "Randomized":
            return randomized(pricing, spec["window"], per_user_seed(spec["seed"], user_id), flat)
        raise ValueError(kind)
    pin = market.steady_best
    if kind == "AllOnDemand":
        return AllOnDemand()
    if kind == "AllReserved":
        return PinnedSingle(AllReserved(market.contract_pricing(pin), flat), pin)
    if kind == "Separate":
        return PinnedSingle(Separate(market.contract_pricing(pin), flat), pin)
    if kind == "Deterministic":
        return MarketDeterministic.with_window(market, spec["window"], flat)
    if kind == "Randomized":
        return market_randomized(market, spec["window"], per_user_seed(spec["seed"], user_id), flat)
    raise ValueError(kind)


def replay(policy, demands):
    """Drive a policy over a demand trace with window-aware futures."""
    w = policy.window
    od = []
    res = []
    for t, d in enumerate(demands):
        hi = min(t + 1 + w, len(demands))
        fut = demands[t + 1 : hi] if w > 0 else []
        o, r = policy.decide(d, fut)
        od.append(o)
        for cid, n in r:
            res.append([t, cid, n])
    return od, res


# ------------------------------------------------------- cross-validation


def stress_window_scans():
    """Flat vs old vs naive on randomized op streams, incl. growth paths."""
    rng = Rng(0xA11CE)
    cases = 0
    for tau in [1, 2, 3, 5, 7, 16, 64, 350]:
        for rep in range(6):
            t_len = 400 if tau >= 16 else 80
            flat = FlatWindowScan()
            old = OldWindowScan()
            naive = NaiveScan(tau)
            res_times = RunQueue()
            for t in range(t_len):
                if rng.chance(0.1):
                    d = 16 + rng.below(200)  # spike past DENSE_MIN -> grow
                else:
                    d = rng.below(6)
                naive.insert(d)
                x_ins = res_times.active_at(t, tau)
                for s in (flat, old):
                    s.expire_before(max(0, t + 1 - tau))
                    s.insert(t, d, x_ins)
                assert flat.violations() == old.violations() == naive.violations(t), (
                    f"insert mismatch tau={tau} rep={rep} t={t}: "
                    f"flat={flat.violations()} old={old.violations()} "
                    f"naive={naive.violations(t)}"
                )
                n_res = rng.below(4) if rng.chance(0.35) else 0
                for _ in range(n_res):
                    flat.reserve()
                    old.reserve()
                    naive.reserve(t)
                    res_times.push(t)
                    assert flat.violations() == old.violations() == naive.violations(t)
                assert flat.buffered() == old.buffered()
            cases += 1
    print(f"  window-scan stress: {cases} cases OK (flat == old == naive)")


def stress_ucb():
    """UCB arm-machinery invariants: exploration orders, reseed == fresh,
    flat == old decision streams, epoch accounting."""
    market = Market(0.05, [Contract(1.0, 0.025, 100), Contract(1.5, 0.01, 300)])
    orders = set()
    for seed in range(64):
        o = exploration_order(seed)
        assert o[0] == SEED_ARM and sorted(o) == list(range(ARMS)), o
        orders.add(tuple(o))
    assert len(orders) > 1, "exploration order ignores the seed"
    rng = Rng(0x0CB)
    demands = [int(rng.below(6)) for _ in range(1500)]
    dirty = UcbThreshold(market, 1, True)
    replay(dirty, demands)
    dirty.reseed(7)
    fresh = UcbThreshold(market, 7, True)
    old = UcbThreshold(market, 7, False)
    d_out = replay(dirty, demands)
    f_out = replay(fresh, demands)
    o_out = replay(old, demands)
    assert d_out == f_out, "reseed(7) diverged from a fresh UCB instance"
    assert f_out == o_out, "UCB streams diverged between flat and old layouts"
    # every finished epoch lands in exactly one arm's pull count
    assert sum(fresh.pulls) == fresh.epochs_done == len(demands) // fresh.epoch_len
    assert all(n > 0 for n in fresh.pulls), f"unexplored arms: {fresh.pulls}"
    print("  ucb stress: exploration orders, reseed==fresh, flat==old, epochs OK")


def stress_run_queues():
    """RunQueue vs per-instance queue under both key conventions."""
    rng = Rng(0xB0B)
    for rep in range(40):
        a, b = RunQueue(), OldQueue()
        tau = 1 + rng.below(9)
        key = 0
        for _ in range(300):
            op = rng.below(4)
            if op == 0:
                key += rng.below(3)
                n = rng.below(4)
                a.push_n(key, n)
                b.push_n(key, n)
            elif op == 1:
                t = key + rng.below(5)
                assert a.active_at(t, tau) == b.active_at(t, tau), f"rep={rep}"
            elif op == 2:
                s = key - rng.below(6)
                assert a.count_after(s) == b.count_after(s), f"rep={rep}"
            else:
                m = key - rng.below(4)
                a.expire_before(m)
                b.expire_before(m)
                assert a.total() == b.total(), f"rep={rep}"
    print("  run-queue stress: 40 cases OK (coalesced == per-instance)")


# ----------------------------------------------------------- the fixtures

USER_ID = 3


def gen_demands(seed, t_len, zero_p, lo_span, spike_p=0.0, spike_span=0):
    rng = Rng(seed)
    out = []
    for _ in range(t_len):
        if rng.chance(zero_p):
            out.append(0)
        elif spike_p and rng.chance(spike_p):
            out.append(1 + int(rng.below(spike_span)))
        else:
            out.append(1 + int(rng.below(lo_span)))
    return out


def fixture_markets():
    """Four committed markets: the two paper-scale menus plus two
    short-term ones whose reservations expire inside the trace (the expiry
    paths are where the coalesced-run bookkeeping actually runs)."""
    return {
        "single": {
            "kind": "single",
            "p": 0.08 / 69.0,  # EC2 Standard Small, Sec. II-A
            "alpha": 0.4875,
            "tau": 8760,
            "demands": gen_demands(0xD0_0001, 2200, 0.08, 3),
        },
        "menu2": {
            "kind": "menu",
            "p": 0.01,
            "contracts": [[1.0, 0.004, 600], [1.5, 0.002, 1800]],
            "demands": gen_demands(0xD0_0002, 450, 0.1, 2, spike_p=0.05, spike_span=3),
        },
        "single_small": {
            "kind": "single",
            "p": 0.2,
            "alpha": 0.2,
            "tau": 6,
            "demands": gen_demands(0xD0_0003, 150, 0.2, 4),
        },
        "menu_small": {
            "kind": "menu",
            "p": 0.1,
            "contracts": [[0.3, 0.0, 5], [0.9, 0.0, 30]],
            "demands": gen_demands(0xD0_0004, 120, 0.25, 3),
        },
    }


def fixture_specs(w):
    return [
        {"kind": "AllOnDemand"},
        {"kind": "AllReserved"},
        {"kind": "Separate"},
        {"kind": "Deterministic", "window": 0},
        {"kind": "Randomized", "window": 0, "seed": 1},
        {"kind": "Deterministic", "window": w},
        {"kind": "Randomized", "window": w, "seed": 9},
        {"kind": "Ucb", "seed": 5},
    ]


def build_market(desc):
    if desc["kind"] == "single":
        return Market.single(Pricing(desc["p"], desc["alpha"], desc["tau"]))
    return Market(desc["p"], [Contract(u, r, t) for u, r, t in desc["contracts"]])


def main():
    print("cross-validating flat structures against the pre-rewrite layout…")
    stress_window_scans()
    stress_run_queues()
    stress_ucb()

    markets = fixture_markets()
    cases = []
    total_res = 0
    for mname, desc in markets.items():
        market = build_market(desc)
        # windows must undercut every term on the menu
        min_term = min(c.term for c in market.contracts)
        w = min(4, min_term - 1)
        for spec in fixture_specs(w):
            demands = desc["demands"]
            od_flat, res_flat = replay(build_policy(spec, market, USER_ID, True), demands)
            od_old, res_old = replay(build_policy(spec, market, USER_ID, False), demands)
            assert od_flat == od_old and res_flat == res_old, (
                f"decision stream diverged: market={mname} spec={spec}"
            )
            total_res += sum(n for _, _, n in res_flat)
            cases.append(
                {
                    "market": mname,
                    "spec": spec,
                    "od": od_flat,
                    "reservations": res_flat,
                }
            )
    # the fixture must actually exercise the reservation machinery
    assert total_res > 50, f"suspiciously few reservations pinned: {total_res}"
    per_market = {m: 0 for m in markets}
    for c in cases:
        per_market[c["market"]] += sum(n for _, _, n in c["reservations"])
    for m, n in per_market.items():
        assert n > 0, f"market {m} pinned no reservations"
    print(f"  policy streams: {len(cases)} cases OK (flat == old), "
          f"{total_res} reservations pinned {per_market}")

    fixture = {
        "comment": "generated by gen_golden.py — decision streams recorded from "
        "the pre-rewrite bookkeeping (dict histogram + per-instance queues), "
        "cross-checked against the flat structures; do not hand-edit",
        "user_id": USER_ID,
        "markets": markets,
        "cases": cases,
    }
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden_decisions.json")
    with open(out, "w") as f:
        json.dump(fixture, f, separators=(",", ":"))
        f.write("\n")
    print(f"wrote {out} ({os.path.getsize(out) // 1024} KiB)")


if __name__ == "__main__":
    main()
