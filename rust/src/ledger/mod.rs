//! Reservation ledger and billing engine over a [`Market`] menu.
//!
//! The ledger tracks *actual* reservations (not the phantom bookkeeping the
//! online algorithms use internally) **per contract id**, exposes the
//! number of reservations active at the current slot, and accumulates the
//! exact cost decomposition generalizing problem (1):
//!
//! ```text
//! C = Σ_t  o_t·p  +  Σ_j r_{j,t}·upfront_j  +  Σ_j rate_j·(reserved use on j)
//! ```
//!
//! Reserved usage is billed against the **cheapest applicable** active
//! reservation first (ascending usage rate — [`Market::rate_order`]).
//!
//! It also verifies the feasibility constraint
//! `o_t + Σ_j active_j(t) ≥ d_t` on every slot, so any policy bug that
//! under-provisions is caught at billing time, and — for single-contract
//! markets — it maintains the cost identity `C = n + (1−α)·Od + α·S`
//! (Eq. 34) used by tests. [`Ledger::single`] embeds a classic [`Pricing`]
//! via [`Market::single`]; that path is bit-identical to the v1 billing
//! arithmetic (`upfront = 1`, `rate = α·p`).

use crate::algos::{Decision, RunQueue, SaveState};
use crate::pricing::{ContractId, Market, Pricing};
use crate::util::state::{StateReader, StateWriter};

/// Errors surfaced by the billing engine. (Display/Error are hand-written:
/// `thiserror` is not in the offline vendor set.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LedgerError {
    Underprovisioned { t: usize, d: u32, o: u32, active: u32 },
    OverOnDemand { t: usize, o: u32, d: u32 },
    /// A decision referenced a contract id outside the market menu.
    UnknownContract { t: usize, contract: ContractId },
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            LedgerError::Underprovisioned { t, d, o, active } => write!(
                f,
                "slot {t}: demand {d} exceeds on-demand {o} + active reservations {active}"
            ),
            LedgerError::OverOnDemand { t, o, d } => write!(
                f,
                "slot {t}: on-demand count {o} exceeds demand {d} (wasteful decision rejected)"
            ),
            LedgerError::UnknownContract { t, contract } => write!(
                f,
                "slot {t}: decision references contract {contract} outside the market menu"
            ),
        }
    }
}

impl std::error::Error for LedgerError {}

/// Itemized cost report for one simulated user / policy run. Costs are in
/// market currency (for [`Ledger::single`], the normalized fee unit).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostReport {
    /// Total cost.
    pub total: f64,
    /// Upfront fees paid (for single-contract normalized markets this
    /// equals the number of reservations).
    pub reservation_fees: f64,
    /// On-demand running costs Σ o_t p.
    pub on_demand_cost: f64,
    /// Discounted reserved running costs.
    pub reserved_usage_cost: f64,
    /// Number of reservations made (all contracts).
    pub reservations: u64,
    /// Total instance-slots served on demand.
    pub on_demand_slots: u64,
    /// Total instance-slots served by reservations.
    pub reserved_slots: u64,
    /// Total demand instance-slots.
    pub demand_slots: u64,
    /// Peak simultaneous active reservations (all contracts).
    pub peak_active: u32,
    /// Slots processed.
    pub slots: usize,
}

impl CostReport {
    /// `S` from the paper: cost of serving everything on demand.
    pub fn all_on_demand_cost(&self, pricing: &Pricing) -> f64 {
        pricing.p * self.demand_slots as f64
    }

    /// Check Eq. (34): `C = n + (1−α)·Od + α·S` (floating tolerance).
    /// Meaningful for single-contract normalized markets.
    pub fn identity_holds(&self, pricing: &Pricing, tol: f64) -> bool {
        let s = self.all_on_demand_cost(pricing);
        let rhs = self.reservations as f64
            + (1.0 - pricing.alpha) * self.on_demand_cost
            + pricing.alpha * s;
        (self.total - rhs).abs() <= tol * (1.0 + self.total.abs())
    }
}

/// The reservation ledger + billing engine. Drive it slot by slot with the
/// policy's typed decisions.
#[derive(Debug, Clone)]
pub struct Ledger {
    market: Market,
    /// Expiry slots (exclusive) of active reservations, one FIFO run queue
    /// per contract id — reservations of a contract are acquired in time
    /// order, so each queue's front run expires first, and a purchase batch
    /// of `n` instances occupies one `(expiry, n)` run instead of `n`
    /// entries.
    active: Vec<RunQueue>,
    /// Next slot to bill (slots must be billed consecutively from 0).
    t: usize,
    report: CostReport,
}

impl Ledger {
    pub fn new(market: Market) -> Ledger {
        let k = market.len();
        Ledger {
            market,
            active: (0..k).map(|_| RunQueue::default()).collect(),
            t: 0,
            report: CostReport::default(),
        }
    }

    /// Single-contract convenience: bill a classic [`Pricing`] through the
    /// bit-identical [`Market::single`] embedding.
    pub fn single(pricing: Pricing) -> Ledger {
        Ledger::new(Market::single(pricing))
    }

    pub fn market(&self) -> &Market {
        &self.market
    }

    /// Number of reservations (across all contracts) that can serve demand
    /// at the *current* slot.
    pub fn active_now(&mut self) -> u32 {
        let t = self.t;
        let mut total = 0u32;
        for q in self.active.iter_mut() {
            q.expire_before(t + 1);
            total += q.total();
        }
        total
    }

    /// Current slot index.
    pub fn now(&self) -> usize {
        self.t
    }

    /// Bill one slot with a typed decision: register the decision's new
    /// reservations at slot `t`, run `decision.on_demand` instances on
    /// demand, and serve `demand − on_demand` instances on active
    /// reservations, cheapest usage rate first. Advances the clock.
    pub fn bill(&mut self, demand: u32, decision: &Decision<'_>) -> Result<(), LedgerError> {
        let t = self.t;
        let on_demand = decision.on_demand;
        if on_demand > demand {
            return Err(LedgerError::OverOnDemand { t, o: on_demand, d: demand });
        }
        // Validate the whole decision before mutating anything, so a
        // recoverable error leaves no unpaid phantom reservations behind.
        for &(cid, _) in decision.reservations {
            if cid >= self.market.len() {
                return Err(LedgerError::UnknownContract { t, contract: cid });
            }
        }
        // Feasibility: new reservations (active from t, term >= 1) plus
        // surviving old ones must cover the non-on-demand remainder.
        let active = self.active_now() + decision.total_reserved();
        let reserved_use = demand - on_demand;
        if reserved_use > active {
            return Err(LedgerError::Underprovisioned { t, d: demand, o: on_demand, active });
        }
        // Register new reservations: contract j active for [t, t+term_j-1].
        let mut fees = 0.0f64;
        let mut new_count = 0u64;
        for &(cid, n) in decision.reservations {
            let c = self.market.contract(cid);
            self.active[cid].push_n(t + c.term, n); // one run per purchase batch
            fees += n as f64 * c.upfront;
            new_count += n as u64;
        }

        let p = self.market.p();
        let od = on_demand as f64 * p;
        // Serve reserved usage against the cheapest applicable contract
        // first (ascending usage rate).
        let mut ru = 0.0f64;
        let mut rem = reserved_use;
        for &cid in self.market.rate_order() {
            if rem == 0 {
                break;
            }
            let avail = self.active[cid].total();
            let take = rem.min(avail);
            ru += self.market.contract(cid).rate * take as f64;
            rem -= take;
        }

        let r = &mut self.report;
        r.reservation_fees += fees;
        r.on_demand_cost += od;
        r.reserved_usage_cost += ru;
        r.total += fees + od + ru;
        r.reservations += new_count;
        r.on_demand_slots += on_demand as u64;
        r.reserved_slots += reserved_use as u64;
        r.demand_slots += demand as u64;
        r.peak_active = r.peak_active.max(active);
        r.slots += 1;

        self.t += 1;
        Ok(())
    }

    /// Single-contract shorthand: `reserve_new` reservations of contract 0
    /// plus `on_demand` on-demand instances. The low-level entry point for
    /// callers still speaking the v1 vocabulary; contract 0 is the whole
    /// menu of a [`Ledger::single`].
    pub fn bill_slot(
        &mut self,
        demand: u32,
        reserve_new: u32,
        on_demand: u32,
    ) -> Result<(), LedgerError> {
        let res = [(0usize, reserve_new)];
        let decision =
            Decision { on_demand, reservations: &res[..usize::from(reserve_new > 0)] };
        self.bill(demand, &decision)
    }

    /// Final report.
    pub fn report(&self) -> CostReport {
        self.report
    }

    /// Rewind to slot 0 with an empty report, keeping the market and the
    /// per-contract queue allocations — after `reset()` the ledger bills
    /// bit-identically to a fresh `Ledger::new(market)` (the fleet engine
    /// reuses one ledger across every user in a shard).
    pub fn reset(&mut self) {
        for q in &mut self.active {
            q.clear();
        }
        self.t = 0;
        self.report = CostReport::default();
    }
}

impl SaveState for Ledger {
    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.active.len());
        for q in &self.active {
            q.save_state(w);
        }
        w.usize(self.t);
        let r = &self.report;
        w.f64_bits(r.total);
        w.f64_bits(r.reservation_fees);
        w.f64_bits(r.on_demand_cost);
        w.f64_bits(r.reserved_usage_cost);
        w.u64(r.reservations);
        w.u64(r.on_demand_slots);
        w.u64(r.reserved_slots);
        w.u64(r.demand_slots);
        w.u32(r.peak_active);
        w.usize(r.slots);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let k = r.usize()?;
        anyhow::ensure!(
            k == self.active.len(),
            "checkpoint has {} contract queues, ledger has {}",
            k,
            self.active.len()
        );
        for q in &mut self.active {
            q.restore_state(r)?;
        }
        self.t = r.usize()?;
        self.report = CostReport {
            total: r.f64_bits()?,
            reservation_fees: r.f64_bits()?,
            on_demand_cost: r.f64_bits()?,
            reserved_usage_cost: r.f64_bits()?,
            reservations: r.u64()?,
            on_demand_slots: r.u64()?,
            reserved_slots: r.u64()?,
            demand_slots: r.u64()?,
            peak_active: r.u32()?,
            slots: r.usize()?,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::Contract;

    fn pricing() -> Pricing {
        Pricing::normalized(0.1, 0.5, 3)
    }

    #[test]
    fn bills_on_demand_only() {
        let mut l = Ledger::single(pricing());
        for _ in 0..10 {
            l.bill_slot(2, 0, 2).unwrap();
        }
        let r = l.report();
        assert!((r.total - 10.0 * 2.0 * 0.1).abs() < 1e-12);
        assert_eq!(r.reservations, 0);
        assert_eq!(r.on_demand_slots, 20);
        assert_eq!(r.demand_slots, 20);
    }

    #[test]
    fn reservation_expires_after_tau() {
        let mut l = Ledger::single(pricing());
        l.bill_slot(1, 1, 0).unwrap(); // reserve at t=0, covers t=0,1,2
        assert_eq!(l.active_now(), 1);
        l.bill_slot(1, 0, 0).unwrap(); // t=1 reserved
        l.bill_slot(1, 0, 0).unwrap(); // t=2 reserved
        // t=3: reservation expired, must use on-demand
        assert_eq!(l.active_now(), 0);
        let err = l.bill_slot(1, 0, 0).unwrap_err();
        assert!(matches!(err, LedgerError::Underprovisioned { t: 3, .. }));
    }

    #[test]
    fn cost_decomposition_example() {
        // reserve 1 at t=0, serve d=1 for 3 slots reserved, then 1 on demand.
        let mut l = Ledger::single(pricing());
        l.bill_slot(1, 1, 0).unwrap();
        l.bill_slot(1, 0, 0).unwrap();
        l.bill_slot(1, 0, 0).unwrap();
        l.bill_slot(1, 0, 1).unwrap();
        let r = l.report();
        // fee 1 + 3 * (0.5*0.1) + 1 * 0.1
        assert!((r.total - (1.0 + 0.15 + 0.1)).abs() < 1e-12);
        assert!(r.identity_holds(&pricing(), 1e-9));
    }

    #[test]
    fn rejects_overprovisioned_on_demand() {
        let mut l = Ledger::single(pricing());
        let err = l.bill_slot(1, 0, 2).unwrap_err();
        assert!(matches!(err, LedgerError::OverOnDemand { .. }));
    }

    #[test]
    fn multi_reservation_overlap() {
        let mut l = Ledger::single(pricing());
        l.bill_slot(1, 1, 0).unwrap(); // res A t=0..2
        l.bill_slot(3, 2, 0).unwrap(); // res B,C t=1..3, active=3
        assert_eq!(l.active_now(), 3);
        l.bill_slot(3, 0, 0).unwrap(); // t=2 all reserved
        // t=3: A expired; B,C active
        assert_eq!(l.active_now(), 2);
        l.bill_slot(3, 0, 1).unwrap();
        let r = l.report();
        assert_eq!(r.reservations, 3);
        assert_eq!(r.peak_active, 3);
        assert!(r.identity_holds(&pricing(), 1e-9));
    }

    #[test]
    fn zero_demand_slots_are_free_without_actions() {
        let mut l = Ledger::single(pricing());
        for _ in 0..5 {
            l.bill_slot(0, 0, 0).unwrap();
        }
        assert_eq!(l.report().total, 0.0);
    }

    #[test]
    fn identity_holds_on_mixed_run() {
        let pr = Pricing::normalized(0.07, 0.3, 4);
        let mut l = Ledger::single(pr);
        let demands = [0u32, 2, 5, 1, 0, 7, 3, 3, 2, 1, 4, 0];
        let mut rng = crate::util::rng::Rng::new(5);
        for &d in &demands {
            let active = l.active_now();
            // random feasible decision
            let max_new = 3u32;
            let rnew = (rng.below(max_new as u64 + 1) as u32).min(d.saturating_sub(active) + 1);
            let covered = (active + rnew).min(d);
            let od = d - covered;
            l.bill_slot(d, rnew, od).unwrap();
        }
        assert!(l.report().identity_holds(&pr, 1e-9));
    }

    fn two_term_market() -> Market {
        // dear-rate short contract + cheap-rate long contract; both survive
        // dominance pruning ((p - rate) * term > upfront on each).
        Market::new(
            0.1,
            vec![
                Contract { upfront: 0.2, rate: 0.03, term: 4 },
                Contract { upfront: 0.8, rate: 0.01, term: 10 },
            ],
        )
    }

    #[test]
    fn multi_contract_bills_cheapest_rate_first() {
        let m = two_term_market();
        assert_eq!(m.len(), 2);
        assert_eq!(m.rate_order(), &[1, 0]);
        let mut l = Ledger::new(m);
        // one reservation of each contract, demand 1: usage must be billed
        // at the cheap 0.01 rate, not 0.03.
        let res = [(0usize, 1u32), (1usize, 1u32)];
        l.bill(1, &Decision { on_demand: 0, reservations: &res }).unwrap();
        let r = l.report();
        assert!((r.reservation_fees - 1.0).abs() < 1e-12);
        assert!((r.reserved_usage_cost - 0.01).abs() < 1e-12, "{r:?}");
        // demand 2: both reservations used: 0.01 + 0.03 more
        l.bill(2, &Decision { on_demand: 0, reservations: &[] }).unwrap();
        assert!((l.report().reserved_usage_cost - (0.01 + 0.04)).abs() < 1e-12);
    }

    #[test]
    fn multi_contract_per_term_expiry() {
        let mut l = Ledger::new(two_term_market());
        let res = [(0usize, 1u32), (1usize, 1u32)];
        l.bill(2, &Decision { on_demand: 0, reservations: &res }).unwrap(); // t=0
        for _ in 1..4 {
            l.bill(2, &Decision { on_demand: 0, reservations: &[] }).unwrap();
        }
        // t=4: the term-4 contract expired, only the term-10 one remains
        assert_eq!(l.active_now(), 1);
        let err = l.bill(2, &Decision { on_demand: 0, reservations: &[] }).unwrap_err();
        assert!(matches!(err, LedgerError::Underprovisioned { t: 4, active: 1, .. }));
    }

    #[test]
    fn unknown_contract_is_rejected_without_side_effects() {
        let mut l = Ledger::new(two_term_market());
        // valid entry listed first must NOT register before the bad id fails
        let res = [(0usize, 2u32), (7usize, 1u32)];
        let err = l.bill(2, &Decision { on_demand: 0, reservations: &res }).unwrap_err();
        assert!(matches!(err, LedgerError::UnknownContract { t: 0, contract: 7 }));
        assert_eq!(l.active_now(), 0, "no phantom reservations after a rejected decision");
        assert_eq!(l.report(), CostReport::default());
        // the slot can be re-billed cleanly with a corrected decision
        let fixed = [(0usize, 2u32)];
        l.bill(2, &Decision { on_demand: 0, reservations: &fixed }).unwrap();
        assert_eq!(l.report().reservations, 2);
        assert!((l.report().reservation_fees - 0.4).abs() < 1e-12);
    }

    #[test]
    fn underprovisioned_is_rejected_without_side_effects() {
        let mut l = Ledger::new(two_term_market());
        // 1 new reservation cannot cover reserved_use = 2
        let res = [(0usize, 1u32)];
        let err = l.bill(2, &Decision { on_demand: 0, reservations: &res }).unwrap_err();
        assert!(matches!(err, LedgerError::Underprovisioned { t: 0, active: 1, .. }));
        assert_eq!(l.active_now(), 0, "no phantom reservations after a rejected decision");
        assert_eq!(l.report(), CostReport::default());
        // corrected decision re-bills the same slot cleanly
        l.bill(2, &Decision { on_demand: 1, reservations: &res }).unwrap();
        assert_eq!(l.report().reservations, 1);
    }

    #[test]
    fn reset_is_equivalent_to_fresh_ledger() {
        let m = two_term_market();
        let mut reused = Ledger::new(m.clone());
        let res = [(0usize, 2u32)];
        reused.bill(2, &Decision { on_demand: 0, reservations: &res }).unwrap();
        reused.bill(1, &Decision { on_demand: 1, reservations: &[] }).unwrap();
        reused.reset();
        let mut fresh = Ledger::new(m);
        for l in [&mut reused, &mut fresh] {
            l.bill(2, &Decision { on_demand: 1, reservations: &res[..1] }).unwrap();
            l.bill(0, &Decision { on_demand: 0, reservations: &[] }).unwrap();
        }
        assert_eq!(reused.report(), fresh.report());
        assert_eq!(reused.report().total.to_bits(), fresh.report().total.to_bits());
    }

    #[test]
    fn save_restore_continues_billing_bit_identically() {
        let m = two_term_market();
        let mut orig = Ledger::new(m.clone());
        let res = [(0usize, 2u32), (1usize, 1u32)];
        orig.bill(3, &Decision { on_demand: 0, reservations: &res }).unwrap();
        orig.bill(2, &Decision { on_demand: 1, reservations: &[] }).unwrap();

        let mut w = StateWriter::new();
        orig.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut copy = Ledger::new(m);
        copy.bill(1, &Decision { on_demand: 1, reservations: &[] }).unwrap(); // stale
        let mut r = StateReader::new(&bytes);
        copy.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(copy.report(), orig.report());

        for l in [&mut orig, &mut copy] {
            l.bill(2, &Decision { on_demand: 0, reservations: &[] }).unwrap();
            l.bill(3, &Decision { on_demand: 1, reservations: &[] }).unwrap();
        }
        assert_eq!(copy.report().total.to_bits(), orig.report().total.to_bits());
        assert_eq!(copy.report(), orig.report());
    }

    /// A checkpoint byte-crafted exactly as the pre-coalescing ledger wrote
    /// it — **one usize expiry key per active instance** — must restore
    /// into the run-coalesced queues, re-serialize to the identical bytes,
    /// and keep billing with the same expiry schedule.
    #[test]
    fn pre_rewrite_blob_restores_byte_exactly() {
        // tau = 3; two instances bought at t=2 (expiry key 5) and one at
        // t=3 (key 6), now at t=4 — the old layout wrote each instance.
        let mut w = StateWriter::new();
        w.usize(1); // contract count
        w.usize(3); // active instances, expanded per instance
        w.usize(5);
        w.usize(5);
        w.usize(6);
        w.usize(4); // t
        w.f64_bits(3.45); // total = fees 3.0 + usage 0.25 + on-demand 0.2
        w.f64_bits(3.0);
        w.f64_bits(0.2);
        w.f64_bits(0.25);
        w.u64(3); // reservations
        w.u64(2); // on_demand_slots
        w.u64(5); // reserved_slots
        w.u64(7); // demand_slots
        w.u32(3); // peak_active
        w.usize(4); // slots
        let blob = w.into_bytes();

        let mut l = Ledger::single(pricing());
        let mut r = StateReader::new(&blob);
        l.restore_state(&mut r).unwrap();
        r.finish().unwrap();

        let mut w2 = StateWriter::new();
        l.save_state(&mut w2);
        assert_eq!(w2.into_bytes(), blob, "wire format must stay byte-identical");

        // continuation follows the recorded expiry schedule: 3 active at
        // t=4, 1 at t=5 (the t=2 pair lapses), 0 at t=6.
        assert_eq!(l.active_now(), 3);
        l.bill_slot(3, 0, 0).unwrap();
        assert_eq!(l.active_now(), 1);
        l.bill_slot(1, 0, 0).unwrap();
        assert_eq!(l.active_now(), 0);
        l.bill_slot(1, 0, 1).unwrap();
        assert_eq!(l.report().reservations, 3);
    }

    #[test]
    fn restore_rejects_contract_count_mismatch() {
        let mut w = StateWriter::new();
        Ledger::new(two_term_market()).save_state(&mut w);
        let bytes = w.into_bytes();
        let mut single = Ledger::single(pricing());
        let mut r = StateReader::new(&bytes);
        let err = single.restore_state(&mut r).unwrap_err().to_string();
        assert!(err.contains("contract queues"), "{err}");
    }

    #[test]
    fn bill_slot_matches_typed_bill_on_single_market() {
        let pr = Pricing::normalized(0.07, 0.3, 4);
        let mut a = Ledger::single(pr);
        let mut b = Ledger::single(pr);
        let steps: [(u32, u32, u32); 5] = [(2, 1, 1), (3, 0, 1), (1, 0, 0), (0, 0, 0), (2, 1, 1)];
        for &(d, r, od) in &steps {
            a.bill_slot(d, r, od).unwrap();
            let res = [(0usize, r)];
            b.bill(d, &Decision { on_demand: od, reservations: &res[..usize::from(r > 0)] })
                .unwrap();
        }
        assert_eq!(a.report().total.to_bits(), b.report().total.to_bits());
        assert_eq!(a.report(), b.report());
    }
}
