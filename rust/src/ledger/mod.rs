//! Reservation ledger and billing engine.
//!
//! The ledger tracks *actual* reservations (not the phantom bookkeeping the
//! online algorithms use internally), exposes the number of reservations
//! active at the current slot, and accumulates the exact cost decomposition
//! from problem (1):
//!
//! ```text
//! C = Σ_t  o_t·p  +  r_t  +  α·p·(d_t − o_t)
//! ```
//!
//! It also verifies the feasibility constraint
//! `o_t + Σ_{i=t−τ+1..t} r_i ≥ d_t` on every slot, so any policy bug that
//! under-provisions is caught at billing time, and it maintains the cost
//! identity `C = n + (1−α)·Od + α·S` (Eq. 34) used by tests.

use std::collections::VecDeque;

use crate::pricing::Pricing;

/// Errors surfaced by the billing engine. (Display/Error are hand-written:
/// `thiserror` is not in the offline vendor set.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LedgerError {
    Underprovisioned { t: usize, d: u32, o: u32, active: u32 },
    OverOnDemand { t: usize, o: u32, d: u32 },
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            LedgerError::Underprovisioned { t, d, o, active } => write!(
                f,
                "slot {t}: demand {d} exceeds on-demand {o} + active reservations {active}"
            ),
            LedgerError::OverOnDemand { t, o, d } => write!(
                f,
                "slot {t}: on-demand count {o} exceeds demand {d} (wasteful decision rejected)"
            ),
        }
    }
}

impl std::error::Error for LedgerError {}

/// Itemized cost report for one simulated user / policy run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostReport {
    /// Total cost (normalized: reservation fee = 1).
    pub total: f64,
    /// Upfront fees paid (== number of reservations, fee normalized to 1).
    pub reservation_fees: f64,
    /// On-demand running costs Σ o_t p.
    pub on_demand_cost: f64,
    /// Discounted reserved running costs Σ α p (d_t − o_t).
    pub reserved_usage_cost: f64,
    /// Number of reservations made.
    pub reservations: u64,
    /// Total instance-slots served on demand.
    pub on_demand_slots: u64,
    /// Total instance-slots served by reservations.
    pub reserved_slots: u64,
    /// Total demand instance-slots.
    pub demand_slots: u64,
    /// Peak simultaneous active reservations.
    pub peak_active: u32,
    /// Slots processed.
    pub slots: usize,
}

impl CostReport {
    /// `S` from the paper: cost of serving everything on demand.
    pub fn all_on_demand_cost(&self, pricing: &Pricing) -> f64 {
        pricing.p * self.demand_slots as f64
    }

    /// Check Eq. (34): `C = n + (1−α)·Od + α·S` (floating tolerance).
    pub fn identity_holds(&self, pricing: &Pricing, tol: f64) -> bool {
        let s = self.all_on_demand_cost(pricing);
        let rhs = self.reservations as f64 + (1.0 - pricing.alpha) * self.on_demand_cost + pricing.alpha * s;
        (self.total - rhs).abs() <= tol * (1.0 + self.total.abs())
    }
}

/// The reservation ledger + billing engine. Drive it slot by slot with the
/// policy's decisions.
#[derive(Debug, Clone)]
pub struct Ledger {
    pricing: Pricing,
    /// Expiry slot (exclusive) of each active reservation, in FIFO order —
    /// reservations are acquired in time order so the front expires first.
    active: VecDeque<usize>,
    /// Next slot to bill (slots must be billed consecutively from 0).
    t: usize,
    report: CostReport,
}

impl Ledger {
    pub fn new(pricing: Pricing) -> Ledger {
        Ledger { pricing, active: VecDeque::new(), t: 0, report: CostReport::default() }
    }

    pub fn pricing(&self) -> &Pricing {
        &self.pricing
    }

    /// Number of reservations that can serve demand at the *current* slot
    /// (those reserved in `[t−τ+1, t]` — equivalently not yet expired).
    pub fn active_now(&mut self) -> u32 {
        let t = self.t;
        while matches!(self.active.front(), Some(&e) if e <= t) {
            self.active.pop_front();
        }
        self.active.len() as u32
    }

    /// Current slot index.
    pub fn now(&self) -> usize {
        self.t
    }

    /// Bill one slot: `reserve_new` fresh reservations are made at slot `t`,
    /// `on_demand` instances run on demand, and `demand − on_demand`
    /// instances run on active reservations. Advances the clock.
    pub fn bill_slot(
        &mut self,
        demand: u32,
        reserve_new: u32,
        on_demand: u32,
    ) -> Result<(), LedgerError> {
        let t = self.t;
        if on_demand > demand {
            return Err(LedgerError::OverOnDemand { t, o: on_demand, d: demand });
        }
        // Register new reservations: active for slots [t, t+tau-1].
        for _ in 0..reserve_new {
            self.active.push_back(t + self.pricing.tau);
        }
        let active = self.active_now();
        let reserved_use = demand - on_demand;
        if reserved_use > active {
            return Err(LedgerError::Underprovisioned { t, d: demand, o: on_demand, active });
        }

        let p = self.pricing.p;
        let alpha = self.pricing.alpha;
        let fees = reserve_new as f64;
        let od = on_demand as f64 * p;
        let ru = alpha * p * reserved_use as f64;

        let r = &mut self.report;
        r.reservation_fees += fees;
        r.on_demand_cost += od;
        r.reserved_usage_cost += ru;
        r.total += fees + od + ru;
        r.reservations += reserve_new as u64;
        r.on_demand_slots += on_demand as u64;
        r.reserved_slots += reserved_use as u64;
        r.demand_slots += demand as u64;
        r.peak_active = r.peak_active.max(active);
        r.slots += 1;

        self.t += 1;
        Ok(())
    }

    /// Final report.
    pub fn report(&self) -> CostReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pricing() -> Pricing {
        Pricing::normalized(0.1, 0.5, 3)
    }

    #[test]
    fn bills_on_demand_only() {
        let mut l = Ledger::new(pricing());
        for _ in 0..10 {
            l.bill_slot(2, 0, 2).unwrap();
        }
        let r = l.report();
        assert!((r.total - 10.0 * 2.0 * 0.1).abs() < 1e-12);
        assert_eq!(r.reservations, 0);
        assert_eq!(r.on_demand_slots, 20);
        assert_eq!(r.demand_slots, 20);
    }

    #[test]
    fn reservation_expires_after_tau() {
        let mut l = Ledger::new(pricing());
        l.bill_slot(1, 1, 0).unwrap(); // reserve at t=0, covers t=0,1,2
        assert_eq!(l.active_now(), 1);
        l.bill_slot(1, 0, 0).unwrap(); // t=1 reserved
        l.bill_slot(1, 0, 0).unwrap(); // t=2 reserved
        // t=3: reservation expired, must use on-demand
        assert_eq!(l.active_now(), 0);
        let err = l.bill_slot(1, 0, 0).unwrap_err();
        assert!(matches!(err, LedgerError::Underprovisioned { t: 3, .. }));
    }

    #[test]
    fn cost_decomposition_example() {
        // reserve 1 at t=0, serve d=1 for 3 slots reserved, then 1 on demand.
        let mut l = Ledger::new(pricing());
        l.bill_slot(1, 1, 0).unwrap();
        l.bill_slot(1, 0, 0).unwrap();
        l.bill_slot(1, 0, 0).unwrap();
        l.bill_slot(1, 0, 1).unwrap();
        let r = l.report();
        // fee 1 + 3 * (0.5*0.1) + 1 * 0.1
        assert!((r.total - (1.0 + 0.15 + 0.1)).abs() < 1e-12);
        assert!(r.identity_holds(&pricing(), 1e-9));
    }

    #[test]
    fn rejects_overprovisioned_on_demand() {
        let mut l = Ledger::new(pricing());
        let err = l.bill_slot(1, 0, 2).unwrap_err();
        assert!(matches!(err, LedgerError::OverOnDemand { .. }));
    }

    #[test]
    fn multi_reservation_overlap() {
        let mut l = Ledger::new(pricing());
        l.bill_slot(1, 1, 0).unwrap(); // res A t=0..2
        l.bill_slot(3, 2, 0).unwrap(); // res B,C t=1..3, active=3
        assert_eq!(l.active_now(), 3);
        l.bill_slot(3, 0, 0).unwrap(); // t=2 all reserved
        // t=3: A expired; B,C active
        assert_eq!(l.active_now(), 2);
        l.bill_slot(3, 0, 1).unwrap();
        let r = l.report();
        assert_eq!(r.reservations, 3);
        assert_eq!(r.peak_active, 3);
        assert!(r.identity_holds(&pricing(), 1e-9));
    }

    #[test]
    fn zero_demand_slots_are_free_without_actions() {
        let mut l = Ledger::new(pricing());
        for _ in 0..5 {
            l.bill_slot(0, 0, 0).unwrap();
        }
        assert_eq!(l.report().total, 0.0);
    }

    #[test]
    fn identity_holds_on_mixed_run() {
        let pr = Pricing::normalized(0.07, 0.3, 4);
        let mut l = Ledger::new(pr);
        let demands = [0u32, 2, 5, 1, 0, 7, 3, 3, 2, 1, 4, 0];
        let mut rng = crate::util::rng::Rng::new(5);
        for &d in &demands {
            let active = l.active_now();
            // random feasible decision
            let max_new = 3u32;
            let rnew = (rng.below(max_new as u64 + 1) as u32).min(d.saturating_sub(active) + 1);
            let covered = (active + rnew).min(d);
            let od = d - covered;
            l.bill_slot(d, rnew, od).unwrap();
        }
        assert!(l.report().identity_holds(&pr, 1e-9));
    }
}
