//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts`) and execute them from the Rust hot path.
//!
//! Wire protocol (see `python/compile/aot.py`): HLO **text** — the
//! xla_extension 0.5.1 behind the published `xla` crate rejects jax≥0.5's
//! 64-bit-id serialized protos, while the text parser reassigns ids.
//! Every artifact is shape-specialized; `manifest.json` carries the
//! catalog and this module picks a variant and zero-pads batches to fit.

pub mod artifact;
pub mod checkpoint;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json;
pub use artifact::{ArtifactMeta, FleetStepOutput};

/// A compiled artifact plus its manifest metadata.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with row-major f32 buffers. Inputs must be passed in the
    /// artifact's HLO parameter order (= manifest order); names are checked.
    /// Returns the flattened f32 outputs in tuple order.
    pub fn execute_f32(&self, inputs: &[(&str, &[f32])]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, expects {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for ((name, buf), (want_name, dims)) in inputs.iter().zip(&self.meta.inputs) {
            if name != want_name {
                bail!(
                    "artifact {}: input #{} is '{name}', expected '{want_name}' (parameter order matters)",
                    self.meta.name,
                    literals.len()
                );
            }
            let expect: usize = dims.iter().product();
            if expect != buf.len() {
                bail!(
                    "artifact {}: input '{name}' needs {expect} f32s ({dims:?}), got {}",
                    self.meta.name,
                    buf.len()
                );
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow!("reshape {name}: {e:?}"))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact {}: {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}")))
            .collect()
    }
}

/// The artifact registry: a PJRT CPU client plus every compiled module.
pub struct Runtime {
    artifacts: HashMap<String, Artifact>,
    dir: PathBuf,
    platform: String,
}

impl Runtime {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        Self::load_filtered(dir, |_| true)
    }

    /// Load a subset (predicate over artifact names) — tests and examples
    /// use this to skip the big production variants for fast startup.
    pub fn load_filtered(dir: impl AsRef<Path>, keep: impl Fn(&str) -> bool) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} — run `make artifacts` first"))?;
        let parsed = json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let entries = parsed.as_arr().ok_or_else(|| anyhow!("manifest: expected a JSON array"))?;

        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("create PJRT CPU client: {e:?}"))?;
        let platform = client.platform_name();

        let mut artifacts = HashMap::new();
        for entry in entries {
            let meta = ArtifactMeta::from_json(entry)?;
            if !keep(&meta.name) {
                continue;
            }
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", meta.name))?;
            artifacts.insert(meta.name.clone(), Artifact { meta, exe });
        }
        Ok(Runtime { artifacts, dir, platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded (have: {:?})", self.names()))
    }

    /// Smallest loaded `fleet_step` variant that fits `(users, window, k)`;
    /// the caller pads its batch to the variant's shape.
    pub fn pick_fleet_step(&self, users: usize, window: usize, k: usize) -> Result<&Artifact> {
        self.artifacts
            .values()
            .filter(|a| a.meta.kind == "fleet_step")
            .filter(|a| {
                a.meta.param("B") >= users && a.meta.param("W") >= window && a.meta.param("K") >= k
            })
            .min_by_key(|a| a.meta.param("B") * a.meta.param("W"))
            .ok_or_else(|| {
                anyhow!(
                    "no fleet_step artifact fits B>={users} W>={window} K>={k} (have: {:?})",
                    self.names()
                )
            })
    }

    /// Run the fleet-step analytics tick, padding the batch as needed.
    /// `demand`/`reserved` are `users × window` row-major; `z_grid` may be
    /// shorter than the artifact's K (padded with +inf ⇒ never triggered).
    pub fn fleet_step(
        &self,
        p: f64,
        demand: &[f32],
        reserved: &[f32],
        users: usize,
        window: usize,
        z_grid: &[f32],
    ) -> Result<FleetStepOutput> {
        if demand.len() != users * window || reserved.len() != users * window {
            bail!("fleet_step: demand/reserved must be users*window = {} f32s", users * window);
        }
        let artifact = self.pick_fleet_step(users, window, z_grid.len())?;
        let b = artifact.meta.param("B");
        let w = artifact.meta.param("W");
        let k = artifact.meta.param("K");

        let pad = |src: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; b * w];
            for u in 0..users {
                out[u * w..u * w + window].copy_from_slice(&src[u * window..(u + 1) * window]);
            }
            out
        };
        let d_pad = pad(demand);
        let x_pad = pad(reserved);
        let mut m_pad = vec![0.0f32; b * w];
        for u in 0..users {
            m_pad[u * w..u * w + window].iter_mut().for_each(|v| *v = 1.0);
        }
        // Thresholds are padded with a huge sentinel: strictly-greater
        // comparisons never fire on the padding columns.
        let mut z_pad = vec![f32::MAX; k];
        z_pad[..z_grid.len()].copy_from_slice(z_grid);

        let outs = artifact.execute_f32(&[
            ("p", &[p as f32]),
            ("demand", &d_pad),
            ("reserved", &x_pad),
            ("mask", &m_pad),
            ("z_grid", &z_pad),
        ])?;
        let counts = outs[0][..users].to_vec();
        let mut decisions = Vec::with_capacity(users * z_grid.len());
        for u in 0..users {
            decisions.extend_from_slice(&outs[1][u * k..u * k + z_grid.len()]);
        }
        Ok(FleetStepOutput { counts, decisions, k: z_grid.len() })
    }

    /// Batched AR forecast through the `ar_forecast` artifact. `history` is
    /// `users × len` row-major (oldest first), `coef` is `users × (k+1)`.
    /// Returns `(users × horizon, horizon)`.
    pub fn ar_forecast(
        &self,
        history: &[f32],
        coef: &[f32],
        users: usize,
        len: usize,
    ) -> Result<(Vec<f32>, usize)> {
        if history.len() != users * len || coef.len() % users != 0 {
            bail!("ar_forecast: history must be users*len, coef users*(k+1)");
        }
        let k_user = coef.len() / users - 1;
        let artifact = self
            .artifacts
            .values()
            .filter(|a| a.meta.kind == "ar_forecast")
            .filter(|a| {
                a.meta.param("B") >= users
                    && a.meta.param("L") >= len
                    && a.meta.param("k") >= k_user
            })
            .min_by_key(|a| a.meta.param("B") * a.meta.param("L"))
            .ok_or_else(|| {
                anyhow!("no ar_forecast artifact fits B>={users} L>={len} k>={k_user}")
            })?;
        let b = artifact.meta.param("B");
        let l = artifact.meta.param("L");
        let ka = artifact.meta.param("k");
        let h = artifact.meta.param("H");

        // History is right-aligned (newest last); left-pad with the oldest
        // value so AR lags see a sensible, non-zero past.
        let mut h_pad = vec![0.0f32; b * l];
        for u in 0..users {
            let row = &history[u * len..(u + 1) * len];
            let lead = row.first().copied().unwrap_or(0.0);
            h_pad[u * l..u * l + (l - len)].iter_mut().for_each(|v| *v = lead);
            h_pad[u * l + (l - len)..(u + 1) * l].copy_from_slice(row);
        }
        // Coefficients [c, a_1..a_k_user] -> [c, a_1..a_ka] zero-padded.
        let mut c_pad = vec![0.0f32; b * (ka + 1)];
        for u in 0..users {
            let src = &coef[u * (k_user + 1)..(u + 1) * (k_user + 1)];
            c_pad[u * (ka + 1)..u * (ka + 1) + k_user + 1].copy_from_slice(src);
        }
        let outs = artifact.execute_f32(&[("history", &h_pad), ("coef", &c_pad)])?;
        let mut fc = Vec::with_capacity(users * h);
        for u in 0..users {
            fc.extend_from_slice(&outs[0][u * h..(u + 1) * h]);
        }
        Ok((fc, h))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_integration.rs (they
    // need built artifacts); here we only test metadata parsing.
    use super::artifact::ArtifactMeta;
    use crate::util::json;

    #[test]
    fn meta_from_manifest_entry_preserves_order() {
        let doc = r#"{"name": "fleet_step_b8_w64_k8", "kind": "fleet_step",
            "file": "fleet_step_b8_w64_k8.hlo.txt",
            "inputs": {"p": [1], "demand": [8, 64], "reserved": [8, 64],
                       "mask": [8, 64], "z_grid": [8]},
            "outputs": {"counts": [8], "decisions": [8, 8]},
            "params": {"B": 8, "W": 64, "K": 8}}"#;
        let v = json::parse(doc).unwrap();
        let meta = ArtifactMeta::from_json(&v).unwrap();
        assert_eq!(meta.name, "fleet_step_b8_w64_k8");
        assert_eq!(meta.param("W"), 64);
        assert_eq!(meta.inputs.len(), 5);
        // inputs keep aot.py argument order (p, demand, reserved, mask, z_grid)
        assert_eq!(meta.inputs[0].0, "p");
        assert_eq!(meta.inputs[1].0, "demand");
        assert_eq!(meta.inputs[4].0, "z_grid");
        assert_eq!(meta.outputs[0].0, "counts");
    }
}
