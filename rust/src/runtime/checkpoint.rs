//! `cloudreserve-ckpt/v1`: checksummed crash-recovery snapshots for chunked
//! fleet runs.
//!
//! A checkpoint captures everything the chunked replay loop needs to resume
//! bit-identically at a chunk boundary: the running [`FleetAggregate`], the
//! serialized state of every [`ShardRunner`](crate::sim::engine::ShardRunner)
//! (policy expiry queues, window-scan spend, RNG words, ledger totals), the
//! quarantine list, and fingerprints of the trace/market/spec so a resume
//! against mismatched inputs is rejected instead of silently producing a
//! wrong aggregate.
//!
//! File layout (little-endian):
//!
//! ```text
//!   magic "CLDRCKP1" | u64 payload_len | payload | u64 fnv1a64(payload)
//! ```
//!
//! Writes are crash-safe: bytes stream to `<path>.tmp`, are fsynced, the
//! previous checkpoint (if any) is renamed to `<path>.prev`, and the temp
//! file renames onto `path`. A crash at any point leaves either the old
//! checkpoint intact or both generations on disk — [`Checkpoint::load`]
//! falls back to `<path>.prev` when the newest file is torn or corrupt.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::algos::SaveState;
use crate::pricing::Market;
use crate::sim::fleet::{FleetAggregate, PolicySpec};
use crate::util::faults::{site, Fault, FaultPlan};
use crate::util::state::{fnv1a64, StateReader, StateWriter};

const MAGIC: &[u8; 8] = b"CLDRCKP1";

/// One checksum-failed chunk that was skipped under `--on-corrupt skip`:
/// the structured quarantine record surfaced in the run report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuarantinedChunk {
    pub chunk: usize,
    /// Byte offset of the chunk payload in the trace file.
    pub offset: u64,
    pub byte_len: u64,
    /// Users whose results are missing from the aggregate.
    pub users_skipped: u32,
    /// Human-readable cause (checksum mismatch details, decode error, ...).
    pub error: String,
}

/// Stable fingerprint of a market: on-demand rate plus every surviving
/// contract, bit-exact on the f64 fields.
pub fn market_fingerprint(market: &Market) -> u64 {
    let mut w = StateWriter::new();
    w.f64_bits(market.p());
    w.usize(market.len());
    for c in market.contracts() {
        w.f64_bits(c.upfront);
        w.f64_bits(c.rate);
        w.usize(c.term);
    }
    fnv1a64(w.bytes())
}

/// Stable fingerprint of a policy spec (tag + every parameter, threshold
/// bit-exact, including the randomized base seed).
pub fn spec_fingerprint(spec: &PolicySpec) -> u64 {
    let mut w = StateWriter::new();
    match *spec {
        PolicySpec::AllOnDemand => w.u8(0),
        PolicySpec::AllReserved => w.u8(1),
        PolicySpec::Separate => w.u8(2),
        PolicySpec::Deterministic { z, window } => {
            w.u8(3);
            match z {
                None => w.u8(0),
                Some(z) => {
                    w.u8(1);
                    w.f64_bits(z);
                }
            }
            w.usize(window);
        }
        PolicySpec::Randomized { window, seed } => {
            w.u8(4);
            w.usize(window);
            w.u64(seed);
        }
        PolicySpec::Ucb { seed } => {
            w.u8(5);
            w.u64(seed);
        }
        PolicySpec::AdaptiveWindow => w.u8(6),
    }
    fnv1a64(w.bytes())
}

/// A point-in-time snapshot of a chunked fleet run at a chunk boundary.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// [`ChunkedPopulation::fingerprint64`](crate::trace::io::ChunkedPopulation::fingerprint64)
    /// of the trace being replayed.
    pub trace_fp: u64,
    pub market_fp: u64,
    pub spec_fp: u64,
    /// Total chunks in the trace (cross-checked on resume).
    pub n_chunks: u64,
    /// First chunk NOT yet folded into the aggregate; resume starts here.
    pub next_chunk: u64,
    pub aggregate: FleetAggregate,
    pub quarantined: Vec<QuarantinedChunk>,
    /// Serialized [`ShardRunner`](crate::sim::engine::ShardRunner) state
    /// blobs, one per shard. Restored for fidelity when the resume uses the
    /// same shard count; per-user results are sharding-independent, so a
    /// different count simply rebuilds fresh runners.
    pub runners: Vec<Vec<u8>>,
}

impl Checkpoint {
    fn payload(&self) -> Vec<u8> {
        let mut w = StateWriter::new();
        w.u64(self.trace_fp);
        w.u64(self.market_fp);
        w.u64(self.spec_fp);
        w.u64(self.n_chunks);
        w.u64(self.next_chunk);
        self.aggregate.save_state(&mut w);
        w.usize(self.quarantined.len());
        for q in &self.quarantined {
            w.usize(q.chunk);
            w.u64(q.offset);
            w.u64(q.byte_len);
            w.u32(q.users_skipped);
            w.str(&q.error);
        }
        w.usize(self.runners.len());
        for r in &self.runners {
            w.blob(r);
        }
        w.into_bytes()
    }

    fn from_payload(payload: &[u8]) -> Result<Checkpoint> {
        let mut r = StateReader::new(payload);
        let trace_fp = r.u64()?;
        let market_fp = r.u64()?;
        let spec_fp = r.u64()?;
        let n_chunks = r.u64()?;
        let next_chunk = r.u64()?;
        let mut aggregate = FleetAggregate::new();
        aggregate.restore_state(&mut r)?;
        let nq = r.usize()?;
        let mut quarantined = Vec::with_capacity(nq.min(1024));
        for _ in 0..nq {
            quarantined.push(QuarantinedChunk {
                chunk: r.usize()?,
                offset: r.u64()?,
                byte_len: r.u64()?,
                users_skipped: r.u32()?,
                error: r.str()?,
            });
        }
        let nr = r.usize()?;
        let mut runners = Vec::with_capacity(nr.min(1024));
        for _ in 0..nr {
            runners.push(r.blob()?.to_vec());
        }
        r.finish()?;
        Ok(Checkpoint {
            trace_fp,
            market_fp,
            spec_fp,
            n_chunks,
            next_chunk,
            aggregate,
            quarantined,
            runners,
        })
    }

    /// Serialize to the on-disk v1 framing (magic, length, payload, FNV).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut bytes = Vec::with_capacity(24 + payload.len());
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        bytes
    }

    /// Parse the on-disk framing, verifying magic, length, and checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        ensure!(bytes.len() >= 16, "checkpoint is {} bytes, shorter than its header", bytes.len());
        if &bytes[0..8] != MAGIC {
            bail!("not a cloudreserve checkpoint (bad magic)");
        }
        let payload_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        ensure!(
            bytes.len() == 16 + payload_len + 8,
            "checkpoint is torn: header says {} payload bytes, file has {} \
             (expected {} total)",
            payload_len,
            bytes.len().saturating_sub(24),
            16 + payload_len + 8
        );
        let payload = &bytes[16..16 + payload_len];
        let stored = u64::from_le_bytes(bytes[16 + payload_len..].try_into().unwrap());
        let got = fnv1a64(payload);
        ensure!(
            got == stored,
            "checkpoint payload checksum mismatch (stored {stored:#018x}, computed {got:#018x})"
        );
        Checkpoint::from_payload(payload)
    }

    /// Write crash-safely: temp file + fsync + rename, retaining the
    /// previous checkpoint at `<path>.prev` as a fallback generation.
    /// `faults` (when armed) may tear or flip the bytes at the
    /// [`site::CKPT_WRITE`] failpoint, keyed by `next_chunk` — the injected
    /// damage lands *in the renamed file*, exercising the `.prev` fallback.
    pub fn write_atomic(&self, path: &Path, faults: Option<&FaultPlan>) -> Result<()> {
        let mut bytes = self.to_bytes();
        if let Some(plan) = faults {
            match plan.check(site::CKPT_WRITE, self.next_chunk, 0) {
                Some(Fault::TornWrite { keep }) => {
                    let keep = (keep % bytes.len().max(1) as u64) as usize;
                    bytes.truncate(keep);
                }
                Some(Fault::BitFlip { byte, bit }) => {
                    let at = (byte % bytes.len().max(1) as u64) as usize;
                    bytes[at] ^= 1 << (bit & 7);
                }
                // read-path faults don't apply to a write site
                Some(Fault::ReadError) | Some(Fault::Kill) | None => {}
            }
        }
        let tmp = sibling(path, ".tmp");
        {
            let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
            use std::io::Write;
            f.write_all(&bytes)?;
            f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
        }
        let prev = sibling(path, ".prev");
        if path.exists() {
            std::fs::rename(path, &prev)
                .with_context(|| format!("rotate {path:?} -> {prev:?}"))?;
        }
        std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    /// Load `path`, falling back to `<path>.prev` when the newest
    /// generation is missing, torn, or checksum-corrupt. Returns the
    /// checkpoint and whether the fallback was used.
    pub fn load(path: &Path) -> Result<(Checkpoint, bool)> {
        let newest = std::fs::read(path)
            .with_context(|| format!("read checkpoint {path:?}"))
            .and_then(|bytes| {
                Checkpoint::from_bytes(&bytes).with_context(|| format!("parse checkpoint {path:?}"))
            });
        let newest_err = match newest {
            Ok(ckpt) => return Ok((ckpt, false)),
            Err(e) => e,
        };
        let prev = sibling(path, ".prev");
        let fallback = std::fs::read(&prev)
            .with_context(|| format!("read fallback checkpoint {prev:?}"))
            .and_then(|bytes| {
                Checkpoint::from_bytes(&bytes)
                    .with_context(|| format!("parse fallback checkpoint {prev:?}"))
            });
        match fallback {
            Ok(ckpt) => Ok((ckpt, true)),
            Err(fallback_err) => Err(fallback_err.context(format!(
                "newest checkpoint also unusable: {newest_err:#}"
            ))),
        }
    }

    /// Reject a resume whose inputs differ from the checkpointed run, with
    /// a per-component message naming what changed.
    pub fn ensure_matches(
        &self,
        trace_fp: u64,
        market_fp: u64,
        spec_fp: u64,
        n_chunks: u64,
    ) -> Result<()> {
        ensure!(
            self.trace_fp == trace_fp,
            "checkpoint was taken against a different trace file \
             (checkpoint {:#018x}, current {trace_fp:#018x})",
            self.trace_fp
        );
        ensure!(
            self.market_fp == market_fp,
            "checkpoint was taken against a different market \
             (checkpoint {:#018x}, current {market_fp:#018x})",
            self.market_fp
        );
        ensure!(
            self.spec_fp == spec_fp,
            "checkpoint was taken with a different policy spec \
             (checkpoint {:#018x}, current {spec_fp:#018x})",
            self.spec_fp
        );
        ensure!(
            self.n_chunks == n_chunks,
            "checkpoint expects {} chunks, trace has {n_chunks}",
            self.n_chunks
        );
        ensure!(
            self.next_chunk <= self.n_chunks,
            "checkpoint next_chunk {} is past its own chunk count {}",
            self.next_chunk,
            self.n_chunks
        );
        Ok(())
    }
}

/// `path` with `suffix` appended to its final component.
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(suffix);
    PathBuf::from(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::{Contract, Pricing};

    fn sample() -> Checkpoint {
        let mut aggregate = FleetAggregate::new();
        aggregate.merge(&crate::sim::fleet::UserResult {
            user_id: 7,
            group: crate::analysis::classify::Group::G2Medium,
            normalized_cost: 0.8125,
            absolute_cost: 12.5,
            reservations: 3,
        });
        Checkpoint {
            trace_fp: 0x1111_2222_3333_4444,
            market_fp: 0x5555_6666_7777_8888,
            spec_fp: 0x9999_aaaa_bbbb_cccc,
            n_chunks: 12,
            next_chunk: 5,
            aggregate,
            quarantined: vec![QuarantinedChunk {
                chunk: 2,
                offset: 420,
                byte_len: 999,
                users_skipped: 4,
                error: "chunk 2: checksum mismatch".to_string(),
            }],
            runners: vec![vec![1, 2, 3], vec![], vec![255; 40]],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}", std::process::id()))
    }

    #[test]
    fn bytes_round_trip() {
        let ckpt = sample();
        let back = Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
        assert_eq!(back.trace_fp, ckpt.trace_fp);
        assert_eq!(back.market_fp, ckpt.market_fp);
        assert_eq!(back.spec_fp, ckpt.spec_fp);
        assert_eq!(back.n_chunks, 12);
        assert_eq!(back.next_chunk, 5);
        assert_eq!(back.aggregate.users(), 1);
        assert_eq!(
            back.aggregate.mean_normalized().to_bits(),
            ckpt.aggregate.mean_normalized().to_bits()
        );
        assert_eq!(back.quarantined, ckpt.quarantined);
        assert_eq!(back.runners, ckpt.runners);
    }

    #[test]
    fn corruption_and_truncation_are_rejected() {
        let bytes = sample().to_bytes();
        // flipped payload byte -> checksum mismatch
        let mut bad = bytes.clone();
        bad[20] ^= 0x40;
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("checksum"), "unexpected: {err}");
        // torn tail -> length mismatch
        let torn = &bytes[..bytes.len() - 5];
        let err = Checkpoint::from_bytes(torn).unwrap_err();
        assert!(err.to_string().contains("torn"), "unexpected: {err}");
        // wrong magic
        let mut wrong = bytes.clone();
        wrong[0] = b'X';
        assert!(Checkpoint::from_bytes(&wrong).is_err());
    }

    #[test]
    fn write_rotates_previous_generation_and_load_prefers_newest() {
        let path = tmp("ckpt_rotate");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        let mut a = sample();
        a.next_chunk = 3;
        a.write_atomic(&path, None).unwrap();
        let mut b = sample();
        b.next_chunk = 6;
        b.write_atomic(&path, None).unwrap();
        assert!(sibling(&path, ".prev").exists());
        let (loaded, used_fallback) = Checkpoint::load(&path).unwrap();
        assert!(!used_fallback);
        assert_eq!(loaded.next_chunk, 6);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
    }

    #[test]
    fn load_falls_back_to_prev_when_newest_is_torn() {
        let path = tmp("ckpt_fallback");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(sibling(&path, ".prev")).ok();
        let mut a = sample();
        a.next_chunk = 3;
        a.write_atomic(&path, None).unwrap();
        // second write torn by an injected fault (keyed by next_chunk=6)
        let plan =
            FaultPlan::new().script(site::CKPT_WRITE, 6, u32::MAX, Fault::TornWrite { keep: 10 });
        let mut b = sample();
        b.next_chunk = 6;
        b.write_atomic(&path, Some(&plan)).unwrap();
        let (loaded, used_fallback) = Checkpoint::load(&path).unwrap();
        assert!(used_fallback, "torn newest checkpoint must fall back to .prev");
        assert_eq!(loaded.next_chunk, 3);
        // both generations gone -> error mentions both failures
        std::fs::remove_file(sibling(&path, ".prev")).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("also unusable"), "unexpected: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprints_distinguish_inputs() {
        let m1 = Market::single(Pricing::normalized(0.1, 0.5, 100));
        let m2 = Market::single(Pricing::normalized(0.1, 0.5, 101));
        let m3 = Market::new(
            0.01,
            vec![
                Contract { upfront: 1.0, rate: 0.004, term: 600 },
                Contract { upfront: 1.5, rate: 0.002, term: 1800 },
            ],
        );
        assert_ne!(market_fingerprint(&m1), market_fingerprint(&m2));
        assert_ne!(market_fingerprint(&m1), market_fingerprint(&m3));
        assert_eq!(market_fingerprint(&m1), market_fingerprint(&m1.clone()));

        let s1 = PolicySpec::Randomized { window: 0, seed: 11 };
        let s2 = PolicySpec::Randomized { window: 0, seed: 12 };
        let s3 = PolicySpec::Deterministic { z: None, window: 0 };
        let s4 = PolicySpec::Deterministic { z: Some(0.4), window: 0 };
        assert_ne!(spec_fingerprint(&s1), spec_fingerprint(&s2));
        assert_ne!(spec_fingerprint(&s1), spec_fingerprint(&s3));
        assert_ne!(spec_fingerprint(&s3), spec_fingerprint(&s4));
        assert_eq!(spec_fingerprint(&s1), spec_fingerprint(&s1.clone()));

        let u1 = PolicySpec::Ucb { seed: 11 };
        let u2 = PolicySpec::Ucb { seed: 12 };
        let aw = PolicySpec::AdaptiveWindow;
        assert_ne!(spec_fingerprint(&u1), spec_fingerprint(&u2));
        assert_ne!(spec_fingerprint(&u1), spec_fingerprint(&aw));
        assert_ne!(spec_fingerprint(&u1), spec_fingerprint(&s1));
        assert_ne!(spec_fingerprint(&aw), spec_fingerprint(&s3));
        assert_eq!(spec_fingerprint(&u1), spec_fingerprint(&u1.clone()));
    }

    #[test]
    fn mismatched_resume_inputs_are_rejected_with_component_names() {
        let ckpt = sample();
        assert!(ckpt
            .ensure_matches(ckpt.trace_fp, ckpt.market_fp, ckpt.spec_fp, ckpt.n_chunks)
            .is_ok());
        let e = ckpt
            .ensure_matches(1, ckpt.market_fp, ckpt.spec_fp, ckpt.n_chunks)
            .unwrap_err();
        assert!(e.to_string().contains("different trace"));
        let e = ckpt
            .ensure_matches(ckpt.trace_fp, 1, ckpt.spec_fp, ckpt.n_chunks)
            .unwrap_err();
        assert!(e.to_string().contains("different market"));
        let e = ckpt
            .ensure_matches(ckpt.trace_fp, ckpt.market_fp, 1, ckpt.n_chunks)
            .unwrap_err();
        assert!(e.to_string().contains("different policy spec"));
        let e = ckpt
            .ensure_matches(ckpt.trace_fp, ckpt.market_fp, ckpt.spec_fp, 13)
            .unwrap_err();
        assert!(e.to_string().contains("13"));
    }
}
