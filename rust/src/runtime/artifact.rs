//! Artifact metadata: the manifest entry describing one AOT-compiled HLO
//! module (name, input/output shapes in HLO parameter order, static
//! shape parameters).

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Parsed manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// Inputs in HLO parameter order: (name, dims).
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Outputs in tuple order: (name, dims).
    pub outputs: Vec<(String, Vec<usize>)>,
    /// Static shape parameters (B, W, K, ...).
    pub params: Vec<(String, usize)>,
}

impl ArtifactMeta {
    pub fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("manifest entry missing 'name'"))?
            .to_string();
        let kind = v
            .get("kind")
            .as_str()
            .ok_or_else(|| anyhow!("manifest entry '{name}' missing 'kind'"))?
            .to_string();
        let file = v
            .get("file")
            .as_str()
            .ok_or_else(|| anyhow!("manifest entry '{name}' missing 'file'"))?
            .to_string();
        let dims_of = |j: &Json, what: &str| -> Result<Vec<usize>> {
            j.as_arr()
                .ok_or_else(|| anyhow!("'{name}': {what} dims not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("'{name}': bad dim in {what}")))
                .collect()
        };
        let io_of = |key: &str| -> Result<Vec<(String, Vec<usize>)>> {
            v.get(key)
                .as_obj()
                .ok_or_else(|| anyhow!("'{name}': '{key}' not an object"))?
                .iter()
                .map(|(k, dims)| Ok((k.clone(), dims_of(dims, k)?)))
                .collect()
        };
        let inputs = io_of("inputs")?;
        let outputs = io_of("outputs")?;
        let params = v
            .get("params")
            .as_obj()
            .map(|m| {
                m.iter()
                    .filter_map(|(k, val)| val.as_usize().map(|u| (k.clone(), u)))
                    .collect()
            })
            .unwrap_or_default();
        Ok(ArtifactMeta { name, kind, file, inputs, outputs, params })
    }

    /// Static shape parameter lookup (0 if absent).
    pub fn param(&self, key: &str) -> usize {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0)
    }
}

/// Output of a fleet-step analytics tick.
#[derive(Debug, Clone)]
pub struct FleetStepOutput {
    /// Violation counts `V_u`, one per (unpadded) user.
    pub counts: Vec<f32>,
    /// Row-major `users × k` decision matrix: 1.0 iff `p·V_u > z_k`.
    pub decisions: Vec<f32>,
    /// Number of thresholds per user in `decisions`.
    pub k: usize,
}

impl FleetStepOutput {
    /// Decision for user `u` at threshold index `k`.
    pub fn decided(&self, u: usize, k: usize) -> bool {
        self.decisions[u * self.k + k] > 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn param_lookup_defaults_to_zero() {
        let meta = ArtifactMeta {
            name: "x".into(),
            kind: "k".into(),
            file: "f".into(),
            inputs: vec![],
            outputs: vec![],
            params: vec![("B".into(), 8)],
        };
        assert_eq!(meta.param("B"), 8);
        assert_eq!(meta.param("nope"), 0);
    }

    #[test]
    fn rejects_missing_fields() {
        let v = json::parse(r#"{"name": "a"}"#).unwrap();
        assert!(ArtifactMeta::from_json(&v).is_err());
    }

    #[test]
    fn fleet_output_indexing() {
        let out = FleetStepOutput {
            counts: vec![1.0, 2.0],
            decisions: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            k: 3,
        };
        assert!(out.decided(0, 0));
        assert!(!out.decided(0, 1));
        assert!(out.decided(1, 2));
    }
}
