//! Fleet analytics: user classification (Fig. 4), normalized-cost CDFs
//! (Fig. 5-7), and plain-text table rendering for the report harnesses.

pub mod classify;
pub mod report;
