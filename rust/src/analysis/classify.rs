//! User classification by demand-fluctuation level (Sec. VII-A, Fig. 4):
//! the ratio σ/μ of the demand curve determines the group.

use crate::trace::Population;
use crate::util::stats::Summary;

/// The paper's three user groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Group {
    /// σ/μ ≥ 5 — highly sporadic, best served on demand.
    G1Sporadic,
    /// 1 ≤ σ/μ < 5 — needs an intelligent mixed strategy.
    G2Medium,
    /// σ/μ < 1 — stable, best served reserved.
    G3Stable,
}

impl Group {
    pub fn label(&self) -> &'static str {
        match self {
            Group::G1Sporadic => "Group 1 (sigma/mu >= 5)",
            Group::G2Medium => "Group 2 (1 <= sigma/mu < 5)",
            Group::G3Stable => "Group 3 (sigma/mu < 1)",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            Group::G1Sporadic => "G1",
            Group::G2Medium => "G2",
            Group::G3Stable => "G3",
        }
    }

    pub fn all() -> [Group; 3] {
        [Group::G1Sporadic, Group::G2Medium, Group::G3Stable]
    }
}

/// Classify one user from its demand summary.
pub fn classify(summary: &Summary) -> Group {
    let cov = summary.cov();
    if cov >= 5.0 {
        Group::G1Sporadic
    } else if cov >= 1.0 {
        Group::G2Medium
    } else {
        Group::G3Stable
    }
}

/// Classification of a whole population: `(group, mean, cov)` per user —
/// the scatter behind Fig. 4.
pub fn classify_population(pop: &Population) -> Vec<(u32, Group, f64, f64)> {
    pop.users
        .iter()
        .map(|u| {
            let s = u.summary();
            (u.user_id, classify(&s), s.mean, s.cov())
        })
        .collect()
}

/// Group membership counts `(g1, g2, g3)`.
pub fn group_counts(pop: &Population) -> (usize, usize, usize) {
    let mut c = (0, 0, 0);
    for u in &pop.users {
        match classify(&u.summary()) {
            Group::G1Sporadic => c.0 += 1,
            Group::G2Medium => c.1 += 1,
            Group::G3Stable => c.2 += 1,
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UserTrace;

    fn summary_of(d: &[u32]) -> Summary {
        crate::util::stats::summarize_u32(d)
    }

    #[test]
    fn boundary_values() {
        // cov exactly 1 -> group 2; cov exactly 5 -> group 1
        let s = Summary { n: 2, mean: 1.0, std: 1.0, min: 0.0, max: 2.0 };
        assert_eq!(classify(&s), Group::G2Medium);
        let s5 = Summary { n: 2, mean: 1.0, std: 5.0, min: 0.0, max: 6.0 };
        assert_eq!(classify(&s5), Group::G1Sporadic);
        let s09 = Summary { n: 2, mean: 1.0, std: 0.99, min: 0.0, max: 2.0 };
        assert_eq!(classify(&s09), Group::G3Stable);
    }

    #[test]
    fn constant_demand_is_stable() {
        assert_eq!(classify(&summary_of(&[7, 7, 7, 7])), Group::G3Stable);
    }

    #[test]
    fn single_spike_is_sporadic() {
        let mut d = vec![0u32; 1000];
        d[3] = 100;
        assert_eq!(classify(&summary_of(&d)), Group::G1Sporadic);
    }

    #[test]
    fn zero_demand_is_stable() {
        // all-zero: cov defined as 0 -> group 3 (degenerate but harmless)
        assert_eq!(classify(&summary_of(&[0, 0, 0])), Group::G3Stable);
    }

    #[test]
    fn population_counts_sum() {
        let pop = Population {
            users: vec![
                UserTrace::new(0, vec![7, 7, 7]),
                UserTrace::new(1, {
                    let mut d = vec![0u32; 500];
                    d[0] = 100;
                    d
                }),
            ],
        };
        let (g1, g2, g3) = group_counts(&pop);
        assert_eq!(g1 + g2 + g3, 2);
        assert_eq!(g1, 1);
        assert_eq!(g3, 1);
    }
}
