//! Plain-text rendering of the paper's figures and tables: CDF series
//! (Fig. 5/6/7), the Fig. 4 scatter, and Table II. The harnesses under
//! `examples/` and `rust/benches/` print these; CSV export lets external
//! plotting reproduce the actual figures.

use std::fmt::Write as _;

use crate::util::stats::{ecdf, linspace};

/// A named series of per-user normalized costs.
#[derive(Debug, Clone)]
pub struct CostSeries {
    pub name: String,
    pub values: Vec<f64>,
}

impl CostSeries {
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }
}

/// Render a CDF table like Fig. 5: one row per grid point, one column per
/// algorithm.
pub fn render_cdf_table(
    title: &str,
    series: &[CostSeries],
    lo: f64,
    hi: f64,
    points: usize,
) -> String {
    let grid = linspace(lo, hi, points);
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let mut header = format!("{:>10}", "cost");
    for s in series {
        header.push_str(&format!(" {:>24}", truncate(&s.name, 24)));
    }
    let _ = writeln!(out, "{header}");
    let cdfs: Vec<Vec<(f64, f64)>> = series.iter().map(|s| ecdf(&s.values, &grid)).collect();
    for (i, &x) in grid.iter().enumerate() {
        let mut row = format!("{x:>10.3}");
        for cdf in &cdfs {
            row.push_str(&format!(" {:>24.4}", cdf[i].1));
        }
        let _ = writeln!(out, "{row}");
    }
    out
}

/// CSV form of the same table (for plotting).
pub fn cdf_csv(series: &[CostSeries], lo: f64, hi: f64, points: usize) -> String {
    let grid = linspace(lo, hi, points);
    let mut out = String::from("cost");
    for s in series {
        out.push(',');
        out.push_str(&s.name.replace(',', ";"));
    }
    out.push('\n');
    let cdfs: Vec<Vec<(f64, f64)>> = series.iter().map(|s| ecdf(&s.values, &grid)).collect();
    for (i, &x) in grid.iter().enumerate() {
        let _ = write!(out, "{x}");
        for cdf in &cdfs {
            let _ = write!(out, ",{}", cdf[i].1);
        }
        out.push('\n');
    }
    out
}

/// Render Table II: average normalized cost, rows = algorithms, columns =
/// (All users, Group 1, Group 2, Group 3).
pub fn render_table2(rows: &[(String, [f64; 4])]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "TABLE II  AVERAGE COST PERFORMANCE (NORMALIZED TO ALL-ON-DEMAND)");
    let _ = writeln!(
        out,
        "{:<28} {:>10} {:>10} {:>10} {:>10}",
        "Algorithm", "All users", "Group 1", "Group 2", "Group 3"
    );
    for (name, vals) in rows {
        let _ = writeln!(
            out,
            "{:<28} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            truncate(name, 28),
            vals[0],
            vals[1],
            vals[2],
            vals[3]
        );
    }
    out
}

/// ASCII scatter of (mean, cov) pairs on log-x — the Fig. 4 reproduction.
pub fn render_fig4_scatter(points: &[(f64, f64)], width: usize, height: usize) -> String {
    let mut canvas = vec![vec![' '; width]; height];
    // x: log10(mean) in [-2, 4]; y: cov in [0, 20] clamped
    for &(mean, cov) in points {
        let lx = mean.max(1e-2).log10();
        let xi = (((lx + 2.0) / 6.0) * (width - 1) as f64).round() as usize;
        let yi = ((cov.min(20.0) / 20.0) * (height - 1) as f64).round() as usize;
        let (xi, yi) = (xi.min(width - 1), yi.min(height - 1));
        let c = if cov >= 5.0 {
            'o' // group 1, matching the paper's markers
        } else if cov >= 1.0 {
            'x'
        } else {
            '+'
        };
        canvas[height - 1 - yi][xi] = c;
    }
    let mut out = String::from(
        "Fig. 4 — demand fluctuation (sigma/mu, y, clamped at 20) vs mean demand (log10, x in [-2,4])\n  markers: o = Group 1, x = Group 2, + = Group 3\n",
    );
    for row in canvas {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n.saturating_sub(1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_table_contains_all_series() {
        let series = vec![
            CostSeries { name: "A".into(), values: vec![0.5, 0.9, 1.2] },
            CostSeries { name: "B".into(), values: vec![1.0, 1.0, 1.0] },
        ];
        let t = render_cdf_table("Fig 5a", &series, 0.0, 2.0, 5);
        assert!(t.contains("Fig 5a"));
        assert!(t.lines().count() >= 7);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let series = vec![CostSeries { name: "A".into(), values: vec![0.5] }];
        let csv = cdf_csv(&series, 0.0, 1.0, 3);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "cost,A");
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn table2_renders_rows() {
        let rows = vec![
            ("All-reserved".to_string(), [16.48, 48.99, 1.25, 0.61]),
            ("Randomized".to_string(), [0.76, 1.02, 0.79, 0.63]),
        ];
        let t = render_table2(&rows);
        assert!(t.contains("All-reserved"));
        assert!(t.contains("48.99"));
    }

    #[test]
    fn scatter_renders_markers() {
        let pts = vec![(0.1, 10.0), (5.0, 2.0), (100.0, 0.3)];
        let s = render_fig4_scatter(&pts, 40, 10);
        assert!(s.contains('o'));
        assert!(s.contains('x'));
        assert!(s.contains('+'));
    }

    #[test]
    fn series_mean() {
        let s = CostSeries { name: "m".into(), values: vec![1.0, 3.0] };
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
