//! Instance pricing, v2: the paper's normalized single-contract model
//! (Sec. II-A), a catalog of real offerings (Table I), and the [`market`]
//! menu API the rest of the stack is built on.
//!
//! Two levels of abstraction:
//!
//! * [`Pricing`] — the paper's three-parameter reduction of **one**
//!   reservation option, normalized to a fee of 1: `p` (on-demand rate per
//!   slot), `alpha` (discount after reservation), `tau` (term in slots).
//!   Running one instance on demand for `h` slots costs `p·h`; reserved,
//!   `1 + α·p·h`. This remains the analysis vocabulary (break-even `β`,
//!   competitive ratios) and the fast-path currency of the engine.
//! * [`market::Market`] — the v2 menu: a shared on-demand rate plus any
//!   number of typed [`market::Contract`]s (`upfront`, `rate`, `term`) in
//!   raw market currency, validated, term-sorted, dominance-pruned, with
//!   per-contract break-evens. [`market::Market::single`] embeds a
//!   `Pricing` bit-identically; every billing and policy layer consumes
//!   `Market`, and single-contract menus take the classic code path.
//!
//! Migration (v1 → v2): `Ledger::new(pricing)` → `Ledger::single(pricing)`
//! or `Ledger::new(Market::single(pricing))`; fleet/engine entry points now
//! take `&Market`; `Policy::decide` returns a typed
//! [`Decision`](crate::algos::Decision) carrying per-contract reservation
//! counts. See PERF.md § "Market API v2 migration".

pub mod catalog;
pub mod market;

pub use market::{Contract, ContractId, Market};

/// Normalized pricing parameters (reservation fee == 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pricing {
    /// On-demand rate per slot, normalized to the reservation fee.
    pub p: f64,
    /// Reserved-usage discount factor in [0, 1].
    pub alpha: f64,
    /// Reservation period in slots.
    pub tau: usize,
}

impl Pricing {
    /// Build from raw dollar figures: hourly on-demand rate, one-time upfront
    /// fee, discounted hourly rate, and the reservation period in slots.
    pub fn from_rates(on_demand: f64, upfront: f64, discounted: f64, tau: usize) -> Pricing {
        assert!(on_demand > 0.0, "on-demand rate must be positive");
        assert!(upfront > 0.0, "upfront fee must be positive");
        assert!(discounted >= 0.0 && discounted <= on_demand, "0 <= discounted <= on-demand");
        assert!(tau >= 1, "reservation period must be at least one slot");
        Pricing { p: on_demand / upfront, alpha: discounted / on_demand, tau }
    }

    /// Direct construction from normalized parameters.
    pub fn normalized(p: f64, alpha: f64, tau: usize) -> Pricing {
        assert!(p > 0.0, "p must be positive");
        assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
        assert!(tau >= 1);
        Pricing { p, alpha, tau }
    }

    /// Break-even point `β = 1/(1-α)` (Eq. 10): the on-demand spend within a
    /// reservation period at which reserving becomes worthwhile.
    /// Unbounded (`+inf`) when `alpha == 1` — reserving then never pays off.
    pub fn beta(&self) -> f64 {
        if self.alpha >= 1.0 {
            f64::INFINITY
        } else {
            1.0 / (1.0 - self.alpha)
        }
    }

    /// Deterministic competitive ratio `2 - α` (Proposition 1).
    pub fn deterministic_ratio(&self) -> f64 {
        2.0 - self.alpha
    }

    /// Randomized competitive ratio `e / (e - 1 + α)` (Proposition 3).
    pub fn randomized_ratio(&self) -> f64 {
        std::f64::consts::E / (std::f64::consts::E - 1.0 + self.alpha)
    }

    /// Cost of running one instance on demand for `h` slots.
    pub fn on_demand_cost(&self, h: u64) -> f64 {
        self.p * h as f64
    }

    /// Cost of one reservation plus `h` discounted usage slots.
    pub fn reserved_cost(&self, h: u64) -> f64 {
        1.0 + self.alpha * self.p * h as f64
    }

    /// Usage slots within one period above which reserving is cheaper.
    pub fn break_even_hours(&self) -> f64 {
        self.beta() / self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The EC2 Standard Small example worked in Sec. II-A.
    #[test]
    fn ec2_small_normalization() {
        let pr = Pricing::from_rates(0.08, 69.0, 0.039, 8760);
        assert!((pr.p - 0.08 / 69.0).abs() < 1e-12);
        assert!((pr.alpha - 0.4875).abs() < 1e-12);
        // 100 hours reserved: (69 + 0.039*100)/69 = 72.9/69
        let c = pr.reserved_cost(100);
        assert!((c - 72.9 / 69.0).abs() < 1e-9, "c={c}");
    }

    #[test]
    fn beta_matches_eq10() {
        let pr = Pricing::normalized(0.01, 0.5, 100);
        assert!((pr.beta() - 2.0).abs() < 1e-12);
        let pr0 = Pricing::normalized(0.01, 0.0, 100);
        assert!((pr0.beta() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn beta_unbounded_at_alpha_one() {
        let pr = Pricing::normalized(0.01, 1.0, 100);
        assert!(pr.beta().is_infinite());
    }

    #[test]
    fn competitive_ratios_at_ec2_alpha() {
        // Sec. IV/V: 1.51-competitive deterministic, 1.23 randomized at EC2's
        // alpha = 0.4875 (the paper rounds alpha to 0.49).
        let pr = Pricing::from_rates(0.08, 69.0, 0.039, 8760);
        assert!((pr.deterministic_ratio() - 1.5125).abs() < 1e-9);
        let r = pr.randomized_ratio();
        // e/(e-1+0.4875) = 1.2323...; the paper rounds to 1.23
        assert!((r - 1.2323).abs() < 1e-3, "randomized ratio {r}");
    }

    #[test]
    fn ratio_extremes() {
        let a0 = Pricing::normalized(0.01, 0.0, 10);
        assert!((a0.deterministic_ratio() - 2.0).abs() < 1e-12);
        let ski_rental = std::f64::consts::E / (std::f64::consts::E - 1.0);
        assert!((a0.randomized_ratio() - ski_rental).abs() < 1e-12);
        let a1 = Pricing::normalized(0.01, 1.0, 10);
        assert!((a1.deterministic_ratio() - 1.0).abs() < 1e-12);
        assert!((a1.randomized_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn break_even_hours_ec2() {
        let pr = Pricing::from_rates(0.08, 69.0, 0.039, 8760);
        // beta/p = (1/(1-0.4875)) / (0.08/69) = 69/(0.08-0.039) ~ 1682.9 h
        assert!((pr.break_even_hours() - 69.0 / (0.08 - 0.039)).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_negative_rate() {
        Pricing::from_rates(-0.08, 69.0, 0.039, 8760);
    }

    #[test]
    #[should_panic]
    fn rejects_discount_above_od() {
        Pricing::from_rates(0.08, 69.0, 0.09, 8760);
    }
}
