//! Catalog of real IaaS offerings (paper Table I plus the vendors it cites)
//! and the trace-compressed variant used throughout Sec. VII.

use super::Pricing;

/// A named offering in the catalog.
#[derive(Debug, Clone)]
pub struct Offering {
    pub vendor: &'static str,
    pub instance_type: &'static str,
    pub plan: &'static str,
    /// Raw dollars per hour, on demand.
    pub on_demand_hourly: f64,
    /// Raw upfront dollars for the reservation.
    pub upfront: f64,
    /// Raw dollars per hour when reserved.
    pub reserved_hourly: f64,
    /// Reservation period in hours.
    pub period_hours: usize,
}

impl Offering {
    pub fn pricing(&self) -> Pricing {
        Pricing::from_rates(self.on_demand_hourly, self.upfront, self.reserved_hourly, self.period_hours)
    }
}

/// Table I — Amazon EC2, Light Utilization, Linux, US East (Feb 10, 2013).
pub const EC2_STANDARD_SMALL: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Small",
    plan: "1-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.08,
    upfront: 69.0,
    reserved_hourly: 0.039,
    period_hours: 8760,
};

/// Table I — second row.
pub const EC2_STANDARD_MEDIUM: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Medium",
    plan: "1-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.16,
    upfront: 138.0,
    reserved_hourly: 0.078,
    period_hours: 8760,
};

/// Vendors where reserved usage is free after the upfront fee (alpha = 0),
/// e.g. ElasticHosts / GoGrid as cited in Sec. II-A. Figures are
/// representative (one month prepaid, usage free).
pub const FLATFEE_MONTHLY: Offering = Offering {
    vendor: "ElasticHosts-style",
    instance_type: "1GHz/1GB",
    plan: "Monthly prepaid (free usage)",
    on_demand_hourly: 0.06,
    upfront: 30.0,
    reserved_hourly: 0.0,
    period_hours: 720,
};

/// All catalog entries.
pub fn catalog() -> Vec<Offering> {
    vec![EC2_STANDARD_SMALL, EC2_STANDARD_MEDIUM, FLATFEE_MONTHLY]
}

/// The Sec. VII trace-compressed pricing: Google traces span one month, so
/// the paper shortens the billing cycle hour->minute and the reservation
/// period 1 year -> 8760 minutes (~6 days). Rates per *slot* keep the same
/// normalized `p` and `alpha`; only the slot meaning changes.
pub fn ec2_small_compressed() -> Pricing {
    let base = EC2_STANDARD_SMALL.pricing();
    // Same normalized parameters; tau is interpreted in minutes.
    Pricing { p: base.p, alpha: base.alpha, tau: 8760 }
}

/// Pretty-print the catalog as the Table I reproduction.
pub fn render_table1() -> String {
    let mut out = String::new();
    out.push_str("TABLE I  PRICING OF ON-DEMAND AND RESERVED INSTANCES (reproduction)\n");
    out.push_str(&format!(
        "{:<16} {:<42} {:>9} {:>9} {:>8} {:>7} {:>7}\n",
        "Instance", "Plan", "Upfront", "Hourly", "p", "alpha", "beta"
    ));
    for o in catalog() {
        let pr = o.pricing();
        out.push_str(&format!(
            "{:<16} {:<42} {:>9} {:>9} {:>8.5} {:>7.4} {:>7.3}\n",
            o.instance_type,
            "On-Demand",
            "$0",
            format!("${:.3}", o.on_demand_hourly),
            pr.p,
            "-",
            "-"
        ));
        out.push_str(&format!(
            "{:<16} {:<42} {:>9} {:>9} {:>8} {:>7.4} {:>7.3}\n",
            "",
            o.plan,
            format!("${:.0}", o.upfront),
            format!("${:.3}", o.reserved_hourly),
            "",
            pr.alpha,
            pr.beta()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_constants_match_paper() {
        let s = EC2_STANDARD_SMALL;
        assert_eq!(s.on_demand_hourly, 0.08);
        assert_eq!(s.upfront, 69.0);
        assert_eq!(s.reserved_hourly, 0.039);
        let m = EC2_STANDARD_MEDIUM;
        assert_eq!(m.on_demand_hourly, 0.16);
        assert_eq!(m.upfront, 138.0);
        assert_eq!(m.reserved_hourly, 0.078);
    }

    #[test]
    fn small_and_medium_have_same_alpha_shape() {
        // Medium is exactly 2x small in all dollar figures -> identical
        // normalized parameters.
        let s = EC2_STANDARD_SMALL.pricing();
        let m = EC2_STANDARD_MEDIUM.pricing();
        assert!((s.p - m.p).abs() < 1e-12);
        assert!((s.alpha - m.alpha).abs() < 1e-12);
    }

    #[test]
    fn flatfee_has_zero_alpha() {
        let f = FLATFEE_MONTHLY.pricing();
        assert_eq!(f.alpha, 0.0);
        assert!((f.beta() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compressed_pricing_keeps_normalization() {
        let c = ec2_small_compressed();
        let b = EC2_STANDARD_SMALL.pricing();
        assert_eq!(c.tau, 8760);
        assert!((c.p - b.p).abs() < 1e-15);
        assert!((c.alpha - b.alpha).abs() < 1e-15);
    }

    #[test]
    fn table_renders_all_rows() {
        let t = render_table1();
        assert!(t.contains("Standard Small"));
        assert!(t.contains("Standard Medium"));
        assert!(t.contains("$69"));
        assert!(t.contains("$138"));
    }
}
