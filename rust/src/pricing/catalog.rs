//! Catalog of real IaaS offerings (paper Table I plus the vendors it
//! cites), the trace-compressed variant used throughout Sec. VII, and
//! [`Market`] menus combining multiple terms (the Sec. IX extension).

use super::market::{Contract, Market};
use super::Pricing;

/// A named offering in the catalog.
#[derive(Debug, Clone)]
pub struct Offering {
    pub vendor: &'static str,
    pub instance_type: &'static str,
    pub plan: &'static str,
    /// Raw dollars per hour, on demand.
    pub on_demand_hourly: f64,
    /// Raw upfront dollars for the reservation.
    pub upfront: f64,
    /// Raw dollars per hour when reserved.
    pub reserved_hourly: f64,
    /// Reservation period in hours.
    pub period_hours: usize,
}

impl Offering {
    pub fn pricing(&self) -> Pricing {
        Pricing::from_rates(
            self.on_demand_hourly,
            self.upfront,
            self.reserved_hourly,
            self.period_hours,
        )
    }
}

/// Table I — Amazon EC2, Light Utilization, Linux, US East (Feb 10, 2013).
pub const EC2_STANDARD_SMALL: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Small",
    plan: "1-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.08,
    upfront: 69.0,
    reserved_hourly: 0.039,
    period_hours: 8760,
};

/// Table I — second row.
pub const EC2_STANDARD_MEDIUM: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Medium",
    plan: "1-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.16,
    upfront: 138.0,
    reserved_hourly: 0.078,
    period_hours: 8760,
};

/// Table I's 3-year column for the Standard Small row: the deeper
/// commitment EC2 sold alongside the 1-year plan (2013 price-book shape:
/// upfront ~1.54x the 1-year fee, discounted rate a further ~38% lower,
/// period 3 x 8760 h).
pub const EC2_STANDARD_SMALL_3YR: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Small",
    plan: "3-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.08,
    upfront: 106.10,
    reserved_hourly: 0.024,
    period_hours: 26280,
};

/// 3-year Standard Medium: exactly 2x the Small figures, like the 1-year
/// rows.
pub const EC2_STANDARD_MEDIUM_3YR: Offering = Offering {
    vendor: "Amazon EC2",
    instance_type: "Standard Medium",
    plan: "3-Year Reserved (Light, Linux, US East)",
    on_demand_hourly: 0.16,
    upfront: 212.20,
    reserved_hourly: 0.048,
    period_hours: 26280,
};

/// Vendors where reserved usage is free after the upfront fee (alpha = 0),
/// e.g. ElasticHosts / GoGrid as cited in Sec. II-A. Figures are
/// representative (one month prepaid, usage free).
pub const FLATFEE_MONTHLY: Offering = Offering {
    vendor: "ElasticHosts-style",
    instance_type: "1GHz/1GB",
    plan: "Monthly prepaid (free usage)",
    on_demand_hourly: 0.06,
    upfront: 30.0,
    reserved_hourly: 0.0,
    period_hours: 720,
};

/// All catalog entries.
pub fn catalog() -> Vec<Offering> {
    vec![
        EC2_STANDARD_SMALL,
        EC2_STANDARD_SMALL_3YR,
        EC2_STANDARD_MEDIUM,
        EC2_STANDARD_MEDIUM_3YR,
        FLATFEE_MONTHLY,
    ]
}

/// The Sec. VII trace-compressed pricing: Google traces span one month, so
/// the paper shortens the billing cycle hour->minute and the reservation
/// period 1 year -> 8760 minutes (~6 days). Rates per *slot* keep the same
/// normalized `p` and `alpha`; only the slot meaning changes.
pub fn ec2_small_compressed() -> Pricing {
    let base = EC2_STANDARD_SMALL.pricing();
    // Same normalized parameters; tau is interpreted in minutes.
    Pricing { p: base.p, alpha: base.alpha, tau: 8760 }
}

/// Two-term Standard Small [`Market`]: the 1-year and 3-year Table I
/// offerings, trace-compressed like [`ec2_small_compressed`] (terms in
/// minute-slots at the same normalized parameters, fees normalized to the
/// 1-year upfront).
pub fn ec2_two_term_compressed() -> Market {
    let base = ec2_small_compressed();
    let deep = EC2_STANDARD_SMALL_3YR;
    let deep_fee = deep.upfront / EC2_STANDARD_SMALL.upfront;
    let deep_alpha = deep.reserved_hourly / deep.on_demand_hourly;
    Market::with_labels(
        base.p,
        vec![
            (
                "1yr-light".to_string(),
                Contract { upfront: 1.0, rate: base.alpha * base.p, term: base.tau },
            ),
            (
                "3yr-light".to_string(),
                Contract { upfront: deep_fee, rate: deep_alpha * base.p, term: 3 * base.tau },
            ),
        ],
    )
}

/// Pretty-print the catalog as the Table I reproduction.
pub fn render_table1() -> String {
    let mut out = String::new();
    out.push_str("TABLE I  PRICING OF ON-DEMAND AND RESERVED INSTANCES (reproduction)\n");
    out.push_str(&format!(
        "{:<16} {:<42} {:>9} {:>9} {:>8} {:>7} {:>7}\n",
        "Instance", "Plan", "Upfront", "Hourly", "p", "alpha", "beta"
    ));
    for o in catalog() {
        let pr = o.pricing();
        out.push_str(&format!(
            "{:<16} {:<42} {:>9} {:>9} {:>8.5} {:>7.4} {:>7.3}\n",
            o.instance_type,
            "On-Demand",
            "$0",
            format!("${:.3}", o.on_demand_hourly),
            pr.p,
            "-",
            "-"
        ));
        out.push_str(&format!(
            "{:<16} {:<42} {:>9} {:>9} {:>8} {:>7.4} {:>7.3}\n",
            "",
            o.plan,
            format!("${:.0}", o.upfront),
            format!("${:.3}", o.reserved_hourly),
            "",
            pr.alpha,
            pr.beta()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_constants_match_paper() {
        let s = EC2_STANDARD_SMALL;
        assert_eq!(s.on_demand_hourly, 0.08);
        assert_eq!(s.upfront, 69.0);
        assert_eq!(s.reserved_hourly, 0.039);
        let m = EC2_STANDARD_MEDIUM;
        assert_eq!(m.on_demand_hourly, 0.16);
        assert_eq!(m.upfront, 138.0);
        assert_eq!(m.reserved_hourly, 0.078);
    }

    #[test]
    fn small_and_medium_have_same_alpha_shape() {
        // Medium is exactly 2x small in all dollar figures -> identical
        // normalized parameters.
        let s = EC2_STANDARD_SMALL.pricing();
        let m = EC2_STANDARD_MEDIUM.pricing();
        assert!((s.p - m.p).abs() < 1e-12);
        assert!((s.alpha - m.alpha).abs() < 1e-12);
    }

    #[test]
    fn flatfee_has_zero_alpha() {
        let f = FLATFEE_MONTHLY.pricing();
        assert_eq!(f.alpha, 0.0);
        assert!((f.beta() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compressed_pricing_keeps_normalization() {
        let c = ec2_small_compressed();
        let b = EC2_STANDARD_SMALL.pricing();
        assert_eq!(c.tau, 8760);
        assert!((c.p - b.p).abs() < 1e-15);
        assert!((c.alpha - b.alpha).abs() < 1e-15);
    }

    #[test]
    fn table_renders_all_rows() {
        let t = render_table1();
        assert!(t.contains("Standard Small"));
        assert!(t.contains("Standard Medium"));
        assert!(t.contains("$69"));
        assert!(t.contains("$138"));
        assert!(t.contains("$106"));
        assert!(t.contains("$212"));
        assert!(t.contains("3-Year Reserved"));
    }

    /// Golden anchor: every offering's normalized (p, alpha, beta) against
    /// the paper's published figures. The 1-year Standard Small row is the
    /// worked example of Sec. II-A (p = 0.08/69 ~ 1.16e-3, alpha = 0.4875,
    /// beta = 1/(1-alpha) ~ 1.9512); Medium is exactly 2x in dollars and
    /// hence identical normalized; the 3-year rows follow the 2013
    /// price-book shape recorded in this catalog.
    #[test]
    fn golden_normalized_parameters_match_table1() {
        let golden: &[(&Offering, f64, f64, f64)] = &[
            (&EC2_STANDARD_SMALL, 0.08 / 69.0, 0.4875, 1.951_219_512_195_122),
            (&EC2_STANDARD_MEDIUM, 0.16 / 138.0, 0.4875, 1.951_219_512_195_122),
            (&EC2_STANDARD_SMALL_3YR, 0.08 / 106.10, 0.30, 1.0 / 0.7),
            (&EC2_STANDARD_MEDIUM_3YR, 0.16 / 212.20, 0.30, 1.0 / 0.7),
            (&FLATFEE_MONTHLY, 0.06 / 30.0, 0.0, 1.0),
        ];
        for (o, p, alpha, beta) in golden {
            let pr = o.pricing();
            let what = format!("{} {}", o.instance_type, o.plan);
            assert!((pr.p - p).abs() < 1e-12, "{what}: p={} want {p}", pr.p);
            assert!((pr.alpha - alpha).abs() < 1e-12, "{what}: alpha={}", pr.alpha);
            assert!((pr.beta() - beta).abs() < 1e-9, "{what}: beta={}", pr.beta());
        }
        // the paper's compressed variant keeps the same normalized figures
        let c = ec2_small_compressed();
        assert!((c.p - 0.08 / 69.0).abs() < 1e-12);
        assert!((c.alpha - 0.4875).abs() < 1e-12);
    }

    /// Golden anchor: the rendered Table I reproduction carries the
    /// normalized figures (formatted) for the paper-cited rows.
    #[test]
    fn golden_render_table1_figures() {
        let t = render_table1();
        // Small 1-year: p = 0.0011594..., alpha 0.4875, beta 1.951
        assert!(t.contains("0.00116"), "missing normalized p:\n{t}");
        assert!(t.contains("0.4875"), "missing alpha:\n{t}");
        assert!(t.contains("1.951"), "missing beta:\n{t}");
        // 3-year Small: alpha = 0.024/0.08 = 0.3, beta = 1/0.7 = 1.429
        assert!(t.contains("0.3000"), "missing 3yr alpha:\n{t}");
        assert!(t.contains("1.429"), "missing 3yr beta:\n{t}");
        // flat-fee: alpha 0, beta 1
        assert!(t.contains("0.0000"), "missing flatfee alpha:\n{t}");
    }

    #[test]
    fn two_term_market_anchored_to_table1() {
        let m = ec2_two_term_compressed();
        assert_eq!(m.len(), 2);
        assert_eq!(m.label(0), "1yr-light");
        assert_eq!(m.label(1), "3yr-light");
        assert_eq!(m.contract(0).term, 8760);
        assert_eq!(m.contract(1).term, 3 * 8760);
        assert!((m.alpha(0) - 0.4875).abs() < 1e-12);
        assert!((m.alpha(1) - 0.30).abs() < 1e-12);
        assert!((m.contract(1).upfront - 106.10 / 69.0).abs() < 1e-12);
        assert!((m.alpha_max() - 0.4875).abs() < 1e-12);
        // deeper commitment has the better steady-state cost
        assert_eq!(m.steady_best(), Some(1));
    }
}
