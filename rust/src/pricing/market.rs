//! The v2 contract menu: a [`Market`] of typed [`Contract`]s.
//!
//! The paper's Table I catalogs many concurrent reserved offerings
//! (light/medium/heavy utilization, 1-year and 3-year terms), and its
//! extension discussion (Sec. IX) generalizes the online algorithms beyond
//! a single reservation option. The v1 API reduced the whole market to one
//! [`Pricing`] triple; a [`Market`] instead carries a *menu*:
//!
//! * a market-wide on-demand rate `p` (per slot, in market currency),
//! * a validated, **term-sorted** list of [`Contract`]s — each an upfront
//!   fee, a discounted usage rate, and a term length in slots,
//! * per-contract derived figures: the discount factor `α_j = rate_j / p`
//!   and the break-even spend `β_j = upfront_j / (1 − α_j)` (the Eq. 10
//!   generalization — the on-demand spend within one term at which
//!   committing to contract `j` pays off),
//! * cross-contract **dominance pruning**: contracts that can never be the
//!   cheapest way to serve any usage pattern are dropped at construction
//!   (see [`Market::new`] for the exact rules).
//!
//! Currency: nothing requires the upfront fee to be 1. [`Market::single`]
//! embeds a normalized [`Pricing`] as the one-contract menu with
//! `upfront = 1` and reproduces its arithmetic **bit-identically** — the
//! fast path the batched engine takes for single-contract markets.

use super::Pricing;

/// Identifies a contract within a [`Market`]: the index into the sorted,
/// pruned menu. Stable for the lifetime of the `Market` value.
pub type ContractId = usize;

/// One reservation contract: pay `upfront` once, then run instances at the
/// discounted `rate` per slot for `term` slots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Contract {
    /// One-time reservation fee, in market currency.
    pub upfront: f64,
    /// Discounted usage rate per slot while the reservation is active.
    pub rate: f64,
    /// Reservation term in billing slots.
    pub term: usize,
}

impl Contract {
    /// Discount factor relative to an on-demand rate `p` (`α` in the paper).
    pub fn alpha_at(&self, p: f64) -> f64 {
        self.rate / p
    }

    /// Break-even on-demand spend within one term at rate `p`: the Eq. 10
    /// generalization `β = upfront / (1 − α)`. `+inf` when the contract
    /// carries no effective discount (`rate ≥ p`).
    pub fn beta_at(&self, p: f64) -> f64 {
        let alpha = self.alpha_at(p);
        if alpha >= 1.0 {
            f64::INFINITY
        } else {
            self.upfront / (1.0 - alpha)
        }
    }

    /// Steady-state cost per slot at full utilization: the fee amortized
    /// over the term plus the discounted rate. The menu policies use this
    /// to rank contracts that trigger simultaneously.
    pub fn steady_cost(&self) -> f64 {
        self.upfront / self.term as f64 + self.rate
    }
}

/// A validated menu of reservation contracts sharing one on-demand rate.
///
/// Construction sorts contracts by ascending term (ties: ascending upfront,
/// then rate) and applies dominance pruning; [`ContractId`]s index the
/// *final* menu. An empty menu (everything pruned) is valid and means
/// "reserving never helps" — policies degrade to all-on-demand.
#[derive(Debug, Clone, PartialEq)]
pub struct Market {
    p: f64,
    contracts: Vec<Contract>,
    labels: Vec<String>,
    /// `α_j` per contract. For [`Market::single`] this is the original
    /// `Pricing::alpha` verbatim (not recomputed), keeping the fast path
    /// bit-identical.
    alphas: Vec<f64>,
    /// Break-even spend `β_j` per contract (same caveat as `alphas`).
    betas: Vec<f64>,
    /// Contract ids sorted by ascending usage rate — the billing order
    /// (cheapest active reservation serves demand first).
    rate_order: Vec<ContractId>,
    /// Contract with the lowest steady-state cost per slot, if any.
    steady_best: Option<ContractId>,
}

impl Market {
    /// Build a menu with auto-generated labels (`c0`, `c1`, … in input
    /// order). See [`Market::with_labels`] for the validation rules.
    pub fn new(p: f64, contracts: Vec<Contract>) -> Market {
        let entries = contracts
            .into_iter()
            .enumerate()
            .map(|(i, c)| (format!("c{i}"), c))
            .collect();
        Market::with_labels(p, entries)
    }

    /// Build a labelled menu. Panics (like [`Pricing::normalized`]) unless
    /// `p > 0` and every contract has `upfront > 0`, `0 ≤ rate ≤ p`, and
    /// `term ≥ 1`.
    ///
    /// Dominance pruning drops a contract `B` when it can never be the
    /// strictly cheapest option:
    /// * **on-demand dominance** — `(p − rate_B)·term_B ≤ upfront_B`: even
    ///   full utilization over the whole term never beats paying on demand;
    /// * **pairwise dominance** — some `A` has `term_A ≥ term_B`,
    ///   `upfront_A ≤ upfront_B`, `rate_A ≤ rate_B` (strictly better in at
    ///   least one, or an exact duplicate appearing earlier in the sorted
    ///   order): `A` covers every usage `B` could, no costlier.
    ///
    /// Both rules preserve the optimal cost of serving any fixed usage
    /// horizon (`min_horizon_cost`) — property-tested in
    /// `rust/tests/market_props.rs`.
    pub fn with_labels(p: f64, entries: Vec<(String, Contract)>) -> Market {
        assert!(p > 0.0, "on-demand rate must be positive");
        for (label, c) in &entries {
            assert!(c.upfront > 0.0, "{label}: upfront fee must be positive");
            assert!(c.rate >= 0.0, "{label}: discounted rate must be non-negative");
            assert!(c.rate <= p, "{label}: discounted rate must not exceed the on-demand rate");
            assert!(c.term >= 1, "{label}: term must be at least one slot");
        }
        let mut entries = entries;
        entries.sort_by(|(_, a), (_, b)| {
            a.term
                .cmp(&b.term)
                .then(a.upfront.total_cmp(&b.upfront))
                .then(a.rate.total_cmp(&b.rate))
        });
        let kept: Vec<(String, Contract)> = entries
            .iter()
            .enumerate()
            .filter(|(i, (_, c))| !Market::dominated(p, &entries, *i, c))
            .map(|(_, e)| e.clone())
            .collect();
        let (labels, contracts): (Vec<String>, Vec<Contract>) = kept.into_iter().unzip();
        let alphas: Vec<f64> = contracts.iter().map(|c| c.alpha_at(p)).collect();
        let betas: Vec<f64> = contracts.iter().map(|c| c.beta_at(p)).collect();
        Market::assemble(p, contracts, labels, alphas, betas)
    }

    /// Validated + sorted but **unpruned** menu — for analysis and the
    /// pruning-invariance property tests. Production callers want
    /// [`Market::new`].
    pub fn new_unpruned(p: f64, contracts: Vec<Contract>) -> Market {
        assert!(p > 0.0, "on-demand rate must be positive");
        for c in &contracts {
            assert!(c.upfront > 0.0 && c.rate >= 0.0 && c.rate <= p && c.term >= 1);
        }
        let mut contracts = contracts;
        contracts.sort_by(|a, b| {
            a.term
                .cmp(&b.term)
                .then(a.upfront.total_cmp(&b.upfront))
                .then(a.rate.total_cmp(&b.rate))
        });
        let labels = (0..contracts.len()).map(|i| format!("c{i}")).collect();
        let alphas: Vec<f64> = contracts.iter().map(|c| c.alpha_at(p)).collect();
        let betas: Vec<f64> = contracts.iter().map(|c| c.beta_at(p)).collect();
        Market::assemble(p, contracts, labels, alphas, betas)
    }

    /// Embed a classic normalized [`Pricing`] as a one-contract market:
    /// `upfront = 1`, `rate = α·p`, `term = τ`. No pruning is applied (an
    /// `α = 1` pricing stays representable), and the stored `α`/`β` are the
    /// `Pricing` values verbatim, so every derived quantity — and therefore
    /// the whole single-contract policy/billing path — is bit-identical to
    /// the v1 arithmetic.
    pub fn single(pricing: Pricing) -> Market {
        let c = Contract { upfront: 1.0, rate: pricing.alpha * pricing.p, term: pricing.tau };
        Market::assemble(
            pricing.p,
            vec![c],
            vec!["reserved".to_string()],
            vec![pricing.alpha],
            vec![pricing.beta()],
        )
    }

    fn assemble(
        p: f64,
        contracts: Vec<Contract>,
        labels: Vec<String>,
        alphas: Vec<f64>,
        betas: Vec<f64>,
    ) -> Market {
        let mut rate_order: Vec<ContractId> = (0..contracts.len()).collect();
        rate_order
            .sort_by(|&a, &b| contracts[a].rate.total_cmp(&contracts[b].rate).then(a.cmp(&b)));
        let steady_best = (0..contracts.len()).min_by(|&a, &b| {
            contracts[a].steady_cost().total_cmp(&contracts[b].steady_cost()).then(a.cmp(&b))
        });
        Market { p, contracts, labels, alphas, betas, rate_order, steady_best }
    }

    fn dominated(p: f64, entries: &[(String, Contract)], i: usize, c: &Contract) -> bool {
        // on-demand dominance (equality keeps the tie on the on-demand side)
        if (p - c.rate) * c.term as f64 <= c.upfront {
            return true;
        }
        entries.iter().enumerate().any(|(j, (_, o))| {
            if j == i {
                return false;
            }
            let weakly = o.term >= c.term && o.upfront <= c.upfront && o.rate <= c.rate;
            let strictly = o.term > c.term || o.upfront < c.upfront || o.rate < c.rate;
            // exact duplicates: keep the first in sorted order
            weakly && (strictly || j < i)
        })
    }

    /// Market-wide on-demand rate per slot.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Number of contracts on the (pruned) menu.
    pub fn len(&self) -> usize {
        self.contracts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.contracts.is_empty()
    }

    /// One contract on the menu: the batched engine routes these through
    /// the classic single-contract policies (the bit-identical fast path).
    pub fn is_single(&self) -> bool {
        self.contracts.len() == 1
    }

    pub fn contract(&self, cid: ContractId) -> Contract {
        self.contracts[cid]
    }

    pub fn contracts(&self) -> &[Contract] {
        &self.contracts
    }

    pub fn label(&self, cid: ContractId) -> &str {
        &self.labels[cid]
    }

    /// Discount factor `α_j` of contract `cid`.
    pub fn alpha(&self, cid: ContractId) -> f64 {
        self.alphas[cid]
    }

    /// Break-even spend `β_j` of contract `cid`.
    pub fn beta(&self, cid: ContractId) -> f64 {
        self.betas[cid]
    }

    /// Largest discount factor on the menu (0 when empty). The generalized
    /// deterministic policy's empirical comparison bound is `2 − α_max`.
    pub fn alpha_max(&self) -> f64 {
        self.alphas.iter().copied().fold(0.0, f64::max)
    }

    /// Contract ids in ascending usage-rate order — the order the ledger
    /// bills reserved usage in (cheapest applicable reservation first).
    pub fn rate_order(&self) -> &[ContractId] {
        &self.rate_order
    }

    /// The contract with the lowest full-utilization cost per slot.
    pub fn steady_best(&self) -> Option<ContractId> {
        self.steady_best
    }

    /// The classic normalized pricing view of contract `cid`: on-demand
    /// rate and term renormalized to that contract's fee. For
    /// [`Market::single`] this round-trips the original `Pricing` exactly
    /// (`p / 1.0 == p`, stored `α`, same `τ`).
    pub fn contract_pricing(&self, cid: ContractId) -> Pricing {
        let c = self.contracts[cid];
        Pricing { p: self.p / c.upfront, alpha: self.alphas[cid], tau: c.term }
    }

    /// Cheapest way to run one instance for `h` consecutive slots starting
    /// a fresh commitment: on demand, or any single contract whose term
    /// covers `h`. The invariant dominance pruning must preserve.
    pub fn min_horizon_cost(&self, h: u64) -> f64 {
        let mut best = self.p * h as f64;
        for c in &self.contracts {
            if c.term as u64 >= h {
                best = best.min(c.upfront + c.rate * h as f64);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_trips_pricing_bitwise() {
        let pr = Pricing::normalized(0.08 / 69.0, 0.4875, 8760);
        let m = Market::single(pr);
        assert!(m.is_single());
        let back = m.contract_pricing(0);
        assert_eq!(back.p.to_bits(), pr.p.to_bits());
        assert_eq!(back.alpha.to_bits(), pr.alpha.to_bits());
        assert_eq!(back.tau, pr.tau);
        assert_eq!(m.beta(0).to_bits(), pr.beta().to_bits());
        assert_eq!(m.contract(0).rate.to_bits(), (pr.alpha * pr.p).to_bits());
    }

    #[test]
    fn single_keeps_alpha_one_contract() {
        // alpha = 1 would be pruned by Market::new (never beneficial), but
        // the single embedding must keep it representable.
        let pr = Pricing::normalized(0.1, 1.0, 10);
        let m = Market::single(pr);
        assert_eq!(m.len(), 1);
        assert!(m.beta(0).is_infinite());
    }

    #[test]
    fn sorts_by_term_and_prunes_on_demand_dominated() {
        let m = Market::new(
            0.1,
            vec![
                Contract { upfront: 2.0, rate: 0.05, term: 50 },
                Contract { upfront: 1.0, rate: 0.05, term: 10 },
                // never beats on-demand: (0.1 - 0.09) * 20 = 0.2 < 5
                Contract { upfront: 5.0, rate: 0.09, term: 20 },
            ],
        );
        assert_eq!(m.len(), 2);
        assert_eq!(m.contract(0).term, 10);
        assert_eq!(m.contract(1).term, 50);
    }

    #[test]
    fn prunes_pairwise_dominated() {
        let m = Market::new(
            0.1,
            vec![
                Contract { upfront: 1.0, rate: 0.02, term: 50 },
                // same upfront, worse rate, shorter term -> dominated
                Contract { upfront: 1.0, rate: 0.03, term: 40 },
            ],
        );
        assert_eq!(m.len(), 1);
        assert_eq!(m.contract(0).term, 50);
    }

    #[test]
    fn keeps_one_of_exact_duplicates() {
        let c = Contract { upfront: 1.0, rate: 0.02, term: 50 };
        let m = Market::new(0.1, vec![c, c]);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn everything_pruned_is_a_valid_empty_menu() {
        let m = Market::new(0.1, vec![Contract { upfront: 10.0, rate: 0.05, term: 3 }]);
        assert!(m.is_empty());
        assert!(!m.is_single());
        assert_eq!(m.alpha_max(), 0.0);
        assert_eq!(m.steady_best(), None);
        // min cost degrades to pure on-demand
        assert!((m.min_horizon_cost(7) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn derived_figures_match_definitions() {
        let m = Market::new(
            0.08,
            vec![
                Contract { upfront: 0.2, rate: 0.039, term: 6 },
                Contract { upfront: 0.45, rate: 0.031, term: 18 },
            ],
        );
        assert_eq!(m.len(), 2);
        assert!((m.alpha(0) - 0.4875).abs() < 1e-12);
        assert!((m.alpha(1) - 0.3875).abs() < 1e-12);
        assert!((m.beta(0) - 0.2 / (1.0 - 0.4875)).abs() < 1e-12);
        assert!((m.beta(1) - 0.45 / (1.0 - 0.3875)).abs() < 1e-12);
        assert!((m.alpha_max() - 0.4875).abs() < 1e-12);
        // c1 is cheaper both in rate and steady-state
        assert_eq!(m.rate_order(), &[1, 0]);
        assert_eq!(m.steady_best(), Some(1));
    }

    #[test]
    fn min_horizon_cost_picks_cheapest_applicable() {
        let m = Market::new(
            0.1,
            vec![
                Contract { upfront: 0.3, rate: 0.02, term: 5 },
                Contract { upfront: 0.8, rate: 0.01, term: 20 },
            ],
        );
        // h=1: on demand (0.1) beats 0.32 and 0.81
        assert!((m.min_horizon_cost(1) - 0.1).abs() < 1e-12);
        // h=5: short contract 0.3 + 0.1 = 0.4 < 0.5 on demand
        assert!((m.min_horizon_cost(5) - 0.4).abs() < 1e-12);
        // h=20: only the long contract applies: 0.8 + 0.2 = 1.0 < 2.0
        assert!((m.min_horizon_cost(20) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_rate_above_on_demand() {
        Market::new(0.05, vec![Contract { upfront: 1.0, rate: 0.06, term: 10 }]);
    }
}
