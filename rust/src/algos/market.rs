//! Menu-generalized online policies over a [`Market`] of contracts — the
//! paper's Sec. IX extension, promoted to a first-class API (this module
//! supersedes the former `algos::multislope` sketch).
//!
//! * [`MarketDeterministic`] — Algorithm 1 generalized per contract: each
//!   contract `j` keeps its own break-even window scan (window `term_j`,
//!   threshold `β_j`); when some contract's window shows unjustified
//!   on-demand spend past its break-even, the policy commits to the
//!   triggered contract with the best steady-state cost per slot. A
//!   reservation of *any* contract compensates *every* scan (the uniform-
//!   increment phantom bookkeeping of [`WindowScan`]), so cross-contract
//!   double-charging of the same usage is impossible.
//! * [`MarketRandomized`] — the same machinery with per-contract
//!   thresholds `z_j` drawn from the Eq. 24 density (scaled by each
//!   contract's fee), generalizing Algorithm 2.
//! * [`PinnedSingle`] — adapter running any single-contract policy against
//!   one designated contract of a multi-contract market (used for the
//!   All-reserved / Separate baselines in scenario reports).
//!
//! With a single-contract menu, [`MarketDeterministic`] *is* Algorithm 1:
//! same scan updates, same trigger condition, same coverage accounting —
//! asserted bit-identically against [`Deterministic`](super::deterministic::Deterministic)
//! in the tests below and in `rust/tests/market_props.rs`. Competitive
//! guarantees for true multi-contract menus are open (the paper leaves the
//! theory to future work); reports compare against `2 − α_max` empirically.
//!
//! **Known limitation (inherited from the `multislope` sketch):** because
//! every purchase compensates *every* scan, a deeper contract whose
//! break-even sits above a shallower one's can never accumulate enough
//! violations to trigger — each shallow purchase resets it. On menus where
//! the shallow contract fires first (e.g. the committed
//! `table1_two_term` scenario), the policy therefore behaves like the
//! shallow-only Algorithm 1 even when the offline optimum commits deep; it
//! still satisfies the `2 − α_max` comparison, but leaves the deep
//! contract's savings on the table. Fixing this needs spend-accounting
//! across tiers (count shallow fees as spend inside deeper windows) — a
//! ROADMAP open item, not attempted here.

use std::collections::VecDeque;

use super::density::sample_z;
use super::window::WindowScan;
use super::{Decision, Policy};
use crate::pricing::{ContractId, Market};
use crate::util::rng::Rng;

/// Deterministic menu policy: per-contract break-even scans over a shared
/// reservation pool. Purely online (`window() == 0`).
pub struct MarketDeterministic {
    market: Market,
    /// Per-contract reservation threshold (default: `β_j`). `+inf`-like
    /// sentinels mean "never commit to this contract".
    thresholds: Vec<f64>,
    /// One break-even scan per contract, window length `term_j`.
    scans: Vec<WindowScan>,
    /// Times of ALL reservations (any contract) still inside contract j's
    /// scan window — the per-scan `x` bookkeeping at insertion.
    res_times: Vec<VecDeque<usize>>,
    /// Actual coverage: expiry slots (exclusive) per contract, FIFO.
    cover: Vec<VecDeque<usize>>,
    /// Scratch: reservations made this slot, per contract.
    counts: Vec<u32>,
    /// Reusable typed-decision buffer.
    out: Vec<(ContractId, u32)>,
    t: usize,
    label: &'static str,
}

impl MarketDeterministic {
    /// Generalized Algorithm 1: threshold `β_j` per contract.
    pub fn new(market: Market) -> MarketDeterministic {
        let thresholds = (0..market.len()).map(|j| market.beta(j)).collect();
        MarketDeterministic::with_thresholds(market, thresholds)
    }

    /// Generalized `A_z` family: explicit per-contract thresholds, in
    /// market currency (a threshold of `β_j` reproduces `new`).
    pub fn with_thresholds(market: Market, thresholds: Vec<f64>) -> MarketDeterministic {
        assert_eq!(thresholds.len(), market.len(), "one threshold per contract");
        assert!(thresholds.iter().all(|z| *z >= 0.0), "thresholds must be non-negative");
        let k = market.len();
        MarketDeterministic {
            market,
            thresholds,
            scans: (0..k).map(|_| WindowScan::new()).collect(),
            res_times: (0..k).map(|_| VecDeque::new()).collect(),
            cover: (0..k).map(|_| VecDeque::new()).collect(),
            counts: vec![0; k],
            out: Vec::with_capacity(k),
            t: 0,
            label: "Deterministic",
        }
    }

    pub fn market(&self) -> &Market {
        &self.market
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    /// Active reservations (all contracts) covering slot `t`.
    fn covered(&mut self, t: usize) -> u32 {
        let mut total = 0u32;
        for q in self.cover.iter_mut() {
            while matches!(q.front(), Some(&e) if e <= t) {
                q.pop_front();
            }
            total += q.len() as u32;
        }
        total
    }
}

impl Policy for MarketDeterministic {
    fn name(&self) -> String {
        format!("{}(menu k={})", self.label, self.market.len())
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        let t = self.t;
        self.t += 1;
        let k = self.market.len();
        let p = self.market.p();

        // Update every contract's scan with this slot. A slot actually
        // covered by active reservations (of ANY term) must not count as a
        // violation in any scan — otherwise a short-term scan accumulates
        // stale violations while a long reservation covers the demand and
        // fires spuriously at its expiry. `x_ins` therefore takes the max
        // of the scan's own phantom bookkeeping and the real coverage.
        // (For a single-contract menu both quantities coincide and this is
        // exactly Algorithm 1's bookkeeping.)
        let covered_now = self.covered(t);
        for j in 0..k {
            let term = self.market.contract(j).term;
            self.scans[j].expire_before((t + 1).saturating_sub(term));
            let times = &mut self.res_times[j];
            while matches!(times.front(), Some(&rt) if rt + term <= t) {
                times.pop_front();
            }
            let x_ins = (times.len() as u32).max(covered_now);
            self.scans[j].insert(t, demand, x_ins);
        }

        // Commit while any contract's window shows unjustified on-demand
        // spend past its break-even; among simultaneously triggered
        // contracts, take the best steady-state cost per slot (ties: the
        // shortest term). Every reservation compensates every scan, so the
        // loop strictly shrinks the violation excess and terminates.
        for c in self.counts.iter_mut() {
            *c = 0;
        }
        loop {
            let mut pick: Option<ContractId> = None;
            for j in 0..k {
                if p * self.scans[j].violations() as f64 > self.thresholds[j] + 1e-12 {
                    pick = match pick {
                        Some(i)
                            if self.market.contract(i).steady_cost()
                                <= self.market.contract(j).steady_cost() =>
                        {
                            Some(i)
                        }
                        _ => Some(j),
                    };
                }
            }
            let Some(j) = pick else { break };
            self.cover[j].push_back(t + self.market.contract(j).term);
            self.counts[j] += 1;
            for i in 0..k {
                self.scans[i].reserve();
                self.res_times[i].push_back(t);
            }
        }

        self.out.clear();
        for j in 0..k {
            if self.counts[j] > 0 {
                self.out.push((j, self.counts[j]));
            }
        }
        let covered = self.covered(t);
        Decision { on_demand: demand.saturating_sub(covered), reservations: &self.out }
    }
}

/// Randomized menu policy: one threshold draw per contract at construction
/// (randomness over algorithms, not per-slot coins — Sec. V-A), then
/// deterministic behaviour via [`MarketDeterministic`].
pub struct MarketRandomized {
    inner: MarketDeterministic,
    seed: u64,
}

impl MarketRandomized {
    /// Generalized Algorithm 2: `z_j` drawn from contract `j`'s Eq. 24
    /// density (computed in `j`'s normalized pricing, scaled back by its
    /// fee). Contract 0 consumes `Rng::new(seed)` exactly like the classic
    /// single-contract [`Randomized`](super::randomized::Randomized).
    pub fn new(market: Market, seed: u64) -> MarketRandomized {
        let mut thresholds = Vec::with_capacity(market.len());
        for cid in 0..market.len() {
            let mut rng = Rng::new(seed ^ (cid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let z = sample_z(&market.contract_pricing(cid), &mut rng);
            // alpha = 1 draws z = +inf: never commit to this contract.
            // Clamp to a finite sentinel (same as the classic policy).
            let z_abs = if z.is_finite() {
                z * market.contract(cid).upfront
            } else {
                f64::MAX / 4.0
            };
            thresholds.push(z_abs);
        }
        let mut inner = MarketDeterministic::with_thresholds(market, thresholds);
        inner.label = "Randomized";
        MarketRandomized { inner, seed }
    }

    /// The drawn per-contract thresholds (for analysis / logging).
    pub fn thresholds(&self) -> &[f64] {
        self.inner.thresholds()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl Policy for MarketRandomized {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        self.inner.decide(demand, future)
    }
}

/// Adapter: run a single-contract policy against one designated contract
/// of a multi-contract market. The inner policy decides in its own
/// normalized view ([`Market::contract_pricing`]); this wrapper rewrites
/// its contract-0 reservations to `cid`.
pub struct PinnedSingle<P> {
    inner: P,
    cid: ContractId,
    out: [(ContractId, u32); 1],
}

impl<P: Policy> PinnedSingle<P> {
    pub fn new(inner: P, cid: ContractId) -> PinnedSingle<P> {
        PinnedSingle { inner, cid, out: [(cid, 0)] }
    }

    pub fn contract(&self) -> ContractId {
        self.cid
    }
}

impl<P: Policy> Policy for PinnedSingle<P> {
    fn name(&self) -> String {
        format!("{}@{}", self.inner.name(), self.cid)
    }

    fn window(&self) -> usize {
        self.inner.window()
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        let (on_demand, reserve) = {
            let dec = self.inner.decide(demand, future);
            (dec.on_demand, dec.total_reserved())
        };
        self.out = [(self.cid, reserve)];
        Decision { on_demand, reservations: &self.out[..usize::from(reserve > 0)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::deterministic::Deterministic;
    use crate::algos::randomized::Randomized;
    use crate::ledger::{CostReport, Ledger};
    use crate::pricing::{Contract, Pricing};
    use crate::util::rng::Rng;

    fn run(policy: &mut dyn Policy, demands: &[u32], market: &Market) -> CostReport {
        let mut ledger = Ledger::new(market.clone());
        for &d in demands {
            let dec = policy.decide(d, &[]);
            ledger.bill(d, &dec).unwrap();
        }
        ledger.report()
    }

    #[test]
    fn single_menu_matches_algorithm1_bitwise() {
        let pricing = Pricing::normalized(0.05, 0.4, 60);
        let market = Market::single(pricing);
        let mut rng = Rng::new(8);
        for case in 0..20 {
            let demands: Vec<u32> = (0..300)
                .map(|_| if rng.chance(0.4) { rng.below(4) as u32 } else { 0 })
                .collect();
            let menu = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
            let classic = run(&mut Deterministic::online(pricing), &demands, &market);
            assert_eq!(
                menu.total.to_bits(),
                classic.total.to_bits(),
                "case {case}: menu {} vs classic {}",
                menu.total,
                classic.total
            );
            assert_eq!(menu.reservations, classic.reservations);
            assert_eq!(menu.on_demand_slots, classic.on_demand_slots);
        }
    }

    #[test]
    fn single_menu_randomized_matches_classic_bitwise() {
        let pricing = Pricing::normalized(0.05, 0.4875, 40);
        let market = Market::single(pricing);
        let demands: Vec<u32> = (0..200).map(|i| ((i / 7) % 3) as u32).collect();
        for seed in 0..20u64 {
            let mut menu = MarketRandomized::new(market.clone(), seed);
            let mut classic = Randomized::online(pricing, seed);
            assert!((menu.thresholds()[0] - classic.threshold()).abs() < 1e-12
                || (!classic.threshold().is_finite() && menu.thresholds()[0] > 1e100));
            let a = run(&mut menu, &demands, &market);
            let b = run(&mut classic, &demands, &market);
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "seed {seed}");
        }
    }

    fn two_tier() -> Market {
        Market::new(
            0.05,
            vec![
                Contract { upfront: 1.0, rate: 0.025, term: 100 },
                Contract { upfront: 1.5, rate: 0.01, term: 300 },
            ],
        )
    }

    #[test]
    fn stable_demand_commits_to_the_deep_contract() {
        // Long stable demand: the 3x-term contract has the better
        // steady-state cost AND the lower break-even in slots, so the menu
        // policy commits deep and matches the deep-only alternative.
        let market = two_tier();
        let demands = vec![1u32; 900];
        let menu = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert!(menu.reservations >= 1);
        assert!(menu.reserved_slots > 0);
        let shallow = Market::new(0.05, vec![market.contract(0)]);
        let deep = Market::new(0.05, vec![market.contract(1)]);
        let rs = run(&mut MarketDeterministic::new(shallow.clone()), &demands, &shallow);
        let rd = run(&mut MarketDeterministic::new(deep.clone()), &demands, &deep);
        assert!(
            menu.total <= rs.total.min(rd.total) + 1e-9,
            "menu {} vs shallow {} deep {}",
            menu.total,
            rs.total,
            rd.total
        );
    }

    #[test]
    fn sporadic_demand_reserves_nothing() {
        let market = two_tier();
        let mut demands = vec![0u32; 2000];
        demands[100] = 3;
        demands[1500] = 2;
        let r = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert_eq!(r.reservations, 0);
    }

    #[test]
    fn empty_menu_degenerates_to_on_demand() {
        // a menu where reserving never pays prunes to empty
        let market = Market::new(0.1, vec![Contract { upfront: 10.0, rate: 0.05, term: 3 }]);
        assert!(market.is_empty());
        let demands = vec![4u32; 50];
        let r = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert_eq!(r.reservations, 0);
        assert_eq!(r.on_demand_slots, 200);
    }

    #[test]
    fn coverage_feasible_on_random_menus() {
        let mut rng = Rng::new(77);
        for _ in 0..15 {
            let p = 0.1 + rng.f64() * 0.2;
            let market = Market::new(
                p,
                vec![
                    Contract {
                        upfront: 0.2 + rng.f64() * 0.3,
                        rate: rng.f64() * 0.5 * p,
                        term: 10 + rng.below(20) as usize,
                    },
                    Contract {
                        upfront: 0.8 + rng.f64() * 1.2,
                        rate: rng.f64() * 0.3 * p,
                        term: 40 + rng.below(60) as usize,
                    },
                ],
            );
            let demands: Vec<u32> = (0..400).map(|_| rng.below(5) as u32).collect();
            // Ledger::bill errors on any infeasible decision.
            let det = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
            let rebuilt = det.reservation_fees + det.on_demand_cost + det.reserved_usage_cost;
            assert!((det.total - rebuilt).abs() < 1e-9);
            run(&mut MarketRandomized::new(market.clone(), 5), &demands, &market);
        }
    }

    #[test]
    fn pinned_single_rewrites_contract_id() {
        let market = two_tier();
        let pinned_cid = market.steady_best().unwrap();
        let inner = crate::algos::baselines::AllReserved::new(market.contract_pricing(pinned_cid));
        let mut p = PinnedSingle::new(inner, pinned_cid);
        let dec = p.decide(3, &[]);
        assert_eq!(dec.on_demand, 0);
        assert_eq!(dec.reservations, &[(pinned_cid, 3)]);
        // and it bills cleanly through the market ledger
        let mut l = Ledger::new(market.clone());
        let mut p2 = PinnedSingle::new(
            crate::algos::baselines::AllReserved::new(market.contract_pricing(pinned_cid)),
            pinned_cid,
        );
        for d in [3u32, 1, 0, 2] {
            let dec = p2.decide(d, &[]);
            l.bill(d, &dec).unwrap();
        }
        assert_eq!(l.report().on_demand_slots, 0);
    }
}
