//! Menu-generalized online policies over a [`Market`] of contracts — the
//! paper's Sec. IX extension, promoted to a first-class API (this module
//! supersedes the former `algos::multislope` sketch).
//!
//! * [`MarketDeterministic`] — Algorithm 1 generalized per contract: each
//!   contract `j` keeps its own break-even window scan (window `term_j`,
//!   threshold `β_j`); when some contract's window shows unjustified
//!   on-demand spend past its break-even, the policy commits to the
//!   triggered contract with the best steady-state cost per slot.
//! * [`MarketRandomized`] — the same machinery with per-contract
//!   thresholds `z_j` drawn from the Eq. 24 density (scaled by each
//!   contract's fee), generalizing Algorithm 2.
//! * [`PinnedSingle`] — adapter running any single-contract policy against
//!   one designated contract of a multi-contract market (used for the
//!   All-reserved / Separate baselines in scenario reports).
//!
//! # Cross-tier spend accounting
//!
//! Each contract's [`WindowScan`] tracks its *own* uncompensated on-demand
//! spend: a purchase of contract `c` compensates only the scans whose
//! break-even its fee actually covers (`β_i ≤ β_c`). A deeper contract
//! (higher break-even) therefore keeps accumulating the spend that cheaper
//! purchases left unjustified, and eventually triggers under sustained
//! demand — the former implementation compensated *every* scan on *every*
//! purchase, which let a shallow contract permanently shadow a deeper one
//! (the `table1_two_term` scenario used to commit shallow-only). A slot
//! already covered by an active reservation of *any* contract **at
//! insertion time** enters every scan as compensated (no on-demand spend
//! can happen there), so served usage is never double-charged. Coverage
//! that arrives *after* insertion is credited only through compensation:
//! with a prediction window, up to `w` already-inserted future slots that
//! a cheaper purchase later covers stay counted in deeper scans — by
//! design, the cheaper contract's spending (fee + discounted usage) keeps
//! accumulating toward break-evens its own fee does not justify, at most
//! `w` slots of lookahead early. The no-permanent-shadowing and (windowless)
//! spend-conservation properties are pinned in
//! `rust/tests/market_props.rs`; the cost sandwich against the joint
//! offline DP in `rust/tests/differential.rs`.
//!
//! # Prediction windows over menus (Sec. VI)
//!
//! [`MarketDeterministic::with_window`] / [`MarketRandomized::with_window`]
//! run every contract's scan over the shifted window `[t+w−τ_j+1, t+w]`
//! (Algorithm 3 semantics per contract) and add Algorithm 3's guard: with a
//! window, the policy only commits while current demand exceeds current
//! coverage. `w` must be shorter than every term on the menu (`w < min τ`).
//!
//! With a single-contract menu, [`MarketDeterministic`] *is* Algorithm 1
//! (and Algorithm 3 when `w > 0`): same scan updates, same trigger
//! condition, same coverage accounting — asserted bit-identically against
//! [`Deterministic`](super::deterministic::Deterministic) in the tests
//! below, in `rust/tests/market_props.rs`, and in
//! `rust/tests/differential.rs`. Competitive guarantees for true
//! multi-contract menus are open (the paper leaves the theory to future
//! work); reports compare against `2 − α_max` empirically.

use super::density::sample_z;
use super::window::WindowScan;
use super::{kernels, Decision, Policy, RunQueue, SaveState};
use crate::pricing::{ContractId, Market};
use crate::util::rng::Rng;
use crate::util::state::{StateReader, StateWriter};

/// Deterministic menu policy: per-contract break-even scans over a shared
/// reservation pool, with cross-tier spend accounting and an optional
/// prediction window (`window() == w`).
pub struct MarketDeterministic {
    market: Market,
    /// Per-contract reservation threshold (default: `β_j`). `+inf`-like
    /// sentinels mean "never commit to this contract".
    thresholds: Vec<f64>,
    /// Prediction window `w < min τ`; 0 = purely online.
    w: usize,
    /// Structure-of-arrays caches of the per-contract menu constants the
    /// per-slot loops index (`contract(j).term` / `beta(j)` /
    /// `steady_cost(j)` chase the menu Vec; these are flat, read-only
    /// arrays computed once at construction — same values, same f64 bits).
    terms: Vec<usize>,
    betas: Vec<f64>,
    steady: Vec<f64>,
    /// One break-even scan per contract, window length `term_j`.
    scans: Vec<WindowScan>,
    /// Times of the reservations that *compensated* contract j's scan and
    /// are still inside its window — the per-scan `x` bookkeeping at
    /// insertion, coalesced into `(time, count)` runs. A purchase of
    /// contract `c` lands here only for scans with `β_j ≤ β_c` (cross-tier
    /// accounting).
    res_times: Vec<RunQueue>,
    /// Actual coverage: expiry slots (exclusive) per contract, FIFO runs.
    cover: Vec<RunQueue>,
    /// Scratch: reservations made this slot, per contract.
    counts: Vec<u32>,
    /// Scratch: per-contract violation counts for the steady-cost pick.
    viol: Vec<u32>,
    /// Reusable typed-decision buffer.
    out: Vec<(ContractId, u32)>,
    t: usize,
    /// Next window slot index to insert into the scans (`t + w` ahead).
    next_scan_slot: usize,
    label: &'static str,
}

impl MarketDeterministic {
    /// Generalized Algorithm 1: threshold `β_j` per contract, no window.
    pub fn new(market: Market) -> MarketDeterministic {
        MarketDeterministic::with_window(market, 0)
    }

    /// Generalized Algorithm 3: threshold `β_j` per contract, prediction
    /// window `w` (must satisfy `w < term_j` for every menu contract).
    pub fn with_window(market: Market, w: usize) -> MarketDeterministic {
        let thresholds = (0..market.len()).map(|j| market.beta(j)).collect();
        MarketDeterministic::with_thresholds_window(market, thresholds, w)
    }

    /// Generalized `A_z` family: explicit per-contract thresholds, in
    /// market currency (a threshold of `β_j` reproduces `new`).
    pub fn with_thresholds(market: Market, thresholds: Vec<f64>) -> MarketDeterministic {
        MarketDeterministic::with_thresholds_window(market, thresholds, 0)
    }

    /// Fully general `A^w_z` over a menu.
    pub fn with_thresholds_window(
        market: Market,
        thresholds: Vec<f64>,
        w: usize,
    ) -> MarketDeterministic {
        assert_eq!(thresholds.len(), market.len(), "one threshold per contract");
        assert!(thresholds.iter().all(|z| *z >= 0.0), "thresholds must be non-negative");
        assert!(
            w == 0 || market.contracts().iter().all(|c| w < c.term),
            "prediction window must be shorter than every term on the menu"
        );
        let k = market.len();
        let terms = (0..k).map(|j| market.contract(j).term).collect();
        let betas = (0..k).map(|j| market.beta(j)).collect();
        let steady = (0..k).map(|j| market.contract(j).steady_cost()).collect();
        MarketDeterministic {
            market,
            thresholds,
            w,
            terms,
            betas,
            steady,
            scans: (0..k).map(|_| WindowScan::new()).collect(),
            res_times: (0..k).map(|_| RunQueue::default()).collect(),
            cover: (0..k).map(|_| RunQueue::default()).collect(),
            counts: vec![0; k],
            viol: vec![0; k],
            out: Vec::with_capacity(k),
            t: 0,
            next_scan_slot: 0,
            label: "Deterministic",
        }
    }

    pub fn market(&self) -> &Market {
        &self.market
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    /// Current violation count of contract `j`'s break-even scan — the
    /// uncompensated spend is `p ·` this. Diagnostics for the
    /// spend-conservation property tests.
    pub fn scan_violations(&self, j: ContractId) -> u32 {
        self.scans[j].violations()
    }

    /// Overwrite contract `j`'s reservation-trigger threshold **mid-run**
    /// (market currency, like [`with_thresholds`](Self::with_thresholds)).
    /// Thresholds enter only the trigger comparison `p·V_j > z_j` — no
    /// scan, queue, or coverage state derives from them — so swapping them
    /// between slots is safe and takes effect at the next `decide`. This is
    /// the hook the learning-augmented policies
    /// ([`crate::algos::learned`]) use to switch arms; note that
    /// [`Reset`](super::Reset) deliberately does NOT restore thresholds, so
    /// a learned wrapper's reset/reseed must re-set them itself.
    pub(crate) fn set_threshold(&mut self, j: ContractId, z: f64) {
        assert!(z >= 0.0, "threshold must be non-negative, got {z}");
        self.thresholds[j] = z;
    }

    /// Rename the policy for reports (learned wrappers relabel their inner
    /// machinery the same way [`MarketRandomized`] does).
    pub(crate) fn set_label(&mut self, label: &'static str) {
        self.label = label;
    }
}

impl super::Reset for MarketDeterministic {
    fn reset(&mut self) {
        for s in &mut self.scans {
            s.clear();
        }
        for q in &mut self.res_times {
            q.clear();
        }
        for q in &mut self.cover {
            q.clear();
        }
        for c in &mut self.counts {
            *c = 0;
        }
        self.out.clear();
        self.t = 0;
        self.next_scan_slot = 0;
    }
}

impl SaveState for MarketDeterministic {
    /// Serializes only dynamic state: thresholds (MarketRandomized redraws
    /// them, so they are not derivable from the menu), per-contract scans /
    /// compensation times / coverage expiries, and the slot cursors. The
    /// menu-derived `terms`/`betas`/`steady` arrays are reconstructed by the
    /// constructor; `counts`/`out` are per-slot scratch.
    fn save_state(&self, w: &mut StateWriter) {
        let k = self.market.len();
        w.usize(k);
        for &z in &self.thresholds {
            w.f64_bits(z);
        }
        for scan in &self.scans {
            scan.save_state(w);
        }
        for q in &self.res_times {
            q.save_state(w);
        }
        for q in &self.cover {
            q.save_state(w);
        }
        w.usize(self.t);
        w.usize(self.next_scan_slot);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let k = r.usize()?;
        anyhow::ensure!(
            k == self.market.len(),
            "checkpoint has {} contracts, market has {}",
            k,
            self.market.len()
        );
        for z in &mut self.thresholds {
            *z = r.f64_bits()?;
            anyhow::ensure!(*z >= 0.0, "checkpointed threshold {z} is negative");
        }
        for scan in &mut self.scans {
            scan.restore_state(r)?;
        }
        for q in &mut self.res_times {
            q.restore_state(r)?;
        }
        for q in &mut self.cover {
            q.restore_state(r)?;
        }
        self.t = r.usize()?;
        self.next_scan_slot = r.usize()?;
        for c in &mut self.counts {
            *c = 0;
        }
        self.out.clear();
        Ok(())
    }
}

impl Policy for MarketDeterministic {
    fn name(&self) -> String {
        if self.w == 0 {
            format!("{}(menu k={})", self.label, self.market.len())
        } else {
            format!("{}(menu k={},w={})", self.label, self.market.len(), self.w)
        }
    }

    fn window(&self) -> usize {
        self.w
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        let t = self.t;
        self.t += 1;
        let k = self.market.len();
        let p = self.market.p();

        // Slide every contract's check window to [t+w−τ_j+1, t+w], then
        // insert the newly visible slots (at t=0 this is 0..=w in one go,
        // afterwards one slot per step unless the horizon shrinks at the
        // trace tail). A slot actually covered by active reservations (of
        // ANY term) must not count as a violation in any scan — otherwise
        // a short-term scan accumulates stale violations while a long
        // reservation covers the demand and fires spuriously at its
        // expiry. `x_ins` therefore takes the max of the scan's own
        // compensation bookkeeping and the real coverage. (For a
        // single-contract menu both quantities coincide and this is
        // exactly Algorithm 1's — resp. Algorithm 3's — bookkeeping.)
        let covered_now = kernels::covered_now(&mut self.cover, t);
        let right = t + self.w;
        kernels::expire_scans(&mut self.scans, &self.terms, right);
        let visible_end = t + self.w.min(future.len());
        while self.next_scan_slot <= visible_end {
            let s = self.next_scan_slot;
            let d_s = if s == t { demand } else { future[s - t - 1] };
            let cov_s = if s == t { covered_now } else { kernels::covered_at(&self.cover, s) };
            for j in 0..k {
                let own = self.res_times[j].active_at(s, self.terms[j]);
                let x_ins = own.max(cov_s);
                self.scans[j].insert(s, d_s, x_ins);
            }
            self.next_scan_slot += 1;
        }

        // Commit while any contract's window shows unjustified on-demand
        // spend past its break-even; among simultaneously triggered
        // contracts, take the best steady-state cost per slot (ties: the
        // shortest term). Cross-tier accounting: a purchase of contract j
        // compensates exactly the scans whose break-even its fee covers
        // (β_i ≤ β_j) — deeper scans keep their violations and keep
        // accumulating across cheaper purchases. Each iteration buys from
        // a triggered scan, whose total violation excess strictly shrinks
        // on compensation, so the loop terminates.
        for c in self.counts.iter_mut() {
            *c = 0;
        }
        let mut cov = covered_now;
        kernels::gather_violations(&self.scans, &mut self.viol);
        loop {
            let Some(j) = kernels::pick_triggered(p, &self.viol, &self.thresholds, &self.steady)
            else {
                break;
            };
            // Algorithm 3's extra guard (Sec. VI): with a prediction
            // window, only commit while current demand exceeds coverage.
            if self.w > 0 && cov >= demand {
                break;
            }
            self.cover[j].push(t + self.terms[j]);
            cov += 1;
            self.counts[j] += 1;
            let cap = self.betas[j];
            for i in 0..k {
                if self.betas[i] <= cap {
                    self.scans[i].reserve();
                    self.res_times[i].push(t);
                }
            }
            kernels::gather_violations(&self.scans, &mut self.viol);
        }

        self.out.clear();
        for j in 0..k {
            if self.counts[j] > 0 {
                self.out.push((j, self.counts[j]));
            }
        }
        Decision { on_demand: demand.saturating_sub(cov), reservations: &self.out }
    }
}

/// Randomized menu policy: one threshold draw per contract at construction
/// (randomness over algorithms, not per-slot coins — Sec. V-A), then
/// deterministic behaviour via [`MarketDeterministic`].
pub struct MarketRandomized {
    inner: MarketDeterministic,
    seed: u64,
}

impl MarketRandomized {
    /// Generalized Algorithm 2: `z_j` drawn from contract `j`'s Eq. 24
    /// density (computed in `j`'s normalized pricing, scaled back by its
    /// fee). Contract 0 consumes `Rng::new(seed)` exactly like the classic
    /// single-contract [`Randomized`](super::randomized::Randomized).
    pub fn new(market: Market, seed: u64) -> MarketRandomized {
        MarketRandomized::with_window(market, 0, seed)
    }

    /// Generalized Algorithm 4: the same threshold draws driving the
    /// windowed deterministic machinery (`w < min τ`, Sec. VI).
    pub fn with_window(market: Market, w: usize, seed: u64) -> MarketRandomized {
        let mut thresholds = Vec::with_capacity(market.len());
        for cid in 0..market.len() {
            let mut rng = Rng::new(seed ^ (cid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let z = sample_z(&market.contract_pricing(cid), &mut rng);
            // alpha = 1 draws z = +inf: never commit to this contract.
            // Clamp to a finite sentinel (same as the classic policy).
            let z_abs = if z.is_finite() {
                z * market.contract(cid).upfront
            } else {
                f64::MAX / 4.0
            };
            thresholds.push(z_abs);
        }
        let mut inner = MarketDeterministic::with_thresholds_window(market, thresholds, w);
        inner.label = "Randomized";
        MarketRandomized { inner, seed }
    }

    /// Redraw every contract's threshold from a new seed and rewind to
    /// slot 0, exactly as if freshly constructed with that seed (same RNG
    /// streams, same draw order — shard-reuse path of the fleet engine).
    pub fn reseed(&mut self, seed: u64) {
        use super::Reset;
        for cid in 0..self.inner.market.len() {
            let mut rng = Rng::new(seed ^ (cid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let z = sample_z(&self.inner.market.contract_pricing(cid), &mut rng);
            self.inner.thresholds[cid] = if z.is_finite() {
                z * self.inner.market.contract(cid).upfront
            } else {
                f64::MAX / 4.0
            };
        }
        self.seed = seed;
        self.inner.reset();
    }

    /// The drawn per-contract thresholds (for analysis / logging).
    pub fn thresholds(&self) -> &[f64] {
        self.inner.thresholds()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl SaveState for MarketRandomized {
    /// Like the classic randomized policy, all randomness is consumed at
    /// construction/reseed; the drawn thresholds travel inside `inner`.
    fn save_state(&self, w: &mut StateWriter) {
        w.u64(self.seed);
        self.inner.save_state(w);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.seed = r.u64()?;
        self.inner.restore_state(r)
    }
}

impl Policy for MarketRandomized {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn window(&self) -> usize {
        self.inner.window()
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        self.inner.decide(demand, future)
    }
}

/// Adapter: run a single-contract policy against one designated contract
/// of a multi-contract market. The inner policy decides in its own
/// normalized view ([`Market::contract_pricing`]); this wrapper rewrites
/// its contract-0 reservations to `cid`.
pub struct PinnedSingle<P> {
    inner: P,
    cid: ContractId,
    out: [(ContractId, u32); 1],
}

impl<P: Policy> PinnedSingle<P> {
    pub fn new(inner: P, cid: ContractId) -> PinnedSingle<P> {
        PinnedSingle { inner, cid, out: [(cid, 0)] }
    }

    pub fn contract(&self) -> ContractId {
        self.cid
    }
}

impl<P: super::Reset> super::Reset for PinnedSingle<P> {
    fn reset(&mut self) {
        self.inner.reset();
        self.out = [(self.cid, 0)];
    }
}

impl<P: SaveState> SaveState for PinnedSingle<P> {
    fn save_state(&self, w: &mut StateWriter) {
        self.inner.save_state(w);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.inner.restore_state(r)?;
        self.out = [(self.cid, 0)];
        Ok(())
    }
}

impl<P: Policy> Policy for PinnedSingle<P> {
    fn name(&self) -> String {
        format!("{}@{}", self.inner.name(), self.cid)
    }

    fn window(&self) -> usize {
        self.inner.window()
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        let (on_demand, reserve) = {
            let dec = self.inner.decide(demand, future);
            (dec.on_demand, dec.total_reserved())
        };
        self.out = [(self.cid, reserve)];
        Decision { on_demand, reservations: &self.out[..usize::from(reserve > 0)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::deterministic::Deterministic;
    use crate::algos::randomized::Randomized;
    use crate::ledger::{CostReport, Ledger};
    use crate::pricing::{Contract, Pricing};
    use crate::util::rng::Rng;

    fn run(policy: &mut dyn Policy, demands: &[u32], market: &Market) -> CostReport {
        let w = policy.window();
        let mut ledger = Ledger::new(market.clone());
        for (t, &d) in demands.iter().enumerate() {
            let hi = (t + 1 + w).min(demands.len());
            let fut = if w == 0 { &[] } else { &demands[t + 1..hi] };
            let dec = policy.decide(d, fut);
            ledger.bill(d, &dec).unwrap();
        }
        ledger.report()
    }

    #[test]
    fn single_menu_matches_algorithm1_bitwise() {
        let pricing = Pricing::normalized(0.05, 0.4, 60);
        let market = Market::single(pricing);
        let mut rng = Rng::new(8);
        for case in 0..20 {
            let demands: Vec<u32> = (0..300)
                .map(|_| if rng.chance(0.4) { rng.below(4) as u32 } else { 0 })
                .collect();
            let menu = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
            let classic = run(&mut Deterministic::online(pricing), &demands, &market);
            assert_eq!(
                menu.total.to_bits(),
                classic.total.to_bits(),
                "case {case}: menu {} vs classic {}",
                menu.total,
                classic.total
            );
            assert_eq!(menu.reservations, classic.reservations);
            assert_eq!(menu.on_demand_slots, classic.on_demand_slots);
        }
    }

    #[test]
    fn single_menu_randomized_matches_classic_bitwise() {
        let pricing = Pricing::normalized(0.05, 0.4875, 40);
        let market = Market::single(pricing);
        let demands: Vec<u32> = (0..200).map(|i| ((i / 7) % 3) as u32).collect();
        for seed in 0..20u64 {
            let mut menu = MarketRandomized::new(market.clone(), seed);
            let mut classic = Randomized::online(pricing, seed);
            assert!((menu.thresholds()[0] - classic.threshold()).abs() < 1e-12
                || (!classic.threshold().is_finite() && menu.thresholds()[0] > 1e100));
            let a = run(&mut menu, &demands, &market);
            let b = run(&mut classic, &demands, &market);
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "seed {seed}");
        }
    }

    fn two_tier() -> Market {
        Market::new(
            0.05,
            vec![
                Contract { upfront: 1.0, rate: 0.025, term: 100 },
                Contract { upfront: 1.5, rate: 0.01, term: 300 },
            ],
        )
    }

    #[test]
    fn stable_demand_commits_to_the_deep_contract() {
        // Long stable demand: the 3x-term contract has the better
        // steady-state cost AND the lower break-even in slots, so the menu
        // policy commits deep and matches the deep-only alternative.
        let market = two_tier();
        let demands = vec![1u32; 900];
        let menu = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert!(menu.reservations >= 1);
        assert!(menu.reserved_slots > 0);
        let shallow = Market::new(0.05, vec![market.contract(0)]);
        let deep = Market::new(0.05, vec![market.contract(1)]);
        let rs = run(&mut MarketDeterministic::new(shallow.clone()), &demands, &shallow);
        let rd = run(&mut MarketDeterministic::new(deep.clone()), &demands, &deep);
        assert!(
            menu.total <= rs.total.min(rd.total) + 1e-9,
            "menu {} vs shallow {} deep {}",
            menu.total,
            rs.total,
            rd.total
        );
    }

    #[test]
    fn cross_tier_accounting_unshadows_the_deep_contract() {
        // p = 0.1; shallow {0.3, rate 0, term 5} has β = 0.3 (4 violating
        // slots trigger it); deep {0.9, rate 0, term 30} has β = 0.9 (10
        // violating slots). Shallow fires first and its purchases do NOT
        // compensate the deep scan (β_deep > β_shallow), so the deep scan
        // keeps accumulating 4 violations per shallow cycle and must fire
        // by the third cycle. The former every-purchase-compensates-all
        // accounting reset the deep scan each cycle and never committed
        // deep.
        let market = Market::new(
            0.1,
            vec![
                Contract { upfront: 0.3, rate: 0.0, term: 5 },
                Contract { upfront: 0.9, rate: 0.0, term: 30 },
            ],
        );
        assert_eq!(market.len(), 2);
        let demands = vec![1u32; 47];
        let mut policy = MarketDeterministic::new(market.clone());
        let mut per_contract = [0u32; 2];
        let mut ledger = Ledger::new(market.clone());
        for &d in &demands {
            let dec = policy.decide(d, &[]);
            for &(cid, n) in dec.reservations {
                per_contract[cid] += n;
            }
            ledger.bill(d, &dec).unwrap();
        }
        assert!(per_contract[0] >= 1, "shallow fires first: {per_contract:?}");
        assert!(per_contract[1] >= 1, "deep must eventually fire: {per_contract:?}");
    }

    #[test]
    fn single_menu_windowed_matches_algorithm3_bitwise() {
        let pricing = Pricing::normalized(0.05, 0.4, 60);
        let market = Market::single(pricing);
        let mut rng = Rng::new(31);
        for case in 0..15 {
            let w = 1 + rng.below(40) as usize;
            let demands: Vec<u32> = (0..300)
                .map(|_| if rng.chance(0.5) { rng.below(4) as u32 } else { 0 })
                .collect();
            let menu = run(
                &mut MarketDeterministic::with_window(market.clone(), w),
                &demands,
                &market,
            );
            let classic = run(&mut Deterministic::with_window(pricing, w), &demands, &market);
            assert_eq!(
                menu.total.to_bits(),
                classic.total.to_bits(),
                "case {case} w={w}: menu {} vs classic {}",
                menu.total,
                classic.total
            );
            assert_eq!(menu.reservations, classic.reservations);
            assert_eq!(menu.on_demand_slots, classic.on_demand_slots);
            // randomized windowed pair on the same seed
            let seed = 1000 + case as u64;
            let mr = run(
                &mut MarketRandomized::with_window(market.clone(), w, seed),
                &demands,
                &market,
            );
            let rc = run(&mut Randomized::with_window(pricing, w, seed), &demands, &market);
            assert_eq!(mr.total.to_bits(), rc.total.to_bits(), "case {case} w={w} randomized");
        }
    }

    #[test]
    fn menu_window_never_reserves_while_covered() {
        // Sec. VI guard on a menu: with a window, commitments only happen
        // while current demand exceeds coverage — so total active
        // reservations never exceed the peak demand level.
        let market = two_tier();
        let demands = vec![1u32; 400];
        let mut policy = MarketDeterministic::with_window(market.clone(), 20);
        let r = run(&mut policy, &demands, &market);
        assert!(r.reservations >= 1);
        assert!(r.peak_active <= 1, "guard violated: peak {}", r.peak_active);
    }

    #[test]
    fn menu_window_cuts_on_demand_slots_on_stable_demand() {
        let market = two_tier();
        let demands = vec![1u32; 900];
        let online = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        let windowed =
            run(&mut MarketDeterministic::with_window(market.clone(), 30), &demands, &market);
        assert!(
            windowed.on_demand_slots < online.on_demand_slots,
            "windowed od={} online od={}",
            windowed.on_demand_slots,
            online.on_demand_slots
        );
    }

    #[test]
    #[should_panic(expected = "shorter than every term")]
    fn menu_window_must_undercut_every_term() {
        let market = two_tier();
        // min term is 100: a window of 100 must be rejected
        MarketDeterministic::with_window(market, 100);
    }

    #[test]
    fn sporadic_demand_reserves_nothing() {
        let market = two_tier();
        let mut demands = vec![0u32; 2000];
        demands[100] = 3;
        demands[1500] = 2;
        let r = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert_eq!(r.reservations, 0);
    }

    #[test]
    fn empty_menu_degenerates_to_on_demand() {
        // a menu where reserving never pays prunes to empty
        let market = Market::new(0.1, vec![Contract { upfront: 10.0, rate: 0.05, term: 3 }]);
        assert!(market.is_empty());
        let demands = vec![4u32; 50];
        let r = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
        assert_eq!(r.reservations, 0);
        assert_eq!(r.on_demand_slots, 200);
    }

    #[test]
    fn coverage_feasible_on_random_menus() {
        let mut rng = Rng::new(77);
        for _ in 0..15 {
            let p = 0.1 + rng.f64() * 0.2;
            let market = Market::new(
                p,
                vec![
                    Contract {
                        upfront: 0.2 + rng.f64() * 0.3,
                        rate: rng.f64() * 0.5 * p,
                        term: 10 + rng.below(20) as usize,
                    },
                    Contract {
                        upfront: 0.8 + rng.f64() * 1.2,
                        rate: rng.f64() * 0.3 * p,
                        term: 40 + rng.below(60) as usize,
                    },
                ],
            );
            let demands: Vec<u32> = (0..400).map(|_| rng.below(5) as u32).collect();
            // Ledger::bill errors on any infeasible decision.
            let det = run(&mut MarketDeterministic::new(market.clone()), &demands, &market);
            let rebuilt = det.reservation_fees + det.on_demand_cost + det.reserved_usage_cost;
            assert!((det.total - rebuilt).abs() < 1e-9);
            run(&mut MarketRandomized::new(market.clone(), 5), &demands, &market);
        }
    }

    #[test]
    fn reset_matches_fresh_construction_bitwise() {
        use crate::algos::Reset;
        let market = two_tier();
        let mut rng = Rng::new(123);
        let mut reused = MarketDeterministic::with_window(market.clone(), 20);
        for case in 0..6 {
            let demands: Vec<u32> = (0..350).map(|_| rng.below(4) as u32).collect();
            reused.reset();
            let a = run(&mut reused, &demands, &market);
            let mut fresh = MarketDeterministic::with_window(market.clone(), 20);
            let b = run(&mut fresh, &demands, &market);
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "case {case}");
            assert_eq!(a.reservations, b.reservations, "case {case}");
        }
    }

    #[test]
    fn reseed_matches_fresh_construction_bitwise() {
        let market = two_tier();
        let mut rng = Rng::new(321);
        let mut reused = MarketRandomized::with_window(market.clone(), 15, 0);
        for seed in [9u64, 0, 77, 1 << 60] {
            let demands: Vec<u32> = (0..350).map(|_| rng.below(4) as u32).collect();
            reused.reseed(seed);
            let mut fresh = MarketRandomized::with_window(market.clone(), 15, seed);
            for (za, zb) in reused.thresholds().iter().zip(fresh.thresholds()) {
                assert_eq!(za.to_bits(), zb.to_bits(), "seed {seed}");
            }
            let a = run(&mut reused, &demands, &market);
            let b = run(&mut fresh, &demands, &market);
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "seed {seed}");
            assert_eq!(a.reservations, b.reservations, "seed {seed}");
        }
    }

    /// A checkpoint byte-crafted exactly as the pre-coalescing menu policy
    /// wrote it — contract count, thresholds, per-contract scans, then
    /// `res_times`/`cover` as **one usize key per purchased instance** —
    /// must restore into the run-coalesced policy, re-serialize to the
    /// identical bytes, and keep deciding consistently.
    #[test]
    fn pre_rewrite_checkpoint_blob_restores_byte_exactly() {
        let market = two_tier(); // betas: c0 = 2.0, c1 = 1.875
        // State after buying two instances of contract 1 at t = 40: its
        // purchase compensates only scans with β_i ≤ β_1, i.e. scan 1.
        let mut w = StateWriter::new();
        w.usize(2);
        w.f64_bits(2.0);
        w.f64_bits(1.875);
        for g in [0i64, 2] {
            w.i64(g);
            w.usize(2);
            for &(slot, e) in &[(40usize, 1i64), (41, 2)] {
                w.usize(slot);
                w.i64(e);
            }
        }
        w.usize(0); // res_times[0]: contract 0's scan was not compensated
        w.usize(2); // res_times[1]: one wire entry per instance
        w.usize(40);
        w.usize(40);
        w.usize(0); // cover[0]
        w.usize(2); // cover[1]: expiry slots 40 + 300, expanded per instance
        w.usize(340);
        w.usize(340);
        w.usize(42); // t
        w.usize(42); // next_scan_slot
        let blob = w.into_bytes();

        let mut policy = MarketDeterministic::new(market);
        let mut r = StateReader::new(&blob);
        policy.restore_state(&mut r).unwrap();
        r.finish().unwrap();

        let mut w2 = StateWriter::new();
        policy.save_state(&mut w2);
        assert_eq!(w2.into_bytes(), blob, "wire format must stay byte-identical");

        // continuation: the two contract-1 instances cover slot 42, scan 0
        // holds 2 violations (p·V = 0.1 ≤ β_0), nothing triggers.
        let dec = policy.decide(1, &[]);
        assert_eq!(dec.on_demand, 0);
        assert_eq!(dec.total_reserved(), 0);
        assert_eq!(policy.scan_violations(0), 2);
        assert_eq!(policy.scan_violations(1), 0);
    }

    #[test]
    fn pinned_single_rewrites_contract_id() {
        let market = two_tier();
        let pinned_cid = market.steady_best().unwrap();
        let inner = crate::algos::baselines::AllReserved::new(market.contract_pricing(pinned_cid));
        let mut p = PinnedSingle::new(inner, pinned_cid);
        let dec = p.decide(3, &[]);
        assert_eq!(dec.on_demand, 0);
        assert_eq!(dec.reservations, &[(pinned_cid, 3)]);
        // and it bills cleanly through the market ledger
        let mut l = Ledger::new(market.clone());
        let mut p2 = PinnedSingle::new(
            crate::algos::baselines::AllReserved::new(market.contract_pricing(pinned_cid)),
            pinned_cid,
        );
        for d in [3u32, 1, 0, 2] {
            let dec = p2.decide(d, &[]);
            l.bill(d, &dec).unwrap();
        }
        assert_eq!(l.report().on_demand_slots, 0);
    }
}
