//! Instance-acquisition policies: the paper's online algorithms, the
//! benchmark baselines, and the offline optimum.
//!
//! | paper | here |
//! |---|---|
//! | Algorithm 1 (`A_β`) | [`deterministic::Deterministic`] with `z = β` |
//! | family `A_z` (Sec. V-A) | [`deterministic::Deterministic`] with custom `z` |
//! | Algorithm 2 (randomized) | [`randomized::Randomized`] |
//! | Algorithm 3 (`A^w_β`) | [`deterministic::Deterministic`] with window `w` |
//! | Algorithm 4 (randomized + window) | [`randomized::Randomized`] with window `w` |
//! | All-on-demand / All-reserved / Separate (Sec. VII-B) | [`baselines`] |
//! | offline OPT (Sec. III) | [`offline`] |
//! | menu generalization (Sec. IX extension) | [`market`] |

pub mod baselines;
pub mod density;
pub mod deterministic;
pub(crate) mod kernels;
pub mod learned;
pub mod market;
pub mod offline;
pub mod randomized;
pub mod window;

use crate::pricing::{ContractId, Pricing};
use crate::util::state::{StateReader, StateWriter};

/// One slot's typed purchase decision: run `on_demand` instances on demand,
/// commit to `reservations` — `(contract id, count)` pairs from the
/// [`Market`](crate::pricing::Market) menu — and serve the rest of the
/// demand on active reservations.
///
/// The slice is **borrowed from the policy** (each policy owns a small
/// reusable buffer), so deciding allocates nothing; copy the counts out if
/// you need to keep them past the next `decide` call. Single-contract
/// policies always reserve contract 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Decision<'a> {
    pub on_demand: u32,
    pub reservations: &'a [(ContractId, u32)],
}

impl<'a> Decision<'a> {
    /// A pure on-demand decision (no reservations).
    pub fn on_demand_only(n: u32) -> Decision<'static> {
        Decision { on_demand: n, reservations: &[] }
    }

    /// Total new reservations across all contracts.
    pub fn total_reserved(&self) -> u32 {
        self.reservations.iter().map(|&(_, n)| n).sum()
    }

    /// New reservations of one specific contract.
    pub fn reserved(&self, cid: ContractId) -> u32 {
        self.reservations.iter().filter(|&&(c, _)| c == cid).map(|&(_, n)| n).sum()
    }
}

/// An online instance-acquisition policy. Drive it slot by slot; slots are
/// implicit and must be fed consecutively from 0.
///
/// `future` carries the predicted demands `d̂_{t+1}, …, d̂_{t+w}` for
/// prediction-window policies (Sec. VI); online policies ignore it. It is an
/// error to shrink the prediction horizon mid-run except at the trace tail.
pub trait Policy: Send {
    /// Human-readable name used in reports.
    fn name(&self) -> String;
    /// Decide purchases for the next slot given its demand. The returned
    /// [`Decision`] borrows the policy's internal reservation buffer.
    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_>;
    /// Prediction window length `w` this policy wants (0 for online).
    fn window(&self) -> usize {
        0
    }
}

/// Coalesced expiry bookkeeping shared by the policies and the ledger: a
/// FIFO of `(key, count)` **runs** with nondecreasing keys, replacing one
/// `VecDeque` entry per purchased instance. Keys are slot indices — either
/// reservation times (expire when `key + τ ≤ t`) or precomputed expiry
/// slots (expire when `key ≤ t`); each holder picks one convention. A
/// purchase batch of `n` instances is one run, so expiry loops walk runs,
/// not instances, and the cached total makes the common "how many are
/// active" probe O(1) after expiry.
#[derive(Debug, Clone, Default)]
pub(crate) struct RunQueue {
    runs: std::collections::VecDeque<(usize, u32)>,
    total: u32,
}

impl RunQueue {
    /// Append `n` entries with key `key`. Keys must be pushed in
    /// nondecreasing order (they are event times); equal keys coalesce into
    /// the trailing run.
    pub(crate) fn push_n(&mut self, key: usize, n: u32) {
        if n == 0 {
            return;
        }
        debug_assert!(
            !matches!(self.runs.back(), Some(&(k, _)) if k > key),
            "keys must be nondecreasing"
        );
        match self.runs.back_mut() {
            Some((k, c)) if *k == key => *c += n,
            _ => self.runs.push_back((key, n)),
        }
        self.total += n;
    }

    pub(crate) fn push(&mut self, key: usize) {
        self.push_n(key, 1);
    }

    /// Drop runs with `key < min_keep`. O(runs dropped), not instances.
    pub(crate) fn expire_before(&mut self, min_keep: usize) {
        while matches!(self.runs.front(), Some(&(k, _)) if k < min_keep) {
            let (_, c) = self.runs.pop_front().unwrap();
            self.total -= c;
        }
    }

    /// Count of entries still active at slot `t` under reservation-time
    /// keys (an entry from time `rt` with lifetime `τ` is active while
    /// `rt + τ > t`), dropping expired runs. This is the one shared
    /// phantom-reservation expiry helper — the policies' `res_times` /
    /// `scan_res` / `cover` bookkeeping all route through it.
    pub(crate) fn active_at(&mut self, t: usize, tau: usize) -> u32 {
        self.expire_before((t + 1).saturating_sub(tau));
        self.total
    }

    /// Entries currently held (after whatever expiry the holder ran).
    pub(crate) fn total(&self) -> u32 {
        self.total
    }

    /// Entries with `key > s`, without expiring anything — the
    /// `covered_at` probe under expiry-slot keys. Runs are nondecreasing,
    /// so the matching entries are a suffix.
    pub(crate) fn count_after(&self, s: usize) -> u32 {
        self.runs.iter().rev().take_while(|&&(k, _)| k > s).map(|&(_, c)| c).sum()
    }

    /// Drop all entries, keeping the allocation.
    pub(crate) fn clear(&mut self) {
        self.runs.clear();
        self.total = 0;
    }
}

/// Rewind a policy to its freshly-constructed state **without dropping its
/// heap allocations**, so one policy instance can replay many users (the
/// streaming fleet engine builds one policy per shard, not per user).
///
/// Contract: after `reset()`, `decide` must produce bit-identical output to
/// a newly constructed instance with the same parameters. Randomized
/// policies reseed instead (their threshold draw depends on the per-user
/// seed) — see `Randomized::reseed` / `market::MarketRandomized::reseed`.
pub(crate) trait Reset {
    fn reset(&mut self);
}

/// Checkpointable mutable state, the crash-recovery sibling of [`Reset`].
///
/// Contract: after `restore_state` on an instance constructed with the same
/// parameters (pricing, window, menu), `decide` must produce bit-identical
/// output to the instance that was saved. Only dynamic state is serialized —
/// derived configuration (pricing tables, break-even thresholds that never
/// change, window length) is re-derived from the constructor arguments and
/// cross-checked where cheap.
pub(crate) trait SaveState {
    fn save_state(&self, w: &mut StateWriter);
    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()>;
}

impl SaveState for RunQueue {
    /// Runs are expanded back to one key per instance on the wire, exactly
    /// the sequence the pre-coalescing per-instance deques serialized — so
    /// every policy and ledger checkpoint format stays byte-identical.
    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.total as usize);
        for &(k, c) in &self.runs {
            for _ in 0..c {
                w.usize(k);
            }
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let n = r.seq_len(8)?;
        self.clear();
        let mut prev = 0usize;
        for i in 0..n {
            let k = r.usize()?;
            anyhow::ensure!(
                i == 0 || k >= prev,
                "reservation queue state: keys must be nondecreasing (entry {i}: {k} after {prev})"
            );
            prev = k;
            self.push_n(k, 1);
        }
        Ok(())
    }
}

/// Construct every policy evaluated in Sec. VII, in the paper's order.
/// `seed` feeds the randomized policy's threshold draw.
pub fn benchmark_suite(pricing: &Pricing, seed: u64) -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(baselines::AllOnDemand::new()),
        Box::new(baselines::AllReserved::new(*pricing)),
        Box::new(baselines::Separate::new(*pricing)),
        Box::new(deterministic::Deterministic::online(*pricing)),
        Box::new(randomized::Randomized::online(*pricing, seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_queue_expiry() {
        let mut q = RunQueue::default();
        q.push(0);
        q.push(2);
        assert_eq!(q.active_at(2, 3), 2); // res@0 active t=0,1,2
        assert_eq!(q.active_at(3, 3), 1); // res@0 expired
        assert_eq!(q.active_at(4, 3), 1);
        assert_eq!(q.active_at(5, 3), 0);
    }

    #[test]
    fn run_queue_coalesces_equal_keys() {
        let mut q = RunQueue::default();
        q.push_n(4, 3);
        q.push(4);
        q.push_n(7, 2);
        assert_eq!(q.runs.len(), 2, "equal keys must share one run");
        assert_eq!(q.total(), 6);
        assert_eq!(q.count_after(4), 2);
        assert_eq!(q.count_after(3), 6);
        q.expire_before(5);
        assert_eq!(q.total(), 2);
        q.expire_before(8);
        assert_eq!(q.total(), 0);
    }

    #[test]
    fn run_queue_save_restore_round_trip() {
        let mut q = RunQueue::default();
        q.push(3);
        q.push_n(9, 2);
        q.push(14);
        let mut w = StateWriter::new();
        q.save_state(&mut w);
        let bytes = w.into_bytes();
        // the wire format expands runs: 4 per-instance keys
        assert_eq!(bytes.len(), 8 + 4 * 8);

        let mut restored = RunQueue::default();
        restored.push(777); // stale content must be discarded
        let mut r = StateReader::new(&bytes);
        restored.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(restored.runs, q.runs);
        assert_eq!(restored.total(), q.total());
    }

    #[test]
    fn run_queue_restore_rejects_decreasing_keys() {
        let mut w = StateWriter::new();
        w.usize(2);
        w.usize(9);
        w.usize(3); // out of order — not a state any run produces
        let bytes = w.into_bytes();
        let mut q = RunQueue::default();
        let err = q.restore_state(&mut StateReader::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("nondecreasing"), "{err}");
    }

    #[test]
    fn run_queue_restore_rejects_oversized_length() {
        let mut w = StateWriter::new();
        w.usize(1 << 50); // claims ~10^15 entries in an empty payload
        let bytes = w.into_bytes();
        let mut q = RunQueue::default();
        assert!(q.restore_state(&mut StateReader::new(&bytes)).is_err());
    }

    #[test]
    fn suite_has_five_policies() {
        let pr = Pricing::normalized(0.01, 0.5, 10);
        let suite = benchmark_suite(&pr, 1);
        assert_eq!(suite.len(), 5);
        let names: Vec<String> = suite.iter().map(|p| p.name()).collect();
        assert!(names.iter().any(|n| n.contains("on-demand")));
        assert!(names.iter().any(|n| n.contains("reserved")));
        assert!(names.iter().any(|n| n.contains("Separate")));
        assert!(names.iter().any(|n| n.contains("Deterministic")));
        assert!(names.iter().any(|n| n.contains("Randomized")));
    }
}
