//! Instance-acquisition policies: the paper's online algorithms, the
//! benchmark baselines, and the offline optimum.
//!
//! | paper | here |
//! |---|---|
//! | Algorithm 1 (`A_β`) | [`deterministic::Deterministic`] with `z = β` |
//! | family `A_z` (Sec. V-A) | [`deterministic::Deterministic`] with custom `z` |
//! | Algorithm 2 (randomized) | [`randomized::Randomized`] |
//! | Algorithm 3 (`A^w_β`) | [`deterministic::Deterministic`] with window `w` |
//! | Algorithm 4 (randomized + window) | [`randomized::Randomized`] with window `w` |
//! | All-on-demand / All-reserved / Separate (Sec. VII-B) | [`baselines`] |
//! | offline OPT (Sec. III) | [`offline`] |
//! | menu generalization (Sec. IX extension) | [`market`] |

pub mod baselines;
pub mod density;
pub mod deterministic;
pub mod market;
pub mod offline;
pub mod randomized;
pub mod window;

use crate::pricing::{ContractId, Pricing};
use crate::util::state::{StateReader, StateWriter};

/// One slot's typed purchase decision: run `on_demand` instances on demand,
/// commit to `reservations` — `(contract id, count)` pairs from the
/// [`Market`](crate::pricing::Market) menu — and serve the rest of the
/// demand on active reservations.
///
/// The slice is **borrowed from the policy** (each policy owns a small
/// reusable buffer), so deciding allocates nothing; copy the counts out if
/// you need to keep them past the next `decide` call. Single-contract
/// policies always reserve contract 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Decision<'a> {
    pub on_demand: u32,
    pub reservations: &'a [(ContractId, u32)],
}

impl<'a> Decision<'a> {
    /// A pure on-demand decision (no reservations).
    pub fn on_demand_only(n: u32) -> Decision<'static> {
        Decision { on_demand: n, reservations: &[] }
    }

    /// Total new reservations across all contracts.
    pub fn total_reserved(&self) -> u32 {
        self.reservations.iter().map(|&(_, n)| n).sum()
    }

    /// New reservations of one specific contract.
    pub fn reserved(&self, cid: ContractId) -> u32 {
        self.reservations.iter().filter(|&&(c, _)| c == cid).map(|&(_, n)| n).sum()
    }
}

/// An online instance-acquisition policy. Drive it slot by slot; slots are
/// implicit and must be fed consecutively from 0.
///
/// `future` carries the predicted demands `d̂_{t+1}, …, d̂_{t+w}` for
/// prediction-window policies (Sec. VI); online policies ignore it. It is an
/// error to shrink the prediction horizon mid-run except at the trace tail.
pub trait Policy: Send {
    /// Human-readable name used in reports.
    fn name(&self) -> String;
    /// Decide purchases for the next slot given its demand. The returned
    /// [`Decision`] borrows the policy's internal reservation buffer.
    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_>;
    /// Prediction window length `w` this policy wants (0 for online).
    fn window(&self) -> usize {
        0
    }
}

/// Helper shared by policies: active *actual* reservations bookkeeping.
#[derive(Debug, Clone, Default)]
pub(crate) struct ResQueue {
    times: std::collections::VecDeque<usize>,
}

impl ResQueue {
    /// Count of reservations still active at slot `t` (made in `[t−τ+1, t]`),
    /// dropping expired entries.
    fn active_at(&mut self, t: usize, tau: usize) -> u32 {
        while matches!(self.times.front(), Some(&rt) if rt + tau <= t) {
            self.times.pop_front();
        }
        self.times.len() as u32
    }

    fn push(&mut self, t: usize) {
        self.times.push_back(t);
    }

    /// Drop all entries, keeping the allocation.
    fn clear(&mut self) {
        self.times.clear();
    }
}

/// Rewind a policy to its freshly-constructed state **without dropping its
/// heap allocations**, so one policy instance can replay many users (the
/// streaming fleet engine builds one policy per shard, not per user).
///
/// Contract: after `reset()`, `decide` must produce bit-identical output to
/// a newly constructed instance with the same parameters. Randomized
/// policies reseed instead (their threshold draw depends on the per-user
/// seed) — see `Randomized::reseed` / `market::MarketRandomized::reseed`.
pub(crate) trait Reset {
    fn reset(&mut self);
}

/// Checkpointable mutable state, the crash-recovery sibling of [`Reset`].
///
/// Contract: after `restore_state` on an instance constructed with the same
/// parameters (pricing, window, menu), `decide` must produce bit-identical
/// output to the instance that was saved. Only dynamic state is serialized —
/// derived configuration (pricing tables, break-even thresholds that never
/// change, window length) is re-derived from the constructor arguments and
/// cross-checked where cheap.
pub(crate) trait SaveState {
    fn save_state(&self, w: &mut StateWriter);
    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()>;
}

impl SaveState for ResQueue {
    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.times.len());
        for &t in &self.times {
            w.usize(t);
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let n = r.usize()?;
        self.times.clear();
        for _ in 0..n {
            self.times.push_back(r.usize()?);
        }
        Ok(())
    }
}

/// Construct every policy evaluated in Sec. VII, in the paper's order.
/// `seed` feeds the randomized policy's threshold draw.
pub fn benchmark_suite(pricing: &Pricing, seed: u64) -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(baselines::AllOnDemand::new()),
        Box::new(baselines::AllReserved::new(*pricing)),
        Box::new(baselines::Separate::new(*pricing)),
        Box::new(deterministic::Deterministic::online(*pricing)),
        Box::new(randomized::Randomized::online(*pricing, seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn res_queue_expiry() {
        let mut q = ResQueue::default();
        q.push(0);
        q.push(2);
        assert_eq!(q.active_at(2, 3), 2); // res@0 active t=0,1,2
        assert_eq!(q.active_at(3, 3), 1); // res@0 expired
        assert_eq!(q.active_at(4, 3), 1);
        assert_eq!(q.active_at(5, 3), 0);
    }

    #[test]
    fn res_queue_save_restore_round_trip() {
        let mut q = ResQueue::default();
        q.push(3);
        q.push(9);
        q.push(14);
        let mut w = StateWriter::new();
        q.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut restored = ResQueue::default();
        restored.push(777); // stale content must be discarded
        let mut r = StateReader::new(&bytes);
        restored.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(restored.times, q.times);
    }

    #[test]
    fn suite_has_five_policies() {
        let pr = Pricing::normalized(0.01, 0.5, 10);
        let suite = benchmark_suite(&pr, 1);
        assert_eq!(suite.len(), 5);
        let names: Vec<String> = suite.iter().map(|p| p.name()).collect();
        assert!(names.iter().any(|n| n.contains("on-demand")));
        assert!(names.iter().any(|n| n.contains("reserved")));
        assert!(names.iter().any(|n| n.contains("Separate")));
        assert!(names.iter().any(|n| n.contains("Deterministic")));
        assert!(names.iter().any(|n| n.contains("Randomized")));
    }
}
