//! Learning-augmented acquisition policies (ROADMAP "learning-augmented
//! policy family", after Wu et al. arXiv:1607.05178).
//!
//! The paper's online algorithms are worst-case optimal but ignore
//! everything a trace reveals about itself. The two policies here learn
//! from the demand stream while staying behind the ordinary
//! [`Policy`]/`decide` interface, so the fleet engine, checkpointing, and
//! the differential harness drive them like any other policy:
//!
//! * [`UcbThreshold`] — keeps the deterministic machinery of
//!   [`MarketDeterministic`] but **learns the reservation-trigger
//!   threshold**: each contract's trigger is `m · β_j` for a multiplier
//!   `m` drawn from a small arm grid centered on the deterministic seed
//!   arm `m = 1` (Algorithm 1's `z = β`). Arms are switched between
//!   fixed-length epochs by a UCB1 rule over a policy-side cost estimate.
//! * [`AdaptiveWindow`] — reuses the `forecast/` AR model to synthesize a
//!   prediction window (Sec. VI semantics) and **adapts the trusted
//!   window length to the measured forecast error**, degrading to
//!   (approximately) windowless Algorithm 1 behavior when the forecast is
//!   bad.
//!
//! Neither policy carries the paper's `2 − α` guarantee — see PERF.md
//! §"Learned policies" for what is and is not a theorem here. The
//! differential harness pins the sanity sandwich `joint DP ≤ learned`
//! and the scenario reports account per-policy **regret vs the joint DP**.

use super::market::MarketDeterministic;
use super::{Decision, Policy, Reset, SaveState};
use crate::forecast::{ArForecaster, Forecaster};
use crate::pricing::Market;
use crate::util::rng::Rng;
use crate::util::state::{StateReader, StateWriter};

/// The threshold-multiplier arm grid, as fractions of each contract's
/// break-even threshold `β_j`. Arm `1.0` reproduces the deterministic
/// policy's trigger exactly (the "seeded from the deterministic z" arm);
/// smaller multipliers reserve more eagerly, larger ones more lazily.
pub const ARM_MULTIPLIERS: [f64; 5] = [0.5, 0.75, 1.0, 1.25, 1.5];

const ARMS: usize = ARM_MULTIPLIERS.len();

/// Index of the multiplier-`1.0` arm in [`ARM_MULTIPLIERS`]: always
/// explored first so the policy starts as plain Algorithm 1 on the menu.
const SEED_ARM: usize = 2;

/// Epoch length bounds: long enough for a reservation decision to show up
/// in the cost signal, short enough that short traces still switch arms.
const EPOCH_MIN: usize = 8;
const EPOCH_MAX: usize = 256;

/// UCB threshold selection over [`MarketDeterministic`].
///
/// Time is split into fixed-length epochs (length derived from the menu's
/// shortest term, clamped to `[EPOCH_MIN, EPOCH_MAX]`). At each epoch
/// boundary an arm — a per-contract threshold multiplier — is chosen by
/// UCB1 over the per-epoch reward `clamp(1 − cost_est/od_cost, −1, 1)`,
/// where `cost_est` is a **policy-side estimate** (upfront fees plus
/// on-demand spend plus reserved slots at the menu's cheapest rate) and
/// `od_cost` is the all-on-demand cost of the epoch's demand. The estimate
/// is a learning signal, not billing — the `Ledger` remains the only
/// source of truth for cost.
///
/// The `seed` only permutes the initial exploration order of the non-seed
/// arms; everything else is deterministic. `reseed` restores the
/// freshly-constructed state for a new seed (the reseed-equals-fresh
/// invariant the fleet engine relies on, like `MarketRandomized`).
pub struct UcbThreshold {
    inner: MarketDeterministic,
    seed: u64,
    epoch_len: usize,
    /// Flat copies of menu facts consulted in `decide` while the
    /// [`Decision`] still borrows `inner` (field-disjoint access).
    p: f64,
    upfronts: Vec<f64>,
    min_rate: f64,
    arm: usize,
    slot_in_epoch: usize,
    epochs_done: u64,
    pulls: [u64; ARMS],
    reward_sum: [f64; ARMS],
    order: [usize; ARMS],
    epoch_cost: f64,
    epoch_od_cost: f64,
}

impl UcbThreshold {
    pub fn new(market: Market, seed: u64) -> UcbThreshold {
        let epoch_len = market
            .contracts()
            .iter()
            .map(|c| c.term)
            .min()
            .unwrap_or(EPOCH_MAX)
            .clamp(EPOCH_MIN, EPOCH_MAX);
        let p = market.p();
        let upfronts: Vec<f64> = market.contracts().iter().map(|c| c.upfront).collect();
        let min_rate =
            market.contracts().iter().map(|c| c.rate).fold(f64::INFINITY, f64::min).min(p);
        let mut inner = MarketDeterministic::new(market);
        inner.set_label("UCB");
        let mut policy = UcbThreshold {
            inner,
            seed,
            epoch_len,
            p,
            upfronts,
            min_rate,
            arm: SEED_ARM,
            slot_in_epoch: 0,
            epochs_done: 0,
            pulls: [0; ARMS],
            reward_sum: [0.0; ARMS],
            order: [0; ARMS],
            epoch_cost: 0.0,
            epoch_od_cost: 0.0,
        };
        policy.reseed(seed);
        policy
    }

    /// Exploration order: the deterministic seed arm first, then the
    /// remaining arms in a seed-shuffled order.
    fn exploration_order(seed: u64) -> [usize; ARMS] {
        let mut rest: Vec<usize> = (0..ARMS).filter(|&a| a != SEED_ARM).collect();
        Rng::new(seed).shuffle(&mut rest);
        let mut order = [SEED_ARM; ARMS];
        order[1..].copy_from_slice(&rest);
        order
    }

    /// Redraw exploration order and wipe all learned statistics, restoring
    /// the freshly-constructed state for `seed`.
    pub fn reseed(&mut self, seed: u64) {
        self.seed = seed;
        self.order = Self::exploration_order(seed);
        self.arm = self.order[0];
        self.pulls = [0; ARMS];
        self.reward_sum = [0.0; ARMS];
        self.epochs_done = 0;
        self.slot_in_epoch = 0;
        self.epoch_cost = 0.0;
        self.epoch_od_cost = 0.0;
        self.inner.reset();
        self.apply_arm();
    }

    /// Push the current arm's thresholds into the inner policy.
    /// `MarketDeterministic::reset` deliberately leaves thresholds alone,
    /// so every path that changes `arm` or resets `inner` re-applies.
    fn apply_arm(&mut self) {
        let mult = ARM_MULTIPLIERS[self.arm];
        for j in 0..self.inner.market().len() {
            let beta = self.inner.market().beta(j);
            self.inner.set_threshold(j, mult * beta);
        }
    }

    /// UCB1 over mean reward; unexplored arms first in `order`; ties break
    /// to the lowest arm index (deterministic).
    fn select_arm(&self) -> usize {
        for &a in &self.order {
            if self.pulls[a] == 0 {
                return a;
            }
        }
        let ln_n = (self.epochs_done as f64).ln();
        let mut best = 0;
        let mut best_idx = f64::NEG_INFINITY;
        for a in 0..ARMS {
            let mean = self.reward_sum[a] / self.pulls[a] as f64;
            let idx = mean + (2.0 * ln_n / self.pulls[a] as f64).sqrt();
            if idx > best_idx {
                best_idx = idx;
                best = a;
            }
        }
        best
    }

    fn finish_epoch(&mut self) {
        let reward = if self.epoch_od_cost > 0.0 {
            (1.0 - self.epoch_cost / self.epoch_od_cost).clamp(-1.0, 1.0)
        } else {
            0.0
        };
        self.pulls[self.arm] += 1;
        self.reward_sum[self.arm] += reward;
        self.epochs_done += 1;
        self.epoch_cost = 0.0;
        self.epoch_od_cost = 0.0;
        self.slot_in_epoch = 0;
    }

    /// Arm pull counts, in [`ARM_MULTIPLIERS`] order (diagnostics/tests).
    pub fn pulls(&self) -> [u64; ARMS] {
        self.pulls
    }
}

impl Policy for UcbThreshold {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        if self.slot_in_epoch == 0 {
            self.arm = self.select_arm();
            self.apply_arm();
        }
        let dec = self.inner.decide(demand, &[]);
        let mut fees = 0.0;
        for &(j, n) in dec.reservations {
            fees += self.upfronts[j] * n as f64;
        }
        let served_reserved = demand.saturating_sub(dec.on_demand);
        self.epoch_cost +=
            fees + self.p * dec.on_demand as f64 + self.min_rate * served_reserved as f64;
        self.epoch_od_cost += self.p * demand as f64;
        self.slot_in_epoch += 1;
        if self.slot_in_epoch == self.epoch_len {
            self.finish_epoch();
        }
        dec
    }
}

impl Reset for UcbThreshold {
    fn reset(&mut self) {
        let seed = self.seed;
        self.reseed(seed);
    }
}

impl SaveState for UcbThreshold {
    /// Wire: seed, arm, slot_in_epoch, epochs_done, arm table (count-
    /// prefixed `(pulls u64, reward f64, order usize)` triples), epoch
    /// accumulators, then the inner policy blob (which carries the live
    /// thresholds, so restore does not re-apply the arm).
    fn save_state(&self, w: &mut StateWriter) {
        w.u64(self.seed);
        w.usize(self.arm);
        w.usize(self.slot_in_epoch);
        w.u64(self.epochs_done);
        w.usize(ARMS);
        for a in 0..ARMS {
            w.u64(self.pulls[a]);
            w.f64_bits(self.reward_sum[a]);
            w.usize(self.order[a]);
        }
        w.f64_bits(self.epoch_cost);
        w.f64_bits(self.epoch_od_cost);
        self.inner.save_state(w);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.seed = r.u64()?;
        let arm = r.usize()?;
        anyhow::ensure!(arm < ARMS, "UCB state: arm index {arm} out of range (grid has {ARMS})");
        self.arm = arm;
        let slot = r.usize()?;
        anyhow::ensure!(
            slot < self.epoch_len,
            "UCB state: slot_in_epoch {slot} out of range (epoch length {})",
            self.epoch_len
        );
        self.slot_in_epoch = slot;
        self.epochs_done = r.u64()?;
        let n = r.seq_len(8 + 8 + 8)?;
        anyhow::ensure!(n == ARMS, "UCB state: checkpoint has {n} arms, grid has {ARMS}");
        let mut seen = [false; ARMS];
        for a in 0..ARMS {
            self.pulls[a] = r.u64()?;
            self.reward_sum[a] = r.f64_bits()?;
            let o = r.usize()?;
            anyhow::ensure!(
                o < ARMS && !seen[o],
                "UCB state: exploration order is not a permutation of 0..{ARMS}"
            );
            seen[o] = true;
            self.order[a] = o;
        }
        self.epoch_cost = r.f64_bits()?;
        self.epoch_od_cost = r.f64_bits()?;
        anyhow::ensure!(
            self.epoch_cost.is_finite() && self.epoch_od_cost.is_finite(),
            "UCB state: non-finite epoch accumulators"
        );
        self.inner.restore_state(r)
    }
}

/// AR forecaster shape for [`AdaptiveWindow`]: small-order model refit
/// frequently on a bounded rolling history.
const AR_K: usize = 3;
const AR_REFIT: usize = 32;
const AR_HISTORY: usize = 256;

/// Slots of pure observation before the forecast is trusted at all.
const WARMUP: usize = 32;
/// EWMA smoothing for the relative one-step-ahead forecast error.
const ERR_SMOOTH: f64 = 0.1;
/// Error below which the full window is trusted.
const ERR_FULL: f64 = 0.2;
/// Error at or above which the policy degrades to the windowless fallback.
const ERR_NONE: f64 = 0.6;
/// Cap on the synthetic window length (beyond ~a few β's worth of slots
/// the AR tail is noise anyway).
const W_CAP: usize = 16;

/// Forecast-driven adaptive prediction windows.
///
/// Wraps a windowed [`MarketDeterministic`] (`w_max = min(min τ − 1,
/// W_CAP)`) and feeds it a **synthetic** prediction window built from the
/// streaming AR forecaster instead of oracle demand. The trusted length
/// `w_cur ∈ {0, w_max/2, w_max}` follows an EWMA of the relative one-step
/// forecast error: accurate forecasts widen the window toward Sec. VI's
/// Algorithm 3 behavior, inaccurate ones shrink it to 0, where the
/// synthetic window is all zeros and the policy approximates windowless
/// Algorithm 1 (the inner policy still applies the window guard, so the
/// fallback is conservative, never over-reserving past current demand).
///
/// The inner policy is always fed exactly `w_max` slots — the `Policy`
/// contract forbids shrinking the horizon mid-run — with slots beyond
/// `w_cur` zeroed. To the driver this is an **online** policy
/// (`window() == 0`): the engine hands it no oracle future and the
/// forecast window is manufactured internally.
pub struct AdaptiveWindow {
    inner: MarketDeterministic,
    forecaster: ArForecaster,
    w_max: usize,
    w_cur: usize,
    err_ewma: f64,
    last_pred: f64,
    t: usize,
    synth: Vec<u32>,
    pred: Vec<f64>,
    scratch: Vec<f64>,
}

impl AdaptiveWindow {
    pub fn new(market: Market) -> AdaptiveWindow {
        let w_max = market
            .contracts()
            .iter()
            .map(|c| c.term - 1)
            .min()
            .unwrap_or(0)
            .min(W_CAP);
        let mut inner = if w_max == 0 {
            MarketDeterministic::new(market)
        } else {
            MarketDeterministic::with_window(market, w_max)
        };
        inner.set_label("AdaptiveWindow");
        AdaptiveWindow {
            inner,
            forecaster: ArForecaster::new(AR_K, AR_REFIT, AR_HISTORY),
            w_max,
            w_cur: 0,
            err_ewma: 0.0,
            last_pred: 0.0,
            t: 0,
            synth: Vec::with_capacity(w_max),
            pred: Vec::with_capacity(w_max.max(1)),
            scratch: Vec::with_capacity(AR_K + 1),
        }
    }

    /// Current trusted window length (diagnostics/tests).
    pub fn current_window(&self) -> usize {
        self.w_cur
    }

    /// Current smoothed relative forecast error (diagnostics/tests).
    pub fn forecast_error(&self) -> f64 {
        self.err_ewma
    }
}

impl Policy for AdaptiveWindow {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        // Score the forecast made one slot ago against what just arrived.
        if self.t > 0 {
            let rel = (self.last_pred - demand as f64).abs() / demand.max(1) as f64;
            self.err_ewma = (1.0 - ERR_SMOOTH) * self.err_ewma + ERR_SMOOTH * rel;
        }
        self.forecaster.observe(demand);
        self.t += 1;
        self.w_cur = if self.w_max == 0 || self.t <= WARMUP || self.err_ewma >= ERR_NONE {
            0
        } else if self.err_ewma <= ERR_FULL {
            self.w_max
        } else {
            self.w_max / 2
        };
        // Predict at least one step so the error tracker always has a
        // forecast to score, even while the window is collapsed.
        let horizon = self.w_max.max(1);
        self.forecaster.predict_f64_into(horizon, &mut self.pred, &mut self.scratch);
        self.last_pred = self.pred[0];
        self.synth.clear();
        for i in 0..self.w_max {
            let v = if i < self.w_cur { self.pred[i].round().max(0.0) as u32 } else { 0 };
            self.synth.push(v);
        }
        self.inner.decide(demand, &self.synth)
    }
}

impl Reset for AdaptiveWindow {
    fn reset(&mut self) {
        self.inner.reset();
        self.forecaster.reset();
        self.w_cur = 0;
        self.err_ewma = 0.0;
        self.last_pred = 0.0;
        self.t = 0;
        self.synth.clear();
        self.pred.clear();
        self.scratch.clear();
    }
}

impl SaveState for AdaptiveWindow {
    /// Wire: forecaster blob, error tracker (`err_ewma`, `last_pred`),
    /// `w_cur`, `t`, then the inner policy blob. `w_max` is derived from
    /// the constructor's market and cross-checked.
    fn save_state(&self, w: &mut StateWriter) {
        self.forecaster.save_state(w);
        w.f64_bits(self.err_ewma);
        w.f64_bits(self.last_pred);
        w.usize(self.w_cur);
        w.usize(self.t);
        self.inner.save_state(w);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.forecaster.restore_state(r)?;
        self.err_ewma = r.f64_bits()?;
        self.last_pred = r.f64_bits()?;
        anyhow::ensure!(
            self.err_ewma.is_finite() && self.err_ewma >= 0.0 && self.last_pred.is_finite(),
            "adaptive-window state: corrupt error tracker"
        );
        let w_cur = r.usize()?;
        anyhow::ensure!(
            w_cur <= self.w_max,
            "adaptive-window state: window {w_cur} exceeds maximum {}",
            self.w_max
        );
        self.w_cur = w_cur;
        self.t = r.usize()?;
        self.inner.restore_state(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::{Contract, Pricing};

    fn menu() -> Market {
        Market::new(
            0.05,
            vec![
                Contract { upfront: 1.0, rate: 0.025, term: 100 },
                Contract { upfront: 1.5, rate: 0.01, term: 300 },
            ],
        )
    }

    fn single() -> Market {
        Market::single(Pricing::normalized(0.2, 0.2, 40))
    }

    fn demands(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(6) as u32).collect()
    }

    fn run_decisions(p: &mut dyn Policy, demands: &[u32]) -> Vec<(u32, Vec<(usize, u32)>)> {
        demands
            .iter()
            .map(|&d| {
                let dec = p.decide(d, &[]);
                (dec.on_demand, dec.reservations.to_vec())
            })
            .collect()
    }

    #[test]
    fn ucb_reseed_matches_fresh_instance() {
        let ds = demands(700, 9);
        let mut reused = UcbThreshold::new(menu(), 1);
        run_decisions(&mut reused, &ds); // dirty it with a different seed
        for seed in [0u64, 7, 42] {
            reused.reseed(seed);
            let mut fresh = UcbThreshold::new(menu(), seed);
            assert_eq!(
                run_decisions(&mut reused, &ds),
                run_decisions(&mut fresh, &ds),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn ucb_explores_every_arm_on_long_traces() {
        let ds = demands(ARMS * 300, 3);
        let mut p = UcbThreshold::new(menu(), 5);
        run_decisions(&mut p, &ds);
        assert!(
            p.pulls().iter().all(|&n| n > 0),
            "every arm should be pulled at least once: {:?}",
            p.pulls()
        );
    }

    #[test]
    fn ucb_save_restore_resumes_bit_identically() {
        let ds = demands(900, 11);
        let (head, tail) = ds.split_at(450);
        let mut live = UcbThreshold::new(menu(), 13);
        run_decisions(&mut live, head);
        let mut w = StateWriter::new();
        live.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut restored = UcbThreshold::new(menu(), 99); // wrong seed on purpose
        run_decisions(&mut restored, &ds[..100]); // and dirty state
        let mut r = StateReader::new(&bytes);
        restored.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(run_decisions(&mut live, tail), run_decisions(&mut restored, tail));
    }

    #[test]
    fn ucb_restore_rejects_corrupt_arm_table() {
        let mut w = StateWriter::new();
        w.u64(1); // seed
        w.usize(0); // arm
        w.usize(0); // slot_in_epoch
        w.u64(0); // epochs
        w.usize(1 << 50); // claims ~10^15 arms in an empty payload
        let bytes = w.into_bytes();
        let mut p = UcbThreshold::new(menu(), 1);
        let err = p.restore_state(&mut StateReader::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("exceeds remaining capacity"), "{err}");
    }

    #[test]
    fn ucb_restore_rejects_bad_order_permutation() {
        let mut w = StateWriter::new();
        w.u64(1);
        w.usize(0);
        w.usize(0);
        w.u64(0);
        w.usize(ARMS);
        for _ in 0..ARMS {
            w.u64(0);
            w.f64_bits(0.0);
            w.usize(0); // every arm claims order slot 0
        }
        w.f64_bits(0.0);
        w.f64_bits(0.0);
        let bytes = w.into_bytes();
        let mut p = UcbThreshold::new(menu(), 1);
        let err = p.restore_state(&mut StateReader::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("permutation"), "{err}");
    }

    #[test]
    fn ucb_on_single_contract_market_runs() {
        let ds = demands(300, 21);
        let mut p = UcbThreshold::new(single(), 4);
        let out = run_decisions(&mut p, &ds);
        assert_eq!(out.len(), ds.len());
    }

    #[test]
    fn adaptive_window_reset_matches_fresh_instance() {
        let ds = demands(500, 17);
        let mut reused = AdaptiveWindow::new(menu());
        run_decisions(&mut reused, &ds);
        reused.reset();
        let mut fresh = AdaptiveWindow::new(menu());
        assert_eq!(run_decisions(&mut reused, &ds), run_decisions(&mut fresh, &ds));
    }

    #[test]
    fn adaptive_window_save_restore_resumes_bit_identically() {
        let ds = demands(600, 23);
        let (head, tail) = ds.split_at(300);
        let mut live = AdaptiveWindow::new(menu());
        run_decisions(&mut live, head);
        let mut w = StateWriter::new();
        live.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut restored = AdaptiveWindow::new(menu());
        run_decisions(&mut restored, &ds[..50]); // dirty state
        let mut r = StateReader::new(&bytes);
        restored.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(run_decisions(&mut live, tail), run_decisions(&mut restored, tail));
        assert!((live.forecast_error() - restored.forecast_error()).abs() == 0.0);
    }

    #[test]
    fn adaptive_window_trusts_predictable_traces() {
        // Perfectly periodic demand: the AR(3) forecaster locks on and the
        // window should open up after warmup.
        let ds: Vec<u32> = (0..400).map(|t| 2 + (t % 2) as u32).collect();
        let mut p = AdaptiveWindow::new(menu());
        run_decisions(&mut p, &ds);
        assert!(
            p.current_window() > 0,
            "window stayed closed on a predictable trace (err={})",
            p.forecast_error()
        );
    }

    #[test]
    fn adaptive_window_restore_rejects_oversized_history() {
        let mut w = StateWriter::new();
        w.usize(1 << 50); // forecaster history length bomb
        let bytes = w.into_bytes();
        let mut p = AdaptiveWindow::new(menu());
        assert!(p.restore_state(&mut StateReader::new(&bytes)).is_err());
    }

    #[test]
    fn learned_policies_keep_window_zero_for_the_driver() {
        assert_eq!(UcbThreshold::new(menu(), 1).window(), 0);
        assert_eq!(AdaptiveWindow::new(menu()).window(), 0);
    }
}
