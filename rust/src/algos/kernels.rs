//! Flat, branch-light kernels for the per-slot policy hot path.
//!
//! The menu policies run the same four k-contract sweeps every slot:
//! expire each break-even scan, probe future coverage, pick the triggered
//! contract with the best steady-state cost, and compensate covered scans
//! after a purchase. Each sweep here iterates contiguous SoA arrays (the
//! `terms` / `betas` / `steady` columns hoisted at construction) with no
//! per-iteration branching on contract structs, so the compiler can keep
//! the loops in registers and autovectorize the arithmetic. The `bench`
//! subcommand measures them via the `kernels` section of BENCH.json.

use crate::algos::window::WindowScan;
use crate::algos::RunQueue;

/// Expire every scan's window left edge for a lookahead ending at `right`:
/// scan `j` keeps slots `≥ right + 1 − terms[j]`.
#[inline]
pub(crate) fn expire_scans(scans: &mut [WindowScan], terms: &[usize], right: usize) {
    for (scan, &term) in scans.iter_mut().zip(terms) {
        scan.expire_before((right + 1).saturating_sub(term));
    }
}

/// Total instances covered by active reservations at the current slot `t`
/// under expiry-slot keys, dropping expired runs.
#[inline]
pub(crate) fn covered_now(cover: &mut [RunQueue], t: usize) -> u32 {
    let mut total = 0u32;
    for q in cover.iter_mut() {
        q.expire_before(t + 1);
        total += q.total();
    }
    total
}

/// Instances still covered at the *future* slot `s` (strictly later expiry),
/// without expiring anything — the lookahead probe of the windowed sweeps.
#[inline]
pub(crate) fn covered_at(cover: &[RunQueue], s: usize) -> u32 {
    cover.iter().map(|q| q.count_after(s)).sum()
}

/// The steady-cost pick: among contracts whose uncompensated on-demand
/// spend `p·V_j` exceeds the threshold, return the one with the lowest
/// full-utilization cost per slot. Strict `<` keeps the earliest triggered
/// contract on steady-cost ties, matching the pre-flat fold.
#[inline]
pub(crate) fn pick_triggered(
    p: f64,
    viol: &[u32],
    thresholds: &[f64],
    steady: &[f64],
) -> Option<usize> {
    debug_assert!(viol.len() == thresholds.len() && viol.len() == steady.len());
    let k = viol.len().min(thresholds.len()).min(steady.len());
    let (viol, thresholds, steady) = (&viol[..k], &thresholds[..k], &steady[..k]);
    let mut best = usize::MAX;
    let mut best_cost = f64::INFINITY;
    for j in 0..k {
        let triggered = p * viol[j] as f64 > thresholds[j] + 1e-12;
        if triggered && steady[j] < best_cost {
            best = j;
            best_cost = steady[j];
        }
    }
    (best != usize::MAX).then_some(best)
}

/// Refresh the violation-count column from the scans.
#[inline]
pub(crate) fn gather_violations(scans: &[WindowScan], viol: &mut [u32]) {
    for (v, s) in viol.iter_mut().zip(scans) {
        *v = s.violations();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_prefers_lowest_steady_cost_among_triggered() {
        let viol = [10, 10, 10];
        let thresholds = [5.0, 0.5, 0.5]; // contract 0 not triggered at p=0.1
        let steady = [0.001, 0.03, 0.02];
        assert_eq!(pick_triggered(0.1, &viol, &thresholds, &steady), Some(2));
    }

    #[test]
    fn pick_keeps_earliest_on_steady_ties() {
        let viol = [10, 10];
        let thresholds = [0.5, 0.5];
        let steady = [0.02, 0.02];
        assert_eq!(pick_triggered(0.1, &viol, &thresholds, &steady), Some(0));
    }

    #[test]
    fn pick_returns_none_when_nothing_triggers() {
        let viol = [1, 0];
        let thresholds = [0.5, 0.5];
        let steady = [0.02, 0.01];
        assert_eq!(pick_triggered(0.1, &viol, &thresholds, &steady), None);
    }

    #[test]
    fn covered_probes_match_queue_contents() {
        let mut cover = vec![RunQueue::default(), RunQueue::default()];
        cover[0].push_n(5, 2); // expires after slot 4
        cover[1].push_n(9, 3);
        assert_eq!(covered_at(&cover, 3), 5);
        assert_eq!(covered_at(&cover, 5), 3);
        assert_eq!(covered_now(&mut cover, 4), 5); // keys > 4 survive
        assert_eq!(covered_now(&mut cover, 5), 3);
        assert_eq!(covered_now(&mut cover, 9), 0);
    }

    #[test]
    fn expire_scans_uses_per_contract_terms() {
        let mut scans = vec![WindowScan::new(), WindowScan::new()];
        scans[0].insert(0, 1, 0);
        scans[1].insert(0, 1, 0);
        let terms = [2usize, 10];
        expire_scans(&mut scans, &terms, 5); // keeps >= 4 resp. >= 0
        assert_eq!(scans[0].violations(), 0);
        assert_eq!(scans[1].violations(), 1);
    }
}
