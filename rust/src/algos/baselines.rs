//! Benchmark strategies from Sec. VII-B:
//!
//! * **All-on-demand** — never reserve (the prevalent practice),
//! * **All-reserved** — keep enough active reservations to cover demand,
//! * **Separate** — the Bahncard extension of Sec. II-D: split demand into
//!   per-level *virtual users*, each running its own single-instance
//!   Algorithm-1 (`A_β`) without sharing reservations. Its inefficiency —
//!   no time-multiplexing across levels — is exactly what motivates the
//!   paper's joint algorithm.

use super::window::WindowScan;
use super::{Decision, Policy, RunQueue, SaveState};
use crate::pricing::{ContractId, Pricing};
use crate::util::state::{StateReader, StateWriter};

/// Never reserve; serve everything on demand.
#[derive(Debug, Clone, Default)]
pub struct AllOnDemand;

impl AllOnDemand {
    pub fn new() -> AllOnDemand {
        AllOnDemand
    }
}

impl super::Reset for AllOnDemand {
    fn reset(&mut self) {}
}

impl SaveState for AllOnDemand {
    fn save_state(&self, _w: &mut StateWriter) {}

    fn restore_state(&mut self, _r: &mut StateReader<'_>) -> anyhow::Result<()> {
        Ok(())
    }
}

impl Policy for AllOnDemand {
    fn name(&self) -> String {
        "All-on-demand".to_string()
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        Decision::on_demand_only(demand)
    }
}

/// Reserve whatever active coverage is missing; never use on-demand.
#[derive(Debug, Clone)]
pub struct AllReserved {
    pricing: Pricing,
    cover: RunQueue,
    t: usize,
    out: [(ContractId, u32); 1],
}

impl AllReserved {
    pub fn new(pricing: Pricing) -> AllReserved {
        AllReserved { pricing, cover: RunQueue::default(), t: 0, out: [(0, 0)] }
    }
}

impl super::Reset for AllReserved {
    fn reset(&mut self) {
        self.cover.clear();
        self.t = 0;
        self.out = [(0, 0)];
    }
}

impl SaveState for AllReserved {
    fn save_state(&self, w: &mut StateWriter) {
        self.cover.save_state(w);
        w.usize(self.t);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.cover.restore_state(r)?;
        self.t = r.usize()?;
        self.out = [(0, 0)];
        Ok(())
    }
}

impl Policy for AllReserved {
    fn name(&self) -> String {
        "All-reserved".to_string()
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        let t = self.t;
        self.t += 1;
        let active = self.cover.active_at(t, self.pricing.tau);
        let reserve = demand.saturating_sub(active);
        self.cover.push_n(t, reserve); // one coalesced run per purchase batch
        self.out = [(0, reserve)];
        Decision { on_demand: 0, reservations: &self.out[..usize::from(reserve > 0)] }
    }
}

/// Per-level state of one virtual user running single-instance `A_β`.
#[derive(Debug, Clone)]
struct Level {
    scan: WindowScan,
    cover: RunQueue,
    scan_res: RunQueue,
}

impl Level {
    fn new() -> Level {
        Level { scan: WindowScan::new(), cover: RunQueue::default(), scan_res: RunQueue::default() }
    }
}

/// The Sec. II-D Bahncard extension: virtual user `k` sees demand
/// `I(d_t ≥ k)` and reserves independently; reservations are never shared
/// across levels.
pub struct Separate {
    pricing: Pricing,
    levels: Vec<Level>,
    t: usize,
    out: [(ContractId, u32); 1],
}

impl Separate {
    pub fn new(pricing: Pricing) -> Separate {
        Separate { pricing, levels: Vec::new(), t: 0, out: [(0, 0)] }
    }

    /// One virtual user's step: `(reserve, on_demand)` for its 0/1 demand.
    fn step_level(level: &mut Level, t: usize, demand01: u32, pricing: &Pricing) -> (u32, u32) {
        let tau = pricing.tau;
        let beta = pricing.beta();
        level.scan.expire_before((t + 1).saturating_sub(tau));
        // x at insertion = reservations of THIS virtual user within range
        let x_ins = level.scan_res.active_at(t, tau);
        level.scan.insert(t, demand01, x_ins);
        let mut reserve = 0u32;
        while pricing.p * level.scan.violations() as f64 > beta + 1e-12 {
            level.scan.reserve();
            level.cover.push(t);
            level.scan_res.push(t);
            reserve += 1;
        }
        let covered = level.cover.active_at(t, tau);
        (reserve, demand01.saturating_sub(covered.min(demand01)))
    }
}

impl super::Reset for Separate {
    fn reset(&mut self) {
        // levels are lazily re-created per user (their count tracks the
        // peak demand seen), so dropping them IS the fresh state
        self.levels.clear();
        self.t = 0;
        self.out = [(0, 0)];
    }
}

impl SaveState for Separate {
    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.t);
        w.usize(self.levels.len());
        for level in &self.levels {
            level.scan.save_state(w);
            level.cover.save_state(w);
            level.scan_res.save_state(w);
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.t = r.usize()?;
        // each level serializes at least an empty scan (16 bytes) plus two
        // empty queues (8 bytes each), bounding the level count
        let n = r.seq_len(32)?;
        self.levels.clear();
        for _ in 0..n {
            let mut level = Level::new();
            level.scan.restore_state(r)?;
            level.cover.restore_state(r)?;
            level.scan_res.restore_state(r)?;
            self.levels.push(level);
        }
        self.out = [(0, 0)];
        Ok(())
    }
}

impl Policy for Separate {
    fn name(&self) -> String {
        "Separate (Bahncard ext.)".to_string()
    }

    fn decide(&mut self, demand: u32, _future: &[u32]) -> Decision<'_> {
        let t = self.t;
        self.t += 1;
        // Lazily create levels up to the highest demand seen.
        while self.levels.len() < demand as usize {
            self.levels.push(Level::new());
        }
        let mut reserve = 0u32;
        let mut on_demand = 0u32;
        for (k, level) in self.levels.iter_mut().enumerate() {
            let d_k = u32::from((k as u32) < demand); // level k+1 active iff d_t >= k+1
            // Perf (PERF.md §Policy hot path): idle levels — no demand now
            // and no pending violations — cannot change any output this
            // slot, and their lazy expiry is safe to defer: violations only
            // *leave* the window with time, so a skipped level's V can
            // only be an over-estimate the next time it is touched, which
            // we fix by expiring before reading. Skipping turns the per-
            // slot cost from O(peak demand) to O(d_t + hot levels).
            if d_k == 0 && level.scan.violations() == 0 {
                continue;
            }
            let (r, od) = Self::step_level(level, t, d_k, &self.pricing);
            reserve += r;
            on_demand += od;
        }
        self.out = [(0, reserve)];
        Decision { on_demand, reservations: &self.out[..usize::from(reserve > 0)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::Ledger;

    fn run(
        policy: &mut dyn Policy,
        demands: &[u32],
        pricing: Pricing,
    ) -> crate::ledger::CostReport {
        let mut ledger = Ledger::single(pricing);
        for &d in demands {
            let dec = policy.decide(d, &[]);
            ledger.bill(d, &dec).unwrap();
        }
        ledger.report()
    }

    #[test]
    fn all_on_demand_cost_is_ps() {
        let pricing = Pricing::normalized(0.1, 0.5, 5);
        let demands = [2u32, 0, 3, 1];
        let r = run(&mut AllOnDemand::new(), &demands, pricing);
        assert!((r.total - 0.1 * 6.0).abs() < 1e-12);
        assert_eq!(r.reservations, 0);
    }

    #[test]
    fn all_reserved_never_on_demand() {
        let pricing = Pricing::normalized(0.1, 0.5, 3);
        let demands = [1u32, 2, 1, 3, 0, 2];
        let r = run(&mut AllReserved::new(pricing), &demands, pricing);
        assert_eq!(r.on_demand_slots, 0);
        assert!(r.reservations >= 3);
        assert!(r.identity_holds(&pricing, 1e-9));
    }

    #[test]
    fn all_reserved_renews_after_expiry() {
        let pricing = Pricing::normalized(0.1, 0.5, 2);
        let demands = [1u32, 0, 0, 1];
        let r = run(&mut AllReserved::new(pricing), &demands, pricing);
        // reservation at t=0 expires before t=3 -> must reserve again
        assert_eq!(r.reservations, 2);
    }

    #[test]
    fn separate_reserves_per_level_without_multiplexing() {
        // Demand alternates between levels: a joint strategy could serve
        // both phases with the reservations of the first, Separate cannot.
        // Phase 1: d=1 long enough to trigger level-1 reservation.
        // Phase 2: d=1 continues — but now served by level-1's reservation.
        // Compare against a pattern where the *level* shifts: d=2 bursts
        // force level-2 to pay separately even though level-1's reserved
        // instance sits idle half the time.
        let pricing = Pricing::normalized(0.1, 0.0, 60); // beta=1: 11 violations to reserve
        let mut demands = Vec::new();
        // 15 slots at d=1 -> level 1 reserves
        demands.extend(vec![1u32; 15]);
        // 15 slots at d=0 (level-1 instance idle)
        demands.extend(vec![0u32; 15]);
        // 15 slots at d=1 again — joint would reuse, and so does Separate's
        // level 1 (same level). Now push demand to level 2:
        demands.extend(vec![2u32; 15]);
        let rsep = run(&mut Separate::new(pricing), &demands, pricing);
        let mut joint = super::super::deterministic::Deterministic::online(pricing);
        let rjoint = run(&mut joint, &demands, pricing);
        assert!(rsep.total >= rjoint.total,
            "separate {} should cost at least joint {}", rsep.total, rjoint.total);
    }

    #[test]
    fn separate_on_single_instance_demand_matches_deterministic() {
        // For d_t <= 1 the problem reduces to the Bahncard problem and
        // Separate == Algorithm 1 exactly.
        use crate::util::rng::Rng;
        let pricing = Pricing::normalized(0.15, 0.3, 8);
        let mut rng = Rng::new(21);
        for _ in 0..10 {
            let demands: Vec<u32> = (0..120).map(|_| u32::from(rng.chance(0.4))).collect();
            let rsep = run(&mut Separate::new(pricing), &demands, pricing);
            let mut det = super::super::deterministic::Deterministic::online(pricing);
            let rdet = run(&mut det, &demands, pricing);
            assert!((rsep.total - rdet.total).abs() < 1e-9,
                "sep={} det={} demands={demands:?}", rsep.total, rdet.total);
        }
    }

    #[test]
    fn separate_coverage_feasible_on_random_demand() {
        use crate::util::rng::Rng;
        let pricing = Pricing::normalized(0.2, 0.2, 6);
        let mut rng = Rng::new(33);
        let demands: Vec<u32> = (0..300).map(|_| rng.below(5) as u32).collect();
        // bill_slot panics on infeasible decisions
        let r = run(&mut Separate::new(pricing), &demands, pricing);
        assert!(r.identity_holds(&pricing, 1e-9));
    }
}
