//! The randomized algorithm's threshold distribution — Eq. (24):
//!
//! ```text
//! f(z) = (1−α)·e^{(1−α)z} / (e−1+α)          for z ∈ [0, β)
//!        + Dirac(z−β) · α/(e−1+α)            (an atom at z = β)
//! ```
//!
//! The continuous part integrates to `(e−1)/(e−1+α)` and the atom carries
//! the remaining `α/(e−1+α)` — a *discontinuous* density, which the paper
//! notes is essential: the usual continuous `e^z/(e−1)` choice from
//! ski-rental/TCP-ack (its `α = 0` special case) is not optimal here.

use crate::pricing::Pricing;
use crate::util::rng::Rng;

/// Probability that the draw lands exactly on the atom `z = β`.
pub fn atom_mass(alpha: f64) -> f64 {
    alpha / (std::f64::consts::E - 1.0 + alpha)
}

/// Continuous part of the density on `[0, β)`.
pub fn pdf_continuous(alpha: f64, z: f64) -> f64 {
    let beta = 1.0 / (1.0 - alpha);
    if !(0.0..beta).contains(&z) {
        return 0.0;
    }
    (1.0 - alpha) * ((1.0 - alpha) * z).exp() / (std::f64::consts::E - 1.0 + alpha)
}

/// CDF `F(z) = P[Z ≤ z]` including the atom at `β`.
pub fn cdf(alpha: f64, z: f64) -> f64 {
    let beta = 1.0 / (1.0 - alpha);
    if z < 0.0 {
        0.0
    } else if z < beta {
        (((1.0 - alpha) * z).exp() - 1.0) / (std::f64::consts::E - 1.0 + alpha)
    } else {
        1.0
    }
}

/// Draw a threshold `z ∈ [0, β]` according to Eq. (24) by inverse CDF:
/// `u < (e−1)/(e−1+α)` maps through `z = ln(1 + u(e−1+α))/(1−α)`;
/// larger `u` hits the atom at `β`.
///
/// `alpha = 1` degenerates (β = ∞, reserving never helps); we return
/// `+inf`, which makes `A_z` never reserve — the optimal behaviour there.
pub fn sample_z(pricing: &Pricing, rng: &mut Rng) -> f64 {
    let alpha = pricing.alpha;
    if alpha >= 1.0 {
        return f64::INFINITY;
    }
    let e = std::f64::consts::E;
    let u = rng.f64();
    if u >= (e - 1.0) / (e - 1.0 + alpha) {
        pricing.beta()
    } else {
        (1.0 + u * (e - 1.0 + alpha)).ln() / (1.0 - alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_mass_plus_atom_is_one() {
        for &alpha in &[0.0, 0.2, 0.4875, 0.8, 0.99] {
            let beta = 1.0 / (1.0 - alpha);
            // numeric integral of the continuous part
            let n = 20_000;
            let h = beta / n as f64;
            let integral: f64 = (0..n)
                .map(|i| pdf_continuous(alpha, (i as f64 + 0.5) * h) * h)
                .sum();
            let total = integral + atom_mass(alpha);
            assert!((total - 1.0).abs() < 1e-4, "alpha={alpha} total={total}");
        }
    }

    #[test]
    fn alpha_zero_matches_classic_ski_rental_density() {
        // f(z) = e^z/(e-1) on [0,1), no atom.
        assert!(atom_mass(0.0) < 1e-12);
        let e = std::f64::consts::E;
        for &z in &[0.0f64, 0.3, 0.7, 0.99] {
            let expect = z.exp() / (e - 1.0);
            assert!((pdf_continuous(0.0, z) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn cdf_is_monotone_and_reaches_one() {
        let alpha = 0.4875;
        let beta = 1.0 / (1.0 - alpha);
        let mut prev = -1.0;
        for i in 0..=100 {
            let z = beta * i as f64 / 100.0;
            let c = cdf(alpha, z);
            assert!(c >= prev);
            prev = c;
        }
        assert!((cdf(alpha, beta) - 1.0).abs() < 1e-12);
        // just below beta, the atom is missing:
        let just_below = cdf(alpha, beta * (1.0 - 1e-9));
        assert!((just_below - (1.0 - atom_mass(alpha))).abs() < 1e-6);
    }

    #[test]
    fn sampler_matches_cdf() {
        use crate::util::rng::Rng;
        let pricing = Pricing::normalized(0.01, 0.4875, 100);
        let mut rng = Rng::new(123);
        let n = 200_000;
        let beta = pricing.beta();
        let mut at_beta = 0usize;
        let mut below_half_beta = 0usize;
        for _ in 0..n {
            let z = sample_z(&pricing, &mut rng);
            assert!((0.0..=beta + 1e-12).contains(&z));
            if (z - beta).abs() < 1e-12 {
                at_beta += 1;
            }
            if z < beta / 2.0 {
                below_half_beta += 1;
            }
        }
        let atom_emp = at_beta as f64 / n as f64;
        assert!((atom_emp - atom_mass(0.4875)).abs() < 0.01, "atom {atom_emp}");
        let cdf_half = cdf(0.4875, beta / 2.0);
        let emp_half = below_half_beta as f64 / n as f64;
        assert!((emp_half - cdf_half).abs() < 0.01, "half {emp_half} vs {cdf_half}");
    }

    #[test]
    fn alpha_one_samples_infinity() {
        let pricing = Pricing::normalized(0.01, 1.0, 100);
        let mut rng = Rng::new(5);
        assert!(sample_z(&pricing, &mut rng).is_infinite());
    }

    #[test]
    fn expected_z_increases_with_alpha() {
        // Larger discount -> more conservative thresholds on average.
        use crate::util::rng::Rng;
        let mut means = Vec::new();
        for &alpha in &[0.1, 0.5, 0.9] {
            let pricing = Pricing::normalized(0.01, alpha, 100);
            let mut rng = Rng::new(9);
            let n = 50_000;
            let m: f64 = (0..n).map(|_| sample_z(&pricing, &mut rng)).sum::<f64>() / n as f64;
            means.push(m);
        }
        assert!(means[0] < means[1] && means[1] < means[2], "{means:?}");
    }
}
