//! The randomized online algorithm — Algorithm 2 (and Algorithm 4 with a
//! prediction window): draw `z ∈ [0, β]` from the density of Eq. (24) and
//! run `A_z` (resp. `A^w_z`). `e/(e−1+α)`-competitive in expectation
//! (Proposition 3), the best possible for randomized algorithms (Prop. 4).

use super::density::sample_z;
use super::deterministic::Deterministic;
use super::{Decision, Policy, SaveState};
use crate::pricing::Pricing;
use crate::util::rng::Rng;
use crate::util::state::{StateReader, StateWriter};

/// Randomized reservation policy: a single draw of `z` at construction,
/// then deterministic behaviour — the randomness is over algorithms, not
/// over per-slot coin flips (Sec. V-A).
pub struct Randomized {
    inner: Deterministic,
    z: f64,
    seed: u64,
}

impl Randomized {
    /// Algorithm 2.
    pub fn online(pricing: Pricing, seed: u64) -> Randomized {
        Randomized::with_window(pricing, 0, seed)
    }

    /// Algorithm 4: randomized with prediction window `w`.
    pub fn with_window(pricing: Pricing, w: usize, seed: u64) -> Randomized {
        let mut rng = Rng::new(seed);
        let z = sample_z(&pricing, &mut rng);
        // alpha = 1 draws z = +inf: A_z then never reserves, which is
        // optimal (reservation carries no discount). Clamp the threshold fed
        // to Deterministic to a finite sentinel larger than any violation
        // cost can reach in practice while keeping the same behaviour.
        let z_eff = if z.is_finite() { z } else { f64::MAX / 4.0 };
        Randomized { inner: Deterministic::new(pricing, z_eff, w), z, seed }
    }

    /// Redraw the threshold from a new seed and rewind to slot 0, exactly
    /// as if freshly constructed with that seed (the fleet engine reuses
    /// one instance across a shard's users, reseeding per user).
    pub fn reseed(&mut self, seed: u64) {
        use super::Reset;
        let mut rng = Rng::new(seed);
        let z = sample_z(self.inner.pricing(), &mut rng);
        let z_eff = if z.is_finite() { z } else { f64::MAX / 4.0 };
        self.z = z;
        self.seed = seed;
        self.inner.set_threshold(z_eff);
        self.inner.reset();
    }

    /// The drawn threshold (for analysis / logging).
    pub fn threshold(&self) -> f64 {
        self.z
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl SaveState for Randomized {
    /// The policy consumes its RNG entirely at construction/reseed (a single
    /// threshold draw), so its random state is fully captured by the drawn
    /// `z` and the seed; `inner` carries the effective (clamped) threshold.
    fn save_state(&self, w: &mut StateWriter) {
        w.f64_bits(self.z);
        w.u64(self.seed);
        self.inner.save_state(w);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.z = r.f64_bits()?;
        self.seed = r.u64()?;
        self.inner.restore_state(r)
    }
}

impl Policy for Randomized {
    fn name(&self) -> String {
        if self.inner.window() == 0 {
            "Randomized".to_string()
        } else {
            format!("Randomized(w={})", self.inner.window())
        }
    }

    fn window(&self) -> usize {
        self.inner.window()
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        self.inner.decide(demand, future)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::Ledger;

    fn run(policy: &mut dyn Policy, demands: &[u32], pricing: Pricing) -> f64 {
        let w = policy.window();
        let mut ledger = Ledger::single(pricing);
        for t in 0..demands.len() {
            let hi = (t + 1 + w).min(demands.len());
            let dec = policy.decide(demands[t], &demands[t + 1..hi]);
            ledger.bill(demands[t], &dec).unwrap();
        }
        ledger.report().total
    }

    #[test]
    fn deterministic_given_seed() {
        let pricing = Pricing::normalized(0.05, 0.4875, 20);
        let demands: Vec<u32> = (0..100).map(|i| (i % 3) as u32).collect();
        let c1 = run(&mut Randomized::online(pricing, 7), &demands, pricing);
        let c2 = run(&mut Randomized::online(pricing, 7), &demands, pricing);
        assert_eq!(c1, c2);
    }

    #[test]
    fn reseed_matches_fresh_construction() {
        let pricing = Pricing::normalized(0.05, 0.4875, 20);
        let demands: Vec<u32> = (0..150).map(|i| (i % 4) as u32).collect();
        let mut reused = Randomized::online(pricing, 1);
        let _ = run(&mut reused, &demands, pricing); // dirty the state
        for seed in [7u64, 0, 42] {
            reused.reseed(seed);
            let mut fresh = Randomized::online(pricing, seed);
            assert_eq!(reused.threshold().to_bits(), fresh.threshold().to_bits());
            let a = run(&mut reused, &demands, pricing);
            let b = run(&mut fresh, &demands, pricing);
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
        }
    }

    #[test]
    fn different_seeds_give_different_thresholds() {
        let pricing = Pricing::normalized(0.05, 0.4875, 20);
        let zs: Vec<f64> = (0..10).map(|s| Randomized::online(pricing, s).threshold()).collect();
        let distinct = zs
            .iter()
            .filter(|a| zs.iter().filter(|b| (**a - **b).abs() < 1e-12).count() == 1)
            .count();
        assert!(distinct >= 5, "{zs:?}");
    }

    #[test]
    fn threshold_always_in_range() {
        let pricing = Pricing::normalized(0.05, 0.3, 20);
        for s in 0..200 {
            let z = Randomized::online(pricing, s).threshold();
            assert!((0.0..=pricing.beta() + 1e-12).contains(&z));
        }
    }

    #[test]
    fn alpha_one_never_reserves() {
        let pricing = Pricing::normalized(0.05, 1.0, 20);
        let demands = vec![3u32; 200];
        let mut policy = Randomized::online(pricing, 3);
        let mut ledger = Ledger::single(pricing);
        for &d in &demands {
            let dec = policy.decide(d, &[]);
            assert_eq!(dec.total_reserved(), 0);
            ledger.bill(d, &dec).unwrap();
        }
        assert_eq!(ledger.report().reservations, 0);
    }

    #[test]
    fn expected_cost_between_extremes() {
        // For long stable demand, E[C_rand] should be well below
        // all-on-demand and not far above the reserve-immediately cost.
        let pricing = Pricing::normalized(0.05, 0.4, 50);
        let demands = vec![1u32; 300];
        let n = 200;
        let mean: f64 = (0..n)
            .map(|s| run(&mut Randomized::online(pricing, s as u64), &demands, pricing))
            .sum::<f64>()
            / n as f64;
        let all_od = 0.05 * 300.0;
        // A_0 reserves at t=0 and re-reserves every tau
        let aggressive = 6.0 + pricing.alpha * 0.05 * 300.0;
        assert!(mean < all_od, "mean={mean} all_od={all_od}");
        assert!(mean < 1.5 * aggressive, "mean={mean} aggressive={aggressive}");
    }
}
