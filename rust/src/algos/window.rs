//! Sliding-window break-even bookkeeping shared by Algorithms 1 and 3.
//!
//! Algorithm 1 checks, at every slot `t`, the on-demand cost
//! `p · Σ_{i=t−τ+1..t} I(d_i > x_i)` where `x_i` counts actual **and
//! phantom** reservations. A naive implementation rescans the `τ`-slot
//! window per step (O(τ) per slot, O(T·τ) total — 365 M operations per user
//! on the Sec. VII traces). This structure maintains the violation count
//! incrementally in O(1) amortized per step.
//!
//! Key observation: a reservation made at time `t'` increments `x_i` for all
//! `i ∈ [t'−τ+1, t'+τ−1]` (actual coverage forward, phantom backward —
//! lines 6–7 of Algorithm 1). Every slot currently inside the check window
//! is within `τ−1` of the current time, so **each reservation increments
//! every in-window `x_i` uniformly**. Therefore, storing per slot the value
//!
//! ```text
//! e_i = d_i − x_i(at insertion) + G(at insertion)
//! ```
//!
//! where `G` is the total number of reservations made so far, the current
//! violation condition `d_i > x_i` is simply `e_i > G`. Since `G` only
//! grows, a slot that is not violating at insertion can never become
//! violating — so only violating slots are stored at all.
//!
//! ## Flat layout (no hashing on the per-slot path)
//!
//! Violating slots live in a flat power-of-two **ring** (parallel `slot` /
//! `e` arrays), and the currently-counted excesses live in a **dense
//! rotating-base array** instead of a `HashMap<i64, u32>`. The excess
//! `e − g` at insertion equals `demand − x_at_insert`, so it is bounded by
//! the peak demand; and since `g` only grows, every *active* violation
//! satisfies `g < e < g + cap` once `cap` exceeds the peak excess seen.
//! Bucketing by `e mod cap` (`cap` a power of two) therefore gives every
//! active excess a distinct bucket, and the base rotates implicitly as `g`
//! advances: `reserve()` pops the single bucket whose offset just reached
//! zero (calendar-queue style, O(1)), and growth re-counts the ring
//! (amortized O(1) per insert). Entries cleared by `reserve()` stay in the
//! ring until expiry, exactly like the old lazily-cleared deque entries —
//! which keeps the `SaveState` wire format byte-identical.

use crate::algos::SaveState;
use crate::util::state::{StateReader, StateWriter};

/// Smallest ring capacity allocated (entries).
const RING_MIN: usize = 8;
/// Smallest dense-histogram capacity allocated (buckets). Kept deliberately
/// small so the growth path is exercised by ordinary tests.
const DENSE_MIN: usize = 16;
/// Largest per-entry excess `e − g` accepted from a checkpoint. Restoring
/// allocates O(max excess) histogram buckets, so an unvalidated corrupt
/// blob could demand an unbounded allocation; real excesses equal
/// `demand − x_at_insert` per user-slot and sit orders of magnitude below
/// this envelope.
const MAX_RESTORE_EXCESS: i64 = 1 << 24;

/// Incremental tracker of `V = #{i in window : d_i > x_i}`.
#[derive(Debug, Clone, Default)]
pub struct WindowScan {
    /// Total reservations made so far (the uniform offset `G`).
    g: i64,
    /// Flat FIFO ring of violating slots in insertion (= time) order:
    /// parallel `slot` / `e` arrays, power-of-two capacity. Entries whose
    /// `e <= g` have already been cleared from `v`/`dense` and are removed
    /// lazily on expiry.
    ring_slot: Vec<usize>,
    ring_e: Vec<i64>,
    head: usize,
    len: usize,
    /// Dense rotating-base histogram: `dense[e mod cap]` counts the
    /// *currently counted* violations with excess value `e`. Invariant:
    /// every counted entry satisfies `g < e < g + dense.len()`, so buckets
    /// are collision-free.
    dense: Vec<u32>,
    /// Current violation count `V` (== sum of `dense`).
    v: u32,
}

impl WindowScan {
    pub fn new() -> WindowScan {
        WindowScan::default()
    }

    /// Current violation count `V(t) = Σ_window I(d_i > x_i)`.
    #[inline]
    pub fn violations(&self) -> u32 {
        self.v
    }

    /// Total reservations recorded.
    #[inline]
    pub fn reservations(&self) -> i64 {
        self.g
    }

    /// Insert the window's newest slot. `slot` is its time index, `demand`
    /// its demand, and `x_at_insert` the bookkeeping reservation count
    /// `x_slot` at insertion time (= number of reservations whose ±(τ−1)
    /// influence range covers `slot`, i.e. those made at `t' ≥ slot−τ+1`).
    #[inline]
    pub fn insert(&mut self, slot: usize, demand: u32, x_at_insert: u32) {
        let e = demand as i64 - x_at_insert as i64 + self.g;
        if e > self.g {
            self.push_violation(slot, e);
        }
    }

    fn push_violation(&mut self, slot: usize, e: i64) {
        // excess offset is `demand − x_at_insert ∈ [1, peak demand]`
        let off = (e - self.g) as usize;
        if off >= self.dense.len() {
            self.grow_dense(off);
        }
        self.dense[(e as u64 as usize) & (self.dense.len() - 1)] += 1;
        self.v += 1;
        if self.len == self.ring_slot.len() {
            self.grow_ring();
        }
        let idx = (self.head + self.len) & (self.ring_slot.len() - 1);
        self.ring_slot[idx] = slot;
        self.ring_e[idx] = e;
        self.len += 1;
    }

    /// Reallocate the histogram so offsets up to `min_off` fit, re-counting
    /// the ring. The entry being inserted must not be in the ring yet.
    fn grow_dense(&mut self, min_off: usize) {
        let cap = (min_off + 1).next_power_of_two().max(DENSE_MIN).max(self.dense.len() * 2);
        let mut dense = vec![0u32; cap];
        let ring_mask = self.ring_slot.len().wrapping_sub(1);
        for i in 0..self.len {
            let e = self.ring_e[(self.head + i) & ring_mask];
            if e > self.g {
                dense[(e as u64 as usize) & (cap - 1)] += 1;
            }
        }
        self.dense = dense;
    }

    fn grow_ring(&mut self) {
        let old_cap = self.ring_slot.len();
        let cap = (old_cap * 2).max(RING_MIN);
        let mut slots = vec![0usize; cap];
        let mut es = vec![0i64; cap];
        for i in 0..self.len {
            let j = (self.head + i) & (old_cap.wrapping_sub(1));
            slots[i] = self.ring_slot[j];
            es[i] = self.ring_e[j];
        }
        self.ring_slot = slots;
        self.ring_e = es;
        self.head = 0;
    }

    /// Expire slots with index < `oldest_kept` (the window's left edge).
    pub fn expire_before(&mut self, oldest_kept: usize) {
        while self.len > 0 {
            let mask = self.ring_slot.len() - 1;
            if self.ring_slot[self.head] >= oldest_kept {
                break;
            }
            let e = self.ring_e[self.head];
            self.head = (self.head + 1) & mask;
            self.len -= 1;
            if e > self.g {
                // still counted as a violation — remove from the count
                self.dense[(e as u64 as usize) & (self.dense.len() - 1)] -= 1;
                self.v -= 1;
            }
        }
    }

    /// Record one new reservation: `x_i += 1` uniformly over the window
    /// (actual forward coverage + phantom history — Algorithm 1 lines 5–7).
    /// Slots whose excess just reached zero occupy exactly the bucket whose
    /// rotating offset hit 0 — one pop, no hashing.
    #[inline]
    pub fn reserve(&mut self) {
        self.g += 1;
        if !self.dense.is_empty() {
            let idx = (self.g as u64 as usize) & (self.dense.len() - 1);
            self.v -= self.dense[idx];
            self.dense[idx] = 0;
        }
    }

    /// Number of slots currently buffered (diagnostics / memory tests).
    pub fn buffered(&self) -> usize {
        self.len
    }

    /// Reset to the freshly-constructed state, keeping allocations (the
    /// fleet engine reuses one scan across every user in a shard).
    pub fn clear(&mut self) {
        if self.v != 0 {
            // sum(dense) == v, so a zero count means the buckets are clean
            self.dense.fill(0);
        }
        self.g = 0;
        self.head = 0;
        self.len = 0;
        self.v = 0;
    }
}

impl SaveState for WindowScan {
    /// Serializes `g` plus the full ring — including entries whose `e <= g`
    /// that are only removed lazily on expiry — and rebuilds `dense`/`v` on
    /// restore by counting `e > g`. This is the same logical `(slot, e)`
    /// sequence the pre-flat implementation wrote, so existing
    /// `cloudreserve-ckpt/v1` checkpoints restore unchanged.
    fn save_state(&self, w: &mut StateWriter) {
        w.i64(self.g);
        w.usize(self.len);
        let mask = self.ring_slot.len().wrapping_sub(1);
        for i in 0..self.len {
            let j = (self.head + i) & mask;
            w.usize(self.ring_slot[j]);
            w.i64(self.ring_e[j]);
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let g = r.i64()?;
        anyhow::ensure!(g >= 0, "WindowScan state: negative reservation count {g}");
        // each entry is 16 bytes (slot + e), so the length field is bounded
        // by the bytes actually present — a corrupt count cannot force an
        // unbounded allocation
        let n = r.seq_len(16)?;
        if self.v != 0 {
            self.dense.fill(0);
        }
        self.g = g;
        self.head = 0;
        self.len = 0;
        self.v = 0;
        if self.ring_slot.len() < n {
            let cap = n.next_power_of_two().max(RING_MIN);
            self.ring_slot = vec![0; cap];
            self.ring_e = vec![0; cap];
        }
        let mut max_off = 0i64;
        for i in 0..n {
            let slot = r.usize()?;
            let e = r.i64()?;
            if e > g {
                let off = e - g;
                anyhow::ensure!(
                    off <= MAX_RESTORE_EXCESS,
                    "WindowScan state: entry {i} (slot {slot}) has excess {off}, \
                     beyond the restore envelope {MAX_RESTORE_EXCESS}"
                );
                max_off = max_off.max(off);
            }
            self.ring_slot[i] = slot;
            self.ring_e[i] = e;
        }
        self.len = n;
        if max_off as usize >= self.dense.len() {
            self.dense = vec![0u32; (max_off as usize + 1).next_power_of_two().max(DENSE_MIN)];
        }
        let dense_mask = self.dense.len() - 1;
        for i in 0..n {
            let e = self.ring_e[i];
            if e > g {
                self.dense[(e as u64 as usize) & dense_mask] += 1;
                self.v += 1;
            }
        }
        Ok(())
    }
}

/// Reference implementation used by tests: the literal Algorithm-1
/// bookkeeping with an explicit `x` array. O(T·τ) per run.
#[derive(Debug, Clone)]
pub struct NaiveScan {
    /// demand per slot (grows as slots are inserted)
    d: Vec<u32>,
    /// bookkeeping reservation count per slot, sized `len + tau` ahead
    x: Vec<u32>,
    tau: usize,
}

impl NaiveScan {
    pub fn new(tau: usize) -> NaiveScan {
        NaiveScan { d: Vec::new(), x: Vec::new(), tau }
    }

    /// Insert next slot's demand (slot index == number of inserts - 1).
    pub fn insert(&mut self, demand: u32) {
        self.d.push(demand);
        if self.x.len() < self.d.len() + self.tau {
            self.x.resize(self.d.len() + self.tau, 0);
        }
    }

    /// Violations over window ending at `end` (inclusive), width tau.
    pub fn violations(&self, end: usize) -> u32 {
        let lo = (end + 1).saturating_sub(self.tau);
        // clamp once instead of bounds-checking every element: `x` is kept
        // at least as long as `d`, so only the upper edge needs the clamp
        let hi = (end + 1).min(self.d.len());
        (lo..hi).filter(|&i| self.d[i] > self.x[i]).count() as u32
    }

    /// Reserve at time `t`: x_i += 1 for i in [t-tau+1, t+tau-1].
    pub fn reserve(&mut self, t: usize) {
        let lo = (t + 1).saturating_sub(self.tau);
        let hi = t + self.tau - 1;
        if self.x.len() <= hi {
            self.x.resize(hi + 1, 0);
        }
        for i in lo..=hi {
            self.x[i] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::VecDeque;

    /// Drive WindowScan and NaiveScan side by side with random demands and
    /// random interleaved reservations; counts must agree at every step.
    #[test]
    fn matches_naive_reference() {
        let mut rng = Rng::new(0xA11CE);
        for case in 0..50 {
            let tau = 1 + (case % 7);
            let t_len = 40;
            let mut fast = WindowScan::new();
            let mut naive = NaiveScan::new(tau);
            let mut res_times: VecDeque<usize> = VecDeque::new();
            let mut g_total = 0u32;
            for t in 0..t_len {
                let d = rng.below(5) as u32;
                naive.insert(d);
                // bookkeeping x at insertion = reservations made at
                // t' >= t - tau + 1  (all are <= t)
                while matches!(res_times.front(), Some(&rt) if rt + tau <= t) {
                    res_times.pop_front();
                }
                let x_ins = res_times.len() as u32;
                fast.expire_before((t + 1).saturating_sub(tau));
                fast.insert(t, d, x_ins);
                assert_eq!(
                    fast.violations(),
                    naive.violations(t),
                    "insert mismatch case={case} t={t} tau={tau}"
                );
                // random reservations
                let n_res = if rng.chance(0.3) { rng.below(3) as u32 } else { 0 };
                for _ in 0..n_res {
                    fast.reserve();
                    naive.reserve(t);
                    res_times.push_back(t);
                    g_total += 1;
                    assert_eq!(
                        fast.violations(),
                        naive.violations(t),
                        "reserve mismatch case={case} t={t} tau={tau} g={g_total}"
                    );
                }
            }
        }
    }

    /// Same driver at large τ with peak demands well past `DENSE_MIN`, so
    /// the dense histogram must grow and its base must rotate many times;
    /// includes a mid-stream save/restore swap that the remaining replay
    /// must not notice.
    #[test]
    fn matches_naive_reference_large_tau_and_growth() {
        let mut rng = Rng::new(0xB16B00);
        for &tau in &[16usize, 64, 350] {
            let t_len = 600;
            let mut fast = WindowScan::new();
            let mut naive = NaiveScan::new(tau);
            let mut res_times: VecDeque<usize> = VecDeque::new();
            for t in 0..t_len {
                // mostly small demands with occasional spikes >= DENSE_MIN
                let d =
                    if rng.chance(0.15) { 16 + rng.below(200) as u32 } else { rng.below(6) as u32 };
                naive.insert(d);
                while matches!(res_times.front(), Some(&rt) if rt + tau <= t) {
                    res_times.pop_front();
                }
                let x_ins = res_times.len() as u32;
                fast.expire_before((t + 1).saturating_sub(tau));
                fast.insert(t, d, x_ins);
                assert_eq!(fast.violations(), naive.violations(t), "t={t} tau={tau}");
                let n_res = if rng.chance(0.4) { rng.below(4) as u32 } else { 0 };
                for _ in 0..n_res {
                    fast.reserve();
                    naive.reserve(t);
                    res_times.push_back(t);
                    assert_eq!(fast.violations(), naive.violations(t), "t={t} tau={tau}");
                }
                if t == t_len / 2 {
                    // mid-stream round trip: swap in a restored copy
                    let mut w = StateWriter::new();
                    fast.save_state(&mut w);
                    let bytes = w.into_bytes();
                    let mut copy = WindowScan::new();
                    copy.insert(0, 999, 0); // stale state must be discarded
                    let mut r = StateReader::new(&bytes);
                    copy.restore_state(&mut r).unwrap();
                    r.finish().unwrap();
                    assert_eq!(copy.violations(), fast.violations());
                    assert_eq!(copy.buffered(), fast.buffered());
                    fast = copy;
                }
            }
        }
    }

    /// The excess histogram starts empty, grows to the peak offset, and the
    /// rotating base walks far past the capacity without aliasing buckets.
    #[test]
    fn dense_growth_and_base_rotation() {
        let mut w = WindowScan::new();
        w.insert(0, 40, 0); // excess 40 >= DENSE_MIN forces a grow
        assert_eq!(w.violations(), 1);
        for k in 1..40 {
            w.reserve();
            assert_eq!(w.violations(), 1, "still short after {k} reservations");
        }
        w.reserve(); // 40th: excess reaches zero
        assert_eq!(w.violations(), 0);
        // rotate the base far past any power-of-two capacity
        for _ in 0..1000 {
            w.reserve();
        }
        w.insert(1, 3, 0); // e = 3 + g, offset 3 in the rotated base
        assert_eq!(w.violations(), 1);
        w.reserve();
        w.reserve();
        assert_eq!(w.violations(), 1);
        w.reserve();
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn nonviolating_slots_are_not_buffered() {
        let mut w = WindowScan::new();
        w.insert(0, 3, 5); // covered: d=3 <= x=5
        w.insert(1, 0, 0); // zero demand
        assert_eq!(w.buffered(), 0);
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn reserve_clears_unit_violations() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0); // excess 1
        w.insert(1, 1, 0); // excess 1
        w.insert(2, 2, 0); // excess 2
        assert_eq!(w.violations(), 3);
        w.reserve(); // all x += 1: slots 0,1 clear, slot 2 still d>x
        assert_eq!(w.violations(), 1);
        w.reserve();
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn expiry_removes_violations() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0);
        w.insert(1, 1, 0);
        assert_eq!(w.violations(), 2);
        w.expire_before(1);
        assert_eq!(w.violations(), 1);
        w.expire_before(2);
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn expiry_of_cleared_violation_is_noop() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0);
        w.reserve(); // clears it from the count but not the ring
        assert_eq!(w.violations(), 0);
        w.expire_before(5); // lazy removal must not underflow
        assert_eq!(w.violations(), 0);
        assert_eq!(w.buffered(), 0);
    }

    #[test]
    fn save_restore_continues_identically_to_original() {
        // Drive a scan mid-stream (so it holds lazily-cleared entries),
        // snapshot it, and check the restored copy tracks the original
        // through further mixed operations.
        let mut rng = Rng::new(0xC0FFEE);
        let mut orig = WindowScan::new();
        let tau = 5;
        for t in 0..30usize {
            orig.expire_before((t + 1).saturating_sub(tau));
            orig.insert(t, rng.below(4) as u32, rng.below(3) as u32);
            if rng.chance(0.4) {
                orig.reserve();
            }
        }
        let mut w = StateWriter::new();
        orig.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut copy = WindowScan::new();
        copy.insert(0, 9, 0); // stale state must be discarded
        let mut r = StateReader::new(&bytes);
        copy.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(copy.violations(), orig.violations());
        assert_eq!(copy.buffered(), orig.buffered());

        for t in 30..60usize {
            let d = rng.below(4) as u32;
            let x = rng.below(3) as u32;
            let res = rng.chance(0.4);
            for s in [&mut orig, &mut copy] {
                s.expire_before((t + 1).saturating_sub(tau));
                s.insert(t, d, x);
                if res {
                    s.reserve();
                }
            }
            assert_eq!(copy.violations(), orig.violations(), "t={t}");
            assert_eq!(copy.reservations(), orig.reservations(), "t={t}");
        }
    }

    #[test]
    fn insertion_after_reservations_uses_offset() {
        let mut w = WindowScan::new();
        w.reserve();
        w.reserve();
        // new slot with x_at_insert already counting those 2 reservations
        w.insert(5, 3, 2); // e = 3 - 2 + 2 = 3 > g=2 -> violation
        assert_eq!(w.violations(), 1);
        w.reserve(); // g=3, clears e=3
        assert_eq!(w.violations(), 0);
    }

    /// A blob byte-crafted exactly as the pre-flat (hash-map) implementation
    /// wrote it — `g`, entry count, then `(slot, e)` pairs in insertion
    /// order including a lazily-cleared entry — must restore into the flat
    /// scan and re-serialize to the identical bytes.
    #[test]
    fn pre_rewrite_blob_restores_byte_exactly() {
        let mut w = StateWriter::new();
        w.i64(3); // g: three reservations made
        w.usize(4);
        for &(slot, e) in &[(7usize, 2i64), (8, 5), (9, 4), (10, 12)] {
            w.usize(slot);
            w.i64(e);
        }
        let blob = w.into_bytes();

        let mut scan = WindowScan::new();
        let mut r = StateReader::new(&blob);
        scan.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(scan.reservations(), 3);
        assert_eq!(scan.buffered(), 4);
        assert_eq!(scan.violations(), 3); // e in {5, 4, 12} > g=3; e=2 was cleared

        let mut w2 = StateWriter::new();
        scan.save_state(&mut w2);
        assert_eq!(w2.into_bytes(), blob, "wire format must stay byte-identical");

        // and the restored scan behaves: g=4 clears e=4, g=5 clears e=5
        scan.reserve();
        assert_eq!(scan.violations(), 2);
        scan.reserve();
        assert_eq!(scan.violations(), 1);
        scan.expire_before(11); // drops everything but (10, 12)
        assert_eq!(scan.violations(), 1);
        assert_eq!(scan.buffered(), 1);
    }

    #[test]
    fn restore_rejects_oversized_length_field() {
        let mut w = StateWriter::new();
        w.i64(0);
        w.usize(1 << 60); // claims ~10^18 entries in an 8-byte payload
        let blob = w.into_bytes();
        let mut scan = WindowScan::new();
        let err = scan.restore_state(&mut StateReader::new(&blob)).unwrap_err();
        assert!(err.to_string().contains("length"), "unexpected error: {err}");
    }

    #[test]
    fn restore_rejects_excess_beyond_envelope() {
        let mut w = StateWriter::new();
        w.i64(0);
        w.usize(1);
        w.usize(0);
        w.i64(1 << 40); // excess would demand a terabyte-scale histogram
        let blob = w.into_bytes();
        let mut scan = WindowScan::new();
        let err = scan.restore_state(&mut StateReader::new(&blob)).unwrap_err();
        assert!(err.to_string().contains("excess"), "unexpected error: {err}");
    }

    #[test]
    fn restore_rejects_negative_reservation_count() {
        let mut w = StateWriter::new();
        w.i64(-1);
        w.usize(0);
        let blob = w.into_bytes();
        let mut scan = WindowScan::new();
        assert!(scan.restore_state(&mut StateReader::new(&blob)).is_err());
    }
}
