//! Sliding-window break-even bookkeeping shared by Algorithms 1 and 3.
//!
//! Algorithm 1 checks, at every slot `t`, the on-demand cost
//! `p · Σ_{i=t−τ+1..t} I(d_i > x_i)` where `x_i` counts actual **and
//! phantom** reservations. A naive implementation rescans the `τ`-slot
//! window per step (O(τ) per slot, O(T·τ) total — 365 M operations per user
//! on the Sec. VII traces). This structure maintains the violation count
//! incrementally in O(1) amortized per step.
//!
//! Key observation: a reservation made at time `t'` increments `x_i` for all
//! `i ∈ [t'−τ+1, t'+τ−1]` (actual coverage forward, phantom backward —
//! lines 6–7 of Algorithm 1). Every slot currently inside the check window
//! is within `τ−1` of the current time, so **each reservation increments
//! every in-window `x_i` uniformly**. Therefore, storing per slot the value
//!
//! ```text
//! e_i = d_i − x_i(at insertion) + G(at insertion)
//! ```
//!
//! where `G` is the total number of reservations made so far, the current
//! violation condition `d_i > x_i` is simply `e_i > G`. Since `G` only
//! grows, a slot that is not violating at insertion can never become
//! violating — so only violating slots are stored at all.

use std::collections::{HashMap, VecDeque};

use crate::algos::SaveState;
use crate::util::state::{StateReader, StateWriter};

/// Incremental tracker of `V = #{i in window : d_i > x_i}`.
#[derive(Debug, Clone, Default)]
pub struct WindowScan {
    /// Total reservations made so far (the uniform offset `G`).
    g: i64,
    /// Violating slots in insertion (= time) order: `(slot_index, e)`.
    /// Entries whose `e <= g` have already been cleared from `v`/`hist`
    /// and are removed lazily on expiry.
    viol: VecDeque<(usize, i64)>,
    /// Histogram of `e` values among *currently counted* violations.
    hist: HashMap<i64, u32>,
    /// Current violation count `V`.
    v: u32,
}

impl WindowScan {
    pub fn new() -> WindowScan {
        WindowScan::default()
    }

    /// Current violation count `V(t) = Σ_window I(d_i > x_i)`.
    #[inline]
    pub fn violations(&self) -> u32 {
        self.v
    }

    /// Total reservations recorded.
    #[inline]
    pub fn reservations(&self) -> i64 {
        self.g
    }

    /// Insert the window's newest slot. `slot` is its time index, `demand`
    /// its demand, and `x_at_insert` the bookkeeping reservation count
    /// `x_slot` at insertion time (= number of reservations whose ±(τ−1)
    /// influence range covers `slot`, i.e. those made at `t' ≥ slot−τ+1`).
    pub fn insert(&mut self, slot: usize, demand: u32, x_at_insert: u32) {
        let e = demand as i64 - x_at_insert as i64 + self.g;
        if e > self.g {
            self.viol.push_back((slot, e));
            *self.hist.entry(e).or_insert(0) += 1;
            self.v += 1;
        }
    }

    /// Expire slots with index < `oldest_kept` (the window's left edge).
    pub fn expire_before(&mut self, oldest_kept: usize) {
        while matches!(self.viol.front(), Some(&(s, _)) if s < oldest_kept) {
            let (_, e) = self.viol.pop_front().unwrap();
            if e > self.g {
                // still counted as a violation — remove from the count
                let c = self.hist.get_mut(&e).expect("hist entry for active violation");
                *c -= 1;
                if *c == 0 {
                    self.hist.remove(&e);
                }
                self.v -= 1;
            }
        }
    }

    /// Record one new reservation: `x_i += 1` uniformly over the window
    /// (actual forward coverage + phantom history — Algorithm 1 lines 5–7).
    pub fn reserve(&mut self) {
        self.g += 1;
        if let Some(c) = self.hist.remove(&self.g) {
            // slots whose excess just reached zero stop violating
            self.v -= c;
        }
    }

    /// Number of slots currently buffered (diagnostics / memory tests).
    pub fn buffered(&self) -> usize {
        self.viol.len()
    }

    /// Reset to the freshly-constructed state, keeping allocations (the
    /// fleet engine reuses one scan across every user in a shard).
    pub fn clear(&mut self) {
        self.g = 0;
        self.viol.clear();
        self.hist.clear();
        self.v = 0;
    }
}

impl SaveState for WindowScan {
    /// Serializes `g` plus the full `viol` deque — including entries whose
    /// `e <= g` that are only removed lazily on expiry — and rebuilds
    /// `hist`/`v` on restore by counting `e > g`. This reproduces the saved
    /// instance exactly (lazy entries and all) without serializing the
    /// `HashMap`, whose iteration order is nondeterministic.
    fn save_state(&self, w: &mut StateWriter) {
        w.i64(self.g);
        w.usize(self.viol.len());
        for &(slot, e) in &self.viol {
            w.usize(slot);
            w.i64(e);
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.g = r.i64()?;
        let n = r.usize()?;
        self.viol.clear();
        self.hist.clear();
        self.v = 0;
        for _ in 0..n {
            let slot = r.usize()?;
            let e = r.i64()?;
            self.viol.push_back((slot, e));
            if e > self.g {
                *self.hist.entry(e).or_insert(0) += 1;
                self.v += 1;
            }
        }
        Ok(())
    }
}

/// Reference implementation used by tests: the literal Algorithm-1
/// bookkeeping with an explicit `x` array. O(T·τ) per run.
#[derive(Debug, Clone)]
pub struct NaiveScan {
    /// demand per slot (grows as slots are inserted)
    d: Vec<u32>,
    /// bookkeeping reservation count per slot, sized `len + tau` ahead
    x: Vec<u32>,
    tau: usize,
}

impl NaiveScan {
    pub fn new(tau: usize) -> NaiveScan {
        NaiveScan { d: Vec::new(), x: Vec::new(), tau }
    }

    /// Insert next slot's demand (slot index == number of inserts - 1).
    pub fn insert(&mut self, demand: u32) {
        self.d.push(demand);
        if self.x.len() < self.d.len() + self.tau {
            self.x.resize(self.d.len() + self.tau, 0);
        }
    }

    /// Violations over window ending at `end` (inclusive), width tau.
    pub fn violations(&self, end: usize) -> u32 {
        let lo = (end + 1).saturating_sub(self.tau);
        (lo..=end)
            .filter(|&i| i < self.d.len() && self.d[i] > self.x[i])
            .count() as u32
    }

    /// Reserve at time `t`: x_i += 1 for i in [t-tau+1, t+tau-1].
    pub fn reserve(&mut self, t: usize) {
        let lo = (t + 1).saturating_sub(self.tau);
        let hi = t + self.tau - 1;
        if self.x.len() <= hi {
            self.x.resize(hi + 1, 0);
        }
        for i in lo..=hi {
            self.x[i] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Drive WindowScan and NaiveScan side by side with random demands and
    /// random interleaved reservations; counts must agree at every step.
    #[test]
    fn matches_naive_reference() {
        let mut rng = Rng::new(0xA11CE);
        for case in 0..50 {
            let tau = 1 + (case % 7);
            let t_len = 40;
            let mut fast = WindowScan::new();
            let mut naive = NaiveScan::new(tau);
            let mut res_times: VecDeque<usize> = VecDeque::new();
            let mut g_total = 0u32;
            for t in 0..t_len {
                let d = rng.below(5) as u32;
                naive.insert(d);
                // bookkeeping x at insertion = reservations made at
                // t' >= t - tau + 1  (all are <= t)
                while matches!(res_times.front(), Some(&rt) if rt + tau <= t) {
                    res_times.pop_front();
                }
                let x_ins = res_times.len() as u32;
                fast.expire_before((t + 1).saturating_sub(tau));
                fast.insert(t, d, x_ins);
                assert_eq!(
                    fast.violations(),
                    naive.violations(t),
                    "insert mismatch case={case} t={t} tau={tau}"
                );
                // random reservations
                let n_res = if rng.chance(0.3) { rng.below(3) as u32 } else { 0 };
                for _ in 0..n_res {
                    fast.reserve();
                    naive.reserve(t);
                    res_times.push_back(t);
                    g_total += 1;
                    assert_eq!(
                        fast.violations(),
                        naive.violations(t),
                        "reserve mismatch case={case} t={t} tau={tau} g={g_total}"
                    );
                }
            }
        }
    }

    #[test]
    fn nonviolating_slots_are_not_buffered() {
        let mut w = WindowScan::new();
        w.insert(0, 3, 5); // covered: d=3 <= x=5
        w.insert(1, 0, 0); // zero demand
        assert_eq!(w.buffered(), 0);
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn reserve_clears_unit_violations() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0); // excess 1
        w.insert(1, 1, 0); // excess 1
        w.insert(2, 2, 0); // excess 2
        assert_eq!(w.violations(), 3);
        w.reserve(); // all x += 1: slots 0,1 clear, slot 2 still d>x
        assert_eq!(w.violations(), 1);
        w.reserve();
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn expiry_removes_violations() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0);
        w.insert(1, 1, 0);
        assert_eq!(w.violations(), 2);
        w.expire_before(1);
        assert_eq!(w.violations(), 1);
        w.expire_before(2);
        assert_eq!(w.violations(), 0);
    }

    #[test]
    fn expiry_of_cleared_violation_is_noop() {
        let mut w = WindowScan::new();
        w.insert(0, 1, 0);
        w.reserve(); // clears it from the count but not the deque
        assert_eq!(w.violations(), 0);
        w.expire_before(5); // lazy removal must not underflow
        assert_eq!(w.violations(), 0);
        assert_eq!(w.buffered(), 0);
    }

    #[test]
    fn save_restore_continues_identically_to_original() {
        // Drive a scan mid-stream (so it holds lazily-cleared entries),
        // snapshot it, and check the restored copy tracks the original
        // through further mixed operations.
        let mut rng = Rng::new(0xC0FFEE);
        let mut orig = WindowScan::new();
        let tau = 5;
        for t in 0..30usize {
            orig.expire_before((t + 1).saturating_sub(tau));
            orig.insert(t, rng.below(4) as u32, rng.below(3) as u32);
            if rng.chance(0.4) {
                orig.reserve();
            }
        }
        let mut w = StateWriter::new();
        orig.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut copy = WindowScan::new();
        copy.insert(0, 9, 0); // stale state must be discarded
        let mut r = StateReader::new(&bytes);
        copy.restore_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(copy.violations(), orig.violations());
        assert_eq!(copy.buffered(), orig.buffered());

        for t in 30..60usize {
            let d = rng.below(4) as u32;
            let x = rng.below(3) as u32;
            let res = rng.chance(0.4);
            for s in [&mut orig, &mut copy] {
                s.expire_before((t + 1).saturating_sub(tau));
                s.insert(t, d, x);
                if res {
                    s.reserve();
                }
            }
            assert_eq!(copy.violations(), orig.violations(), "t={t}");
            assert_eq!(copy.reservations(), orig.reservations(), "t={t}");
        }
    }

    #[test]
    fn insertion_after_reservations_uses_offset() {
        let mut w = WindowScan::new();
        w.reserve();
        w.reserve();
        // new slot with x_at_insert already counting those 2 reservations
        w.insert(5, 3, 2); // e = 3 - 2 + 2 = 3 > g=2 -> violation
        assert_eq!(w.violations(), 1);
        w.reserve(); // g=3, clears e=3
        assert_eq!(w.violations(), 0);
    }
}
