//! Extension (paper Sec. IX "future work"): combining **multiple reserved
//! offerings** — e.g. EC2's 1-year and 3-year reservations at light /
//! medium / heavy utilization — with on-demand instances. When demand is
//! single-instance and periods are infinite this is Multislope Ski Rental
//! [Lotker et al.]; here we implement the natural generalization of
//! Algorithm 1 to a menu of finite-period offerings:
//!
//! * each offering `j` has `(fee_j, α_j, τ_j)` (fees normalized to the
//!   *base* offering's fee) and its own break-even point
//!   `β_j = fee_j / (1 − α_j)`;
//! * the policy keeps one break-even window scan per offering and, upon
//!   the arrival of each demand, commits to the **deepest** offering whose
//!   window shows unjustified on-demand spend past its break-even point
//!   (deeper = longer period; triggered deeper commitments dominate
//!   shallower ones for the usage that triggered them);
//! * billing runs through [`MultiLedger`], which serves demand with the
//!   most-discounted active reservations first.
//!
//! With a single offering the policy *is* Algorithm 1 (tested), so the
//! `(2−α)` guarantee carries over; for menus we report empirical ratios
//! (`examples/multislope_offerings.rs`) — the paper leaves the theory open.

use std::collections::VecDeque;

use super::window::WindowScan;
use crate::pricing::Pricing;

/// One reserved offering in the menu.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Offering {
    /// Upfront fee, normalized to the base offering's fee.
    pub fee: f64,
    /// Usage discount factor in [0, 1].
    pub alpha: f64,
    /// Reservation period in slots.
    pub tau: usize,
}

impl Offering {
    /// Break-even on-demand spend within `tau` justifying this offering.
    pub fn beta(&self) -> f64 {
        if self.alpha >= 1.0 {
            f64::INFINITY
        } else {
            self.fee / (1.0 - self.alpha)
        }
    }
}

/// Pricing menu: a common on-demand rate plus reserved offerings sorted by
/// commitment depth (ascending `tau`).
#[derive(Debug, Clone)]
pub struct Menu {
    /// On-demand rate per slot, normalized to the base fee.
    pub p: f64,
    pub offerings: Vec<Offering>,
}

impl Menu {
    pub fn new(p: f64, mut offerings: Vec<Offering>) -> Menu {
        assert!(p > 0.0 && !offerings.is_empty());
        offerings.sort_by_key(|o| o.tau);
        for o in &offerings {
            assert!(o.fee > 0.0 && (0.0..=1.0).contains(&o.alpha) && o.tau >= 1);
        }
        Menu { p, offerings }
    }

    /// Single-offering menu equivalent to classic [`Pricing`].
    pub fn from_pricing(pr: &Pricing) -> Menu {
        Menu::new(pr.p, vec![Offering { fee: 1.0, alpha: pr.alpha, tau: pr.tau }])
    }

    /// EC2-style two-tier menu: 1-year light (the paper's Table I) plus a
    /// 3-year heavy-utilization plan (deeper commitment, bigger discount).
    /// Figures follow EC2's 2013 price book shape: the 3-year upfront is
    /// ~1.56x the 1-year and the discounted rate drops a further ~38%.
    pub fn ec2_two_tier_compressed() -> Menu {
        let base = crate::pricing::catalog::ec2_small_compressed();
        Menu::new(
            base.p,
            vec![
                Offering { fee: 1.0, alpha: base.alpha, tau: base.tau },
                Offering { fee: 106.1 / 69.0, alpha: 0.024 / 0.08, tau: 3 * base.tau },
            ],
        )
    }
}

/// An active reservation: expiry slot (exclusive) + its discount.
#[derive(Debug, Clone, Copy)]
struct ActiveRes {
    expiry: usize,
    alpha: f64,
}

/// Itemized multi-offering cost report.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MultiReport {
    pub total: f64,
    pub fees: f64,
    pub on_demand_cost: f64,
    pub reserved_usage_cost: f64,
    pub reservations: u64,
    pub slots: usize,
}

/// Billing for heterogeneous reservations: demand is served by active
/// reservations in ascending-`alpha` order (cheapest usage first), the
/// remainder on demand.
#[derive(Debug, Clone)]
pub struct MultiLedger {
    p: f64,
    active: Vec<ActiveRes>,
    t: usize,
    report: MultiReport,
}

impl MultiLedger {
    pub fn new(p: f64) -> MultiLedger {
        MultiLedger { p, active: Vec::new(), t: 0, report: MultiReport::default() }
    }

    pub fn active_now(&mut self) -> u32 {
        let t = self.t;
        self.active.retain(|r| r.expiry > t);
        self.active.len() as u32
    }

    /// Bill one slot: reserve `new` (offering, count) pairs, then serve
    /// `demand` with reserved capacity first (cheapest α first).
    pub fn bill_slot(&mut self, demand: u32, new: &[(Offering, u32)]) -> Result<(), String> {
        let t = self.t;
        for (o, n) in new {
            for _ in 0..*n {
                self.active.push(ActiveRes { expiry: t + o.tau, alpha: o.alpha });
            }
            self.report.fees += o.fee * *n as f64;
            self.report.total += o.fee * *n as f64;
            self.report.reservations += *n as u64;
        }
        self.active.retain(|r| r.expiry > t);
        self.active.sort_by(|a, b| a.alpha.partial_cmp(&b.alpha).unwrap());
        let reserved_use = (demand as usize).min(self.active.len());
        for r in self.active.iter().take(reserved_use) {
            let c = r.alpha * self.p;
            self.report.reserved_usage_cost += c;
            self.report.total += c;
        }
        let od = demand as usize - reserved_use;
        let c = od as f64 * self.p;
        self.report.on_demand_cost += c;
        self.report.total += c;
        self.report.slots += 1;
        self.t += 1;
        Ok(())
    }

    pub fn report(&self) -> MultiReport {
        self.report
    }
}

/// Generalized Algorithm 1 over an offering menu.
pub struct MultiDeterministic {
    menu: Menu,
    /// One break-even scan per offering (same uniform-increment trick; a
    /// reservation of offering j increments its own scan only — each scan
    /// answers "was on-demand use in *my* window unjustified at *my*
    /// break-even?").
    scans: Vec<WindowScan>,
    /// reservation times per offering (for the per-scan x at insert)
    res_times: Vec<VecDeque<usize>>,
    /// all active (expiry) for coverage
    cover: VecDeque<(usize, usize)>, // (expiry, offering idx)
    t: usize,
}

impl MultiDeterministic {
    pub fn new(menu: Menu) -> MultiDeterministic {
        let n = menu.offerings.len();
        MultiDeterministic {
            menu,
            scans: (0..n).map(|_| WindowScan::new()).collect(),
            res_times: (0..n).map(|_| VecDeque::new()).collect(),
            cover: VecDeque::new(),
            t: 0,
        }
    }

    fn covered(&mut self, t: usize) -> u32 {
        self.cover.retain(|&(e, _)| e > t);
        self.cover.len() as u32
    }

    /// Decide the slot: returns (new reservations per offering, on-demand).
    pub fn decide(&mut self, demand: u32) -> (Vec<(Offering, u32)>, u32) {
        let t = self.t;
        self.t += 1;
        let p = self.menu.p;
        let n = self.menu.offerings.len();

        // update each offering's scan with this slot. A slot actually
        // covered by active reservations (of ANY period) must not count as
        // a violation in any scan — otherwise a short-period scan
        // accumulates stale violations while a long reservation covers the
        // demand and fires spuriously at its expiry. `x_ins` therefore
        // takes the max of the scan's own phantom bookkeeping and the real
        // coverage at this slot.
        let covered_now = self.covered(t);
        for j in 0..n {
            let tau = self.menu.offerings[j].tau;
            let times = &mut self.res_times[j];
            while matches!(times.front(), Some(&rt) if rt + tau <= t) {
                times.pop_front();
            }
            let x_ins = (times.len() as u32).max(covered_now);
            self.scans[j].expire_before((t + 1).saturating_sub(tau));
            self.scans[j].insert(t, demand, x_ins);
        }

        // reserve deepest-first: a deep commitment whose long window shows
        // unjustified spend dominates shallower ones for the same usage.
        // The `covered < demand` guard (the same one Algorithm 3 uses)
        // prevents spurious re-reservation while a *longer*-period
        // reservation still covers the demand: per-offering bookkeeping
        // only looks tau_j ahead and would otherwise forget it.
        let mut covered = self.covered(t);
        let mut new: Vec<(Offering, u32)> = Vec::new();
        for j in (0..n).rev() {
            let o = self.menu.offerings[j];
            let beta = o.beta();
            let mut count = 0u32;
            while covered < demand && p * self.scans[j].violations() as f64 > beta + 1e-12 {
                // reserving offering j compensates this usage everywhere:
                // tell every scan (phantom across all windows).
                for scan in self.scans.iter_mut() {
                    scan.reserve();
                }
                self.res_times[j].push_back(t);
                // other offerings' x-at-insert queues also see coverage:
                for (i, times) in self.res_times.iter_mut().enumerate() {
                    if i != j {
                        times.push_back(t);
                    }
                }
                self.cover.push_back((t + o.tau, j));
                covered += 1;
                count += 1;
            }
            if count > 0 {
                new.push((o, count));
            }
        }
        let covered = self.covered(t);
        (new, demand.saturating_sub(covered))
    }

    /// Run over a demand curve, returning the billed report.
    pub fn run(menu: Menu, demands: &[u32]) -> MultiReport {
        let p = menu.p;
        let mut policy = MultiDeterministic::new(menu);
        let mut ledger = MultiLedger::new(p);
        for &d in demands {
            let (new, _od) = policy.decide(d);
            ledger.bill_slot(d, &new).expect("billing");
        }
        ledger.report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::deterministic::Deterministic;
    use crate::sim::run_policy;
    use crate::util::rng::Rng;

    #[test]
    fn offering_beta_generalizes_eq10() {
        let o = Offering { fee: 2.0, alpha: 0.5, tau: 100 };
        assert!((o.beta() - 4.0).abs() < 1e-12);
        let base = Offering { fee: 1.0, alpha: 0.5, tau: 100 };
        assert!((base.beta() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_offering_matches_algorithm1() {
        let pricing = Pricing::normalized(0.05, 0.4, 60);
        let mut rng = Rng::new(8);
        for case in 0..20 {
            let demands: Vec<u32> = (0..300)
                .map(|_| if rng.chance(0.4) { rng.below(4) as u32 } else { 0 })
                .collect();
            let multi = MultiDeterministic::run(Menu::from_pricing(&pricing), &demands);
            let mut a = Deterministic::online(pricing);
            let classic = run_policy(&mut a, &demands, pricing).unwrap();
            assert!(
                (multi.total - classic.total).abs() < 1e-9,
                "case {case}: multi {} vs classic {}",
                multi.total,
                classic.total
            );
            assert_eq!(multi.reservations, classic.reservations);
        }
    }

    #[test]
    fn two_tier_menu_uses_deep_offering_for_stable_demand() {
        // long stable demand: the 3x-period offering's window accumulates
        // spend past its (higher) break-even -> deep reservations appear.
        let menu = Menu::new(
            0.05,
            vec![
                Offering { fee: 1.0, alpha: 0.5, tau: 100 },
                Offering { fee: 1.5, alpha: 0.2, tau: 300 },
            ],
        );
        let demands = vec![1u32; 900];
        let report = MultiDeterministic::run(menu.clone(), &demands);
        // cheaper than the best single-offering alternative
        let single_shallow =
            MultiDeterministic::run(Menu::new(0.05, vec![menu.offerings[0]]), &demands);
        let single_deep =
            MultiDeterministic::run(Menu::new(0.05, vec![menu.offerings[1]]), &demands);
        assert!(
            report.total <= single_shallow.total.min(single_deep.total) + 1e-9,
            "menu {} vs shallow {} deep {}",
            report.total,
            single_shallow.total,
            single_deep.total
        );
        assert!(report.reservations >= 1);
    }

    #[test]
    fn sporadic_demand_reserves_nothing() {
        let menu = Menu::ec2_two_tier_compressed();
        let mut demands = vec![0u32; 2000];
        demands[100] = 3;
        demands[1500] = 2;
        let report = MultiDeterministic::run(menu, &demands);
        assert_eq!(report.reservations, 0);
    }

    #[test]
    fn multi_ledger_serves_cheapest_first() {
        let mut l = MultiLedger::new(0.1);
        let cheap = Offering { fee: 1.0, alpha: 0.1, tau: 10 };
        let dear = Offering { fee: 1.0, alpha: 0.8, tau: 10 };
        l.bill_slot(1, &[(dear, 1), (cheap, 1)]).unwrap();
        // demand 1 served by alpha=0.1 reservation: usage cost 0.01
        let r = l.report();
        assert!((r.reserved_usage_cost - 0.01).abs() < 1e-12, "{r:?}");
        assert!((r.fees - 2.0).abs() < 1e-12);
    }

    #[test]
    fn multi_ledger_expiry() {
        let mut l = MultiLedger::new(0.1);
        let o = Offering { fee: 1.0, alpha: 0.0, tau: 2 };
        l.bill_slot(1, &[(o, 1)]).unwrap();
        l.bill_slot(1, &[]).unwrap();
        assert_eq!(l.active_now(), 0); // expired at t=2
        l.bill_slot(1, &[]).unwrap(); // now on demand
        let r = l.report();
        assert!((r.on_demand_cost - 0.1).abs() < 1e-12);
    }

    #[test]
    fn coverage_feasible_on_random_menus() {
        let mut rng = Rng::new(77);
        for _ in 0..15 {
            let menu = Menu::new(
                0.02 + rng.f64() * 0.2,
                vec![
                    Offering { fee: 1.0, alpha: rng.f64() * 0.9, tau: 3 + rng.below(20) as usize },
                    Offering {
                        fee: 1.0 + rng.f64() * 2.0,
                        alpha: rng.f64() * 0.5,
                        tau: 30 + rng.below(60) as usize,
                    },
                ],
            );
            let demands: Vec<u32> = (0..400).map(|_| rng.below(5) as u32).collect();
            let report = MultiDeterministic::run(menu, &demands);
            // fees+usage+od must reconstruct the total
            let rebuilt = report.fees + report.on_demand_cost + report.reserved_usage_cost;
            assert!((report.total - rebuilt).abs() < 1e-9);
        }
    }
}
