//! The deterministic online algorithm — Algorithm 1 (`A_β`), its threshold
//! family `A_z` (Sec. V-A), and the prediction-window variant `A^w_z`
//! (Algorithm 3). One implementation covers all of them:
//!
//! * `z = β`, `w = 0`  → Algorithm 1, `(2−α)`-competitive (Prop. 1),
//! * `z ∈ [0, β]`, `w = 0` → the family the randomized algorithm draws from,
//! * `w > 0` → Algorithm 3 (`A^w_z`), checking the window
//!   `[t+w−τ+1, t+w]` and additionally requiring `x_t < d_t` before each
//!   reservation.
//!
//! The break-even scan is O(1) amortized per slot via [`WindowScan`]
//! (see that module for the uniform-increment argument).

use super::window::WindowScan;
use super::{Decision, Policy, RunQueue, SaveState};
use crate::pricing::{ContractId, Pricing};
use crate::util::state::{StateReader, StateWriter};

/// Deterministic online reservation policy (single-contract: always
/// reserves contract 0 of its market).
#[derive(Debug, Clone)]
pub struct Deterministic {
    pricing: Pricing,
    /// Reservation threshold `z ∈ [0, β]`; `z = β` is Algorithm 1.
    z: f64,
    /// Prediction window `w < τ`; 0 = purely online.
    w: usize,
    scan: WindowScan,
    /// Actual reservations for coverage accounting (`x_t` in line 9),
    /// coalesced into `(time, count)` runs.
    cover: RunQueue,
    /// Reservations counted for the scan-window left edge `t+w−τ+1`
    /// (a reservation influences slot `i` iff `|t'−i| ≤ τ−1`).
    scan_res: RunQueue,
    /// Next slot index to be fed (slots are implicit and consecutive).
    t: usize,
    /// Next window slot index to insert into the scan (`t + w` ahead).
    next_scan_slot: usize,
    /// Reusable typed-decision buffer (contract 0, count).
    out: [(ContractId, u32); 1],
}

impl Deterministic {
    /// Algorithm 1: `z = β`, no prediction window.
    pub fn online(pricing: Pricing) -> Deterministic {
        Deterministic::with_threshold(pricing, pricing.beta())
    }

    /// Family member `A_z` (Sec. V-A).
    pub fn with_threshold(pricing: Pricing, z: f64) -> Deterministic {
        Deterministic::new(pricing, z, 0)
    }

    /// Algorithm 3: `A^w_β` with prediction window `w` (must satisfy w < τ).
    pub fn with_window(pricing: Pricing, w: usize) -> Deterministic {
        Deterministic::new(pricing, pricing.beta(), w)
    }

    /// Fully general `A^w_z`.
    pub fn new(pricing: Pricing, z: f64, w: usize) -> Deterministic {
        assert!(z >= 0.0, "threshold must be non-negative");
        assert!(w < pricing.tau, "prediction window must be shorter than the reservation period");
        Deterministic {
            pricing,
            z,
            w,
            scan: WindowScan::new(),
            cover: RunQueue::default(),
            scan_res: RunQueue::default(),
            t: 0,
            next_scan_slot: 0,
            out: [(0, 0)],
        }
    }

    pub fn threshold(&self) -> f64 {
        self.z
    }

    pub(crate) fn pricing(&self) -> &Pricing {
        &self.pricing
    }

    /// Swap the threshold in place (used by `Randomized::reseed`; must be
    /// paired with a `reset()` to stay equivalent to fresh construction).
    pub(crate) fn set_threshold(&mut self, z: f64) {
        assert!(z >= 0.0, "threshold must be non-negative");
        self.z = z;
    }

    /// Bookkeeping count `x_i` at insertion of window slot `i`: reservations
    /// whose influence range `[t'−τ+1, t'+τ−1]` covers `i`, i.e. those made
    /// at `t' ≥ i−τ+1` (reservation times never exceed the current `t ≤ i`).
    fn x_at_insert(&mut self, slot: usize) -> u32 {
        self.scan_res.active_at(slot, self.pricing.tau)
    }

    fn record_reservation(&mut self, t: usize) {
        self.scan.reserve();
        self.cover.push(t);
        self.scan_res.push(t);
    }
}

impl super::Reset for Deterministic {
    fn reset(&mut self) {
        self.scan.clear();
        self.cover.clear();
        self.scan_res.clear();
        self.t = 0;
        self.next_scan_slot = 0;
        self.out = [(0, 0)];
    }
}

impl SaveState for Deterministic {
    fn save_state(&self, w: &mut StateWriter) {
        w.f64_bits(self.z);
        self.scan.save_state(w);
        self.cover.save_state(w);
        self.scan_res.save_state(w);
        w.usize(self.t);
        w.usize(self.next_scan_slot);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let z = r.f64_bits()?;
        anyhow::ensure!(z >= 0.0, "checkpointed threshold {z} is negative");
        self.z = z;
        self.scan.restore_state(r)?;
        self.cover.restore_state(r)?;
        self.scan_res.restore_state(r)?;
        self.t = r.usize()?;
        self.next_scan_slot = r.usize()?;
        self.out = [(0, 0)];
        Ok(())
    }
}

impl Policy for Deterministic {
    fn name(&self) -> String {
        let beta = self.pricing.beta();
        let kind = if (self.z - beta).abs() < 1e-12 {
            "beta".to_string()
        } else {
            format!("z={:.3}", self.z)
        };
        if self.w == 0 {
            format!("Deterministic({kind})")
        } else {
            format!("Deterministic({kind},w={})", self.w)
        }
    }

    fn window(&self) -> usize {
        self.w
    }

    fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        let t = self.t;
        self.t += 1;
        let tau = self.pricing.tau;
        let p = self.pricing.p;

        // Slide the check window to [t+w−τ+1, t+w].
        let right = t + self.w;
        self.scan.expire_before((right + 1).saturating_sub(tau));

        // Insert newly visible slots up to t+w. At t=0 this inserts slots
        // 0..=w in one go; afterwards exactly one slot per step (unless the
        // provided horizon is shorter near the trace tail).
        let visible_end = t + self.w.min(future.len());
        while self.next_scan_slot <= visible_end {
            let s = self.next_scan_slot;
            let d_s = if s == t { demand } else { future[s - t - 1] };
            let x_ins = self.x_at_insert(s);
            self.scan.insert(s, d_s, x_ins);
            self.next_scan_slot += 1;
        }

        // Reserve while the window shows unjustified on-demand use.
        // Strict inequality `p·V > z` as in line 4 / line 3 of the paper;
        // the epsilon guards float dust when z is an exact multiple of p.
        let mut reserve = 0u32;
        loop {
            let violation_cost = p * self.scan.violations() as f64;
            if violation_cost <= self.z + 1e-12 {
                break;
            }
            // Algorithm 3's extra guard: with a prediction window, only
            // reserve while current demand exceeds current coverage.
            if self.w > 0 && self.cover.active_at(t, tau) >= demand {
                break;
            }
            self.record_reservation(t);
            reserve += 1;
        }

        // Launch on-demand instances for the uncovered remainder (line 9).
        let covered = self.cover.active_at(t, tau);
        let on_demand = demand.saturating_sub(covered);
        self.out = [(0, reserve)];
        Decision { on_demand, reservations: &self.out[..usize::from(reserve > 0)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::Ledger;

    fn pr(p: f64, alpha: f64, tau: usize) -> Pricing {
        Pricing::normalized(p, alpha, tau)
    }

    /// Run a policy over demands, bill through the ledger, return report.
    fn run(
        policy: &mut dyn Policy,
        demands: &[u32],
        pricing: Pricing,
    ) -> crate::ledger::CostReport {
        let w = policy.window();
        let mut ledger = Ledger::single(pricing);
        for t in 0..demands.len() {
            let hi = (t + 1 + w).min(demands.len());
            let dec = policy.decide(demands[t], &demands[t + 1..hi]);
            ledger.bill(demands[t], &dec).unwrap();
        }
        ledger.report()
    }

    #[test]
    fn never_reserves_for_sporadic_cheap_demand() {
        // One demand pulse: on-demand cost p << beta, so A_beta never reserves.
        let pricing = pr(0.01, 0.5, 10);
        let mut a = Deterministic::online(pricing);
        let mut demands = vec![0u32; 30];
        demands[5] = 1;
        let r = run(&mut a, &demands, pricing);
        assert_eq!(r.reservations, 0);
        assert!((r.total - 0.01).abs() < 1e-12);
    }

    #[test]
    fn reserves_once_breakeven_exceeded() {
        // Constant demand 1: window on-demand cost grows to > beta = 2 after
        // ceil(beta/p)+1 = 201 slots; tau large enough to hold the window.
        let pricing = pr(0.01, 0.5, 1000);
        let mut a = Deterministic::online(pricing);
        let demands = vec![1u32; 400];
        let r = run(&mut a, &demands, pricing);
        assert_eq!(r.reservations, 1);
        // reservation happens at the first slot where 201 violations seen:
        // slots 0..=200 -> reserve at t=200, on-demand for 0..200
        assert_eq!(r.on_demand_slots, 200);
        assert_eq!(r.reserved_slots, 200);
    }

    #[test]
    fn multi_instance_demand_reserves_multiple() {
        let pricing = pr(0.01, 0.5, 1000);
        let mut a = Deterministic::online(pricing);
        let demands = vec![3u32; 500];
        let r = run(&mut a, &demands, pricing);
        // each demand level accumulates violations; all three eventually reserved
        assert_eq!(r.reservations, 3);
    }

    #[test]
    fn phantom_prevents_double_counting() {
        // After a reservation compensates a window, the same history must not
        // trigger another reservation. Pulse demand that stops right after
        // the break-even point: exactly one reservation.
        let pricing = pr(0.1, 0.0, 100); // beta = 1 -> 11 violations needed
        let mut demands = vec![1u32; 11];
        demands.extend(std::iter::repeat(0).take(50));
        let mut a = Deterministic::online(pricing);
        let r = run(&mut a, &demands, pricing);
        assert_eq!(r.reservations, 1);
    }

    #[test]
    fn z_zero_reserves_immediately() {
        let pricing = pr(0.01, 0.5, 10);
        let mut a = Deterministic::with_threshold(pricing, 0.0);
        let demands = vec![1u32; 5];
        let r = run(&mut a, &demands, pricing);
        assert_eq!(r.reservations, 1);
        assert_eq!(r.on_demand_slots, 0);
    }

    #[test]
    fn matches_literal_algorithm1() {
        // Cross-check the optimized implementation against a literal
        // transcription of Algorithm 1 with explicit x arrays.
        use crate::algos::window::NaiveScan;
        use crate::util::rng::Rng;

        /// `(reserve, on_demand)` per slot from the literal transcription.
        fn literal_a_z(demands: &[u32], pricing: &Pricing, z: f64) -> Vec<(u32, u32)> {
            let tau = pricing.tau;
            let p = pricing.p;
            let mut naive = NaiveScan::new(tau);
            let mut res_times: Vec<usize> = Vec::new();
            let mut out = Vec::new();
            for (t, &d) in demands.iter().enumerate() {
                naive.insert(d);
                let mut reserve = 0u32;
                while p * naive.violations(t) as f64 > z + 1e-12 {
                    naive.reserve(t);
                    res_times.push(t);
                    reserve += 1;
                }
                let active = res_times.iter().filter(|&&rt| rt + tau > t).count() as u32;
                out.push((reserve, d.saturating_sub(active)));
            }
            out
        }

        let mut rng = Rng::new(77);
        for case in 0..40 {
            let tau = 2 + case % 6;
            let pricing = pr(0.05 + 0.1 * rng.f64(), rng.f64() * 0.9, tau);
            let z = rng.f64() * pricing.beta();
            let demands: Vec<u32> = (0..60).map(|_| rng.below(4) as u32).collect();
            let expected = literal_a_z(&demands, &pricing, z);
            let mut a = Deterministic::with_threshold(pricing, z);
            for (t, &d) in demands.iter().enumerate() {
                let got = a.decide(d, &[]);
                assert_eq!(
                    (got.total_reserved(), got.on_demand),
                    expected[t],
                    "case={case} t={t} tau={tau} z={z}"
                );
            }
        }
    }

    #[test]
    fn prediction_window_reserves_earlier() {
        // With w: the scan sees future demand and reserves as soon as the
        // (history+future) window crosses beta AND current demand is uncovered.
        let pricing = pr(0.1, 0.0, 100); // beta = 1 -> >10 violations
        let demands = vec![1u32; 60];
        let mut online = Deterministic::online(pricing);
        let mut pred = Deterministic::with_window(pricing, 20);
        let ron = run(&mut online, &demands, pricing);
        let rpred = run(&mut pred, &demands, pricing);
        assert_eq!(ron.reservations, 1);
        assert_eq!(rpred.reservations, 1);
        // prediction-window variant stops paying on-demand sooner
        assert!(rpred.on_demand_slots < ron.on_demand_slots,
            "pred od={} online od={}", rpred.on_demand_slots, ron.on_demand_slots);
        assert!(rpred.total <= ron.total);
    }

    #[test]
    fn prediction_guard_avoids_idle_reservation() {
        // Heavy future demand but zero current demand: A^w_z must NOT
        // reserve until demand actually arrives (guard x_t < d_t).
        let pricing = pr(0.1, 0.0, 100);
        let mut demands = vec![0u32; 30];
        demands.extend(vec![1u32; 30]);
        let mut pred = Deterministic::with_window(pricing, 25);
        let mut first_reserve_t = None;
        for (t, &d) in demands.iter().enumerate() {
            let hi = (t + 1 + 25).min(demands.len());
            let dec = pred.decide(d, &demands[t + 1..hi]);
            if dec.total_reserved() > 0 && first_reserve_t.is_none() {
                first_reserve_t = Some(t);
            }
        }
        // must not reserve during the zero-demand prefix
        assert!(first_reserve_t.unwrap() >= 30, "reserved at {:?}", first_reserve_t);
    }

    #[test]
    fn coverage_invariant_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let tau = 3 + rng.below(8) as usize;
            let pricing = pr(0.02 + rng.f64() * 0.2, rng.f64(), tau);
            let demands: Vec<u32> = (0..200).map(|_| rng.below(6) as u32).collect();
            let w = rng.below(tau as u64 - 1) as usize;
            let mut a = Deterministic::new(pricing, rng.f64() * pricing.beta(), w);
            // Ledger::bill_slot errors if coverage is violated.
            let _ = run(&mut a, &demands, pricing);
        }
    }

    /// A checkpoint byte-crafted exactly as the pre-coalescing
    /// implementation wrote it — threshold, scan `(slot, e)` pairs, then
    /// `cover`/`scan_res` as **one usize key per purchased instance** —
    /// must restore into the run-coalesced policy, re-serialize to the
    /// identical bytes, and keep deciding consistently.
    #[test]
    fn pre_rewrite_checkpoint_blob_restores_byte_exactly() {
        let pricing = pr(0.1, 0.0, 100); // beta = 1
        let mut w = StateWriter::new();
        w.f64_bits(1.0); // z = beta
        w.i64(2); // scan.g: two compensating reservations
        w.usize(3);
        for &(slot, e) in &[(14usize, 1i64), (15, 3), (16, 4)] {
            w.usize(slot);
            w.i64(e);
        }
        for _ in 0..2 {
            // cover then scan_res: two instances reserved at t = 12, one
            // wire entry each (the old per-instance deque layout)
            w.usize(2);
            w.usize(12);
            w.usize(12);
        }
        w.usize(17); // t
        w.usize(17); // next_scan_slot
        let blob = w.into_bytes();

        let mut policy = Deterministic::online(pricing);
        let mut r = StateReader::new(&blob);
        policy.restore_state(&mut r).unwrap();
        r.finish().unwrap();

        let mut w2 = StateWriter::new();
        policy.save_state(&mut w2);
        assert_eq!(w2.into_bytes(), blob, "wire format must stay byte-identical");

        // continuation: both reservations from t=12 still cover slot 17
        // (12 + 100 > 17) and p·V = 0.2 stays under z, so demand 1 is
        // fully covered with no new commitment.
        let dec = policy.decide(1, &[]);
        assert_eq!(dec.on_demand, 0);
        assert_eq!(dec.total_reserved(), 0);
    }

    #[test]
    fn tau_one_degenerates_to_slotwise_choice() {
        // tau=1: a reservation covers a single slot; break-even beta=2 with
        // p=0.1 can never be exceeded by one slot (p < beta) -> never reserve.
        let pricing = pr(0.1, 0.5, 1);
        let mut a = Deterministic::online(pricing);
        let demands = vec![5u32; 50];
        let r = run(&mut a, &demands, pricing);
        assert_eq!(r.reservations, 0);
    }
}
