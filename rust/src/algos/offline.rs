//! Offline strategies (Sec. III): the exact dynamic program over
//! `(τ−1)`-tuple states, a fast exact special case for single-instance
//! demand (the Bahncard reduction), and cost lower bounds for reporting.
//!
//! The exact DP is intentionally exponential in `τ` — the paper's point is
//! that offline OPT suffers the curse of dimensionality. We use it on small
//! instances to *verify* Lemma 2 (`n_β ≤ n_OPT`), Proposition 1
//! (`C_{A_β} ≤ (2−α)·C_OPT`), and Proposition 3, and to drive the Fig. 2
//! empirical ratio measurements.

use std::collections::HashMap;

use crate::pricing::Pricing;

/// Result of an offline solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OfflineSolution {
    pub cost: f64,
    /// Number of reservations made by the optimal schedule.
    pub reservations: u64,
}

/// Exact offline optimum via dynamic programming over the reservation
/// history tuple `(r_{t−τ+2}, …, r_t)`. State space is `O((D+1)^{τ−1})`
/// where `D = max_t d_t` — use only for small `τ` and demand.
///
/// The per-slot instance split is implied: with `a` active reservations,
/// serving `min(d, a)` on reservations and the rest on demand is optimal
/// because `α ≤ 1` makes discounted usage never more expensive.
pub fn optimal(demands: &[u32], pricing: &Pricing) -> OfflineSolution {
    let tau = pricing.tau;
    let d_max = demands.iter().copied().max().unwrap_or(0);
    // Guard rails: refuse clearly intractable instances.
    let states_bound = ((d_max as u64 + 1) as f64).powi(tau as i32 - 1);
    assert!(
        states_bound <= 5e6,
        "offline DP intractable here: (D+1)^(tau-1) = {states_bound:.0} states — the curse of dimensionality (Sec. III)"
    );

    // State: vector of reservation counts in the last tau-1 slots
    // (oldest first), bit-packed into u64 with just enough bits per entry.
    let hist_len = tau - 1;
    let bits = (64 - (d_max as u64).leading_zeros()).max(1) as u64; // bits to hold 0..=d_max
    assert!(
        hist_len as u64 * bits <= 64,
        "state tuple does not fit a u64 key: tau-1={hist_len} entries x {bits} bits"
    );
    let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let pack = move |hist: &[u32]| -> u64 {
        hist.iter().fold(0u64, |acc, &r| (acc << bits) | r as u64)
    };

    let p = pricing.p;
    let alpha = pricing.alpha;

    // cur: state -> (min cost, reservations made)
    let mut cur: HashMap<u64, (f64, u64)> = HashMap::new();
    cur.insert(pack(&vec![0u32; hist_len]), (0.0, 0));

    let mut hist_buf = vec![0u32; hist_len];
    let unpack = move |mut key: u64, out: &mut Vec<u32>| {
        for i in (0..out.len()).rev() {
            out[i] = (key & mask) as u32;
            key >>= bits;
        }
    };

    for &d in demands {
        let mut next: HashMap<u64, (f64, u64)> = HashMap::new();
        for (&key, &(cost, nres)) in &cur {
            unpack(key, &mut hist_buf);
            let active_hist: u32 = hist_buf.iter().sum();
            // r_t beyond covering current demand is never useful *now*; it
            // can only help future slots, which a later reservation covers
            // at the same fee for a longer remaining window — so capping at
            // the amount needed to cover d keeps optimality. We still allow
            // the full range [0, needed] plus 0..=d_max defensive cap.
            let needed = d.saturating_sub(active_hist.min(d));
            for r_t in 0..=needed.max(0).min(d_max) {
                let active = active_hist + r_t;
                let on_dem = d.saturating_sub(active);
                let step_cost = r_t as f64 + p * on_dem as f64 + alpha * p * (d - on_dem) as f64;
                // shift history: drop oldest, append r_t
                let mut h2 = hist_buf.clone();
                if hist_len > 0 {
                    h2.rotate_left(1);
                    h2[hist_len - 1] = r_t;
                }
                let k2 = pack(&h2);
                let cand = (cost + step_cost, nres + r_t as u64);
                match next.get(&k2) {
                    Some(&(c, _)) if c <= cand.0 => {}
                    _ => {
                        next.insert(k2, cand);
                    }
                }
            }
        }
        cur = next;
    }

    let (&_k, &(cost, reservations)) = cur
        .iter()
        .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
        .expect("non-empty DP frontier");
    OfflineSolution { cost, reservations }
}

/// Exact offline optimum for **single-instance** demand (`d_t ≤ 1`): the
/// Bahncard special case. O(T) with prefix sums: in an optimal schedule,
/// reservations start at demand slots and never overlap (shifting a
/// purchase later within an idle gap only moves its coverage window toward
/// future demand at equal cost).
pub fn optimal_single(demands: &[u32], pricing: &Pricing) -> OfflineSolution {
    assert!(demands.iter().all(|&d| d <= 1), "optimal_single requires d_t <= 1");
    let t_len = demands.len();
    let tau = pricing.tau;
    let p = pricing.p;
    let alpha = pricing.alpha;

    // prefix[i] = number of demand slots before i
    let mut prefix = vec![0u64; t_len + 1];
    for i in 0..t_len {
        prefix[i + 1] = prefix[i] + demands[i] as u64;
    }
    let usage = |a: usize, b: usize| -> u64 {
        // demand slots in [a, b)
        prefix[b.min(t_len)] - prefix[a.min(t_len)]
    };

    // f[t] = (min cost, reservations) to serve slots t..T with no active card.
    let mut f = vec![(0.0f64, 0u64); t_len + 1];
    for t in (0..t_len).rev() {
        // (a) slot t on demand
        let (c1, n1) = f[t + 1];
        let mut best = (demands[t] as f64 * p + c1, n1);
        // (b) buy a card at t (sensible only when d_t = 1)
        if demands[t] == 1 {
            let (c2, n2) = f[(t + tau).min(t_len)];
            let cand = (1.0 + alpha * p * usage(t, t + tau) as f64 + c2, n2 + 1);
            if cand.0 < best.0 {
                best = cand;
            }
        }
        f[t] = best;
    }
    OfflineSolution { cost: f[0].0, reservations: f[0].1 }
}

/// Valid lower bounds on `C_OPT` for instances too large for the exact DP.
/// Currently `max(α·S, L_cover)` where `S = p·Σd_t` and `L_cover` charges
/// every instance-slot its cheapest conceivable rate (`α·p`) plus, for each
/// demand level, the minimum number of fees forced by its busiest window.
/// Weak but sound; used only for report annotations, never for the
/// competitive-ratio verification (which uses the exact DP).
pub fn lower_bound(demands: &[u32], pricing: &Pricing) -> f64 {
    let s: f64 = pricing.p * demands.iter().map(|&d| d as u64).sum::<u64>() as f64;
    let alpha_s = pricing.alpha * s;
    // Cheap secondary term: any schedule serving everything with
    // reservations needs >= ceil(usage-in-period * p * (1-alpha) ... ) — we
    // keep only the trivially sound alpha*S here plus the observation that
    // each instance-slot costs at least min(p, alpha*p + fee/tau) in any
    // schedule: fee amortized over at most tau slots.
    let per_slot_floor = pricing.p.min(pricing.alpha * pricing.p + 1.0 / pricing.tau as f64);
    let floor_total = per_slot_floor * demands.iter().map(|&d| d as u64).sum::<u64>() as f64;
    alpha_s.max(floor_total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pr(p: f64, alpha: f64, tau: usize) -> Pricing {
        Pricing::normalized(p, alpha, tau)
    }

    /// Brute force over all reservation schedules (tiny instances only).
    fn brute_force(demands: &[u32], pricing: &Pricing) -> f64 {
        let t_len = demands.len();
        let d_max = demands.iter().copied().max().unwrap_or(0);
        let tau = pricing.tau;
        fn rec(
            t: usize,
            demands: &[u32],
            res: &mut Vec<u32>,
            pricing: &Pricing,
            d_max: u32,
            tau: usize,
        ) -> f64 {
            if t == demands.len() {
                return 0.0;
            }
            let mut best = f64::INFINITY;
            for r_t in 0..=d_max {
                res.push(r_t);
                let active: u32 = res[res.len().saturating_sub(tau)..].iter().sum();
                let d = demands[t];
                let od = d.saturating_sub(active);
                let c = r_t as f64
                    + pricing.p * od as f64
                    + pricing.alpha * pricing.p * (d - od) as f64
                    + rec(t + 1, demands, res, pricing, d_max, tau);
                best = best.min(c);
                res.pop();
            }
            best
        }
        let mut res = Vec::with_capacity(t_len);
        rec(0, demands, &mut res, pricing, d_max, tau)
    }

    #[test]
    fn dp_matches_brute_force() {
        let mut rng = Rng::new(404);
        for case in 0..30 {
            let tau = 2 + case % 3;
            let pricing = pr(0.1 + rng.f64() * 0.3, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..7).map(|_| rng.below(3) as u32).collect();
            let dp = optimal(&demands, &pricing);
            let bf = brute_force(&demands, &pricing);
            assert!(
                (dp.cost - bf).abs() < 1e-9,
                "case={case} dp={} bf={} demands={demands:?} tau={tau}",
                dp.cost,
                bf
            );
        }
    }

    #[test]
    fn single_matches_dp_on_01_demand() {
        let mut rng = Rng::new(55);
        for case in 0..30 {
            let tau = 2 + case % 4;
            let pricing = pr(0.2 + rng.f64() * 0.5, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..12).map(|_| u32::from(rng.chance(0.5))).collect();
            let a = optimal_single(&demands, &pricing);
            let b = optimal(&demands, &pricing);
            assert!(
                (a.cost - b.cost).abs() < 1e-9,
                "case={case} single={} dp={} demands={demands:?}",
                a.cost,
                b.cost
            );
        }
    }

    #[test]
    fn opt_prefers_reservation_for_stable_demand() {
        let pricing = pr(0.3, 0.2, 5); // 5 slots on demand = 1.5 > 1 + 0.3
        let demands = vec![1u32; 5];
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 1);
        assert!((sol.cost - (1.0 + 0.2 * 0.3 * 5.0)).abs() < 1e-9);
    }

    #[test]
    fn opt_prefers_on_demand_for_single_pulse() {
        let pricing = pr(0.3, 0.5, 5);
        let mut demands = vec![0u32; 10];
        demands[3] = 1;
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 0);
        assert!((sol.cost - 0.3).abs() < 1e-12);
    }

    #[test]
    fn opt_time_multiplexes_levels() {
        // Two interleaved single-level demands that one reservation can
        // serve: d = 1,1,1,1 with tau=4 needs only 1 reservation even though
        // "virtual users" of a separate scheme would see disjoint demand.
        let pricing = pr(0.5, 0.2, 4);
        let demands = vec![1u32, 1, 1, 1];
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 1);
    }

    #[test]
    #[should_panic(expected = "intractable")]
    fn dp_guard_rejects_huge_state_space() {
        let pricing = pr(0.1, 0.5, 30);
        let demands = vec![10u32; 100];
        optimal(&demands, &pricing);
    }

    #[test]
    fn lower_bound_is_sound_on_small_instances() {
        let mut rng = Rng::new(77);
        for case in 0..20 {
            let tau = 2 + case % 3;
            let pricing = pr(0.1 + rng.f64() * 0.4, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..8).map(|_| rng.below(3) as u32).collect();
            let lb = lower_bound(&demands, &pricing);
            let opt = optimal(&demands, &pricing).cost;
            assert!(lb <= opt + 1e-9, "case={case} lb={lb} opt={opt}");
        }
    }

    #[test]
    fn empty_demand_costs_zero() {
        let pricing = pr(0.1, 0.5, 3);
        assert_eq!(optimal(&[], &pricing).cost, 0.0);
        assert_eq!(optimal_single(&[], &pricing).cost, 0.0);
    }
}
