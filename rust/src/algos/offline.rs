//! Offline strategies (Sec. III): the exact dynamic program over
//! `(τ−1)`-tuple states, a fast exact special case for single-instance
//! demand (the Bahncard reduction), and cost lower bounds for reporting.
//!
//! The exact DP is intentionally exponential in `τ` — the paper's point is
//! that offline OPT suffers the curse of dimensionality. We use it on small
//! instances to *verify* Lemma 2 (`n_β ≤ n_OPT`), Proposition 1
//! (`C_{A_β} ≤ (2−α)·C_OPT`), and Proposition 3, and to drive the Fig. 2
//! empirical ratio measurements.

use crate::pricing::{Contract, ContractId, Market, Pricing};

/// Result of an offline solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OfflineSolution {
    pub cost: f64,
    /// Number of reservations made by the optimal schedule.
    pub reservations: u64,
}

/// Whether the exact DP can solve an instance: the packed state space
/// `(D+1)^(τ−1)` fits the size envelope AND the `(τ−1)`-entry history
/// tuple packs into a `u64` key (relevant for tiny `D` — an all-zero
/// trace still needs one bit per entry). Mirrors both of
/// [`optimal_for_contract`]'s guards.
pub fn dp_tractable(d_max: u32, tau: usize) -> bool {
    let bits = (64 - (d_max as u64).leading_zeros()).max(1) as u64;
    ((d_max as u64 + 1) as f64).powi(tau as i32 - 1) <= 1.6e7
        && tau.saturating_sub(1) as u64 * bits <= 64
}

/// Sentinel for empty slots in [`FlatFrontier`]. Packed states can never
/// reach it: a key of all-ones would need `(τ−1)·bits = 64` with every
/// history entry at `2^bits − 1`, which forces a state-space bound of at
/// least `2^39` — far beyond the tractability guard below.
const EMPTY_KEY: u64 = u64::MAX;

/// Open-addressed flat DP frontier: packed `u64` state → (min cost,
/// reservations), linear probing, power-of-two capacity, splitmix64
/// finalizer as the hash. Two of these are double-buffered per solve —
/// `clear()` keeps capacity, so steady state allocates nothing per slot
/// (the seed implementation rebuilt a `HashMap` every slot and cloned the
/// unpacked history tuple in the inner loop).
struct FlatFrontier {
    keys: Vec<u64>,
    costs: Vec<f64>,
    nres: Vec<u64>,
    len: usize,
    mask: usize,
}

impl FlatFrontier {
    fn with_capacity_pow2(cap: usize) -> FlatFrontier {
        let cap = cap.next_power_of_two().max(16);
        FlatFrontier {
            keys: vec![EMPTY_KEY; cap],
            costs: vec![0.0; cap],
            nres: vec![0; cap],
            len: 0,
            mask: cap - 1,
        }
    }

    /// Home slot: packed states are dense integers, so mix thoroughly.
    #[inline]
    fn slot_of(&self, key: u64) -> usize {
        let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        ((z ^ (z >> 31)) as usize) & self.mask
    }

    /// Offer a candidate; the incumbent survives when its cost is `<=` the
    /// candidate's (the exact tie-breaking of the seed HashMap path).
    #[inline]
    fn offer(&mut self, key: u64, cost: f64, nres: u64) {
        if (self.len + 1) * 4 > self.keys.len() * 3 {
            self.grow();
        }
        let mut i = self.slot_of(key);
        loop {
            let k = self.keys[i];
            if k == EMPTY_KEY {
                self.keys[i] = key;
                self.costs[i] = cost;
                self.nres[i] = nres;
                self.len += 1;
                return;
            }
            if k == key {
                if cost < self.costs[i] {
                    self.costs[i] = cost;
                    self.nres[i] = nres;
                }
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        let mut bigger = FlatFrontier::with_capacity_pow2(self.keys.len() * 2);
        for i in 0..self.keys.len() {
            if self.keys[i] != EMPTY_KEY {
                bigger.offer(self.keys[i], self.costs[i], self.nres[i]);
            }
        }
        *self = bigger;
    }

    /// Reset for the next slot, keeping capacity (a memset, not a rebuild).
    fn clear(&mut self) {
        self.keys.fill(EMPTY_KEY);
        self.len = 0;
    }

    fn iter(&self) -> impl Iterator<Item = (u64, f64, u64)> + '_ {
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, &k)| k != EMPTY_KEY)
            .map(move |(i, &k)| (k, self.costs[i], self.nres[i]))
    }
}

/// Exact offline optimum for the classic normalized single-contract
/// pricing: the `upfront = 1`, `rate = α·p` view of
/// [`optimal_for_contract`] (bit-identical arithmetic).
pub fn optimal(demands: &[u32], pricing: &Pricing) -> OfflineSolution {
    let contract =
        Contract { upfront: 1.0, rate: pricing.alpha * pricing.p, term: pricing.tau };
    optimal_for_contract(demands, pricing.p, &contract)
}

/// Exact offline optimum restricted to **one contract type**, via dynamic
/// programming over the reservation history tuple `(r_{t−τ+2}, …, r_t)`
/// with `τ = contract.term`. State space is `O((D+1)^{τ−1})` where
/// `D = max_t d_t` — use only for small `τ` and demand (check
/// [`dp_tractable`] first to avoid the panic).
///
/// The frontier is a double-buffered [`FlatFrontier`] keyed on the packed
/// `u64` state; successor keys are computed arithmetically (mask, shift,
/// or) so the inner loop touches no heap at all. Peak memory is
/// `24 B × capacity × 2` (both buffers; capacity ≤ states / 0.75 rounded to
/// a power of two), which is what bounds the tractability guard.
///
/// The per-slot instance split is implied: with `a` active reservations,
/// serving `min(d, a)` on reservations and the rest on demand is optimal
/// because `rate ≤ p` makes discounted usage never more expensive. Costs
/// are in market currency (`upfront` per fee, `p`/`rate` per slot).
pub fn optimal_for_contract(demands: &[u32], p: f64, contract: &Contract) -> OfflineSolution {
    let tau = contract.term;
    let upfront = contract.upfront;
    let rate = contract.rate;
    let d_max = demands.iter().copied().max().unwrap_or(0);
    // Guard rails: refuse clearly intractable instances — [`dp_tractable`]
    // is the single source of truth (state-count envelope + u64 key
    // width). The flat frontier raised the envelope 3.2x over the seed
    // HashMap path (5e6); at the bound the two buffers peak around 1.5 GB.
    let states_bound = ((d_max as u64 + 1) as f64).powi(tau as i32 - 1);
    assert!(
        dp_tractable(d_max, tau),
        "offline DP intractable here: (D+1)^(tau-1) = {states_bound:.0} states / packed key over 64 bits — the curse of dimensionality (Sec. III)"
    );

    // State: reservation counts of the last tau-1 slots (oldest first),
    // bit-packed into a u64 with just enough bits per entry (the key fits:
    // guaranteed by the dp_tractable assert above).
    let hist_len = tau - 1;
    let bits = (64 - (d_max as u64).leading_zeros()).max(1) as u64; // bits to hold 0..=d_max
    let entry_mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    // Dropping the oldest entry keeps the low (hist_len-1)*bits bits; the
    // shift below then appends r_t as the newest entry.
    let keep_bits = hist_len.saturating_sub(1) as u64 * bits;
    let keep_mask = if keep_bits >= 64 { u64::MAX } else { (1u64 << keep_bits) - 1 };

    let mut cur = FlatFrontier::with_capacity_pow2(1 << 10);
    let mut next = FlatFrontier::with_capacity_pow2(1 << 10);
    cur.offer(0, 0.0, 0); // all-zero history

    for &d in demands {
        next.clear();
        for (key, cost, nres) in cur.iter() {
            // Active coverage = sum of the packed history entries.
            let mut active_hist = 0u32;
            let mut k = key;
            for _ in 0..hist_len {
                active_hist += (k & entry_mask) as u32;
                k >>= bits; // bits < 64 whenever hist_len > 0 (guarded above)
            }
            // r_t beyond covering current demand is never useful *now*; it
            // can only help future slots, which a later reservation covers
            // at the same fee for a longer remaining window — so capping at
            // the amount needed to cover d keeps optimality.
            let needed = d.saturating_sub(active_hist.min(d));
            let shifted = if hist_len == 0 { 0 } else { (key & keep_mask) << bits };
            for r_t in 0..=needed.min(d_max) {
                let active = active_hist + r_t;
                let on_dem = d.saturating_sub(active);
                let step_cost =
                    r_t as f64 * upfront + p * on_dem as f64 + rate * (d - on_dem) as f64;
                let k2 = if hist_len == 0 { 0 } else { shifted | r_t as u64 };
                next.offer(k2, cost + step_cost, nres + r_t as u64);
            }
        }
        std::mem::swap(&mut cur, &mut next);
    }

    let mut best: Option<(f64, u64)> = None;
    for (_key, cost, nres) in cur.iter() {
        match best {
            Some((c, _)) if c <= cost => {}
            _ => best = Some((cost, nres)),
        }
    }
    let (cost, reservations) = best.expect("non-empty DP frontier");
    OfflineSolution { cost, reservations }
}

/// Best offline cost over a [`Market`] menu, restricted to committing to a
/// **single contract type** (plus on-demand): the exact DP per contract,
/// minimized across the menu. Exact for single-contract markets; for true
/// multi-contract menus the unrestricted optimum could only be cheaper, so
/// this is a *feasible offline schedule's* cost — the comparator the
/// scenario runner reports ratios against.
///
/// Contracts outside the DP tractability envelope are skipped (their ids
/// are returned in `skipped`); `best` is `None` when no contract is
/// solvable. An empty menu yields the pure on-demand schedule.
pub fn optimal_market(demands: &[u32], market: &Market) -> MarketOffline {
    let d_max = demands.iter().copied().max().unwrap_or(0);
    let mut per_contract: Vec<(ContractId, OfflineSolution)> = Vec::new();
    let mut skipped: Vec<ContractId> = Vec::new();
    for cid in 0..market.len() {
        let c = market.contract(cid);
        if dp_tractable(d_max, c.term) {
            per_contract.push((cid, optimal_for_contract(demands, market.p(), &c)));
        } else {
            skipped.push(cid);
        }
    }
    // When every contract on a non-empty menu is intractable there is
    // nothing useful to report; otherwise pure on-demand is always a
    // feasible candidate alongside the solved contracts.
    let nothing_solved = !skipped.is_empty() && per_contract.is_empty();
    let mut best: Option<(Option<ContractId>, OfflineSolution)> = if nothing_solved {
        None
    } else {
        let od_cost: f64 = market.p() * demands.iter().map(|&d| d as u64).sum::<u64>() as f64;
        Some((None, OfflineSolution { cost: od_cost, reservations: 0 }))
    };
    for &(cid, sol) in &per_contract {
        match best {
            Some((_, b)) if b.cost <= sol.cost => {}
            _ => best = Some((Some(cid), sol)),
        }
    }
    MarketOffline { best, per_contract, skipped }
}

/// Whether the **joint** multi-contract DP can solve an instance: the
/// product state space `Π_j (D+1)^(τ_j−1)` must fit a tighter envelope
/// than the per-contract guard (the joint frontier explores the full
/// product space and pays a `(D+1)^k` purchase branching per state), the
/// concatenated history tuple must pack into a `u64` key, and the per-slot
/// branching itself must stay small. Mirrors [`optimal_market_joint`]'s
/// guard exactly.
pub fn dp_joint_tractable(d_max: u32, terms: &[usize]) -> bool {
    let bits = (64 - (d_max as u64).leading_zeros()).max(1) as u64;
    let hist_bits: u64 = terms.iter().map(|&t| (t as u64 - 1) * bits).sum();
    let mut states = 1.0f64;
    for &t in terms {
        states *= ((d_max as u64 + 1) as f64).powi(t as i32 - 1);
    }
    let branch = ((d_max as u64 + 1) as f64).powi(terms.len() as i32);
    states <= 1.1e6 && hist_bits <= 64 && branch <= 64.0
}

/// Exact offline optimum over a whole [`Market`] menu: a dynamic program
/// whose state spans **concurrent reservations across all menu contracts**
/// — the per-contract reservation histories `(r_{j,t−τ_j+2}, …, r_{j,t})`
/// concatenated into one packed `u64` key. Returns `None` when the
/// instance fails [`dp_joint_tractable`].
///
/// Unlike the restricted DP, purchases are *not* capped at the amount
/// needed to cover current demand: with heterogeneous usage rates it can
/// pay to commit to a cheaper-rate contract while still covered by a
/// dearer one (the usage re-bills cheapest-first, exactly like
/// [`Ledger::bill`](crate::ledger::Ledger::bill)). Per-slot purchases of
/// each contract are capped at `D = max_t d_t`, which loses nothing: an
/// optimal schedule never holds more than `D` active instances of one
/// contract (usage per slot never exceeds `D`, billing uses the cheapest
/// `D` actives, and dropping the excess only removes fees).
///
/// Because the searched space is a superset of every restricted schedule
/// and of every feasible online decision sequence (billed the same way),
/// the result is a true lower bound for both — the anchor of the
/// `joint ≤ restricted ≤ …` / `joint ≤ online` cost sandwich pinned in
/// `rust/tests/differential.rs`.
///
/// Constant-level traces (`d_t ≡ L`) take a needed-capped fast path that
/// prunes any branch holding more than `L` actives of one contract — see
/// `constant_level` for the exactness argument; bit-equality with the
/// uncapped DP ([`optimal_market_joint_uncapped`]) is asserted in
/// `tests/differential.rs`.
pub fn optimal_market_joint(demands: &[u32], market: &Market) -> Option<OfflineSolution> {
    joint_dp(demands, market, constant_level(demands).unwrap_or(u32::MAX))
}

/// The joint DP with the constant-trace purchase cap disabled — the
/// differential oracle the capped fast path is asserted bit-equal against
/// (`tests/differential.rs`).
pub fn optimal_market_joint_uncapped(
    demands: &[u32],
    market: &Market,
) -> Option<OfflineSolution> {
    joint_dp(demands, market, u32::MAX)
}

/// `Some(level)` iff every slot demands exactly `level` (non-empty trace).
///
/// On such traces, capping each contract's **active count** at `level` is
/// exact: usage per slot is at most `level` and bills cheapest-first, so a
/// schedule holding `a_j > level` actives of contract `j` serves at most
/// `level ≤ a_j − 1` instance-slots on `j` — dropping `j`'s latest
/// purchase leaves every slot's billing untouched (each contract's take
/// `min(rem, avail_j)` is unchanged since `rem ≤ level`) and strictly
/// removes its upfront fee. The cost gap is a whole fee, orders of
/// magnitude above f64 rounding dust, so the capped minimum is
/// *bit-identical* to the uncapped one (the reservation count can differ
/// on exact cost ties — the frontier keeps its incumbent).
fn constant_level(demands: &[u32]) -> Option<u32> {
    let first = *demands.first()?;
    demands.iter().all(|&d| d == first).then_some(first)
}

fn joint_dp(demands: &[u32], market: &Market, cap: u32) -> Option<OfflineSolution> {
    let d_max = demands.iter().copied().max().unwrap_or(0);
    let terms: Vec<usize> = market.contracts().iter().map(|c| c.term).collect();
    if !dp_joint_tractable(d_max, &terms) {
        return None;
    }
    let p = market.p();
    let k = market.len();
    if k == 0 || d_max == 0 {
        let od: f64 = p * demands.iter().map(|&d| d as u64).sum::<u64>() as f64;
        return Some(OfflineSolution { cost: od, reservations: 0 });
    }

    let bits = (64 - (d_max as u64).leading_zeros()).max(1) as u64;
    let entry_mask = (1u64 << bits) - 1; // bits <= 32 for a u32 demand
    let mask_of = |n: u64| if n >= 64 { u64::MAX } else { (1u64 << n) - 1 };
    let hist_len: Vec<u64> = terms.iter().map(|&t| t as u64 - 1).collect();
    let mut offsets = Vec::with_capacity(k);
    let mut acc = 0u64;
    for &h in &hist_len {
        offsets.push(acc);
        acc += h * bits;
    }
    let seg_masks: Vec<u64> = hist_len.iter().map(|&h| mask_of(h * bits)).collect();
    let keep_masks: Vec<u64> =
        hist_len.iter().map(|&h| mask_of(h.saturating_sub(1) * bits)).collect();
    let upfronts: Vec<f64> = market.contracts().iter().map(|c| c.upfront).collect();
    let rates: Vec<f64> = market.contracts().iter().map(|c| c.rate).collect();
    let rate_order: Vec<ContractId> = market.rate_order().to_vec();
    let base = d_max as u64 + 1;
    let branch = base.pow(k as u32); // <= 64 by the guard

    let mut cur = FlatFrontier::with_capacity_pow2(1 << 10);
    let mut next = FlatFrontier::with_capacity_pow2(1 << 10);
    cur.offer(0, 0.0, 0);
    let mut active = vec![0u32; k];
    let mut avail = vec![0u32; k];
    for &d in demands {
        next.clear();
        for (key, cost, nres) in cur.iter() {
            // Per state: active coverage per contract (sum of its history
            // entries) and the combo-invariant part of the successor key
            // (each segment's newest hist−1 entries, already shifted into
            // place — only the appended `r` digit varies per combo).
            // (Term-1 contracts carry no history: sorted first, offset 0.)
            let mut base_key2 = 0u64;
            for j in 0..k {
                if hist_len[j] == 0 {
                    active[j] = 0;
                    continue;
                }
                let seg = (key >> offsets[j]) & seg_masks[j];
                base_key2 |= ((seg & keep_masks[j]) << bits) << offsets[j];
                let mut rest = seg;
                let mut a = 0u32;
                for _ in 0..hist_len[j] {
                    a += (rest & entry_mask) as u32;
                    rest >>= bits;
                }
                active[j] = a;
            }
            'combo: for combo in 0..branch {
                let mut digits = combo;
                let mut fees = 0.0f64;
                let mut bought = 0u64;
                let mut total_active = 0u32;
                let mut key2 = base_key2;
                for j in 0..k {
                    let r = (digits % base) as u32;
                    digits /= base;
                    avail[j] = active[j] + r;
                    // Needed cap (constant traces): more than `cap` actives
                    // of one contract can never be optimal — prune the
                    // branch. The no-purchase digit always survives, so
                    // the frontier never empties. `cap = u32::MAX`
                    // disables this (the general path).
                    if avail[j] > cap {
                        continue 'combo;
                    }
                    total_active += avail[j];
                    fees += r as f64 * upfronts[j];
                    bought += r as u64;
                    if hist_len[j] > 0 {
                        key2 |= (r as u64) << offsets[j];
                    }
                }
                // Serve min(d, active) on reservations (rates never exceed
                // p), billed against the cheapest active contract first —
                // the Ledger's exact convention.
                let usage = d.min(total_active);
                let on_dem = d - usage;
                let mut step = fees + p * on_dem as f64;
                let mut rem = usage;
                for &cid in &rate_order {
                    if rem == 0 {
                        break;
                    }
                    let take = rem.min(avail[cid]);
                    step += rates[cid] * take as f64;
                    rem -= take;
                }
                next.offer(key2, cost + step, nres + bought);
            }
        }
        std::mem::swap(&mut cur, &mut next);
    }

    let mut best: Option<(f64, u64)> = None;
    for (_key, cost, nres) in cur.iter() {
        match best {
            Some((c, _)) if c <= cost => {}
            _ => best = Some((cost, nres)),
        }
    }
    let (cost, reservations) = best.expect("non-empty joint DP frontier");
    Some(OfflineSolution { cost, reservations })
}

/// Result of [`optimal_market`].
#[derive(Debug, Clone, PartialEq)]
pub struct MarketOffline {
    /// Cheapest restricted schedule: the contract it commits to (`None` =
    /// pure on-demand) and its solution. `None` only when every contract
    /// was skipped as intractable.
    pub best: Option<(Option<ContractId>, OfflineSolution)>,
    /// Exact per-contract solutions, in menu order (tractable ones only).
    pub per_contract: Vec<(ContractId, OfflineSolution)>,
    /// Contracts skipped because their term puts the DP out of range.
    pub skipped: Vec<ContractId>,
}

/// Exact offline optimum for **single-instance** demand (`d_t ≤ 1`): the
/// Bahncard special case. O(T) with prefix sums: in an optimal schedule,
/// reservations start at demand slots and never overlap (shifting a
/// purchase later within an idle gap only moves its coverage window toward
/// future demand at equal cost).
pub fn optimal_single(demands: &[u32], pricing: &Pricing) -> OfflineSolution {
    assert!(demands.iter().all(|&d| d <= 1), "optimal_single requires d_t <= 1");
    let t_len = demands.len();
    let tau = pricing.tau;
    let p = pricing.p;
    let alpha = pricing.alpha;

    // prefix[i] = number of demand slots before i
    let mut prefix = vec![0u64; t_len + 1];
    for i in 0..t_len {
        prefix[i + 1] = prefix[i] + demands[i] as u64;
    }
    let usage = |a: usize, b: usize| -> u64 {
        // demand slots in [a, b)
        prefix[b.min(t_len)] - prefix[a.min(t_len)]
    };

    // f[t] = (min cost, reservations) to serve slots t..T with no active card.
    let mut f = vec![(0.0f64, 0u64); t_len + 1];
    for t in (0..t_len).rev() {
        // (a) slot t on demand
        let (c1, n1) = f[t + 1];
        let mut best = (demands[t] as f64 * p + c1, n1);
        // (b) buy a card at t (sensible only when d_t = 1)
        if demands[t] == 1 {
            let (c2, n2) = f[(t + tau).min(t_len)];
            let cand = (1.0 + alpha * p * usage(t, t + tau) as f64 + c2, n2 + 1);
            if cand.0 < best.0 {
                best = cand;
            }
        }
        f[t] = best;
    }
    OfflineSolution { cost: f[0].0, reservations: f[0].1 }
}

/// Valid lower bounds on `C_OPT` for instances too large for the exact DP.
/// Currently `max(α·S, L_cover)` where `S = p·Σd_t` and `L_cover` charges
/// every instance-slot its cheapest conceivable rate (`α·p`) plus, for each
/// demand level, the minimum number of fees forced by its busiest window.
/// Weak but sound; used only for report annotations, never for the
/// competitive-ratio verification (which uses the exact DP).
pub fn lower_bound(demands: &[u32], pricing: &Pricing) -> f64 {
    let total_slots: u64 = demands.iter().map(|&d| d as u64).sum();
    let s: f64 = pricing.p * total_slots as f64;
    let alpha_s = pricing.alpha * s;
    // Cheap secondary term: any schedule serving everything with
    // reservations needs >= ceil(usage-in-period * p * (1-alpha) ... ) — we
    // keep only the trivially sound alpha*S here plus the observation that
    // each instance-slot costs at least min(p, alpha*p + fee/tau) in any
    // schedule: fee amortized over at most tau slots.
    let per_slot_floor = pricing.p.min(pricing.alpha * pricing.p + 1.0 / pricing.tau as f64);
    let floor_total = per_slot_floor * total_slots as f64;
    alpha_s.max(floor_total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pr(p: f64, alpha: f64, tau: usize) -> Pricing {
        Pricing::normalized(p, alpha, tau)
    }

    /// Brute force over all reservation schedules (tiny instances only).
    fn brute_force(demands: &[u32], pricing: &Pricing) -> f64 {
        let t_len = demands.len();
        let d_max = demands.iter().copied().max().unwrap_or(0);
        let tau = pricing.tau;
        fn rec(
            t: usize,
            demands: &[u32],
            res: &mut Vec<u32>,
            pricing: &Pricing,
            d_max: u32,
            tau: usize,
        ) -> f64 {
            if t == demands.len() {
                return 0.0;
            }
            let mut best = f64::INFINITY;
            for r_t in 0..=d_max {
                res.push(r_t);
                let active: u32 = res[res.len().saturating_sub(tau)..].iter().sum();
                let d = demands[t];
                let od = d.saturating_sub(active);
                let c = r_t as f64
                    + pricing.p * od as f64
                    + pricing.alpha * pricing.p * (d - od) as f64
                    + rec(t + 1, demands, res, pricing, d_max, tau);
                best = best.min(c);
                res.pop();
            }
            best
        }
        let mut res = Vec::with_capacity(t_len);
        rec(0, demands, &mut res, pricing, d_max, tau)
    }

    #[test]
    fn dp_matches_brute_force() {
        let mut rng = Rng::new(404);
        for case in 0..30 {
            let tau = 2 + case % 3;
            let pricing = pr(0.1 + rng.f64() * 0.3, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..7).map(|_| rng.below(3) as u32).collect();
            let dp = optimal(&demands, &pricing);
            let bf = brute_force(&demands, &pricing);
            assert!(
                (dp.cost - bf).abs() < 1e-9,
                "case={case} dp={} bf={} demands={demands:?} tau={tau}",
                dp.cost,
                bf
            );
        }
    }

    #[test]
    fn single_matches_dp_on_01_demand() {
        let mut rng = Rng::new(55);
        for case in 0..30 {
            let tau = 2 + case % 4;
            let pricing = pr(0.2 + rng.f64() * 0.5, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..12).map(|_| u32::from(rng.chance(0.5))).collect();
            let a = optimal_single(&demands, &pricing);
            let b = optimal(&demands, &pricing);
            assert!(
                (a.cost - b.cost).abs() < 1e-9,
                "case={case} single={} dp={} demands={demands:?}",
                a.cost,
                b.cost
            );
        }
    }

    #[test]
    fn opt_prefers_reservation_for_stable_demand() {
        let pricing = pr(0.3, 0.2, 5); // 5 slots on demand = 1.5 > 1 + 0.3
        let demands = vec![1u32; 5];
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 1);
        assert!((sol.cost - (1.0 + 0.2 * 0.3 * 5.0)).abs() < 1e-9);
    }

    #[test]
    fn opt_prefers_on_demand_for_single_pulse() {
        let pricing = pr(0.3, 0.5, 5);
        let mut demands = vec![0u32; 10];
        demands[3] = 1;
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 0);
        assert!((sol.cost - 0.3).abs() < 1e-12);
    }

    #[test]
    fn opt_time_multiplexes_levels() {
        // Two interleaved single-level demands that one reservation can
        // serve: d = 1,1,1,1 with tau=4 needs only 1 reservation even though
        // "virtual users" of a separate scheme would see disjoint demand.
        let pricing = pr(0.5, 0.2, 4);
        let demands = vec![1u32, 1, 1, 1];
        let sol = optimal(&demands, &pricing);
        assert_eq!(sol.reservations, 1);
    }

    #[test]
    #[should_panic(expected = "intractable")]
    fn dp_guard_rejects_huge_state_space() {
        let pricing = pr(0.1, 0.5, 30);
        let demands = vec![10u32; 100];
        optimal(&demands, &pricing);
    }

    #[test]
    fn lower_bound_is_sound_on_small_instances() {
        let mut rng = Rng::new(77);
        for case in 0..20 {
            let tau = 2 + case % 3;
            let pricing = pr(0.1 + rng.f64() * 0.4, rng.f64() * 0.9, tau);
            let demands: Vec<u32> = (0..8).map(|_| rng.below(3) as u32).collect();
            let lb = lower_bound(&demands, &pricing);
            let opt = optimal(&demands, &pricing).cost;
            assert!(lb <= opt + 1e-9, "case={case} lb={lb} opt={opt}");
        }
    }

    #[test]
    fn empty_demand_costs_zero() {
        let pricing = pr(0.1, 0.5, 3);
        assert_eq!(optimal(&[], &pricing).cost, 0.0);
        assert_eq!(optimal_single(&[], &pricing).cost, 0.0);
    }

    #[test]
    fn flat_frontier_keeps_minimum_and_grows() {
        let mut f = FlatFrontier::with_capacity_pow2(16);
        // force several growth rounds with dense keys
        for k in 0..500u64 {
            f.offer(k, k as f64, k);
        }
        // re-offer with worse costs: incumbents must survive
        for k in 0..500u64 {
            f.offer(k, k as f64 + 1.0, 999);
        }
        // and with better costs: candidates must win
        f.offer(7, 0.5, 42);
        let mut seen = 0usize;
        for (k, c, n) in f.iter() {
            seen += 1;
            if k == 7 {
                assert_eq!(c, 0.5);
                assert_eq!(n, 42);
            } else {
                assert_eq!(c, k as f64);
                assert_eq!(n, k);
            }
        }
        assert_eq!(seen, 500);
        f.clear();
        assert_eq!(f.iter().count(), 0);
    }

    #[test]
    fn optimal_market_single_matches_classic_bitwise() {
        let pricing = pr(0.3, 0.2, 5);
        let demands = [1u32; 10];
        let classic = optimal(&demands, &pricing);
        let m = Market::single(pricing);
        let res = optimal_market(&demands, &m);
        let (which, sol) = res.best.unwrap();
        // stable demand at these prices: reserving wins over pure on-demand
        assert_eq!(which, Some(0));
        assert_eq!(sol.cost.to_bits(), classic.cost.to_bits());
        assert_eq!(sol.reservations, classic.reservations);
    }

    #[test]
    fn optimal_market_picks_cheaper_contract() {
        // short dear contract vs long cheap contract on stable demand
        let m = Market::new(
            0.3,
            vec![
                crate::pricing::Contract { upfront: 0.5, rate: 0.15, term: 4 },
                crate::pricing::Contract { upfront: 1.0, rate: 0.03, term: 10 },
            ],
        );
        assert_eq!(m.len(), 2);
        let demands = vec![1u32; 10];
        let res = optimal_market(&demands, &m);
        let (which, sol) = res.best.unwrap();
        // c1: 1.0 + 10*0.03 = 1.3; c0 needs >= 2 fees + od; od alone: 3.0
        assert_eq!(which, Some(1));
        assert!((sol.cost - 1.3).abs() < 1e-9, "cost {}", sol.cost);
        assert_eq!(res.skipped.len(), 0);
        assert_eq!(res.per_contract.len(), 2);
    }

    #[test]
    fn optimal_market_empty_menu_is_on_demand() {
        let m =
            Market::new(0.1, vec![crate::pricing::Contract { upfront: 9.0, rate: 0.05, term: 3 }]);
        assert!(m.is_empty());
        let demands = [2u32, 0, 1];
        let res = optimal_market(&demands, &m);
        let (which, sol) = res.best.unwrap();
        assert_eq!(which, None);
        assert!((sol.cost - 0.3).abs() < 1e-12);
    }

    #[test]
    fn optimal_market_skips_long_terms_even_on_zero_demand() {
        // d_max = 0 makes the state-count bound trivially 1, but the packed
        // key still needs one bit per history entry: term >= 66 must be
        // reported as skipped, not panic inside the DP.
        let m = Market::new(
            0.1,
            vec![crate::pricing::Contract { upfront: 1.0, rate: 0.01, term: 200 }],
        );
        assert!(!dp_tractable(0, 200));
        let demands = vec![0u32; 50];
        let res = optimal_market(&demands, &m);
        assert_eq!(res.skipped, vec![0]);
        assert!(res.best.is_none());
    }

    #[test]
    fn optimal_market_skips_intractable_terms() {
        let m = Market::new(
            0.1,
            vec![crate::pricing::Contract { upfront: 1.0, rate: 0.01, term: 100 }],
        );
        let demands = vec![5u32; 50];
        assert!(!dp_tractable(5, 100));
        let res = optimal_market(&demands, &m);
        assert_eq!(res.skipped, vec![0]);
        assert!(res.best.is_none());
    }

    /// Brute force over all joint purchase schedules (per-slot purchases of
    /// each contract in `0..=d_max`), billed exactly like the ledger:
    /// min(d, active) served on reservations, cheapest rate first.
    fn brute_force_market(demands: &[u32], market: &Market) -> f64 {
        fn rec(
            t: usize,
            demands: &[u32],
            hist: &mut [Vec<u32>],
            market: &Market,
            d_max: u32,
        ) -> f64 {
            if t == demands.len() {
                return 0.0;
            }
            let k = market.len();
            let d = demands[t];
            let p = market.p();
            let base = d_max as usize + 1;
            let combos = base.pow(k as u32);
            let mut best = f64::INFINITY;
            for combo in 0..combos {
                let mut digits = combo;
                let mut fees = 0.0;
                for h in hist.iter_mut() {
                    h.push((digits % base) as u32);
                    digits /= base;
                }
                let avail: Vec<u32> = (0..k)
                    .map(|j| {
                        let lo = hist[j].len().saturating_sub(market.contract(j).term);
                        hist[j][lo..].iter().sum::<u32>()
                    })
                    .collect();
                for j in 0..k {
                    fees += *hist[j].last().unwrap() as f64 * market.contract(j).upfront;
                }
                let total: u32 = avail.iter().sum();
                let usage = d.min(total);
                let mut step = fees + p * (d - usage) as f64;
                let mut rem = usage;
                for &cid in market.rate_order() {
                    let take = rem.min(avail[cid]);
                    step += market.contract(cid).rate * take as f64;
                    rem -= take;
                }
                let cand = step + rec(t + 1, demands, hist, market, d_max);
                best = best.min(cand);
                for h in hist.iter_mut() {
                    h.pop();
                }
            }
            best
        }
        let d_max = demands.iter().copied().max().unwrap_or(0);
        let mut hist: Vec<Vec<u32>> = vec![Vec::new(); market.len()];
        rec(0, demands, &mut hist, market, d_max)
    }

    fn joint_test_market() -> Market {
        Market::new(
            0.1,
            vec![
                crate::pricing::Contract { upfront: 0.3, rate: 0.02, term: 4 },
                crate::pricing::Contract { upfront: 0.8, rate: 0.01, term: 10 },
            ],
        )
    }

    #[test]
    fn joint_matches_brute_force_on_tiny_menus() {
        let mut rng = Rng::new(909);
        for case in 0..20 {
            let p = 0.1 + rng.f64() * 0.3;
            let m = Market::new(
                p,
                vec![
                    crate::pricing::Contract {
                        upfront: 0.1 + rng.f64() * 0.5,
                        rate: rng.f64() * 0.5 * p,
                        term: 2 + rng.below(2) as usize,
                    },
                    crate::pricing::Contract {
                        upfront: 0.4 + rng.f64() * 0.8,
                        rate: rng.f64() * 0.3 * p,
                        term: 4 + rng.below(2) as usize,
                    },
                ],
            );
            let demands: Vec<u32> = (0..7).map(|_| rng.below(2) as u32).collect();
            let joint = optimal_market_joint(&demands, &m).expect("tiny instance is tractable");
            let bf = brute_force_market(&demands, &m);
            assert!(
                (joint.cost - bf).abs() < 1e-9,
                "case {case}: joint {} vs brute force {bf} (menu k={})",
                joint.cost,
                m.len()
            );
        }
    }

    #[test]
    fn joint_mixes_contracts_when_mixing_is_cheaper() {
        // 14 slots of unit demand: the long contract covers 10, the short
        // one the 4-slot tail — strictly cheaper than any single-contract
        // schedule (B-only 1.30 with an on-demand tail, A-only 1.34).
        let m = joint_test_market();
        assert_eq!(m.len(), 2);
        let demands = vec![1u32; 14];
        let joint = optimal_market_joint(&demands, &m).unwrap();
        assert!((joint.cost - 1.28).abs() < 1e-9, "joint {}", joint.cost);
        assert_eq!(joint.reservations, 2);
        let restricted = optimal_market(&demands, &m);
        let (_, best) = restricted.best.unwrap();
        assert!(joint.cost < best.cost - 1e-9, "joint {} restricted {}", joint.cost, best.cost);
    }

    #[test]
    fn joint_never_exceeds_restricted() {
        let mut rng = Rng::new(4242);
        let short = Market::new(
            0.2,
            vec![
                crate::pricing::Contract { upfront: 0.3, rate: 0.04, term: 3 },
                crate::pricing::Contract { upfront: 0.6, rate: 0.02, term: 5 },
            ],
        );
        for case in 0..15 {
            // alternate 0/1 demand on the 4+10 menu with 0..=2 on a short
            // menu (keeps the joint product space small in debug builds)
            let (m, demands): (Market, Vec<u32>) = if case % 2 == 0 {
                (joint_test_market(), (0..20).map(|_| rng.below(2) as u32).collect())
            } else {
                (short.clone(), (0..20).map(|_| rng.below(3) as u32).collect())
            };
            let joint = optimal_market_joint(&demands, &m).unwrap();
            let restricted = optimal_market(&demands, &m);
            let (_, best) = restricted.best.unwrap();
            assert!(
                joint.cost <= best.cost + 1e-9 * (1.0 + best.cost),
                "joint {} > restricted {}",
                joint.cost,
                best.cost
            );
        }
    }

    #[test]
    fn joint_single_contract_matches_restricted_dp() {
        let pricing = pr(0.3, 0.2, 5);
        let demands = [1u32; 10];
        let m = Market::single(pricing);
        let joint = optimal_market_joint(&demands, &m).unwrap();
        let classic = optimal(&demands, &pricing);
        assert!((joint.cost - classic.cost).abs() < 1e-9);
        assert_eq!(joint.reservations, classic.reservations);
    }

    #[test]
    fn joint_empty_menu_is_on_demand() {
        let m =
            Market::new(0.1, vec![crate::pricing::Contract { upfront: 9.0, rate: 0.05, term: 3 }]);
        assert!(m.is_empty());
        let joint = optimal_market_joint(&[2, 0, 1], &m).unwrap();
        assert!((joint.cost - 0.3).abs() < 1e-12);
        assert_eq!(joint.reservations, 0);
    }

    #[test]
    fn joint_guard_rejects_wide_menus() {
        // terms 6 + 18 at D = 3 blow the product envelope: 4^22 states
        let m = Market::new(
            0.08,
            vec![
                crate::pricing::Contract { upfront: 0.2, rate: 0.039, term: 6 },
                crate::pricing::Contract { upfront: 0.45, rate: 0.031, term: 18 },
            ],
        );
        let demands = vec![3u32; 40];
        assert!(!dp_joint_tractable(3, &[6, 18]));
        assert!(optimal_market_joint(&demands, &m).is_none());
        // even unit demand overflows here (2^22 states); the committed
        // scenarios compress to terms 4 + 12 (2^14) to stay solvable
        assert!(!dp_joint_tractable(1, &[6, 18]));
        assert!(dp_joint_tractable(1, &[4, 12]));
    }

    #[test]
    fn joint_tractable_handles_term_one_contracts() {
        // a term-1 contract carries no history; the packed key must stay
        // well-formed next to a long-term contract
        let m = Market::new(
            0.5,
            vec![
                crate::pricing::Contract { upfront: 0.2, rate: 0.1, term: 1 },
                crate::pricing::Contract { upfront: 0.9, rate: 0.05, term: 6 },
            ],
        );
        assert_eq!(m.len(), 2);
        let demands = [1u32, 1, 0, 1, 1, 1, 0, 1];
        let joint = optimal_market_joint(&demands, &m).unwrap();
        let bf = brute_force_market(&demands, &m);
        assert!((joint.cost - bf).abs() < 1e-9, "joint {} bf {bf}", joint.cost);
    }

    #[test]
    fn single_matches_dp_in_the_raised_envelope() {
        // tau = 12 on 0/1 demand -> 2^11 = 2048 packed states; beyond what
        // the brute force covers, checked against the Bahncard solver.
        let mut rng = Rng::new(2024);
        for case in 0..10 {
            let pricing = pr(0.1 + rng.f64() * 0.3, rng.f64() * 0.9, 12);
            let demands: Vec<u32> = (0..40).map(|_| u32::from(rng.chance(0.4))).collect();
            let a = optimal_single(&demands, &pricing);
            let b = optimal(&demands, &pricing);
            assert!(
                (a.cost - b.cost).abs() < 1e-9,
                "case={case} single={} dp={}",
                a.cost,
                b.cost
            );
        }
    }
}
