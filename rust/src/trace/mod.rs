//! Workload traces: demand curves per user, a synthetic Google-like
//! generator, the task→instance packing scheduler (the paper's trace
//! preprocessing step), and trace I/O.
//!
//! **Substitution note (DESIGN.md §3):** the paper drives its evaluation
//! with the 2011 Google cluster-usage traces (40 GB, 933 users, 29 days),
//! which are not redistributable here. [`synth`] generates a 933-user,
//! 29-day population whose demand-fluctuation mixture (σ/μ groups of
//! Fig. 4) matches the paper's; the algorithms only ever observe the
//! demand curve `d_t`, so this preserves the evaluation's behaviour.

pub mod io;
pub mod scheduler;
pub mod synth;

/// Slots per simulated day: the paper compresses billing to 1-minute slots.
pub const SLOTS_PER_DAY: usize = 24 * 60;
/// Days covered by the Google traces.
pub const TRACE_DAYS: usize = 29;
/// Slots per simulated month: 29 days of minutes -> 41 760 slots.
pub const TRACE_SLOTS: usize = SLOTS_PER_DAY * TRACE_DAYS;

/// Number of users in the Google trace population.
pub const NUM_USERS: usize = 933;

/// One user's workload: the per-slot instance demand curve.
#[derive(Debug, Clone, PartialEq)]
pub struct UserTrace {
    pub user_id: u32,
    pub demand: Vec<u32>,
}

impl UserTrace {
    pub fn new(user_id: u32, demand: Vec<u32>) -> UserTrace {
        UserTrace { user_id, demand }
    }

    /// Demand summary used for Fig. 4 classification.
    pub fn summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::summarize_u32(&self.demand)
    }

    /// Total instance-slots requested.
    pub fn total_demand(&self) -> u64 {
        self.demand.iter().map(|&d| d as u64).sum()
    }

    /// Peak concurrent instances.
    pub fn peak(&self) -> u32 {
        self.demand.iter().copied().max().unwrap_or(0)
    }
}

/// A whole trace population.
#[derive(Debug, Clone, Default)]
pub struct Population {
    pub users: Vec<UserTrace>,
}

impl Population {
    pub fn len(&self) -> usize {
        self.users.len()
    }

    pub fn is_empty(&self) -> bool {
        self.users.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_constants_match_paper() {
        assert_eq!(TRACE_SLOTS, 41_760);
        assert_eq!(NUM_USERS, 933);
    }

    #[test]
    fn user_trace_stats() {
        let u = UserTrace::new(1, vec![0, 2, 4]);
        assert_eq!(u.total_demand(), 6);
        assert_eq!(u.peak(), 4);
        assert!((u.summary().mean - 2.0).abs() < 1e-12);
    }
}
