//! Workload traces: demand curves per user, a synthetic Google-like
//! generator, the task→instance packing scheduler (the paper's trace
//! preprocessing step), and trace I/O.
//!
//! **Substitution note:** the paper drives its evaluation
//! with the 2011 Google cluster-usage traces (40 GB, 933 users, 29 days),
//! which are not redistributable here. [`synth`] generates a 933-user,
//! 29-day population whose demand-fluctuation mixture (σ/μ groups of
//! Fig. 4) matches the paper's; the algorithms only ever observe the
//! demand curve `d_t`, so this preserves the evaluation's behaviour.

pub mod io;
pub mod scheduler;
pub mod synth;

/// Slots per simulated day: the paper compresses billing to 1-minute slots.
pub const SLOTS_PER_DAY: usize = 24 * 60;
/// Days covered by the Google traces.
pub const TRACE_DAYS: usize = 29;
/// Slots per simulated month: 29 days of minutes -> 41 760 slots.
pub const TRACE_SLOTS: usize = SLOTS_PER_DAY * TRACE_DAYS;

/// Number of users in the Google trace population.
pub const NUM_USERS: usize = 933;

/// One user's workload: the per-slot instance demand curve.
#[derive(Debug, Clone, PartialEq)]
pub struct UserTrace {
    pub user_id: u32,
    pub demand: Vec<u32>,
}

impl UserTrace {
    pub fn new(user_id: u32, demand: Vec<u32>) -> UserTrace {
        UserTrace { user_id, demand }
    }

    /// Demand summary used for Fig. 4 classification.
    pub fn summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::summarize_u32(&self.demand)
    }

    /// Total instance-slots requested.
    pub fn total_demand(&self) -> u64 {
        self.demand.iter().map(|&d| d as u64).sum()
    }

    /// Peak concurrent instances.
    pub fn peak(&self) -> u32 {
        self.demand.iter().copied().max().unwrap_or(0)
    }
}

/// A whole trace population.
#[derive(Debug, Clone, Default)]
pub struct Population {
    pub users: Vec<UserTrace>,
}

impl Population {
    pub fn len(&self) -> usize {
        self.users.len()
    }

    pub fn is_empty(&self) -> bool {
        self.users.is_empty()
    }

    /// Columnar (structure-of-arrays) view for the batched fleet engine.
    pub fn flatten(&self) -> FlatPopulation {
        FlatPopulation::from_population(self)
    }
}

/// Columnar demand store: every user's curve concatenated into one flat
/// `Vec<u32>` with an offsets table, so fleet replay streams one contiguous
/// buffer instead of chasing per-user heap allocations. This is the layout
/// the batched engine ([`crate::sim::engine`]) shards over.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FlatPopulation {
    user_ids: Vec<u32>,
    /// `offsets[i]..offsets[i+1]` indexes user `i`'s demand in `demand`.
    offsets: Vec<usize>,
    demand: Vec<u32>,
}

impl FlatPopulation {
    /// Build from an AoS population (single pass, one big allocation).
    pub fn from_population(pop: &Population) -> FlatPopulation {
        let total: usize = pop.users.iter().map(|u| u.demand.len()).sum();
        let mut user_ids = Vec::with_capacity(pop.users.len());
        let mut offsets = Vec::with_capacity(pop.users.len() + 1);
        let mut demand = Vec::with_capacity(total);
        offsets.push(0);
        for u in &pop.users {
            user_ids.push(u.user_id);
            demand.extend_from_slice(&u.demand);
            offsets.push(demand.len());
        }
        FlatPopulation { user_ids, offsets, demand }
    }

    /// Pre-size the columnar buffers (used by the chunked reader, which
    /// knows the per-chunk user count up front).
    pub fn with_capacity(users: usize, slots: usize) -> FlatPopulation {
        let mut offsets = Vec::with_capacity(users + 1);
        offsets.push(0);
        FlatPopulation {
            user_ids: Vec::with_capacity(users),
            offsets,
            demand: Vec::with_capacity(slots),
        }
    }

    /// Append one user's demand curve in columnar form.
    pub fn push_user(&mut self, user_id: u32, demand: &[u32]) {
        if self.offsets.is_empty() {
            self.offsets.push(0);
        }
        self.user_ids.push(user_id);
        self.demand.extend_from_slice(demand);
        self.offsets.push(self.demand.len());
    }

    /// Drop all users but keep the allocations (chunk-buffer reuse).
    pub fn clear(&mut self) {
        self.user_ids.clear();
        self.demand.clear();
        self.offsets.clear();
        self.offsets.push(0);
    }

    /// Number of users.
    pub fn len(&self) -> usize {
        self.user_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.user_ids.is_empty()
    }

    /// Total instance-slots across all users (the suite-throughput unit).
    pub fn total_slots(&self) -> usize {
        self.demand.len()
    }

    /// User id of the `i`-th user.
    pub fn user_id(&self, i: usize) -> u32 {
        self.user_ids[i]
    }

    /// Borrowed demand curve of the `i`-th user — contiguous, zero-copy.
    pub fn demand(&self, i: usize) -> &[u32] {
        &self.demand[self.offsets[i]..self.offsets[i + 1]]
    }
}

impl From<&Population> for FlatPopulation {
    fn from(pop: &Population) -> FlatPopulation {
        FlatPopulation::from_population(pop)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_constants_match_paper() {
        assert_eq!(TRACE_SLOTS, 41_760);
        assert_eq!(NUM_USERS, 933);
    }

    #[test]
    fn user_trace_stats() {
        let u = UserTrace::new(1, vec![0, 2, 4]);
        assert_eq!(u.total_demand(), 6);
        assert_eq!(u.peak(), 4);
        assert!((u.summary().mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn flatten_preserves_curves_and_ids() {
        let pop = Population {
            users: vec![
                UserTrace::new(7, vec![1, 2, 3]),
                UserTrace::new(9, vec![]),
                UserTrace::new(11, vec![4, 0]),
            ],
        };
        let flat = pop.flatten();
        assert_eq!(flat.len(), 3);
        assert_eq!(flat.total_slots(), 5);
        assert_eq!(flat.user_id(0), 7);
        assert_eq!(flat.demand(0), &[1, 2, 3]);
        assert_eq!(flat.demand(1), &[] as &[u32]);
        assert_eq!(flat.demand(2), &[4, 0]);
    }

    #[test]
    fn flatten_empty_population() {
        let flat = Population::default().flatten();
        assert!(flat.is_empty());
        assert_eq!(flat.total_slots(), 0);
    }

    #[test]
    fn push_user_matches_from_population() {
        let pop = Population {
            users: vec![
                UserTrace::new(3, vec![1, 0, 2]),
                UserTrace::new(5, vec![]),
                UserTrace::new(8, vec![7]),
            ],
        };
        let flat = pop.flatten();
        let mut built = FlatPopulation::default();
        for u in &pop.users {
            built.push_user(u.user_id, &u.demand);
        }
        assert_eq!(flat, built);
        // clear keeps the struct usable and equal to a fresh build
        built.clear();
        assert!(built.is_empty());
        built.push_user(3, &[1, 0, 2]);
        assert_eq!(built.len(), 1);
        assert_eq!(built.demand(0), &[1, 0, 2]);
    }
}
