//! Synthetic Google-like workload generator.
//!
//! Reproduces the *population structure* the paper reports for the Google
//! cluster traces (Sec. VII-A, Fig. 4): 933 users over 29 days of 1-minute
//! slots, classified by demand-fluctuation level σ/μ into
//!
//! * **Group 1** (σ/μ ≥ 5): highly sporadic, small means — rare heavy
//!   bursts over a near-zero baseline;
//! * **Group 2** (1 ≤ σ/μ < 5): medium fluctuation — diurnal load with
//!   noise and occasional surges;
//! * **Group 3** (σ/μ < 1): stable — large means, small relative noise.
//!
//! Group weights are calibrated so Table II's population-wide averages are
//! attainable (the overall All-reserved average of 16.48 pins Group 1 near
//! one third of the users; see the substitution note in [`super`]).

use super::{Population, UserTrace, NUM_USERS, SLOTS_PER_DAY, TRACE_SLOTS};
use crate::util::rng::Rng;

/// Workload archetypes, one per paper group (plus a mixed archetype that
/// lands in group 2's tail to fill the σ/μ continuum like Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Archetype {
    /// Rare heavy bursts on a zero baseline (Group 1).
    Sporadic,
    /// Diurnal pattern + noise + surges (Group 2).
    Diurnal,
    /// Large stable base with small noise and slow trend (Group 3).
    Stable,
    /// Batch-style: long quiet stretches and sustained multi-hour jobs.
    Batch,
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub users: usize,
    pub slots: usize,
    pub seed: u64,
    /// Mixture weights for (Sporadic, Diurnal, Stable, Batch).
    pub weights: [f64; 4],
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            users: NUM_USERS,
            slots: TRACE_SLOTS,
            seed: 2013,
            // ~32% sporadic / ~25% diurnal / ~28% stable / ~15% batch —
            // batch users straddle groups 1-2, yielding roughly the paper's
            // third/third/third split of Fig. 4.
            weights: [0.32, 0.34, 0.19, 0.15],
        }
    }
}

/// Stream the population one user at a time, in user-id order, without
/// materializing the fleet. [`generate`] is implemented on top of this, so
/// the streaming and in-RAM paths are bit-identical by construction.
pub fn for_each_user(cfg: &SynthConfig, mut f: impl FnMut(u32, Vec<u32>)) {
    let mut root = Rng::new(cfg.seed);
    for uid in 0..cfg.users {
        let mut rng = root.fork(uid as u64);
        let archetype = match rng.weighted(&cfg.weights) {
            0 => Archetype::Sporadic,
            1 => Archetype::Diurnal,
            2 => Archetype::Stable,
            _ => Archetype::Batch,
        };
        let demand = generate_user(archetype, cfg.slots, &mut rng);
        f(uid as u32, demand);
    }
}

/// Generate the full population in RAM.
pub fn generate(cfg: &SynthConfig) -> Population {
    let mut users = Vec::with_capacity(cfg.users);
    for_each_user(cfg, |uid, demand| users.push(UserTrace::new(uid, demand)));
    Population { users }
}

/// Stream-generate straight into the v2 chunked trace file: resident
/// memory stays O(slots + chunk RLE bytes) regardless of fleet size.
pub fn generate_chunked(
    cfg: &SynthConfig,
    path: &std::path::Path,
    chunk_users: u32,
) -> anyhow::Result<()> {
    let mut w = super::io::ChunkedWriter::create(path, chunk_users)?;
    let mut err = None;
    for_each_user(cfg, |uid, demand| {
        if err.is_none() {
            err = w.push_user(uid, &demand).err();
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    w.finish()
}

/// Generate one user's demand curve.
pub fn generate_user(archetype: Archetype, slots: usize, rng: &mut Rng) -> Vec<u32> {
    match archetype {
        Archetype::Sporadic => sporadic(slots, rng),
        Archetype::Diurnal => diurnal(slots, rng),
        Archetype::Stable => stable(slots, rng),
        Archetype::Batch => batch(slots, rng),
    }
}

/// Group 1: zero baseline; bursts arrive as a Poisson process (a few per
/// month), each burst needs a Pareto-tailed number of instances for a
/// short exponential duration. σ/μ lands well above 5.
fn sporadic(slots: usize, rng: &mut Rng) -> Vec<u32> {
    let mut d = vec![0u32; slots];
    // expected bursts over the whole trace: 5..60
    let bursts = 10 + rng.below(70) as usize;
    let size_scale = 1.0 + rng.f64() * 2.0; // typical burst height
    for _ in 0..bursts {
        let start = rng.range_usize(0, slots);
        let height = rng.pareto(size_scale, 2.0).min(16.0) as u32;
        // very short bursts (Google tasks are minutes-scale): mean ~4 min.
        // Duration calibrates the All-reserved penalty: a reservation fee
        // amortized over a `dur`-slot burst costs ~1/(p*dur) times the
        // on-demand price, which pins Table II's Group-1 row (~49x); it
        // also keeps the window's violating-slot count small so aggressive
        // A_z draws rarely trigger (the paper's randomized G1 ~ 1.02).
        let dur = (rng.exponential(1.0 / 4.0) as usize).clamp(1, 20);
        for t in start..(start + dur).min(slots) {
            d[t] = d[t].saturating_add(height.max(1));
        }
    }
    d
}

/// Group 2: *structured* medium fluctuation — project-style activity runs
/// (active/idle days follow a sticky Markov chain), deep diurnal swing,
/// day-of-week modulation, mild noise, occasional surges. The σ/μ ∈ [1, 5)
/// variability comes from the on/off envelope + diurnal depth rather than
/// iid spikes: that is what makes aggressive reservation thresholds pay
/// off for these users (paper Fig. 5c / Table II row 4 vs 5).
fn diurnal(slots: usize, rng: &mut Rng) -> Vec<u32> {
    let base = 2.0 + rng.pareto(2.0, 1.3).min(80.0); // mean scale when active
    // Week-scale level plateaus: deployment size follows a piecewise-
    // constant random walk held for several days — longer than the
    // compressed reservation period, so a level that appears stays busy
    // long enough to amortize an aggressive reservation (this is what
    // gives the randomized algorithm its Fig. 5c edge over A_beta).
    let mut level_mult = 0.6 + rng.f64();
    let mut next_level_change = 0usize;
    // sticky active/idle project envelope: BOTH runs are long (active
    // 7-20 days — longer than the compressed reservation period, so
    // aggressive reservations amortize; idle 7-30 days — deep enough that
    // sigma/mu lands in [1, 5))
    let p_stay_active = 0.85 + rng.f64() * 0.1;
    let p_stay_idle = 0.85 + rng.f64() * 0.12;
    let day_amp = 0.05 + 0.15 * rng.f64(); // slight work-hours bump
    let noise = 0.05 + rng.f64() * 0.08;
    let phase = rng.f64();
    let mut active = rng.chance(0.7);
    let mut d = Vec::with_capacity(slots);
    let mut surge_until = 0usize;
    let mut surge_mult = 1.0f64;
    let mut held_eps = 1.0f64;
    for t in 0..slots {
        if t % SLOTS_PER_DAY == 0 {
            active = if active { rng.chance(p_stay_active) } else { !rng.chance(p_stay_idle) };
        }
        if t >= next_level_change {
            level_mult = (level_mult * (0.7 + rng.f64() * 0.7)).clamp(0.25, 2.5);
            next_level_change = t + rng.range_usize(4 * SLOTS_PER_DAY, 12 * SLOTS_PER_DAY);
        }
        let tod = (t % SLOTS_PER_DAY) as f64 / SLOTS_PER_DAY as f64;
        let work = {
            let shifted = (tod + phase).fract();
            if (0.375..0.75).contains(&shifted) { 1.0 + day_amp } else { 1.0 }
        };
        if t >= surge_until && rng.chance(1.0 / (SLOTS_PER_DAY as f64 * 3.0)) {
            // surge lasting 1-6 hours, 1.5-2.5x
            surge_until = t + rng.range_usize(60, 6 * 60);
            surge_mult = 1.5 + rng.f64();
        }
        let s = if t < surge_until { surge_mult } else { 1.0 };
        // hourly-held noise (autoscaling decisions, not per-minute jitter)
        if t % 60 == 0 {
            held_eps = (rng.normal() * noise).exp().min(2.0);
        }
        let env = if active { 1.0 } else { 0.02 };
        let val = base * env * level_mult * work * s * held_eps;
        // quantize to job-sized steps so demand levels are chunky
        let step = (base / 6.0).max(1.0);
        d.push(((val / step).round() * step).max(0.0) as u32);
    }
    d
}

/// Group 3: large stable base, small Gaussian noise, slow linear trend,
/// and mild diurnal ripple. σ/μ < 1 by construction.
fn stable(slots: usize, rng: &mut Rng) -> Vec<u32> {
    let base = 20.0 + rng.pareto(8.0, 1.1).min(2000.0);
    let rel_noise = 0.02 + rng.f64() * 0.18;
    let trend = (rng.f64() - 0.4) * base * 0.5 / slots as f64; // gentle drift
    let ripple = rng.f64() * 0.15;
    let phase = rng.f64() * std::f64::consts::TAU;
    let mut d = Vec::with_capacity(slots);
    for t in 0..slots {
        let tod = (t % SLOTS_PER_DAY) as f64 / SLOTS_PER_DAY as f64;
        let diur = 1.0 + ripple * (std::f64::consts::TAU * tod + phase).sin();
        let val = (base + trend * t as f64) * diur * (1.0 + rel_noise * rng.normal());
        d.push(val.round().max(0.0) as u32);
    }
    d
}

/// Batch-style: ON/OFF renewal process — idle exponential gaps, then
/// sustained jobs of several hours at moderate height. Lands around the
/// group 1/2 boundary depending on duty cycle.
fn batch(slots: usize, rng: &mut Rng) -> Vec<u32> {
    let mut d = vec![0u32; slots];
    let height_scale = 1.0 + rng.f64() * 10.0;
    let mean_gap = (4.0 + rng.f64() * 40.0) * 60.0; // hours of idleness
    let mean_run = (0.5 + rng.f64() * 8.0) * 60.0; // job length
    let mut t = rng.exponential(1.0 / mean_gap) as usize;
    while t < slots {
        let run = (rng.exponential(1.0 / mean_run) as usize).clamp(10, slots);
        let height = (height_scale * (0.5 + rng.f64())).round().max(1.0) as u32;
        for i in t..(t + run).min(slots) {
            d[i] = d[i].saturating_add(height);
        }
        t += run + rng.exponential(1.0 / mean_gap).max(1.0) as usize;
    }
    d
}

/// Valid regime names for spec/CLI parsing (and their error text).
pub const REGIME_NAMES: &[&str] = &["stationary", "drifting", "adversarial"];

/// Demand regimes for the learned-policy differential harness: unlike the
/// Google-like archetypes above (population realism), these isolate the
/// statistical properties learning-augmented policies react to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// iid noise around a fixed per-user mean — the setting where UCB
    /// threshold selection should show decreasing per-slot regret.
    Stationary,
    /// Piecewise-constant level following a random walk — slow
    /// distribution shift that forecast-driven windows can track.
    Drifting,
    /// Busy runs held *just below* a reference term followed by long idle
    /// gaps — the classic adversary against aggressive reservation
    /// triggers (demand vanishes right before a reservation would have
    /// amortized).
    Adversarial,
}

impl Regime {
    /// Parse a regime name (see [`REGIME_NAMES`]).
    pub fn from_name(name: &str) -> anyhow::Result<Regime> {
        match name {
            "stationary" => Ok(Regime::Stationary),
            "drifting" => Ok(Regime::Drifting),
            "adversarial" => Ok(Regime::Adversarial),
            other => anyhow::bail!(crate::util::cli::expected_one_of(
                "trace(regime): regime",
                other,
                REGIME_NAMES
            )),
        }
    }
}

/// Regime generator configuration. `term_hint` anchors the adversarial
/// burst length (bursts stay strictly shorter than it) and the drifting
/// level hold time — pass the menu's shortest term to get worst-case
/// traces for that market.
#[derive(Debug, Clone)]
pub struct RegimeConfig {
    pub users: usize,
    pub slots: usize,
    pub seed: u64,
    pub regime: Regime,
    pub term_hint: usize,
}

impl Default for RegimeConfig {
    fn default() -> Self {
        RegimeConfig {
            users: 20,
            slots: 4000,
            seed: 2013,
            regime: Regime::Stationary,
            term_hint: 64,
        }
    }
}

/// Generate a regime population. Same per-user fork discipline as
/// [`for_each_user`], so traces are reproducible per user id regardless of
/// fleet size.
pub fn generate_regime(cfg: &RegimeConfig) -> Population {
    let mut root = Rng::new(cfg.seed);
    let mut users = Vec::with_capacity(cfg.users);
    for uid in 0..cfg.users {
        let mut rng = root.fork(uid as u64);
        let demand = regime_user(cfg.regime, cfg.slots, cfg.term_hint, &mut rng);
        users.push(UserTrace::new(uid as u32, demand));
    }
    Population { users }
}

/// Generate one user's demand curve under a [`Regime`].
pub fn regime_user(regime: Regime, slots: usize, term_hint: usize, rng: &mut Rng) -> Vec<u32> {
    let term_hint = term_hint.max(2);
    match regime {
        Regime::Stationary => {
            let mean = 1.0 + rng.f64() * 5.0;
            (0..slots).map(|_| rng.poisson(mean).min(1_000) as u32).collect()
        }
        Regime::Drifting => {
            let mut level = 1.0 + rng.f64() * 4.0;
            let hold = (term_hint / 2).max(8);
            let mut d = Vec::with_capacity(slots);
            for t in 0..slots {
                if t > 0 && t % hold == 0 {
                    // random-walk step, reflected into [0, 12]
                    level = (level + rng.normal() * 1.5).abs().min(12.0);
                }
                d.push(rng.poisson(level).min(1_000) as u32);
            }
            d
        }
        Regime::Adversarial => {
            // busy just under the hint, then idle long enough that any
            // reservation bought during the burst is wasted
            let height = 1 + rng.below(4) as u32;
            let mut d = vec![0u32; slots];
            let mut t = rng.range_usize(0, term_hint);
            while t < slots {
                let run = rng.range_usize((term_hint / 2).max(1), term_hint);
                for i in t..(t + run).min(slots) {
                    d[i] = height;
                }
                t += run + rng.range_usize(term_hint, 3 * term_hint);
            }
            d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::classify::{classify, Group};

    #[test]
    fn generation_is_deterministic() {
        let cfg = SynthConfig { users: 10, slots: 2000, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.users, b.users);
    }

    #[test]
    fn streaming_generator_matches_in_ram() {
        let cfg = SynthConfig { users: 17, slots: 800, seed: 99, ..Default::default() };
        let pop = generate(&cfg);
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("synth_v2_{}", std::process::id()));
        generate_chunked(&cfg, &path, 5).unwrap();
        let mut chunked = crate::trace::io::ChunkedPopulation::open(&path).unwrap();
        let mut i = 0usize;
        for c in 0..chunked.n_chunks() {
            let chunk = chunked.read_chunk(c).unwrap();
            for j in 0..chunk.len() {
                assert_eq!(chunk.user_id(j), pop.users[i].user_id);
                assert_eq!(chunk.demand(j), &pop.users[i].demand[..]);
                i += 1;
            }
        }
        assert_eq!(i, pop.users.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn archetypes_land_in_expected_groups() {
        let mut rng = Rng::new(7);
        let slots = 20_000;
        // Sporadic users must be group 1 (or at least >= group 2 tail)
        let mut g1_hits = 0;
        for _ in 0..20 {
            let d = generate_user(Archetype::Sporadic, slots, &mut rng);
            let s = crate::util::stats::summarize_u32(&d);
            if s.cov() >= 5.0 {
                g1_hits += 1;
            }
        }
        assert!(g1_hits >= 16, "sporadic users mostly in group 1: {g1_hits}/20");

        // Stable users must be group 3
        for _ in 0..20 {
            let d = generate_user(Archetype::Stable, slots, &mut rng);
            let s = crate::util::stats::summarize_u32(&d);
            assert!(s.cov() < 1.0, "stable user cov {}", s.cov());
        }
    }

    #[test]
    fn population_covers_all_three_groups_with_reasonable_shares() {
        let cfg = SynthConfig { users: 300, slots: 15_000, ..Default::default() };
        let pop = generate(&cfg);
        let (mut g1, mut g2, mut g3) = (0, 0, 0);
        for u in &pop.users {
            match classify(&u.summary()) {
                Group::G1Sporadic => g1 += 1,
                Group::G2Medium => g2 += 1,
                Group::G3Stable => g3 += 1,
            }
        }
        let n = pop.users.len() as f64;
        for (name, g) in [("g1", g1), ("g2", g2), ("g3", g3)] {
            let share = g as f64 / n;
            assert!(
                (0.12..=0.60).contains(&share),
                "{name} share {share} out of plausible range (g1={g1} g2={g2} g3={g3})"
            );
        }
    }

    #[test]
    fn demand_is_finite_and_bounded() {
        let cfg = SynthConfig { users: 50, slots: 5000, ..Default::default() };
        let pop = generate(&cfg);
        for u in &pop.users {
            assert_eq!(u.demand.len(), 5000);
            assert!(u.peak() < 1_000_000, "peak {}", u.peak());
        }
    }

    #[test]
    fn regime_generation_is_deterministic_and_sized() {
        for regime in [Regime::Stationary, Regime::Drifting, Regime::Adversarial] {
            let cfg = RegimeConfig { users: 5, slots: 600, regime, ..Default::default() };
            let a = generate_regime(&cfg);
            let b = generate_regime(&cfg);
            assert_eq!(a.users, b.users);
            assert_eq!(a.users.len(), 5);
            assert!(a.users.iter().all(|u| u.demand.len() == 600));
        }
    }

    #[test]
    fn adversarial_busy_runs_stay_below_the_term_hint() {
        let term_hint = 40;
        let cfg = RegimeConfig {
            users: 8,
            slots: 3000,
            regime: Regime::Adversarial,
            term_hint,
            ..Default::default()
        };
        let pop = generate_regime(&cfg);
        for u in &pop.users {
            let mut run = 0usize;
            let mut longest = 0usize;
            for &d in &u.demand {
                if d > 0 {
                    run += 1;
                    longest = longest.max(run);
                } else {
                    run = 0;
                }
            }
            assert!(longest >= 1, "user {} never goes busy", u.user_id);
            assert!(
                longest < term_hint,
                "user {}: busy run {longest} reaches the term hint {term_hint}",
                u.user_id
            );
        }
    }

    #[test]
    fn stationary_regime_is_stable_across_halves() {
        let cfg = RegimeConfig {
            users: 6,
            slots: 8000,
            regime: Regime::Stationary,
            ..Default::default()
        };
        let pop = generate_regime(&cfg);
        for u in &pop.users {
            let half = u.demand.len() / 2;
            let m1: f64 =
                u.demand[..half].iter().map(|&d| d as f64).sum::<f64>() / half as f64;
            let m2: f64 =
                u.demand[half..].iter().map(|&d| d as f64).sum::<f64>() / half as f64;
            assert!(m1 > 0.5, "user {} mean too small: {m1}", u.user_id);
            assert!(
                (m1 - m2).abs() / m1 < 0.2,
                "user {}: halves drift ({m1} vs {m2})",
                u.user_id
            );
        }
    }

    #[test]
    fn regime_names_round_trip() {
        assert_eq!(Regime::from_name("stationary").unwrap(), Regime::Stationary);
        assert_eq!(Regime::from_name("drifting").unwrap(), Regime::Drifting);
        assert_eq!(Regime::from_name("adversarial").unwrap(), Regime::Adversarial);
        let err = format!("{:#}", Regime::from_name("chaotic").unwrap_err());
        assert!(err.contains("stationary") && err.contains("adversarial"), "{err}");
    }

    #[test]
    fn diurnal_users_show_daily_period() {
        // Mean lag-(1 day) autocorrelation across users must be clearly
        // positive (individual users can be surge-dominated).
        let mut rng = Rng::new(42);
        let slots = SLOTS_PER_DAY * 20;
        let mut acs = Vec::new();
        for _ in 0..12 {
            let d = generate_user(Archetype::Diurnal, slots, &mut rng);
            let f: Vec<f64> = d.iter().map(|&x| x as f64).collect();
            let m = f.iter().sum::<f64>() / f.len() as f64;
            let lag = SLOTS_PER_DAY;
            let mut num = 0.0;
            let mut den = 0.0;
            for t in 0..f.len() - lag {
                num += (f[t] - m) * (f[t + lag] - m);
            }
            for t in 0..f.len() {
                den += (f[t] - m) * (f[t] - m);
            }
            if den > 0.0 {
                acs.push(num / den);
            } // all-idle users (sticky idle chain) carry no signal - skip
        }
        let mean_ac = acs.iter().sum::<f64>() / acs.len() as f64;
        let positives = acs.iter().filter(|&&a| a > 0.0).count();
        assert!(
            acs.len() >= 8 && mean_ac > 0.04 && positives * 10 >= acs.len() * 7,
            "diurnal mean autocorr {mean_ac:.4}, positives {positives}/{}: {acs:?}",
            acs.len()
        );
    }
}
