//! Trace persistence: a CSV form (`user_id,slot,demand`, sparse — zero
//! slots omitted) for interoperability, and a compact binary form for the
//! 933-user month-long population (run-length encoded, ~100x smaller).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::{FlatPopulation, Population, UserTrace};
use crate::util::state::fnv1a64;

/// Write a population as sparse CSV. NOTE: the format omits zero-demand
/// slots, so users whose entire curve is zero do not round-trip (the
/// binary format is lossless).
pub fn write_csv(pop: &Population, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "user_id,slot,demand")?;
    for u in &pop.users {
        for (t, &d) in u.demand.iter().enumerate() {
            if d > 0 {
                writeln!(w, "{},{},{}", u.user_id, t, d)?;
            }
        }
    }
    Ok(())
}

/// Read a sparse CSV population; `slots` fixes every user's curve length.
pub fn read_csv(path: &Path, slots: usize) -> Result<Population> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut users: std::collections::BTreeMap<u32, Vec<u32>> = std::collections::BTreeMap::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.starts_with("user_id") {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let (Some(uid), Some(slot), Some(d)) = (parts.next(), parts.next(), parts.next()) else {
            bail!("line {}: expected user_id,slot,demand, got '{line}'", lineno + 1);
        };
        let uid: u32 =
            uid.trim().parse().with_context(|| format!("line {}: bad user_id", lineno + 1))?;
        let slot: usize =
            slot.trim().parse().with_context(|| format!("line {}: bad slot", lineno + 1))?;
        let d: u32 = d.trim().parse().with_context(|| format!("line {}: bad demand", lineno + 1))?;
        if slot >= slots {
            bail!("line {}: slot {slot} >= trace length {slots}", lineno + 1);
        }
        users.entry(uid).or_insert_with(|| vec![0; slots])[slot] = d;
    }
    Ok(Population {
        users: users.into_iter().map(|(uid, demand)| UserTrace::new(uid, demand)).collect(),
    })
}

const MAGIC: &[u8; 8] = b"CLDRSV01";

/// Write the compact run-length-encoded binary form.
pub fn write_bin(pop: &Population, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&(pop.users.len() as u32).to_le_bytes())?;
    for u in &pop.users {
        w.write_all(&u.user_id.to_le_bytes())?;
        w.write_all(&(u.demand.len() as u32).to_le_bytes())?;
        // RLE: (value: u32, run: u32)*
        let mut runs: Vec<(u32, u32)> = Vec::new();
        for &d in &u.demand {
            match runs.last_mut() {
                Some((v, r)) if *v == d => *r += 1,
                _ => runs.push((d, 1)),
            }
        }
        w.write_all(&(runs.len() as u32).to_le_bytes())?;
        for (v, r) in runs {
            w.write_all(&v.to_le_bytes())?;
            w.write_all(&r.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the binary form.
pub fn read_bin(path: &Path) -> Result<Population> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a cloudreserve trace file (bad magic)");
    }
    let mut u32buf = [0u8; 4];
    let mut read_u32 = |r: &mut BufReader<File>| -> Result<u32> {
        r.read_exact(&mut u32buf)?;
        Ok(u32::from_le_bytes(u32buf))
    };
    let n_users = read_u32(&mut r)? as usize;
    if n_users > 10_000_000 {
        bail!("implausible user count {n_users}");
    }
    let mut users = Vec::with_capacity(n_users);
    for _ in 0..n_users {
        let uid = read_u32(&mut r)?;
        let len = read_u32(&mut r)? as usize;
        let n_runs = read_u32(&mut r)? as usize;
        let mut demand = Vec::with_capacity(len);
        for _ in 0..n_runs {
            let v = read_u32(&mut r)?;
            let run = read_u32(&mut r)? as usize;
            demand.extend(std::iter::repeat(v).take(run));
        }
        if demand.len() != len {
            bail!("user {uid}: RLE expands to {} slots, header says {len}", demand.len());
        }
        users.push(UserTrace::new(uid, demand));
    }
    Ok(Population { users })
}

// ---------------------------------------------------------------------------
// cloudreserve-trace/v2: chunked columnar format for fleets too large to
// materialize. Layout (all integers little-endian):
//
//   header   magic "CLDRSV02" | u32 n_users | u32 chunk_users
//            | u32 n_chunks | u64 index_offset | u64 total_slots
//   chunks   per user, the v1 RLE record:
//            u32 user_id | u32 len | u32 n_runs | (u32 value, u32 run)*
//   index    per chunk (at index_offset):
//            u64 offset | u64 byte_len | u64 checksum (FNV-1a 64)
//            | u32 first_user_index | u32 users_in_chunk
//
// The index lives at the tail so the writer streams chunks front-to-back
// without knowing the fleet size up front; `finish()` seeks back once to
// patch the header. Readers replay chunks in O(chunk) resident memory.
// ---------------------------------------------------------------------------

const MAGIC_V2: &[u8; 8] = b"CLDRSV02";
const HEADER_V2_LEN: u64 = 8 + 4 + 4 + 4 + 8 + 8;
const INDEX_ENTRY_LEN: u64 = 8 + 8 + 8 + 4 + 4;

/// Typed corruption error for a checksum-failed chunk: carries enough
/// context (chunk index, byte range, expected vs actual checksum) for a
/// quarantine report to be actionable, and lets the recovery layer
/// distinguish corruption (non-retryable) from transient I/O errors
/// (retryable) by downcast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkCorrupt {
    pub chunk: usize,
    /// Byte offset of the chunk payload from the start of the file.
    pub offset: u64,
    pub byte_len: u64,
    pub stored_checksum: u64,
    pub computed_checksum: u64,
}

impl std::fmt::Display for ChunkCorrupt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "chunk {}: checksum mismatch over bytes [{}, {}) (stored {:#018x}, computed {:#018x})",
            self.chunk,
            self.offset,
            self.offset + self.byte_len,
            self.stored_checksum,
            self.computed_checksum
        )
    }
}

impl std::error::Error for ChunkCorrupt {}

/// Per-chunk index entry of the v2 format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Byte offset of the chunk payload from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub byte_len: u64,
    /// FNV-1a 64 checksum of the payload bytes.
    pub checksum: u64,
    /// Global index of the chunk's first user.
    pub first_user_index: u32,
    /// Number of users in this chunk.
    pub users_in_chunk: u32,
}

/// Encode one user as the v1 RLE record into `buf`.
fn encode_user_rle(buf: &mut Vec<u8>, user_id: u32, demand: &[u32]) {
    buf.extend_from_slice(&user_id.to_le_bytes());
    buf.extend_from_slice(&(demand.len() as u32).to_le_bytes());
    let runs_at = buf.len();
    buf.extend_from_slice(&0u32.to_le_bytes()); // n_runs, patched below
    let mut n_runs = 0u32;
    let mut iter = demand.iter().copied();
    if let Some(mut v) = iter.next() {
        let mut run = 1u32;
        for d in iter {
            if d == v {
                run += 1;
            } else {
                buf.extend_from_slice(&v.to_le_bytes());
                buf.extend_from_slice(&run.to_le_bytes());
                n_runs += 1;
                v = d;
                run = 1;
            }
        }
        buf.extend_from_slice(&v.to_le_bytes());
        buf.extend_from_slice(&run.to_le_bytes());
        n_runs += 1;
    }
    buf[runs_at..runs_at + 4].copy_from_slice(&n_runs.to_le_bytes());
}

/// Streaming writer for the v2 chunked format: push users one at a time,
/// chunks flush to disk every `chunk_users`, nothing fleet-sized is held
/// in memory.
pub struct ChunkedWriter {
    w: BufWriter<File>,
    /// Destination path; all bytes stream to `tmp_path` and land here via
    /// one atomic rename in [`finish`](ChunkedWriter::finish).
    final_path: PathBuf,
    tmp_path: PathBuf,
    finished: bool,
    chunk_users: u32,
    buf: Vec<u8>,
    buf_users: u32,
    index: Vec<ChunkMeta>,
    n_users: u32,
    total_slots: u64,
    pos: u64,
}

impl ChunkedWriter {
    /// Create the file and reserve the header; `chunk_users` is the chunk
    /// granularity (also the resident-memory unit on replay).
    ///
    /// The writer streams to `<path>.tmp` and only renames onto `path` in
    /// `finish()`, after an fsync — a crash mid-write (including during the
    /// header patch) can never leave a torn file at `path`. Format bytes
    /// are unchanged from the in-place writer.
    pub fn create(path: &Path, chunk_users: u32) -> Result<ChunkedWriter> {
        ensure!(chunk_users > 0, "chunk_users must be positive");
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp_path = PathBuf::from(tmp);
        let mut w = BufWriter::new(
            File::create(&tmp_path).with_context(|| format!("create {tmp_path:?}"))?,
        );
        w.write_all(&[0u8; HEADER_V2_LEN as usize])?;
        Ok(ChunkedWriter {
            w,
            final_path: path.to_path_buf(),
            tmp_path,
            finished: false,
            chunk_users,
            buf: Vec::new(),
            buf_users: 0,
            index: Vec::new(),
            n_users: 0,
            total_slots: 0,
            pos: HEADER_V2_LEN,
        })
    }

    /// Append one user's demand curve.
    pub fn push_user(&mut self, user_id: u32, demand: &[u32]) -> Result<()> {
        encode_user_rle(&mut self.buf, user_id, demand);
        self.buf_users += 1;
        self.n_users += 1;
        self.total_slots += demand.len() as u64;
        if self.buf_users == self.chunk_users {
            self.flush_chunk()?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<()> {
        if self.buf_users == 0 {
            return Ok(());
        }
        let meta = ChunkMeta {
            offset: self.pos,
            byte_len: self.buf.len() as u64,
            checksum: fnv1a64(&self.buf),
            first_user_index: self.n_users - self.buf_users,
            users_in_chunk: self.buf_users,
        };
        self.w.write_all(&self.buf)?;
        self.pos += meta.byte_len;
        self.index.push(meta);
        self.buf.clear();
        self.buf_users = 0;
        Ok(())
    }

    /// Flush the last partial chunk, write the index, patch the header in
    /// the temp file, fsync, and atomically rename onto the destination.
    pub fn finish(mut self) -> Result<()> {
        self.flush_chunk()?;
        let index_offset = self.pos;
        for m in &self.index {
            self.w.write_all(&m.offset.to_le_bytes())?;
            self.w.write_all(&m.byte_len.to_le_bytes())?;
            self.w.write_all(&m.checksum.to_le_bytes())?;
            self.w.write_all(&m.first_user_index.to_le_bytes())?;
            self.w.write_all(&m.users_in_chunk.to_le_bytes())?;
        }
        self.w.seek(SeekFrom::Start(0))?;
        self.w.write_all(MAGIC_V2)?;
        self.w.write_all(&self.n_users.to_le_bytes())?;
        self.w.write_all(&self.chunk_users.to_le_bytes())?;
        self.w.write_all(&(self.index.len() as u32).to_le_bytes())?;
        self.w.write_all(&index_offset.to_le_bytes())?;
        self.w.write_all(&self.total_slots.to_le_bytes())?;
        self.w.flush()?;
        self.w
            .get_ref()
            .sync_all()
            .with_context(|| format!("fsync {:?}", self.tmp_path))?;
        std::fs::rename(&self.tmp_path, &self.final_path)
            .with_context(|| format!("rename {:?} -> {:?}", self.tmp_path, self.final_path))?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for ChunkedWriter {
    fn drop(&mut self) {
        if !self.finished {
            // abandoned mid-write (error or panic): remove the temp file,
            // never touch whatever lives at the destination path
            let _ = std::fs::remove_file(&self.tmp_path);
        }
    }
}

/// Write an in-RAM population through the chunked writer (tests and small
/// conversions; big fleets should stream via `synth::generate_chunked`).
pub fn write_chunked(pop: &Population, path: &Path, chunk_users: u32) -> Result<()> {
    let mut w = ChunkedWriter::create(path, chunk_users)?;
    for u in &pop.users {
        w.push_user(u.user_id, &u.demand)?;
    }
    w.finish()
}

/// Reader for the v2 chunked format: holds the index in memory and streams
/// one checksummed chunk at a time into a reusable [`FlatPopulation`].
pub struct ChunkedPopulation {
    file: File,
    n_users: u32,
    chunk_users: u32,
    total_slots: u64,
    index: Vec<ChunkMeta>,
}

impl ChunkedPopulation {
    /// Open and validate header + index (payload checksums are verified
    /// lazily, per chunk, on read).
    pub fn open(path: &Path) -> Result<ChunkedPopulation> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut header = [0u8; HEADER_V2_LEN as usize];
        file.read_exact(&mut header).context("short v2 header")?;
        if &header[0..8] != MAGIC_V2 {
            bail!("{path:?}: not a cloudreserve chunked trace file (bad magic)");
        }
        let u32_at = |i: usize| u32::from_le_bytes(header[i..i + 4].try_into().unwrap());
        let u64_at = |i: usize| u64::from_le_bytes(header[i..i + 8].try_into().unwrap());
        let n_users = u32_at(8);
        let chunk_users = u32_at(12);
        let n_chunks = u32_at(16) as u64;
        let index_offset = u64_at(20);
        let total_slots = u64_at(28);
        ensure!(n_users <= 10_000_000, "implausible user count {n_users}");
        ensure!(chunk_users > 0 || n_users == 0, "zero chunk_users with {n_users} users");
        ensure!(
            index_offset + n_chunks * INDEX_ENTRY_LEN <= file_len,
            "index extends past end of file"
        );
        file.seek(SeekFrom::Start(index_offset))?;
        let mut index = Vec::with_capacity(n_chunks as usize);
        let mut entry = [0u8; INDEX_ENTRY_LEN as usize];
        let mut users_seen = 0u64;
        for c in 0..n_chunks {
            file.read_exact(&mut entry).context("short index entry")?;
            let e64 = |i: usize| u64::from_le_bytes(entry[i..i + 8].try_into().unwrap());
            let e32 = |i: usize| u32::from_le_bytes(entry[i..i + 4].try_into().unwrap());
            let m = ChunkMeta {
                offset: e64(0),
                byte_len: e64(8),
                checksum: e64(16),
                first_user_index: e32(24),
                users_in_chunk: e32(28),
            };
            ensure!(
                m.offset >= HEADER_V2_LEN && m.offset + m.byte_len <= index_offset,
                "chunk {c}: payload [{}, {}) outside file body",
                m.offset,
                m.offset + m.byte_len
            );
            ensure!(m.first_user_index as u64 == users_seen, "chunk {c}: user index gap");
            users_seen += m.users_in_chunk as u64;
            index.push(m);
        }
        ensure!(users_seen == n_users as u64, "index covers {users_seen}/{n_users} users");
        Ok(ChunkedPopulation { file, n_users, chunk_users, total_slots, index })
    }

    pub fn n_users(&self) -> usize {
        self.n_users as usize
    }

    pub fn n_chunks(&self) -> usize {
        self.index.len()
    }

    pub fn chunk_users(&self) -> usize {
        self.chunk_users as usize
    }

    /// Total instance-slots across the whole fleet (from the header).
    pub fn total_slots(&self) -> u64 {
        self.total_slots
    }

    pub fn chunk_meta(&self, i: usize) -> ChunkMeta {
        self.index[i]
    }

    /// Read chunk `i` into a fresh columnar population.
    pub fn read_chunk(&mut self, i: usize) -> Result<FlatPopulation> {
        let mut out = FlatPopulation::default();
        self.read_chunk_into(i, &mut out)?;
        Ok(out)
    }

    /// Read chunk `i` into `out` (cleared first), reusing its allocations —
    /// the steady-state replay path allocates nothing per chunk.
    pub fn read_chunk_into(&mut self, i: usize, out: &mut FlatPopulation) -> Result<()> {
        self.read_chunk_into_with(i, out, None)
    }

    /// [`read_chunk_into`](ChunkedPopulation::read_chunk_into) with an
    /// optional injected bit flip `(byte, bit)` applied to the payload
    /// *before* checksum verification (`byte` wraps modulo the payload
    /// length) — the fault-injection hook of the crash-recovery harness.
    /// A checksum failure surfaces as a downcastable [`ChunkCorrupt`].
    pub fn read_chunk_into_with(
        &mut self,
        i: usize,
        out: &mut FlatPopulation,
        flip: Option<(u64, u8)>,
    ) -> Result<()> {
        let m = self.index[i];
        self.file.seek(SeekFrom::Start(m.offset))?;
        let mut payload = vec![0u8; m.byte_len as usize];
        self.file.read_exact(&mut payload).with_context(|| {
            format!(
                "chunk {i}: short read of {} bytes at offset {}",
                m.byte_len, m.offset
            )
        })?;
        if let Some((byte, bit)) = flip {
            if !payload.is_empty() {
                let at = (byte % payload.len() as u64) as usize;
                payload[at] ^= 1 << (bit & 7);
            }
        }
        let got = fnv1a64(&payload);
        if got != m.checksum {
            return Err(anyhow::Error::new(ChunkCorrupt {
                chunk: i,
                offset: m.offset,
                byte_len: m.byte_len,
                stored_checksum: m.checksum,
                computed_checksum: got,
            }));
        }
        out.clear();
        let mut at = 0usize;
        let mut demand: Vec<u32> = Vec::new();
        for _ in 0..m.users_in_chunk {
            ensure!(
                at + 12 <= payload.len(),
                "chunk {i}: truncated user record header at payload byte {at} \
                 (file offset {}), payload is {} bytes",
                m.offset + at as u64,
                payload.len()
            );
            let rd = |a: usize| u32::from_le_bytes(payload[a..a + 4].try_into().unwrap());
            let uid = rd(at);
            let len = rd(at + 4) as usize;
            let n_runs = rd(at + 8) as usize;
            at += 12;
            ensure!(
                at + n_runs * 8 <= payload.len(),
                "chunk {i}: user {uid}: {n_runs} RLE runs truncated at payload byte {at} \
                 (file offset {}), payload is {} bytes",
                m.offset + at as u64,
                payload.len()
            );
            demand.clear();
            demand.reserve(len);
            for r in 0..n_runs {
                let v = rd(at + r * 8);
                let run = rd(at + r * 8 + 4) as usize;
                demand.resize(demand.len() + run, v);
            }
            at += n_runs * 8;
            ensure!(
                demand.len() == len,
                "chunk {i}: user {uid}: RLE expands to {} slots, record header at \
                 file offset {} says {len}",
                demand.len(),
                m.offset + (at - 12 - n_runs * 8) as u64
            );
            out.push_user(uid, &demand);
        }
        ensure!(
            at == payload.len(),
            "chunk {i}: {} trailing bytes after the last user record (file offset {})",
            payload.len() - at,
            m.offset + at as u64
        );
        Ok(())
    }

    /// Stable fingerprint of this trace file's identity: FNV-1a over the
    /// header fields and every index entry. Checkpoints embed it so a
    /// resume against a different (or regenerated) trace is rejected
    /// instead of silently producing a wrong aggregate.
    pub fn fingerprint64(&self) -> u64 {
        let mut bytes = Vec::with_capacity(24 + self.index.len() * INDEX_ENTRY_LEN as usize);
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&self.n_users.to_le_bytes());
        bytes.extend_from_slice(&self.chunk_users.to_le_bytes());
        bytes.extend_from_slice(&self.total_slots.to_le_bytes());
        for m in &self.index {
            bytes.extend_from_slice(&m.offset.to_le_bytes());
            bytes.extend_from_slice(&m.byte_len.to_le_bytes());
            bytes.extend_from_slice(&m.checksum.to_le_bytes());
            bytes.extend_from_slice(&m.first_user_index.to_le_bytes());
            bytes.extend_from_slice(&m.users_in_chunk.to_le_bytes());
        }
        fnv1a64(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{generate, SynthConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}", std::process::id()))
    }

    #[test]
    fn csv_roundtrip() {
        let pop = generate(&SynthConfig { users: 5, slots: 300, ..Default::default() });
        let path = tmp("pop.csv");
        write_csv(&pop, &path).unwrap();
        let back = read_csv(&path, 300).unwrap();
        // sparse CSV drops all-zero users by design; compare the rest
        let nonzero: Vec<_> = pop.users.iter().filter(|u| u.total_demand() > 0).collect();
        assert_eq!(nonzero.len(), back.users.len());
        for (a, b) in nonzero.iter().zip(&back.users) {
            assert_eq!(*a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let pop = generate(&SynthConfig { users: 8, slots: 500, ..Default::default() });
        let path = tmp("pop.bin");
        write_bin(&pop, &path).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(pop.users, back.users);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_is_compact_for_sparse_traces() {
        // mostly-zero trace compresses far below 4 bytes/slot
        let mut demand = vec![0u32; 10_000];
        demand[5000] = 3;
        let pop = Population { users: vec![UserTrace::new(0, demand)] };
        let path = tmp("sparse.bin");
        write_bin(&pop, &path).unwrap();
        let size = std::fs::metadata(&path).unwrap().len();
        assert!(size < 200, "sparse trace file is {size} bytes");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.bin");
        std::fs::write(&path, b"NOTATRACE").unwrap();
        assert!(read_bin(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chunked_roundtrip_matches_flat() {
        let pop = generate(&SynthConfig { users: 23, slots: 400, ..Default::default() });
        let flat = pop.flatten();
        for chunk_users in [1u32, 4, 7, 23, 100] {
            let path = tmp(&format!("pop_v2_{chunk_users}.bin"));
            write_chunked(&pop, &path, chunk_users).unwrap();
            let mut chunked = ChunkedPopulation::open(&path).unwrap();
            assert_eq!(chunked.n_users(), 23);
            assert_eq!(chunked.total_slots(), 23 * 400);
            assert_eq!(chunked.n_chunks(), 23usize.div_ceil(chunk_users as usize));
            let mut seen = 0usize;
            let mut buf = FlatPopulation::default();
            for c in 0..chunked.n_chunks() {
                chunked.read_chunk_into(c, &mut buf).unwrap();
                for i in 0..buf.len() {
                    assert_eq!(buf.user_id(i), flat.user_id(seen));
                    assert_eq!(buf.demand(i), flat.demand(seen), "chunk_users={chunk_users}");
                    seen += 1;
                }
            }
            assert_eq!(seen, flat.len());
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn chunked_rejects_bad_magic_and_truncation() {
        let path = tmp("bad_v2.bin");
        std::fs::write(&path, b"CLDRSV99rest").unwrap();
        assert!(ChunkedPopulation::open(&path).is_err());
        // valid magic but truncated header
        std::fs::write(&path, b"CLDRSV02").unwrap();
        assert!(ChunkedPopulation::open(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chunked_rejects_corrupted_chunk() {
        let pop = generate(&SynthConfig { users: 9, slots: 300, ..Default::default() });
        let path = tmp("corrupt_v2.bin");
        write_chunked(&pop, &path, 4).unwrap();
        // flip one byte inside the first chunk payload (after the header)
        let mut bytes = std::fs::read(&path).unwrap();
        let at = HEADER_V2_LEN as usize + 5;
        bytes[at] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let mut chunked = ChunkedPopulation::open(&path).unwrap();
        let err = chunked.read_chunk(0).unwrap_err();
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");
        // other chunks still verify
        assert!(chunked.read_chunk(1).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chunked_corruption_error_downcasts_with_context() {
        let pop = generate(&SynthConfig { users: 6, slots: 200, ..Default::default() });
        let path = tmp("corrupt_typed_v2.bin");
        write_chunked(&pop, &path, 3).unwrap();
        let mut chunked = ChunkedPopulation::open(&path).unwrap();
        // injected flip instead of on-disk mutation: same verification path
        let mut buf = FlatPopulation::default();
        let err = chunked.read_chunk_into_with(1, &mut buf, Some((7, 2))).unwrap_err();
        let c = err.downcast_ref::<ChunkCorrupt>().expect("ChunkCorrupt downcast");
        assert_eq!(c.chunk, 1);
        assert_eq!(c.offset, chunked.chunk_meta(1).offset);
        assert_eq!(c.byte_len, chunked.chunk_meta(1).byte_len);
        assert_eq!(c.stored_checksum, chunked.chunk_meta(1).checksum);
        assert_ne!(c.computed_checksum, c.stored_checksum);
        assert!(err.to_string().contains("checksum mismatch"), "unexpected error: {err}");
        // the same chunk reads fine without the injected flip
        assert!(chunked.read_chunk_into(1, &mut buf).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chunked_writer_finish_is_atomic() {
        let pop = generate(&SynthConfig { users: 5, slots: 100, ..Default::default() });
        let path = tmp("atomic_v2.bin");
        let tmp_path = {
            let mut t = path.as_os_str().to_os_string();
            t.push(".tmp");
            std::path::PathBuf::from(t)
        };
        std::fs::remove_file(&path).ok();
        // abandoned writer: destination never appears, temp file cleaned up
        {
            let mut w = ChunkedWriter::create(&path, 2).unwrap();
            w.push_user(0, &pop.users[0].demand).unwrap();
            assert!(tmp_path.exists(), "writer should stream to the temp path");
            assert!(!path.exists(), "destination must not exist before finish");
        }
        assert!(!tmp_path.exists(), "drop without finish must remove the temp file");
        assert!(!path.exists());
        // a finished writer replaces the destination and removes the temp
        write_chunked(&pop, &path, 2).unwrap();
        assert!(path.exists());
        assert!(!tmp_path.exists());
        assert_eq!(ChunkedPopulation::open(&path).unwrap().n_users(), 5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fingerprint_is_stable_and_distinguishes_traces() {
        let pop = generate(&SynthConfig { users: 7, slots: 150, ..Default::default() });
        let path_a = tmp("fp_a_v2.bin");
        let path_b = tmp("fp_b_v2.bin");
        write_chunked(&pop, &path_a, 3).unwrap();
        write_chunked(&pop, &path_b, 3).unwrap();
        let fp_a = ChunkedPopulation::open(&path_a).unwrap().fingerprint64();
        let fp_b = ChunkedPopulation::open(&path_b).unwrap().fingerprint64();
        assert_eq!(fp_a, fp_b, "identical content must fingerprint identically");
        // different chunking => different index => different fingerprint
        write_chunked(&pop, &path_b, 2).unwrap();
        let fp_c = ChunkedPopulation::open(&path_b).unwrap().fingerprint64();
        assert_ne!(fp_a, fp_c);
        std::fs::remove_file(path_a).ok();
        std::fs::remove_file(path_b).ok();
    }

    #[test]
    fn chunked_handles_empty_fleet() {
        let path = tmp("empty_v2.bin");
        write_chunked(&Population::default(), &path, 8).unwrap();
        let chunked = ChunkedPopulation::open(&path).unwrap();
        assert_eq!(chunked.n_users(), 0);
        assert_eq!(chunked.n_chunks(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_rejects_out_of_range_slot() {
        let path = tmp("bad.csv");
        std::fs::write(&path, "user_id,slot,demand\n0,999,1\n").unwrap();
        assert!(read_csv(&path, 100).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_rejects_malformed_line() {
        let path = tmp("mal.csv");
        std::fs::write(&path, "user_id,slot,demand\n0,abc,1\n").unwrap();
        assert!(read_csv(&path, 100).is_err());
        std::fs::remove_file(path).ok();
    }
}
