//! Trace persistence: a CSV form (`user_id,slot,demand`, sparse — zero
//! slots omitted) for interoperability, and a compact binary form for the
//! 933-user month-long population (run-length encoded, ~100x smaller).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Population, UserTrace};

/// Write a population as sparse CSV. NOTE: the format omits zero-demand
/// slots, so users whose entire curve is zero do not round-trip (the
/// binary format is lossless).
pub fn write_csv(pop: &Population, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "user_id,slot,demand")?;
    for u in &pop.users {
        for (t, &d) in u.demand.iter().enumerate() {
            if d > 0 {
                writeln!(w, "{},{},{}", u.user_id, t, d)?;
            }
        }
    }
    Ok(())
}

/// Read a sparse CSV population; `slots` fixes every user's curve length.
pub fn read_csv(path: &Path, slots: usize) -> Result<Population> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut users: std::collections::BTreeMap<u32, Vec<u32>> = std::collections::BTreeMap::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.starts_with("user_id") {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let (Some(uid), Some(slot), Some(d)) = (parts.next(), parts.next(), parts.next()) else {
            bail!("line {}: expected user_id,slot,demand, got '{line}'", lineno + 1);
        };
        let uid: u32 =
            uid.trim().parse().with_context(|| format!("line {}: bad user_id", lineno + 1))?;
        let slot: usize =
            slot.trim().parse().with_context(|| format!("line {}: bad slot", lineno + 1))?;
        let d: u32 = d.trim().parse().with_context(|| format!("line {}: bad demand", lineno + 1))?;
        if slot >= slots {
            bail!("line {}: slot {slot} >= trace length {slots}", lineno + 1);
        }
        users.entry(uid).or_insert_with(|| vec![0; slots])[slot] = d;
    }
    Ok(Population {
        users: users.into_iter().map(|(uid, demand)| UserTrace::new(uid, demand)).collect(),
    })
}

const MAGIC: &[u8; 8] = b"CLDRSV01";

/// Write the compact run-length-encoded binary form.
pub fn write_bin(pop: &Population, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&(pop.users.len() as u32).to_le_bytes())?;
    for u in &pop.users {
        w.write_all(&u.user_id.to_le_bytes())?;
        w.write_all(&(u.demand.len() as u32).to_le_bytes())?;
        // RLE: (value: u32, run: u32)*
        let mut runs: Vec<(u32, u32)> = Vec::new();
        for &d in &u.demand {
            match runs.last_mut() {
                Some((v, r)) if *v == d => *r += 1,
                _ => runs.push((d, 1)),
            }
        }
        w.write_all(&(runs.len() as u32).to_le_bytes())?;
        for (v, r) in runs {
            w.write_all(&v.to_le_bytes())?;
            w.write_all(&r.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the binary form.
pub fn read_bin(path: &Path) -> Result<Population> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a cloudreserve trace file (bad magic)");
    }
    let mut u32buf = [0u8; 4];
    let mut read_u32 = |r: &mut BufReader<File>| -> Result<u32> {
        r.read_exact(&mut u32buf)?;
        Ok(u32::from_le_bytes(u32buf))
    };
    let n_users = read_u32(&mut r)? as usize;
    if n_users > 10_000_000 {
        bail!("implausible user count {n_users}");
    }
    let mut users = Vec::with_capacity(n_users);
    for _ in 0..n_users {
        let uid = read_u32(&mut r)?;
        let len = read_u32(&mut r)? as usize;
        let n_runs = read_u32(&mut r)? as usize;
        let mut demand = Vec::with_capacity(len);
        for _ in 0..n_runs {
            let v = read_u32(&mut r)?;
            let run = read_u32(&mut r)? as usize;
            demand.extend(std::iter::repeat(v).take(run));
        }
        if demand.len() != len {
            bail!("user {uid}: RLE expands to {} slots, header says {len}", demand.len());
        }
        users.push(UserTrace::new(uid, demand));
    }
    Ok(Population { users })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{generate, SynthConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}", std::process::id()))
    }

    #[test]
    fn csv_roundtrip() {
        let pop = generate(&SynthConfig { users: 5, slots: 300, ..Default::default() });
        let path = tmp("pop.csv");
        write_csv(&pop, &path).unwrap();
        let back = read_csv(&path, 300).unwrap();
        // sparse CSV drops all-zero users by design; compare the rest
        let nonzero: Vec<_> = pop.users.iter().filter(|u| u.total_demand() > 0).collect();
        assert_eq!(nonzero.len(), back.users.len());
        for (a, b) in nonzero.iter().zip(&back.users) {
            assert_eq!(*a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let pop = generate(&SynthConfig { users: 8, slots: 500, ..Default::default() });
        let path = tmp("pop.bin");
        write_bin(&pop, &path).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(pop.users, back.users);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_is_compact_for_sparse_traces() {
        // mostly-zero trace compresses far below 4 bytes/slot
        let mut demand = vec![0u32; 10_000];
        demand[5000] = 3;
        let pop = Population { users: vec![UserTrace::new(0, demand)] };
        let path = tmp("sparse.bin");
        write_bin(&pop, &path).unwrap();
        let size = std::fs::metadata(&path).unwrap().len();
        assert!(size < 200, "sparse trace file is {size} bytes");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.bin");
        std::fs::write(&path, b"NOTATRACE").unwrap();
        assert!(read_bin(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_rejects_out_of_range_slot() {
        let path = tmp("bad.csv");
        std::fs::write(&path, "user_id,slot,demand\n0,999,1\n").unwrap();
        assert!(read_csv(&path, 100).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_rejects_malformed_line() {
        let path = tmp("mal.csv");
        std::fs::write(&path, "user_id,slot,demand\n0,abc,1\n").unwrap();
        assert!(read_csv(&path, 100).is_err());
        std::fs::remove_file(path).ok();
    }
}
