//! Task → instance packing: the paper's trace preprocessing (Sec. VII-A,
//! "Demand Curve").
//!
//! The Google traces record *tasks* with resource requirements; the paper
//! schedules them onto instances of fixed capacity ("we set an instance to
//! have the same computing capacity as a cluster machine"), with
//! anti-affinity: "computational tasks that cannot run on the same server
//! in the traces (e.g., tasks of MapReduce) are scheduled to different
//! instances". The per-slot instance count is the demand curve `d_t`.
//!
//! This module reproduces that pipeline on synthetic task streams: a
//! first-fit packer over (cpu, mem) vectors with anti-affinity groups.

use crate::util::rng::Rng;

/// A computational task to place.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Arrival slot.
    pub start: usize,
    /// Duration in slots.
    pub duration: usize,
    /// Normalized CPU requirement in (0, 1].
    pub cpu: f64,
    /// Normalized memory requirement in (0, 1].
    pub mem: f64,
    /// Tasks sharing an anti-affinity group may not co-locate
    /// (0 = no constraint).
    pub anti_affinity: u32,
}

/// Instance capacity (a "cluster machine": normalized to 1.0 each axis).
#[derive(Debug, Clone, Copy)]
pub struct Capacity {
    pub cpu: f64,
    pub mem: f64,
}

impl Default for Capacity {
    fn default() -> Self {
        Capacity { cpu: 1.0, mem: 1.0 }
    }
}

/// One running instance during packing.
#[derive(Debug, Clone)]
struct Instance {
    cpu_free: f64,
    mem_free: f64,
    /// anti-affinity groups currently present
    groups: Vec<u32>,
    /// slot at which the last task on this instance ends
    busy_until: usize,
}

/// Pack tasks onto instances slot by slot (first fit, arrival order) and
/// return the demand curve: number of instances holding at least one task
/// per slot.
///
/// Packing is *per-slot* renewed: an instance exists while it holds at
/// least one running task (IaaS instances are billed hourly, the ledger
/// handles billing; here we only need concurrent instance counts).
pub fn demand_curve(tasks: &[Task], capacity: Capacity, slots: usize) -> Vec<u32> {
    // Sweep over slots; maintain active instances with their tasks.
    // For tractability on month-long traces we process arrival events.
    #[derive(Debug)]
    struct Placed {
        instance: usize,
        end: usize,
        cpu: f64,
        mem: f64,
        group: u32,
    }
    let mut by_start: Vec<&Task> = tasks.iter().collect();
    by_start.sort_by_key(|t| t.start);

    let mut instances: Vec<Instance> = Vec::new();
    let mut placed: Vec<Placed> = Vec::new();
    let mut demand = vec![0u32; slots];
    let mut next_task = 0usize;

    for t in 0..slots {
        // release finished tasks
        placed.retain(|p| {
            if p.end <= t {
                let inst = &mut instances[p.instance];
                inst.cpu_free += p.cpu;
                inst.mem_free += p.mem;
                if p.group != 0 {
                    if let Some(pos) = inst.groups.iter().position(|&g| g == p.group) {
                        inst.groups.swap_remove(pos);
                    }
                }
                false
            } else {
                true
            }
        });
        // place arrivals
        while next_task < by_start.len() && by_start[next_task].start == t {
            let task = by_start[next_task];
            next_task += 1;
            if task.duration == 0 || task.cpu <= 0.0 || task.mem <= 0.0 {
                continue; // degenerate task: nothing to place
            }
            let end = (t + task.duration).min(slots);
            // first fit
            let slot_inst = instances.iter().position(|i| {
                i.cpu_free >= task.cpu - 1e-9
                    && i.mem_free >= task.mem - 1e-9
                    && (task.anti_affinity == 0 || !i.groups.contains(&task.anti_affinity))
                    && i.busy_until > t // only reuse instances that are alive now
            });
            let idx = match slot_inst {
                Some(i) => i,
                None => {
                    // reuse a dead slot or push a new instance
                    if let Some(i) = instances.iter().position(|i| i.busy_until <= t) {
                        instances[i] = Instance {
                            cpu_free: capacity.cpu,
                            mem_free: capacity.mem,
                            groups: Vec::new(),
                            busy_until: t,
                        };
                        i
                    } else {
                        instances.push(Instance {
                            cpu_free: capacity.cpu,
                            mem_free: capacity.mem,
                            groups: Vec::new(),
                            busy_until: t,
                        });
                        instances.len() - 1
                    }
                }
            };
            let inst = &mut instances[idx];
            inst.cpu_free -= task.cpu;
            inst.mem_free -= task.mem;
            if task.anti_affinity != 0 {
                inst.groups.push(task.anti_affinity);
            }
            inst.busy_until = inst.busy_until.max(end);
            placed.push(Placed {
                instance: idx,
                end,
                cpu: task.cpu,
                mem: task.mem,
                group: task.anti_affinity,
            });
        }
        // count live instances
        demand[t] = instances.iter().filter(|i| i.busy_until > t).count() as u32;
    }
    demand
}

/// Generate a synthetic task stream resembling one user's job submissions:
/// batched MapReduce-style waves (anti-affine shards) plus singleton tasks.
pub fn synth_tasks(slots: usize, intensity: f64, rng: &mut Rng) -> Vec<Task> {
    let mut tasks = Vec::new();
    let mut group_id = 1u32;
    let mut t = rng.exponential(intensity.max(1e-6)) as usize;
    while t < slots {
        if rng.chance(0.3) {
            // MapReduce wave: n shards that must not co-locate
            let shards = 2 + rng.below(12) as usize;
            let dur = (30.0 + rng.exponential(1.0 / 120.0)) as usize;
            for _ in 0..shards {
                tasks.push(Task {
                    start: t,
                    duration: dur.max(5),
                    cpu: 0.3 + rng.f64() * 0.4,
                    mem: 0.2 + rng.f64() * 0.4,
                    anti_affinity: group_id,
                });
            }
            group_id += 1;
        } else {
            tasks.push(Task {
                start: t,
                duration: (10.0 + rng.exponential(1.0 / 90.0)) as usize,
                cpu: 0.1 + rng.f64() * 0.6,
                mem: 0.1 + rng.f64() * 0.6,
                anti_affinity: 0,
            });
        }
        t += 1 + rng.exponential(intensity.max(1e-6)) as usize;
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_task_single_instance() {
        let tasks = vec![Task { start: 2, duration: 3, cpu: 0.5, mem: 0.5, anti_affinity: 0 }];
        let d = demand_curve(&tasks, Capacity::default(), 10);
        assert_eq!(d, vec![0, 0, 1, 1, 1, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn small_tasks_pack_together() {
        let tasks: Vec<Task> = (0..4)
            .map(|_| Task { start: 0, duration: 5, cpu: 0.2, mem: 0.2, anti_affinity: 0 })
            .collect();
        let d = demand_curve(&tasks, Capacity::default(), 6);
        assert_eq!(d[0], 1, "four 0.2-cpu tasks fit one instance");
    }

    #[test]
    fn big_tasks_need_separate_instances() {
        let tasks: Vec<Task> = (0..3)
            .map(|_| Task { start: 0, duration: 5, cpu: 0.8, mem: 0.5, anti_affinity: 0 })
            .collect();
        let d = demand_curve(&tasks, Capacity::default(), 6);
        assert_eq!(d[0], 3);
    }

    #[test]
    fn anti_affinity_forces_spread() {
        // two small tasks that WOULD fit together but share a group
        let tasks: Vec<Task> = (0..2)
            .map(|_| Task { start: 0, duration: 4, cpu: 0.1, mem: 0.1, anti_affinity: 7 })
            .collect();
        let d = demand_curve(&tasks, Capacity::default(), 5);
        assert_eq!(d[0], 2, "anti-affine shards must not co-locate");
    }

    #[test]
    fn instances_are_reused_after_release() {
        let tasks = vec![
            Task { start: 0, duration: 2, cpu: 0.9, mem: 0.9, anti_affinity: 0 },
            Task { start: 3, duration: 2, cpu: 0.9, mem: 0.9, anti_affinity: 0 },
        ];
        let d = demand_curve(&tasks, Capacity::default(), 6);
        // never more than 1 instance alive
        assert!(d.iter().all(|&x| x <= 1), "{d:?}");
    }

    #[test]
    fn synth_stream_produces_plausible_curve() {
        let mut rng = Rng::new(11);
        let tasks = synth_tasks(2000, 1.0 / 50.0, &mut rng);
        assert!(!tasks.is_empty());
        let d = demand_curve(&tasks, Capacity::default(), 2000);
        assert!(d.iter().any(|&x| x > 0));
        // demand never exceeds total task count
        let peak = d.iter().max().unwrap();
        assert!(*peak as usize <= tasks.len());
    }

    #[test]
    fn degenerate_tasks_are_skipped() {
        let tasks = vec![Task { start: 0, duration: 0, cpu: 0.5, mem: 0.5, anti_affinity: 0 }];
        let d = demand_curve(&tasks, Capacity::default(), 3);
        assert_eq!(d, vec![0, 0, 0]);
    }
}
