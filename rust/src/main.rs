//! `cloudreserve` — CLI for the reservation brokerage.
//!
//! Subcommands:
//! * `pricing-table` — reproduce Table I (catalog + normalized params).
//! * `gen-traces`    — synthesize the Google-like population to CSV/BIN.
//! * `classify`      — Fig. 4: per-user σ/μ classification + scatter.
//! * `simulate`      — run the Sec. VII policy suite over a population,
//!                     printing Table II and the Fig. 5 CDFs.
//! * `serve`         — run the streaming broker on a synthetic feed with
//!                     periodic PJRT analytics ticks (the L3 service demo).
//! * `offline`       — exact offline OPT (small instances) for a demand
//!                     sequence given on the command line.
//! * `scenario`      — run a declarative JSON scenario (market menu +
//!                     trace source + policy set) through the engine and
//!                     emit a comparable normalized-cost report.
//! * `broker`        — run a shared-portfolio broker scenario
//!                     (`"mode": "broker"`): fold the fleet into one
//!                     aggregate demand curve, buy a single reservation
//!                     portfolio with an online policy, and settle the
//!                     realized cost back into per-user bills.
//! * `fleet`         — stream one policy over a chunked trace with
//!                     crash-recovery: periodic checkpoints, `--resume`,
//!                     corrupt-chunk quarantine, and deterministic fault
//!                     injection for recovery drills.
//! * `bench`         — measure the batched fleet engine (suite throughput,
//!                     offline-DP solve times, per-policy decide latency)
//!                     and write the tracked `BENCH.json` perf baseline.

use cloudreserve::algos::offline;
use cloudreserve::analysis::classify::{classify_population, group_counts};
use cloudreserve::analysis::report::{
    render_cdf_table, render_fig4_scatter, render_table2, CostSeries,
};
use cloudreserve::coordinator::{AnalyticsEngine, Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::catalog::{ec2_small_compressed, render_table1};
use cloudreserve::pricing::{Market, Pricing};
use cloudreserve::sim::fleet::run_benchmark_suite;
use cloudreserve::sim::scenario::{self, ParsedScenario};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::trace::{io as trace_io, Population};
use cloudreserve::util::cli::{expected_one_of, Args};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("pricing-table") => cmd_pricing_table(),
        Some("gen-traces") => cmd_gen_traces(&args),
        Some("classify") => cmd_classify(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("offline") => cmd_offline(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("broker") => cmd_broker(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("bench") => cmd_bench(&args),
        _ => {
            eprintln!(
                "usage: cloudreserve <pricing-table|gen-traces|classify|simulate|serve|offline|scenario|broker|fleet|bench> [--options]\n\
                 \n\
                 gen-traces --users N --slots N --seed S --out FILE [--csv] [--chunk-users N] [--plot-user U]\n\
                 classify   [--traces FILE | --users N --slots N --seed S]\n\
                 simulate   [--traces FILE | --users N --slots N] --seed S --threads N [--csv-out FILE]\n\
                 serve      --users N --slots N --shards N --tick N [--artifacts DIR]\n\
                 offline    --tau N --p F --alpha F d1 d2 d3 ...\n\
                 scenario   --spec FILE [--threads N] [--json-out FILE]\n\
                 broker     --spec FILE [--threads N] [--json-out FILE] [--settlement proportional|od-capped]\n\
                 fleet      --trace FILE [--market single|menu2] [--policy NAME --window N --policy-seed S]\n\
                 fleet      [--threads N] [--checkpoint FILE --checkpoint-every N] [--resume [FILE]]\n\
                 fleet      [--on-corrupt fail|skip --read-retries N] [--report FILE]\n\
                 fleet      [--kill-after-chunk N] [--fault-seed S --fault-read-rate F --fault-flip-rate F]\n\
                 bench      [--users N --slots N --seed S --threads N --out FILE] [--quick] [--skip-reference]\n\
                 bench      [--chunk-users N --fleet-max-users N] [--fleet-scale]   (streaming 10^3..10^6 grid)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        // A scripted kill-point is a simulated crash, not a failure of the
        // run itself — give it a distinct exit code so the CI recovery
        // smoke can tell "crashed as planned" from a real error.
        let code =
            if e.downcast_ref::<cloudreserve::util::faults::KillPoint>().is_some() { 3 } else { 1 };
        std::process::exit(code);
    }
}

/// Removes the wrapped file on drop, so scratch files vanish even when the
/// surrounding command errors out mid-way.
struct TempFile(std::path::PathBuf);

impl Drop for TempFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn load_or_generate(args: &Args) -> anyhow::Result<Population> {
    if let Some(path) = args.get("traces") {
        let path = std::path::Path::new(path);
        if path.extension().map(|e| e == "csv").unwrap_or(false) {
            let slots = args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS);
            trace_io::read_csv(path, slots)
        } else {
            trace_io::read_bin(path)
        }
    } else {
        let cfg = SynthConfig {
            users: args.usize_or("users", 200),
            slots: args.usize_or("slots", 10_000),
            seed: args.u64_or("seed", 2013),
            ..Default::default()
        };
        eprintln!("generating {} users x {} slots (seed {})", cfg.users, cfg.slots, cfg.seed);
        Ok(generate(&cfg))
    }
}

fn cmd_pricing_table() -> anyhow::Result<()> {
    print!("{}", render_table1());
    let pr = ec2_small_compressed();
    println!(
        "\ncompressed trace pricing (Sec. VII): p={:.6} alpha={:.4} tau={} minute-slots\n\
         deterministic ratio 2-a = {:.4}, randomized e/(e-1+a) = {:.4}",
        pr.p,
        pr.alpha,
        pr.tau,
        pr.deterministic_ratio(),
        pr.randomized_ratio()
    );
    Ok(())
}

fn cmd_gen_traces(args: &Args) -> anyhow::Result<()> {
    let cfg = SynthConfig {
        users: args.usize_or("users", cloudreserve::trace::NUM_USERS),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let out = args.str_or("out", "traces.bin");
    let path = std::path::Path::new(&out);
    if let Some(cu) = args.get("chunk-users") {
        // Streaming path: chunked v2 format, nothing fleet-sized in RAM —
        // this is the input format of `fleet` and the bench fleet grid.
        let chunk_users: u32 = cu
            .parse()
            .map_err(|_| anyhow::anyhow!("--chunk-users expects a positive integer, got '{cu}'"))?;
        cloudreserve::trace::synth::generate_chunked(&cfg, path, chunk_users)?;
        let chunked = trace_io::ChunkedPopulation::open(path)?;
        println!(
            "wrote {} users x {} slots to {out} ({} chunks of {chunk_users}, fingerprint {:#018x})",
            chunked.n_users(),
            cfg.slots,
            chunked.n_chunks(),
            chunked.fingerprint64()
        );
        return Ok(());
    }
    let pop = generate(&cfg);
    if args.has("csv") || path.extension().map(|e| e == "csv").unwrap_or(false) {
        trace_io::write_csv(&pop, path)?;
    } else {
        trace_io::write_bin(&pop, path)?;
    }
    let (g1, g2, g3) = group_counts(&pop);
    println!("wrote {} users x {} slots to {out} (groups: {g1}/{g2}/{g3})", pop.len(), cfg.slots);
    if let Some(uid) = args.get("plot-user") {
        let uid: u32 = uid.parse()?;
        let user = pop
            .users
            .iter()
            .find(|u| u.user_id == uid)
            .ok_or_else(|| anyhow::anyhow!("no user {uid}"))?;
        // Fig. 3-style: per-day summary of the month-long curve
        println!("Fig. 3 — demand curve of user {uid} (per-day mean/max):");
        for (day, chunk) in user.demand.chunks(cloudreserve::trace::SLOTS_PER_DAY).enumerate() {
            let s = cloudreserve::util::stats::summarize_u32(chunk);
            println!("  day {day:>2}: mean {:>8.1}  max {:>6}", s.mean, s.max as u64);
        }
    }
    Ok(())
}

fn cmd_classify(args: &Args) -> anyhow::Result<()> {
    let pop = load_or_generate(args)?;
    let rows = classify_population(&pop);
    let (g1, g2, g3) = group_counts(&pop);
    println!(
        "Fig. 4 — user demand statistics: {} users -> G1={g1} ({:.0}%), G2={g2} ({:.0}%), G3={g3} ({:.0}%)",
        pop.len(),
        100.0 * g1 as f64 / pop.len() as f64,
        100.0 * g2 as f64 / pop.len() as f64,
        100.0 * g3 as f64 / pop.len() as f64,
    );
    let pts: Vec<(f64, f64)> = rows.iter().map(|(_, _, mean, cov)| (*mean, *cov)).collect();
    print!("{}", render_fig4_scatter(&pts, 72, 20));
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let pop = load_or_generate(args)?;
    let market = Market::single(ec2_small_compressed());
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    let seed = args.u64_or("seed", 1);
    eprintln!("running the Sec. VII suite over {} users ({} threads)...", pop.len(), threads);
    let t0 = std::time::Instant::now();
    let results = run_benchmark_suite(&pop, &market, seed, threads);
    eprintln!("suite done in {:.1}s", t0.elapsed().as_secs_f64());

    let rows: Vec<(String, [f64; 4])> =
        results.iter().map(|r| (r.policy.clone(), r.table2_row())).collect();
    print!("{}", render_table2(&rows));

    let series: Vec<CostSeries> = results
        .iter()
        .map(|r| CostSeries { name: r.policy.clone(), values: r.normalized(None) })
        .collect();
    println!();
    print!(
        "{}",
        render_cdf_table("Fig. 5a — CDF of normalized cost (all users)", &series, 0.0, 2.0, 21)
    );

    if let Some(path) = args.get("csv-out") {
        std::fs::write(path, cloudreserve::analysis::report::cdf_csv(&series, 0.0, 2.0, 101))?;
        eprintln!("wrote CDF csv to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let users = args.usize_or("users", 64);
    let slots = args.usize_or("slots", 2000);
    let shards = args.usize_or("shards", 4);
    let tick = args.usize_or("tick", 500);
    let pricing = ec2_small_compressed();
    let cfg = BrokerConfig { pricing, shards, queue_capacity: 8192, window: 64 };

    let artifacts_dir = args.str_or("artifacts", "artifacts");
    let engine = if std::path::Path::new(&artifacts_dir).join("manifest.json").exists() {
        let rt = cloudreserve::runtime::Runtime::load_filtered(&artifacts_dir, |n| {
            n.starts_with("fleet_step")
        })?;
        eprintln!("PJRT runtime up: platform={} artifacts={:?}", rt.platform(), rt.names());
        Some(AnalyticsEngine::new(rt, pricing, 16, 128))
    } else {
        eprintln!("artifacts not found at {artifacts_dir}: serving without the analytics engine");
        None
    };

    let seed = args.u64_or("seed", 7);
    let pop = generate(&SynthConfig { users, slots, seed, ..Default::default() });
    let broker = Broker::start(cfg, PolicyKind::Deterministic { z: None });
    let t0 = std::time::Instant::now();
    for t in 0..slots {
        for u in &pop.users {
            broker.submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })?;
        }
        if t % tick == tick - 1 {
            if let Some(engine) = &engine {
                let posture = engine.tick(&broker)?;
                eprintln!(
                    "tick t={t}: mean reserve-pressure {:.3}, {} users over break-even | {}",
                    posture.mean_pressure(),
                    posture.over_breakeven().len(),
                    broker.metrics().render()
                );
            } else {
                eprintln!("t={t}: {}", broker.metrics().render());
            }
        }
    }
    let events = users * slots;
    let report = broker.finish()?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {events} demand events in {dt:.2}s ({:.0} events/s); total cost {:.2} ({} reservations)",
        events as f64 / dt,
        report.total_cost(),
        report.total_reservations()
    );
    Ok(())
}

/// `fleet`: stream one policy over a chunked v2 trace with crash recovery —
/// periodic checksummed checkpoints (`--checkpoint`, `--checkpoint-every`),
/// `--resume` to continue a killed run bit-identically, corrupt-chunk
/// quarantine (`--on-corrupt skip`), and deterministic fault injection
/// (`--kill-after-chunk`, `--fault-seed`) for recovery drills. The JSON
/// report carries aggregate f64s as exact bit patterns so CI can assert a
/// resumed run byte-identical to a clean one.
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use cloudreserve::sim::engine::{
        for_each_user_chunked_recoverable, OnCorrupt, RecoveryOptions,
    };
    use cloudreserve::sim::fleet::PolicySpec;
    use cloudreserve::trace::io::ChunkedPopulation;
    use cloudreserve::util::faults::{site, Fault, FaultPlan};
    use cloudreserve::util::json::Json;

    let trace = args.get("trace").ok_or_else(|| {
        anyhow::anyhow!("fleet requires --trace FILE (chunked v2; see `gen-traces --chunk-users`)")
    })?;
    let mut chunked = ChunkedPopulation::open(std::path::Path::new(trace))?;

    let market_name = args.str_or("market", "single");
    let market = match market_name.as_str() {
        "single" => Market::single(ec2_small_compressed()),
        "menu2" => Market::new(
            0.01,
            vec![
                cloudreserve::pricing::Contract { upfront: 1.0, rate: 0.004, term: 600 },
                cloudreserve::pricing::Contract { upfront: 1.5, rate: 0.002, term: 1800 },
            ],
        ),
        other => anyhow::bail!(expected_one_of("--market", other, &["single", "menu2"])),
    };

    let window = args.usize_or("window", 0);
    let policy_seed = args.u64_or("policy-seed", 1);
    let policy_name = args.str_or("policy", "deterministic");
    let spec = match policy_name.as_str() {
        "all-on-demand" => PolicySpec::AllOnDemand,
        "all-reserved" => PolicySpec::AllReserved,
        "separate" => PolicySpec::Separate,
        "deterministic" => PolicySpec::Deterministic { z: None, window },
        "randomized" => PolicySpec::Randomized { window, seed: policy_seed },
        "ucb" => PolicySpec::Ucb { seed: policy_seed },
        "adaptive_window" => PolicySpec::AdaptiveWindow,
        other => anyhow::bail!(expected_one_of("--policy", other, scenario::POLICY_NAMES)),
    };

    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );

    // `--resume FILE` names the checkpoint explicitly; bare `--resume`
    // reuses `--checkpoint`. Either way future checkpoints keep landing on
    // the same path.
    let resume_path = args.get("resume").map(str::to_string);
    let resume = resume_path.is_some() || args.has("resume");
    let checkpoint = args.get("checkpoint").map(str::to_string).or(resume_path);
    anyhow::ensure!(
        !resume || checkpoint.is_some(),
        "--resume needs a checkpoint path (either `--resume FILE` or `--checkpoint FILE`)"
    );

    let on_corrupt = match args.str_or("on-corrupt", "fail").as_str() {
        "fail" => OnCorrupt::Fail,
        "skip" => OnCorrupt::Skip,
        other => anyhow::bail!(expected_one_of("--on-corrupt", other, &["fail", "skip"])),
    };

    let mut plan = FaultPlan::new();
    if let Some(k) = args.get("kill-after-chunk") {
        let key: u64 = k
            .parse()
            .map_err(|_| anyhow::anyhow!("--kill-after-chunk expects a chunk index, got '{k}'"))?;
        plan = plan.script(site::FLEET_AFTER_CHUNK, key, u32::MAX, Fault::Kill);
    }
    if let Some(s) = args.get("fault-seed") {
        let fault_seed: u64 =
            s.parse().map_err(|_| anyhow::anyhow!("--fault-seed expects an integer, got '{s}'"))?;
        plan = plan.seeded(
            fault_seed,
            args.f64_or("fault-read-rate", 0.0),
            args.f64_or("fault-flip-rate", 0.0),
        );
    }

    let opts = RecoveryOptions {
        checkpoint_path: checkpoint.as_deref().map(std::path::Path::new),
        checkpoint_every: args.usize_or("checkpoint-every", 0),
        resume,
        on_corrupt,
        max_read_retries: args.usize_or("read-retries", 2) as u32,
        retry_base_ms: args.u64_or("retry-base-ms", 10),
        faults: plan.is_armed().then_some(&plan),
    };

    eprintln!(
        "fleet: {} ({market_name}) over {} users in {} chunks ({threads} threads){}",
        spec.name(),
        chunked.n_users(),
        chunked.n_chunks(),
        if resume { " [resuming]" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let outcome =
        for_each_user_chunked_recoverable(&mut chunked, &market, &spec, threads, &opts, |_| {})?;
    let wall_s = t0.elapsed().as_secs_f64();

    let agg = &outcome.aggregate;
    println!(
        "fleet done in {wall_s:.2}s: {} users, mean normalized cost {:.6}, \
         total cost {:.2}, {} reservations",
        agg.users(),
        agg.mean_normalized(),
        agg.total_cost(),
        agg.total_reservations()
    );
    if let Some(from) = outcome.resumed_from_chunk {
        println!(
            "resumed from chunk {from}{}; replayed {} chunks this run ({} checkpoints written)",
            if outcome.used_fallback_checkpoint { " (via fallback checkpoint)" } else { "" },
            outcome.chunks_replayed,
            outcome.checkpoints_written
        );
    }
    if !outcome.quarantined.is_empty() {
        println!("quarantined {} chunk(s):", outcome.quarantined.len());
        for q in &outcome.quarantined {
            println!("  chunk {} ({} users skipped): {}", q.chunk, q.users_skipped, q.error);
        }
    }
    let injected = plan.injected();
    if !injected.is_empty() {
        eprintln!("faults injected this run: {}", injected.len());
    }

    if let Some(report) = args.get("report") {
        let hex = |v: f64| Json::Str(format!("{:#018x}", v.to_bits()));
        let num_or_null = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let doc = Json::obj(vec![
            ("schema", Json::Str("cloudreserve-fleetrun/v1".into())),
            ("trace", Json::Str(trace.to_string())),
            ("trace_fingerprint", Json::Str(format!("{:#018x}", chunked.fingerprint64()))),
            ("policy", Json::Str(spec.name())),
            ("market", Json::Str(market_name)),
            ("threads", Json::Num(threads as f64)),
            ("n_chunks", Json::Num(chunked.n_chunks() as f64)),
            ("users", Json::Num(agg.users() as f64)),
            ("mean_normalized", num_or_null(agg.mean_normalized())),
            ("mean_normalized_bits", hex(agg.mean_normalized())),
            ("total_cost", num_or_null(agg.total_cost())),
            ("total_cost_bits", hex(agg.total_cost())),
            ("total_reservations", Json::Num(agg.total_reservations() as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("chunks_replayed", Json::Num(outcome.chunks_replayed as f64)),
            ("checkpoints_written", Json::Num(outcome.checkpoints_written as f64)),
            (
                "resumed_from_chunk",
                outcome.resumed_from_chunk.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null),
            ),
            ("used_fallback_checkpoint", Json::Bool(outcome.used_fallback_checkpoint)),
            (
                "quarantined_chunks",
                Json::Arr(
                    outcome
                        .quarantined
                        .iter()
                        .map(|q| {
                            Json::obj(vec![
                                ("chunk", Json::Num(q.chunk as f64)),
                                ("offset", Json::Num(q.offset as f64)),
                                ("byte_len", Json::Num(q.byte_len as f64)),
                                ("users_skipped", Json::Num(q.users_skipped as f64)),
                                ("error", Json::Str(q.error.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "faults_injected",
                Json::Arr(
                    injected
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("site", Json::Str(f.site.to_string())),
                                ("key", Json::Num(f.key as f64)),
                                ("attempt", Json::Num(f.attempt as f64)),
                                ("kind", Json::Str(f.kind.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(report, doc.dump_pretty())?;
        eprintln!("wrote {report}");
    }
    Ok(())
}

/// `bench`: the tracked perf baseline. Measures (a) Sec. VII suite
/// throughput through the batched engine and — unless `--skip-reference` —
/// the seed per-user path, verifying bit-identical results and recording
/// the speedup; (b) offline-DP solve times over a (D, τ) grid, plus the
/// joint multi-contract DP over a (D, terms) grid; (c) per-policy decide
/// latency and the flat hot-path kernel timings (`kernels`: WindowScan,
/// ledger billing, menu sweep); (d) optionally the fleet-scale streaming
/// grid (`--fleet-scale`); (e) the shared-portfolio broker pipeline
/// (aggregate fold + settlement) at 10^3..10^5 users. Writes everything to
/// `--out` (default `BENCH.json`) so every future PR has a trajectory to
/// beat.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use cloudreserve::sim::engine::{run_fleet_flat, FleetPolicy};
    use cloudreserve::sim::fleet::{run_fleet_reference, suite_specs};
    use cloudreserve::trace::FlatPopulation;
    use cloudreserve::util::bench::{fmt_ns, Bencher};
    use cloudreserve::util::json::Json;
    use cloudreserve::util::rng::Rng;
    use std::time::Instant;

    let quick = args.has("quick");
    let users = args.usize_or("users", cloudreserve::trace::NUM_USERS);
    let default_slots = if quick {
        3 * cloudreserve::trace::SLOTS_PER_DAY
    } else {
        cloudreserve::trace::TRACE_SLOTS
    };
    let slots = args.usize_or("slots", default_slots);
    let seed = args.u64_or("seed", 2013);
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    let out = args.str_or("out", "BENCH.json");
    let skip_reference = args.has("skip-reference");
    let policy_seed = args.u64_or("policy-seed", 1);

    eprintln!("bench: generating {users} users x {slots} slots (seed {seed})...");
    let pop = generate(&SynthConfig { users, slots, seed, ..Default::default() });
    let flat = FlatPopulation::from(&pop);
    let market = Market::single(ec2_small_compressed());
    let user_slots = flat.total_slots() as f64;
    let specs = suite_specs(policy_seed);

    // (a) suite throughput: batched engine, then the seed reference path.
    eprintln!("bench: engine suite ({threads} threads)...");
    let mut engine_rows = Vec::new();
    let mut engine_results = Vec::new();
    let mut engine_total_s = 0.0f64;
    for spec in &specs {
        let t0 = Instant::now();
        let res = run_fleet_flat(&flat, &market, spec, threads);
        let dt = t0.elapsed().as_secs_f64();
        engine_total_s += dt;
        println!(
            "engine    {:<28} {:>9.3}s {:>10.2} M user-slots/s",
            res.policy,
            dt,
            user_slots / dt / 1e6
        );
        engine_rows.push(Json::obj(vec![
            ("policy", Json::Str(res.policy.clone())),
            ("wall_s", Json::Num(dt)),
            ("user_slots_per_s", Json::Num(user_slots / dt)),
        ]));
        engine_results.push(res);
    }
    let engine_tput = user_slots * specs.len() as f64 / engine_total_s;

    let (reference_json, speedup_json, parity) = if skip_reference {
        (Json::Null, Json::Null, "skipped")
    } else {
        eprintln!("bench: reference (seed) suite...");
        let mut ref_rows = Vec::new();
        let mut ref_total_s = 0.0f64;
        let mut identical = true;
        for (spec, engine_res) in specs.iter().zip(&engine_results) {
            let t0 = Instant::now();
            let res = run_fleet_reference(&pop, &market, spec, threads);
            let dt = t0.elapsed().as_secs_f64();
            ref_total_s += dt;
            println!(
                "reference {:<28} {:>9.3}s {:>10.2} M user-slots/s",
                res.policy,
                dt,
                user_slots / dt / 1e6
            );
            identical &= res.per_user.len() == engine_res.per_user.len()
                && res.per_user.iter().zip(&engine_res.per_user).all(|(a, b)| {
                    a.user_id == b.user_id
                        && a.normalized_cost.to_bits() == b.normalized_cost.to_bits()
                        && a.absolute_cost.to_bits() == b.absolute_cost.to_bits()
                        && a.reservations == b.reservations
                });
            ref_rows.push(Json::obj(vec![
                ("policy", Json::Str(res.policy.clone())),
                ("wall_s", Json::Num(dt)),
                ("user_slots_per_s", Json::Num(user_slots / dt)),
            ]));
        }
        anyhow::ensure!(
            identical,
            "batched engine results diverge from the reference path — refusing to record the baseline"
        );
        let ref_tput = user_slots * specs.len() as f64 / ref_total_s;
        println!(
            "suite: engine {:.2} M user-slots/s vs reference {:.2} M -> {:.2}x speedup (results bit-identical)",
            engine_tput / 1e6,
            ref_tput / 1e6,
            engine_tput / ref_tput
        );
        (
            Json::obj(vec![
                ("total_wall_s", Json::Num(ref_total_s)),
                ("user_slots_per_s", Json::Num(ref_tput)),
                ("per_policy", Json::Arr(ref_rows)),
            ]),
            Json::Num(engine_tput / ref_tput),
            "bit-identical",
        )
    };

    // (b) offline-DP solve times across the (D, tau) envelope.
    eprintln!("bench: offline DP grid...");
    let dp_cases: &[(u32, usize, usize)] = if quick {
        &[(2, 5, 120), (3, 5, 120), (2, 7, 120)]
    } else {
        &[(2, 5, 120), (3, 5, 120), (2, 7, 120), (3, 6, 120), (4, 6, 100), (3, 9, 100)]
    };
    let mut dp_rows = Vec::new();
    for &(d_max, tau, t_len) in dp_cases {
        let mut rng = Rng::new(seed ^ ((d_max as u64) << 8) ^ tau as u64);
        let demands: Vec<u32> = (0..t_len).map(|_| rng.below(d_max as u64 + 1) as u32).collect();
        let dp_pricing = Pricing::normalized(0.15, 0.45, tau);
        let t0 = Instant::now();
        let sol = cloudreserve::algos::offline::optimal(&demands, &dp_pricing);
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "dp        D={d_max} tau={tau} T={t_len}{:<12} {:>9.2} ms  (cost {:.4}, {} reservations)",
            "",
            wall_ms,
            sol.cost,
            sol.reservations
        );
        dp_rows.push(Json::obj(vec![
            ("d_max", Json::Num(d_max as f64)),
            ("tau", Json::Num(tau as f64)),
            ("slots", Json::Num(t_len as f64)),
            ("wall_ms", Json::Num(wall_ms)),
            ("cost", Json::Num(sol.cost)),
            ("reservations", Json::Num(sol.reservations as f64)),
        ]));
    }

    // (b') joint multi-contract DP solve times (the scenario comparator).
    eprintln!("bench: joint offline DP grid...");
    let joint_cases: &[(u32, &[usize], usize)] = if quick {
        &[(1, &[4, 12], 120), (2, &[4, 8], 120)]
    } else {
        &[(1, &[4, 12], 120), (2, &[4, 8], 120), (1, &[5, 15], 120), (3, &[3, 6], 100)]
    };
    let mut joint_rows = Vec::new();
    for &(d_max, terms, t_len) in joint_cases {
        let mut rng = Rng::new(seed ^ ((d_max as u64) << 12) ^ terms.len() as u64);
        let demands: Vec<u32> = (0..t_len).map(|_| rng.below(d_max as u64 + 1) as u32).collect();
        let market = Market::new(
            0.1,
            terms
                .iter()
                .map(|&tau| cloudreserve::pricing::Contract {
                    upfront: 0.02 * tau as f64,
                    rate: 0.04,
                    term: tau,
                })
                .collect(),
        );
        assert!(
            cloudreserve::algos::offline::dp_joint_tractable(d_max, terms),
            "bench joint case must be tractable"
        );
        let t0 = Instant::now();
        let sol = cloudreserve::algos::offline::optimal_market_joint(&demands, &market)
            .expect("tractable joint case");
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "dp-joint  D={d_max} terms={terms:?} T={t_len} {:>9.2} ms  (cost {:.4}, {} reservations)",
            wall_ms, sol.cost, sol.reservations
        );
        joint_rows.push(Json::obj(vec![
            ("d_max", Json::Num(d_max as f64)),
            (
                "terms",
                Json::Arr(terms.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("slots", Json::Num(t_len as f64)),
            ("wall_ms", Json::Num(wall_ms)),
            ("cost", Json::Num(sol.cost)),
            ("reservations", Json::Num(sol.reservations as f64)),
        ]));
    }

    // (c) per-policy decide latency on the engine's monomorphic dispatch.
    eprintln!("bench: per-policy decide latency...");
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let micro_slots = if quick { 5_000usize } else { 20_000 };
    let mut rng = Rng::new(42);
    let curve: Vec<u32> = (0..micro_slots)
        .map(|t| {
            let base = 4.0 + 3.0 * ((t as f64) / 720.0).sin();
            (base * (1.0 + 0.3 * rng.normal()).max(0.0)).round() as u32
        })
        .collect();
    let mut decide_rows = Vec::new();
    for spec in &specs {
        let r = bencher.run(&format!("decide/{}", spec.name()), || {
            let mut p = FleetPolicy::build(spec, &market, 1);
            let mut acc = 0u32;
            for &d in &curve {
                let dec = p.decide(d, &[]);
                acc = acc.wrapping_add(dec.total_reserved() ^ dec.on_demand);
            }
            acc
        });
        let ns_per_decide = r.median_ns() / micro_slots as f64;
        println!(
            "decide    {:<28} {:>8.1} ns/decide  (trace {})",
            spec.name(),
            ns_per_decide,
            fmt_ns(r.median_ns())
        );
        decide_rows.push(Json::obj(vec![
            ("policy", Json::Str(spec.name())),
            ("ns_per_decide", Json::Num(ns_per_decide)),
            ("detail", r.to_json()),
        ]));
    }

    // (c'') learned-policy decide latency (UCB threshold selection and the
    // forecast-driven adaptive window), tracked under a separate `learned`
    // section so `decide_ns` stays the 5-policy series CI pins.
    eprintln!("bench: learned-policy decide latency...");
    let mut learned_rows = Vec::new();
    for spec in cloudreserve::sim::fleet::learned_specs(policy_seed) {
        let r = bencher.run(&format!("decide/{}", spec.name()), || {
            let mut p = FleetPolicy::build(&spec, &market, 1);
            let mut acc = 0u32;
            for &d in &curve {
                let dec = p.decide(d, &[]);
                acc = acc.wrapping_add(dec.total_reserved() ^ dec.on_demand);
            }
            acc
        });
        let ns_per_decide = r.median_ns() / micro_slots as f64;
        println!(
            "learned   {:<28} {:>8.1} ns/decide  (trace {})",
            spec.name(),
            ns_per_decide,
            fmt_ns(r.median_ns())
        );
        learned_rows.push(Json::obj(vec![
            ("policy", Json::Str(spec.name())),
            ("ns_per_decide", Json::Num(ns_per_decide)),
            ("detail", r.to_json()),
        ]));
    }
    let learned_json = Json::obj(vec![
        ("slots", Json::Num(micro_slots as f64)),
        ("decide_ns", Json::Arr(learned_rows)),
    ]);

    // (c') flat hot-path kernels (PERF.md §Flat kernels): the dense
    // rotating-base WindowScan, coalesced-run ledger billing, and the menu
    // policy's per-slot k-contract sweep. The end-to-end suite numbers
    // would bury a data-structure regression under trace generation and
    // dispatch; these watch the rewritten structures directly and feed the
    // CI perf gate's `kernels` checks.
    eprintln!("bench: hot-path kernels...");
    let kernel_slots = if quick { 5_000usize } else { 50_000 };
    let ktau = 300usize;
    let mut krng = Rng::new(7);
    let kdemands: Vec<u32> = (0..kernel_slots).map(|_| krng.below(6) as u32).collect();

    let scan_res = bencher.run("kernels/window_scan", || {
        let mut scan = cloudreserve::algos::window::WindowScan::new();
        let mut acc = 0u32;
        for (t, &d) in kdemands.iter().enumerate() {
            scan.expire_before((t + 1).saturating_sub(ktau));
            scan.insert(t, d, 0);
            // drain violations in bursts so reserve() rotates the base
            while scan.violations() > 48 {
                scan.reserve();
            }
            acc = acc.wrapping_add(scan.violations());
        }
        acc
    });
    let scan_ops_per_s = scan_res.throughput(kernel_slots as f64);
    println!(
        "kernel    window_scan                  {:>8.1} ns/slot  ({:.2} M slots/s)",
        scan_res.median_ns() / kernel_slots as f64,
        scan_ops_per_s / 1e6
    );

    let lpricing = Pricing::normalized(0.08, 0.4, 200);
    let ledger_res = bencher.run("kernels/ledger_bill_slot", || {
        // the All-reserved billing pattern: always feasible, always active
        let mut l = cloudreserve::ledger::Ledger::single(lpricing);
        for &d in &kdemands {
            let active = l.active_now();
            l.bill_slot(d, d.saturating_sub(active), 0).unwrap();
        }
        l.report().total
    });
    println!(
        "kernel    ledger_bill_slot             {:>8.1} ns/slot  ({:.2} M slots/s)",
        ledger_res.median_ns() / kernel_slots as f64,
        ledger_res.throughput(kernel_slots as f64) / 1e6
    );

    let kmenu = Market::new(
        0.01,
        vec![
            cloudreserve::pricing::Contract { upfront: 1.0, rate: 0.004, term: 600 },
            cloudreserve::pricing::Contract { upfront: 1.5, rate: 0.002, term: 1800 },
        ],
    );
    let kk = kmenu.len();
    let market_res = bencher.run("kernels/market_sweep", || {
        let mut p = cloudreserve::algos::market::MarketDeterministic::new(kmenu.clone());
        let mut acc = 0u32;
        for &d in &kdemands {
            let dec = p.decide(d, &[]);
            acc = acc.wrapping_add(dec.total_reserved() ^ dec.on_demand);
        }
        acc
    });
    println!(
        "kernel    market_sweep (k={kk})          {:>8.1} ns/contract-slot",
        market_res.median_ns() / (kernel_slots * kk) as f64
    );
    let kernels_json = Json::obj(vec![
        ("slots", Json::Num(kernel_slots as f64)),
        (
            "window_scan",
            Json::obj(vec![
                ("ops_per_s", Json::Num(scan_ops_per_s)),
                ("ns_per_slot", Json::Num(scan_res.median_ns() / kernel_slots as f64)),
                ("detail", scan_res.to_json()),
            ]),
        ),
        (
            "ledger_bill_slot",
            Json::obj(vec![
                ("ns_per_slot", Json::Num(ledger_res.median_ns() / kernel_slots as f64)),
                ("slots_per_s", Json::Num(ledger_res.throughput(kernel_slots as f64))),
                ("detail", ledger_res.to_json()),
            ]),
        ),
        (
            "market_sweep",
            Json::obj(vec![
                ("contracts", Json::Num(kk as f64)),
                (
                    "ns_per_contract_slot",
                    Json::Num(market_res.median_ns() / (kernel_slots * kk) as f64),
                ),
                ("slots_per_s", Json::Num(market_res.throughput(kernel_slots as f64))),
                ("detail", market_res.to_json()),
            ]),
        ),
    ]);

    // (d) fleet-scale grid: stream-generate a chunked trace to disk, then
    // replay it through the bounded-memory chunked path (never holding more
    // than one chunk of users resident), recording wall time, throughput,
    // and the process peak-RSS high-water mark per cell. Cells run in
    // ascending user order so `VmHWM` attributes to the largest completed
    // cell: a flat mark from 10^5 to 10^6 users is the O(chunk) evidence.
    let fleet_json = if args.has("fleet-scale") {
        use cloudreserve::sim::engine::for_each_user_chunked;
        use cloudreserve::sim::fleet::FleetAggregate;
        use cloudreserve::trace::io::ChunkedPopulation;
        use cloudreserve::trace::synth::generate_chunked;
        use cloudreserve::util::mem::peak_rss_kb;

        let chunk_users = args.usize_or("chunk-users", 4096) as u32;
        anyhow::ensure!(chunk_users > 0, "--chunk-users must be positive");
        let fleet_slots = 3 * cloudreserve::trace::SLOTS_PER_DAY; // 4,320 minute-slots
        let full_grid: &[usize] = if quick {
            &[1_000, 10_000]
        } else {
            &[1_000, 10_000, 100_000, 1_000_000]
        };
        let max_users = args.usize_or("fleet-max-users", usize::MAX);
        let grid: Vec<usize> = full_grid.iter().copied().filter(|&u| u <= max_users).collect();

        let single = Market::single(ec2_small_compressed());
        let menu2 = Market::new(
            0.01,
            vec![
                cloudreserve::pricing::Contract { upfront: 1.0, rate: 0.004, term: 600 },
                cloudreserve::pricing::Contract { upfront: 1.5, rate: 0.002, term: 1800 },
            ],
        );
        let markets: [(&str, &Market); 2] = [("single", &single), ("menu2", &menu2)];
        let spec = cloudreserve::sim::fleet::PolicySpec::Deterministic { z: None, window: 0 };

        let tmp_dir = std::env::temp_dir();
        let mut fleet_rows = Vec::new();
        for &n in &grid {
            eprintln!(
                "bench: fleet-scale {n} users x {fleet_slots} slots (chunks of {chunk_users})..."
            );
            let path = tmp_dir.join(format!("cloudreserve_fleet_{n}_{seed}.bin"));
            // Drop guard: the scratch trace is removed even when generation
            // or a replay cell below errors out of this function.
            let _scratch = TempFile(path.clone());
            let cfg = SynthConfig { users: n, slots: fleet_slots, seed, ..Default::default() };
            let t0 = Instant::now();
            generate_chunked(&cfg, &path, chunk_users)?;
            let gen_wall_s = t0.elapsed().as_secs_f64();
            let file_bytes = std::fs::metadata(&path)?.len();

            for (mname, m) in markets {
                let mut chunked = ChunkedPopulation::open(&path)?;
                let mut agg = FleetAggregate::new();
                let t0 = Instant::now();
                for_each_user_chunked(&mut chunked, m, &spec, threads, |u| agg.merge(u))?;
                let replay_wall_s = t0.elapsed().as_secs_f64();
                let cell_user_slots = chunked.total_slots() as f64;
                let peak = peak_rss_kb();
                println!(
                    "fleet     {n:>9} users  {mname:<7} {:>9.3}s gen {:>9.3}s replay {:>10.2} M user-slots/s  peak-RSS {}",
                    gen_wall_s,
                    replay_wall_s,
                    cell_user_slots / replay_wall_s / 1e6,
                    peak.map(|kb| format!("{:.0} MiB", kb as f64 / 1024.0))
                        .unwrap_or_else(|| "n/a".into()),
                );
                fleet_rows.push(Json::obj(vec![
                    ("users", Json::Num(n as f64)),
                    ("slots", Json::Num(fleet_slots as f64)),
                    ("chunk_users", Json::Num(chunk_users as f64)),
                    ("market", Json::Str(mname.to_string())),
                    ("gen_wall_s", Json::Num(gen_wall_s)),
                    ("replay_wall_s", Json::Num(replay_wall_s)),
                    ("user_slots_per_s", Json::Num(cell_user_slots / replay_wall_s)),
                    ("peak_rss_kb", peak.map(|kb| Json::Num(kb as f64)).unwrap_or(Json::Null)),
                    ("file_bytes", Json::Num(file_bytes as f64)),
                    ("mean_normalized", Json::Num(agg.mean_normalized())),
                    ("total_reservations", Json::Num(agg.total_reservations() as f64)),
                ]));
            }
        }
        Json::Arr(fleet_rows)
    } else {
        Json::Null
    };

    // (e) broker aggregate pipeline: stream-generate a chunked trace, then
    // run the shared-portfolio broker over it end to end — chunked
    // aggregate fold + standalone baseline sweep + portfolio replay +
    // proportional settlement — recording aggregate user-slots/s per fleet
    // size. Every cell re-checks the settlement conservation invariant
    // (Σ bills bit-equals the portfolio total), so a perf run can never
    // quietly record a broker that leaks cost.
    eprintln!("bench: broker aggregate pipeline...");
    let broker_json = {
        use cloudreserve::broker::{BrokerRun, ProportionalUsage, STANDALONE_SPEC};
        use cloudreserve::trace::io::ChunkedPopulation;
        use cloudreserve::trace::synth::generate_chunked;

        let chunk_users = args.usize_or("chunk-users", 4096) as u32;
        anyhow::ensure!(chunk_users > 0, "--chunk-users must be positive");
        let broker_slots = 3 * cloudreserve::trace::SLOTS_PER_DAY;
        let grid: &[usize] = if quick { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
        let broker_market = Market::single(ec2_small_compressed());
        let settlement = ProportionalUsage;
        let tmp_dir = std::env::temp_dir();
        let hex = |v: f64| Json::Str(format!("{:#018x}", v.to_bits()));
        let mut rows = Vec::new();
        for &n in grid {
            eprintln!("bench: broker {n} users x {broker_slots} slots (chunks of {chunk_users})...");
            let path = tmp_dir.join(format!("cloudreserve_broker_{n}_{seed}.bin"));
            let _scratch = TempFile(path.clone());
            let cfg = SynthConfig { users: n, slots: broker_slots, seed, ..Default::default() };
            let t0 = Instant::now();
            generate_chunked(&cfg, &path, chunk_users)?;
            let gen_wall_s = t0.elapsed().as_secs_f64();

            let mut chunked = ChunkedPopulation::open(&path)?;
            let cell_user_slots = chunked.total_slots() as f64;
            let t0 = Instant::now();
            let outcome = BrokerRun {
                market: &broker_market,
                policy: STANDALONE_SPEC,
                settlement: &settlement,
                threads,
                offline: false,
            }
            .run_chunked(&mut chunked)?;
            let pipeline_wall_s = t0.elapsed().as_secs_f64();
            let bills_total: f64 = outcome.bills.iter().map(|b| b.amount).sum();
            let bills_conserve =
                bills_total.to_bits() == outcome.aggregate.report.total.to_bits();
            anyhow::ensure!(
                bills_conserve,
                "broker bench: settlement failed to conserve cost at {n} users"
            );
            println!(
                "broker    {n:>9} users  {:>9.3}s gen {:>9.3}s pipeline {:>10.2} M user-slots/s  gain {:.2}",
                gen_wall_s,
                pipeline_wall_s,
                cell_user_slots / pipeline_wall_s / 1e6,
                outcome.multiplexing_gain,
            );
            rows.push(Json::obj(vec![
                ("users", Json::Num(n as f64)),
                ("slots", Json::Num(broker_slots as f64)),
                ("chunk_users", Json::Num(chunk_users as f64)),
                ("policy", Json::Str(outcome.policy.clone())),
                ("settlement", Json::Str(outcome.settlement.clone())),
                ("gen_wall_s", Json::Num(gen_wall_s)),
                ("pipeline_wall_s", Json::Num(pipeline_wall_s)),
                ("user_slots_per_s", Json::Num(cell_user_slots / pipeline_wall_s)),
                ("aggregate_cost", Json::Num(outcome.aggregate.report.total)),
                ("aggregate_cost_bits", hex(outcome.aggregate.report.total)),
                ("standalone_total", Json::Num(outcome.standalone_total)),
                ("multiplexing_gain", Json::Num(outcome.multiplexing_gain)),
                ("bills_conserve", Json::Bool(bills_conserve)),
            ]));
        }
        Json::Arr(rows)
    };

    let created_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let doc = Json::obj(vec![
        ("schema", Json::Str("cloudreserve-bench/v1".into())),
        ("created_unix", Json::Num(created_unix)),
        (
            "config",
            Json::obj(vec![
                ("users", Json::Num(users as f64)),
                ("slots", Json::Num(slots as f64)),
                ("seed", Json::Num(seed as f64)),
                ("policy_seed", Json::Num(policy_seed as f64)),
                ("threads", Json::Num(threads as f64)),
                ("quick", Json::Bool(quick)),
            ]),
        ),
        (
            "suite",
            Json::obj(vec![
                ("user_slots_per_policy", Json::Num(user_slots)),
                (
                    "engine",
                    Json::obj(vec![
                        ("total_wall_s", Json::Num(engine_total_s)),
                        ("user_slots_per_s", Json::Num(engine_tput)),
                        ("per_policy", Json::Arr(engine_rows)),
                    ]),
                ),
                ("reference", reference_json),
                ("speedup_vs_reference", speedup_json),
                ("parity", Json::Str(parity.to_string())),
            ]),
        ),
        ("offline_dp", Json::Arr(dp_rows)),
        ("offline_dp_joint", Json::Arr(joint_rows)),
        ("decide_ns", Json::Arr(decide_rows)),
        ("learned", learned_json),
        ("kernels", kernels_json),
        ("fleet_scale", fleet_json),
        ("broker", broker_json),
    ]);
    std::fs::write(&out, doc.dump_pretty())?;
    println!("wrote {out}");
    Ok(())
}

/// Load and parse the `--spec FILE` JSON document into either scenario
/// mode (`scenario` and `broker` share this, so a broker-mode spec handed
/// to `scenario` still runs correctly, and vice versa gets a clear error).
fn load_scenario(args: &Args) -> anyhow::Result<ParsedScenario> {
    let path = args
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("requires --spec FILE (a JSON scenario spec)"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading spec {path}: {e}"))?;
    let doc = cloudreserve::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing spec {path}: {e}"))?;
    scenario::parse_scenario(&doc)
}

fn threads_from(args: &Args) -> usize {
    args.usize_or("threads", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// `scenario`: load a declarative JSON spec (market menu, trace source,
/// policy set — see `sim::scenario` for the schema), run it through the
/// batched engine, print the normalized-cost report, and optionally write
/// the machine-readable `cloudreserve-scenario/v2` JSON. Broker-mode specs
/// are dispatched to the broker runner.
fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    match load_scenario(args)? {
        ParsedScenario::Policies(spec) => {
            if let Some(d) = &spec.description {
                eprintln!("{}: {d}", spec.name);
            }
            let report = scenario::run(&spec, threads_from(args))?;
            print!("{}", report.render());
            if let Some(out) = args.get("json-out") {
                std::fs::write(out, report.to_json().dump_pretty())?;
                eprintln!("wrote {out}");
            }
            Ok(())
        }
        ParsedScenario::Broker(spec) => run_broker_spec(args, spec),
    }
}

/// `broker`: run a `"mode": "broker"` spec — aggregate the fleet's demand,
/// buy one shared reservation portfolio with the configured online policy,
/// settle the realized cost into per-user bills, and report the
/// multiplexing gain over the isolated-users baseline
/// (`cloudreserve-broker/v1` JSON via `--json-out`).
fn cmd_broker(args: &Args) -> anyhow::Result<()> {
    match load_scenario(args)? {
        ParsedScenario::Broker(spec) => run_broker_spec(args, spec),
        ParsedScenario::Policies(spec) => anyhow::bail!(
            "spec '{}' is a policies-mode scenario; `broker` needs `\"mode\": \"broker\"` \
             (run this one with `scenario --spec ...`)",
            spec.name
        ),
    }
}

fn run_broker_spec(
    args: &Args,
    mut spec: cloudreserve::sim::scenario::BrokerScenarioSpec,
) -> anyhow::Result<()> {
    if let Some(s) = args.get("settlement") {
        // Validate the override up front so a typo fails with the name
        // list instead of after the (possibly long) aggregate run.
        cloudreserve::broker::settlement_from_name(s)?;
        spec.settlement = s.to_string();
    }
    if let Some(d) = &spec.description {
        eprintln!("{}: {d}", spec.name);
    }
    let report = scenario::run_broker(&spec, threads_from(args))?;
    print!("{}", report.render());
    if let Some(out) = args.get("json-out") {
        std::fs::write(out, report.to_json().dump_pretty())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_offline(args: &Args) -> anyhow::Result<()> {
    let tau = args.usize_or("tau", 3);
    let p = args.f64_or("p", 0.1);
    let alpha = args.f64_or("alpha", 0.5);
    let pricing = Pricing::normalized(p, alpha, tau);
    let demands: Vec<u32> = args
        .positionals
        .iter()
        .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad demand '{s}'")))
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!demands.is_empty(), "give a demand sequence, e.g. `offline --tau 3 1 2 0 3`");
    let sol = offline::optimal(&demands, &pricing);
    println!(
        "offline OPT: cost={:.4} reservations={} (lower bound {:.4})",
        sol.cost,
        sol.reservations,
        offline::lower_bound(&demands, &pricing)
    );
    let mut det = cloudreserve::algos::deterministic::Deterministic::online(pricing);
    let rep = cloudreserve::sim::run_policy(&mut det, &demands, pricing)?;
    println!(
        "A_beta online: cost={:.4} reservations={} -> ratio {:.4} (bound {:.4})",
        rep.total,
        rep.reservations,
        rep.total / sol.cost.max(1e-12),
        pricing.deterministic_ratio()
    );
    Ok(())
}
