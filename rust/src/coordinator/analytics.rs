//! The analytics engine: periodically positions the whole fleet against
//! the `A_z` threshold spectrum by running the AOT-compiled L1/L2 window
//! scan (`fleet_step` artifact) over every user's recent window.
//!
//! This is the PJRT hot path: Rust gathers the snapshot, the artifact does
//! the batched compute, Rust interprets the posture. Operators use it to
//! see, per user, how close current on-demand spending is to the
//! break-even point and which aggressiveness levels would reserve *now* —
//! the fleet-wide "to reserve or not to reserve" dashboard.

use anyhow::Result;

use super::broker::{Broker, SnapshotRow};
use crate::pricing::Pricing;
use crate::runtime::Runtime;
use crate::util::stats::linspace;

/// Per-user posture from one analytics tick.
#[derive(Debug, Clone)]
pub struct UserPosture {
    pub user_id: u32,
    /// Violation count `V_u` over the analytics window.
    pub violations: f32,
    /// On-demand spend `p·V_u` as a fraction of the break-even point β.
    pub breakeven_frac: f64,
    /// Fraction of the z-grid that would reserve now (1.0 = even the most
    /// conservative `A_β` reserves; 0.0 = not even `A_0`).
    pub reserve_pressure: f64,
}

/// Fleet-wide posture.
#[derive(Debug, Clone)]
pub struct FleetPosture {
    pub users: Vec<UserPosture>,
    /// The threshold grid the posture was evaluated against.
    pub z_grid: Vec<f32>,
}

impl FleetPosture {
    /// Users whose spend already crossed break-even (A_β would reserve).
    pub fn over_breakeven(&self) -> Vec<u32> {
        self.users.iter().filter(|u| u.breakeven_frac > 1.0).map(|u| u.user_id).collect()
    }

    /// Mean reserve pressure across the fleet.
    pub fn mean_pressure(&self) -> f64 {
        if self.users.is_empty() {
            return 0.0;
        }
        self.users.iter().map(|u| u.reserve_pressure).sum::<f64>() / self.users.len() as f64
    }
}

/// Analytics engine configuration + artifact runtime.
pub struct AnalyticsEngine {
    runtime: Runtime,
    pricing: Pricing,
    z_grid: Vec<f32>,
    /// Max users per artifact execution (the artifact's batch is padded to
    /// this; larger fleets are chunked).
    batch: usize,
}

impl AnalyticsEngine {
    /// `grid_len` thresholds spanning `[0, β]`.
    pub fn new(
        runtime: Runtime,
        pricing: Pricing,
        grid_len: usize,
        batch: usize,
    ) -> AnalyticsEngine {
        let beta = pricing.beta().min(1e6);
        let z_grid: Vec<f32> =
            linspace(0.0, beta, grid_len.max(2)).iter().map(|&z| z as f32).collect();
        AnalyticsEngine { runtime, pricing, z_grid, batch }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn z_grid(&self) -> &[f32] {
        &self.z_grid
    }

    /// Evaluate a snapshot (already gathered) through the artifact.
    pub fn evaluate(&self, rows: &[SnapshotRow]) -> Result<FleetPosture> {
        let mut users = Vec::with_capacity(rows.len());
        let beta = self.pricing.beta();
        for chunk in rows.chunks(self.batch.max(1)) {
            let window = chunk.iter().map(|r| r.demand.len()).max().unwrap_or(0);
            let mut demand = vec![0.0f32; chunk.len() * window];
            let mut coverage = vec![0.0f32; chunk.len() * window];
            for (i, row) in chunk.iter().enumerate() {
                demand[i * window..i * window + row.demand.len()].copy_from_slice(&row.demand);
                coverage[i * window..i * window + row.coverage.len()]
                    .copy_from_slice(&row.coverage);
            }
            let out = self.runtime.fleet_step(
                self.pricing.p,
                &demand,
                &coverage,
                chunk.len(),
                window,
                &self.z_grid,
            )?;
            for (i, row) in chunk.iter().enumerate() {
                let v = out.counts[i];
                let spend = self.pricing.p * v as f64;
                let fired = (0..self.z_grid.len()).filter(|&k| out.decided(i, k)).count();
                users.push(UserPosture {
                    user_id: row.user_id,
                    violations: v,
                    breakeven_frac: if beta.is_finite() { spend / beta } else { 0.0 },
                    reserve_pressure: fired as f64 / self.z_grid.len() as f64,
                });
            }
        }
        Ok(FleetPosture { users, z_grid: self.z_grid.clone() })
    }

    /// Snapshot the broker and evaluate in one call (one "tick").
    pub fn tick(&self, broker: &Broker) -> Result<FleetPosture> {
        let rows = broker.snapshot()?;
        let posture = self.evaluate(&rows);
        broker
            .metrics()
            .analytics_ticks
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        posture
    }
}

// PJRT-backed tests live in rust/tests/runtime_integration.rs; pure logic
// (posture math) is tested there against the small artifact variant.
