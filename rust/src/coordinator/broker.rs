//! The brokerage service: sharded worker threads running per-user policy
//! state machines with billing, fed by a streaming demand API.
//!
//! Every user here is **isolated**: each session owns its own policy and
//! its own [`Ledger`], so the fleet's cost is exactly the sum of per-user
//! standalone costs. That makes this the "no multiplexing" baseline for
//! the shared-portfolio broker in [`crate::broker`], which instead folds
//! the fleet into one aggregate demand curve, buys a single shared
//! reservation portfolio, and settles the (typically smaller) realized
//! cost back to users bit-exactly.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::metrics::Metrics;
use crate::algos::{baselines, deterministic::Deterministic, randomized::Randomized, Policy};
use crate::forecast::{ArForecaster, Forecaster};
use crate::ledger::{CostReport, Ledger};
use crate::pricing::Pricing;

/// One demand observation for one user at one slot. Slots per user must be
/// non-decreasing; gaps are filled with zero demand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DemandEvent {
    pub user_id: u32,
    pub slot: u32,
    pub demand: u32,
}

/// Which policy each user session runs.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    AllOnDemand,
    AllReserved,
    Separate,
    /// `A_z`; `z = None` ⇒ `z = β` (Algorithm 1).
    Deterministic { z: Option<f64> },
    /// Algorithm 2; per-user threshold draw seeded from `seed ^ user_id`.
    Randomized { seed: u64 },
    /// Algorithm 3 driven by a streaming AR(k) forecaster (Sec. VI with
    /// *real* predictions instead of an oracle).
    DeterministicForecast { window: usize, ar_order: usize },
}

impl PolicyKind {
    fn build(&self, pricing: Pricing, user_id: u32) -> UserSession {
        let (policy, forecaster): (Box<dyn Policy>, Option<ArForecaster>) = match *self {
            PolicyKind::AllOnDemand => (Box::new(baselines::AllOnDemand::new()), None),
            PolicyKind::AllReserved => (Box::new(baselines::AllReserved::new(pricing)), None),
            PolicyKind::Separate => (Box::new(baselines::Separate::new(pricing)), None),
            PolicyKind::Deterministic { z } => {
                let z = z.unwrap_or_else(|| pricing.beta());
                (Box::new(Deterministic::with_threshold(pricing, z)), None)
            }
            PolicyKind::Randomized { seed } => (
                Box::new(Randomized::online(pricing, seed ^ ((user_id as u64) << 17))),
                None,
            ),
            PolicyKind::DeterministicForecast { window, ar_order } => (
                Box::new(Deterministic::with_window(pricing, window)),
                Some(ArForecaster::new(ar_order, 64, (ar_order + 2).max(256))),
            ),
        };
        UserSession {
            policy,
            forecaster,
            ledger: Ledger::single(pricing),
            next_slot: 0,
            window: WindowRing::new(64),
            future_buf: Vec::new(),
            f64_buf: Vec::new(),
            scratch: Vec::new(),
            forecast_at: None,
        }
    }
}

/// Rolling (demand, coverage) window per user for the analytics snapshot.
#[derive(Debug, Clone)]
pub(crate) struct WindowRing {
    cap: usize,
    demand: Vec<f32>,
    coverage: Vec<f32>,
    head: usize,
    len: usize,
}

impl WindowRing {
    pub(crate) fn new(cap: usize) -> WindowRing {
        WindowRing { cap, demand: vec![0.0; cap], coverage: vec![0.0; cap], head: 0, len: 0 }
    }

    fn push(&mut self, demand: f32, coverage: f32) {
        self.demand[self.head] = demand;
        self.coverage[self.head] = coverage;
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    /// Copy the window (oldest→newest, zero-padded at the front) into
    /// caller buffers of length `cap`.
    fn snapshot_into(&self, demand: &mut [f32], coverage: &mut [f32]) {
        debug_assert_eq!(demand.len(), self.cap);
        let pad = self.cap - self.len;
        demand[..pad].fill(0.0);
        coverage[..pad].fill(0.0);
        for i in 0..self.len {
            let src = (self.head + self.cap - self.len + i) % self.cap;
            demand[pad + i] = self.demand[src];
            coverage[pad + i] = self.coverage[src];
        }
    }
}

/// Per-user state owned by a worker.
struct UserSession {
    policy: Box<dyn Policy>,
    forecaster: Option<ArForecaster>,
    ledger: Ledger,
    next_slot: u32,
    window: WindowRing,
    // reusable forecast buffers (no allocation on the event hot path —
    // PERF.md §Policy hot path)
    future_buf: Vec<u32>,
    f64_buf: Vec<f64>,
    scratch: Vec<f64>,
    /// Slot at which `future_buf` was computed; the forecast is refreshed
    /// every FORECAST_REFRESH slots and consumed as a shrinking suffix in
    /// between (§Perf L3-4) — the window policy tolerates short horizons.
    forecast_at: Option<u32>,
}

/// Slots between full AR forecast recomputations on the broker hot path.
const FORECAST_REFRESH: u32 = 16;

impl UserSession {
    fn step(&mut self, slot: u32, demand: u32, metrics: &Metrics) -> Result<()> {
        if slot < self.next_slot {
            bail!("slot {slot} arrived out of order (expected >= {})", self.next_slot);
        }
        // gap fill: zero-demand slots keep policy clocks consecutive
        while self.next_slot < slot {
            self.apply(0)?;
            metrics.gap_filled_slots.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics.record_event(0, 0, 0);
        }
        let t0 = Instant::now();
        let (reserve, on_demand) = self.apply(demand)?;
        metrics
            .decide_micros
            .fetch_add(t0.elapsed().as_micros() as u64, std::sync::atomic::Ordering::Relaxed);
        metrics.record_event(demand, reserve, on_demand);
        Ok(())
    }

    fn apply(&mut self, demand: u32) -> Result<(u32, u32)> {
        let t = self.next_slot;
        let mut offset = 0usize;
        match (&mut self.forecaster, self.policy.window()) {
            (Some(f), w) if w > 0 => {
                let stale = match self.forecast_at {
                    None => true,
                    Some(at) => t - at >= FORECAST_REFRESH.min(w as u32),
                };
                if stale {
                    f.predict_f64_into(w, &mut self.f64_buf, &mut self.scratch);
                    self.future_buf.clear();
                    self.future_buf
                        .extend(self.f64_buf.iter().map(|y| y.round().max(0.0) as u32));
                    self.forecast_at = Some(t);
                } else {
                    // consume the cached forecast as a shrinking suffix
                    offset = (t - self.forecast_at.unwrap()) as usize;
                }
                f.observe(demand);
            }
            (Some(f), _) => {
                f.observe(demand);
                self.future_buf.clear();
            }
            (None, _) => self.future_buf.clear(),
        }
        // Typed decision: broker policies are single-contract, so the
        // reservation total is the contract-0 count.
        let (reserve, on_demand) = {
            let dec =
                self.policy.decide(demand, &self.future_buf[offset.min(self.future_buf.len())..]);
            (dec.total_reserved(), dec.on_demand)
        };
        self.ledger
            .bill_slot(demand, reserve, on_demand)
            .map_err(|e| anyhow!("billing: {e}"))?;
        let covered = demand - on_demand;
        self.window.push(demand as f32, covered as f32);
        self.next_slot += 1;
        Ok((reserve, on_demand))
    }
}

/// A per-user analytics snapshot row.
#[derive(Debug, Clone)]
pub struct SnapshotRow {
    pub user_id: u32,
    pub demand: Vec<f32>,
    pub coverage: Vec<f32>,
}

enum Command {
    Demand(DemandEvent),
    /// Reply with every session's window snapshot.
    Snapshot(SyncSender<Vec<SnapshotRow>>),
    /// Reply with final per-user reports and stop.
    Finish(SyncSender<Vec<(u32, CostReport)>>),
}

/// Broker configuration.
#[derive(Debug, Clone)]
pub struct BrokerConfig {
    pub pricing: Pricing,
    pub shards: usize,
    /// Bounded per-shard queue (backpressure).
    pub queue_capacity: usize,
    /// Analytics window length (must not exceed the artifact's W).
    pub window: usize,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        BrokerConfig {
            pricing: crate::pricing::catalog::ec2_small_compressed(),
            shards: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_capacity: 4096,
            window: 64,
        }
    }
}

/// Final broker output.
#[derive(Debug)]
pub struct BrokerReport {
    /// (user_id, billing report), sorted by user id.
    pub per_user: Vec<(u32, CostReport)>,
}

impl BrokerReport {
    pub fn total_cost(&self) -> f64 {
        self.per_user.iter().map(|(_, r)| r.total).sum()
    }

    pub fn total_reservations(&self) -> u64 {
        self.per_user.iter().map(|(_, r)| r.reservations).sum()
    }
}

/// The running brokerage service.
pub struct Broker {
    txs: Vec<SyncSender<Command>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    shards: usize,
}

impl Broker {
    /// Start the broker: `shards` worker threads, all users running
    /// policies built from `kind`.
    pub fn start(cfg: BrokerConfig, kind: PolicyKind) -> Broker {
        let metrics = Arc::new(Metrics::new());
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = sync_channel::<Command>(cfg.queue_capacity);
            let kind = kind.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("broker-shard-{shard}"))
                .spawn(move || worker_loop(rx, cfg, kind, metrics))
                .expect("spawn worker");
            txs.push(tx);
            workers.push(handle);
        }
        Broker { txs, workers, metrics, shards: cfg.shards }
    }

    #[inline]
    fn shard_of(&self, user_id: u32) -> usize {
        // splitmix-style hash so consecutive user ids spread across shards
        let mut x = user_id as u64;
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        (x % self.shards as u64) as usize
    }

    /// Submit one demand event (blocks when the shard queue is full).
    pub fn submit(&self, ev: DemandEvent) -> Result<()> {
        self.txs[self.shard_of(ev.user_id)]
            .send(Command::Demand(ev))
            .map_err(|_| anyhow!("worker for user {} has shut down", ev.user_id))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Gather the analytics snapshot from every shard (blocks until all
    /// queued demand ahead of the snapshot marker is processed — giving a
    /// consistent-per-user cut).
    pub fn snapshot(&self) -> Result<Vec<SnapshotRow>> {
        let mut rows = Vec::new();
        let mut pending = Vec::new();
        for tx in &self.txs {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Command::Snapshot(rtx)).map_err(|_| anyhow!("worker shut down"))?;
            pending.push(rrx);
        }
        for rrx in pending {
            rows.extend(rrx.recv().map_err(|_| anyhow!("worker dropped snapshot"))?);
        }
        rows.sort_by_key(|r| r.user_id);
        Ok(rows)
    }

    /// Drain queues, stop workers, and return the billing reports.
    pub fn finish(self) -> Result<BrokerReport> {
        let mut pending = Vec::new();
        for tx in &self.txs {
            let (rtx, rrx) = sync_channel(1);
            tx.send(Command::Finish(rtx)).map_err(|_| anyhow!("worker shut down"))?;
            pending.push(rrx);
        }
        drop(self.txs);
        let mut per_user = Vec::new();
        for rrx in pending {
            per_user.extend(rrx.recv().map_err(|_| anyhow!("worker dropped report"))?);
        }
        for w in self.workers {
            w.join().map_err(|_| anyhow!("worker panicked"))?;
        }
        per_user.sort_by_key(|(uid, _)| *uid);
        Ok(BrokerReport { per_user })
    }
}

fn worker_loop(rx: Receiver<Command>, cfg: BrokerConfig, kind: PolicyKind, metrics: Arc<Metrics>) {
    let mut sessions: HashMap<u32, UserSession> = HashMap::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Demand(ev) => {
                let session = sessions.entry(ev.user_id).or_insert_with(|| {
                    let mut s = kind.build(cfg.pricing, ev.user_id);
                    s.window = WindowRing::new(cfg.window);
                    s
                });
                if let Err(e) = session.step(ev.slot, ev.demand, &metrics) {
                    // A policy/billing invariant violation is a bug; crash
                    // loudly rather than silently corrupting the ledger.
                    panic!("user {}: {e}", ev.user_id);
                }
            }
            Command::Snapshot(reply) => {
                let mut rows = Vec::with_capacity(sessions.len());
                for (&uid, s) in &sessions {
                    let mut demand = vec![0.0f32; cfg.window];
                    let mut coverage = vec![0.0f32; cfg.window];
                    s.window.snapshot_into(&mut demand, &mut coverage);
                    rows.push(SnapshotRow { user_id: uid, demand, coverage });
                }
                let _ = reply.send(rows);
            }
            Command::Finish(reply) => {
                let reports =
                    sessions.iter().map(|(&uid, s)| (uid, s.ledger.report())).collect();
                let _ = reply.send(reports);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(shards: usize) -> BrokerConfig {
        BrokerConfig {
            pricing: Pricing::normalized(0.05, 0.4, 100),
            shards,
            queue_capacity: 64,
            window: 16,
        }
    }

    #[test]
    fn broker_bills_like_direct_simulation() {
        let pricing = Pricing::normalized(0.05, 0.4, 100);
        let demands: Vec<Vec<u32>> = (0..6)
            .map(|u| (0..200).map(|t| ((t + u) % 4) as u32).collect())
            .collect();

        let broker = Broker::start(cfg(3), PolicyKind::Deterministic { z: None });
        for t in 0..200u32 {
            for (u, d) in demands.iter().enumerate() {
                broker
                    .submit(DemandEvent { user_id: u as u32, slot: t, demand: d[t as usize] })
                    .unwrap();
            }
        }
        let report = broker.finish().unwrap();
        assert_eq!(report.per_user.len(), 6);

        // compare against the sequential simulator
        for (uid, got) in &report.per_user {
            let mut policy = Deterministic::online(pricing);
            let want =
                crate::sim::run_policy(&mut policy, &demands[*uid as usize], pricing).unwrap();
            assert!(
                (got.total - want.total).abs() < 1e-9,
                "user {uid}: broker {} vs direct {}",
                got.total,
                want.total
            );
        }
    }

    #[test]
    fn gap_filling_keeps_clocks_consistent() {
        let broker = Broker::start(cfg(2), PolicyKind::AllOnDemand);
        // user 0 only reports at slots 0 and 10
        broker.submit(DemandEvent { user_id: 0, slot: 0, demand: 2 }).unwrap();
        broker.submit(DemandEvent { user_id: 0, slot: 10, demand: 3 }).unwrap();
        let report = broker.finish().unwrap();
        let (_, r) = &report.per_user[0];
        assert_eq!(r.slots, 11);
        assert_eq!(r.demand_slots, 5);
    }

    #[test]
    fn out_of_order_slot_panics_worker() {
        let broker = Broker::start(cfg(1), PolicyKind::AllOnDemand);
        broker.submit(DemandEvent { user_id: 0, slot: 5, demand: 1 }).unwrap();
        broker.submit(DemandEvent { user_id: 0, slot: 3, demand: 1 }).unwrap();
        // worker dies; finish must surface the failure
        assert!(broker.finish().is_err());
    }

    #[test]
    fn snapshot_returns_all_users() {
        let broker = Broker::start(cfg(4), PolicyKind::AllOnDemand);
        for t in 0..20u32 {
            for u in 0..10u32 {
                broker.submit(DemandEvent { user_id: u, slot: t, demand: u % 3 }).unwrap();
            }
        }
        let rows = broker.snapshot().unwrap();
        assert_eq!(rows.len(), 10);
        assert!(rows.windows(2).all(|w| w[0].user_id < w[1].user_id));
        // newest window entry reflects the last demand
        for r in &rows {
            assert_eq!(r.demand.len(), 16);
            assert_eq!(*r.demand.last().unwrap(), (r.user_id % 3) as f32);
        }
        broker.finish().unwrap();
    }

    #[test]
    fn window_ring_wraps_correctly() {
        let mut w = WindowRing::new(4);
        for i in 0..6 {
            w.push(i as f32, (i * 10) as f32);
        }
        let mut d = vec![0.0; 4];
        let mut c = vec![0.0; 4];
        w.snapshot_into(&mut d, &mut c);
        assert_eq!(d, vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(c, vec![20.0, 30.0, 40.0, 50.0]);
    }

    #[test]
    fn window_ring_pads_when_short() {
        let mut w = WindowRing::new(4);
        w.push(7.0, 1.0);
        let mut d = vec![9.0; 4];
        let mut c = vec![9.0; 4];
        w.snapshot_into(&mut d, &mut c);
        assert_eq!(d, vec![0.0, 0.0, 0.0, 7.0]);
        assert_eq!(c, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn forecast_policy_runs_in_broker() {
        let broker = Broker::start(
            cfg(2),
            PolicyKind::DeterministicForecast { window: 8, ar_order: 2 },
        );
        for t in 0..300u32 {
            broker.submit(DemandEvent { user_id: 0, slot: t, demand: 1 }).unwrap();
        }
        let report = broker.finish().unwrap();
        let (_, r) = &report.per_user[0];
        // stable demand must eventually be reserved
        assert!(r.reservations >= 1);
        assert_eq!(r.demand_slots, 300);
    }
}
