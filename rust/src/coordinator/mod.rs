//! The reservation brokerage coordinator — the L3 service wrapping the
//! paper's policies for multi-tenant, streaming operation.
//!
//! Topology (std threads; tokio is not in the offline vendor set):
//!
//! ```text
//!              submit(DemandEvent)            per-shard bounded queues
//!  ingestion ────────────────────▶ router ──┬─▶ worker 0 ─┐
//!                                           ├─▶ worker 1 ─┤  purchases +
//!                                           └─▶ worker N ─┘  billing
//!                                                 │
//!                        snapshot request/reply   ▼
//!  analytics tick ◀──────────────────────── fleet posture batch
//!        │
//!        └─▶ runtime::fleet_step (AOT PJRT artifact: L1/L2 compute)
//! ```
//!
//! * Each worker owns the policy state machine + billing ledger for its
//!   users; the request path is pure Rust and allocation-light.
//! * The analytics engine periodically snapshots every user's recent
//!   (demand, coverage) window and evaluates the fleet's break-even
//!   posture against a grid of `A_z` thresholds through the AOT artifact —
//!   the L1 Pallas scan is on this (hot) analytics path, Python is not.
//! * Backpressure: bounded channels; `submit` blocks when a shard lags.

pub mod analytics;
pub mod broker;
pub mod metrics;

pub use analytics::{AnalyticsEngine, FleetPosture};
pub use broker::{Broker, BrokerConfig, BrokerReport, DemandEvent, PolicyKind};
pub use metrics::Metrics;
