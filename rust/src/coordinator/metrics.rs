//! Coordinator metrics: cheap atomic counters shared across shards,
//! rendered by the CLI and asserted by integration tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Fleet-wide counters. All methods are lock-free.
#[derive(Debug)]
pub struct Metrics {
    pub events: AtomicU64,
    pub demand_slots: AtomicU64,
    pub reservations: AtomicU64,
    pub on_demand_slots: AtomicU64,
    pub analytics_ticks: AtomicU64,
    pub gap_filled_slots: AtomicU64,
    /// Microseconds spent inside policy decisions (summed across shards).
    pub decide_micros: AtomicU64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            events: AtomicU64::new(0),
            demand_slots: AtomicU64::new(0),
            reservations: AtomicU64::new(0),
            on_demand_slots: AtomicU64::new(0),
            analytics_ticks: AtomicU64::new(0),
            gap_filled_slots: AtomicU64::new(0),
            decide_micros: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_event(&self, demand: u32, reserve: u32, on_demand: u32) {
        self.events.fetch_add(1, Ordering::Relaxed);
        self.demand_slots.fetch_add(demand as u64, Ordering::Relaxed);
        self.reservations.fetch_add(reserve as u64, Ordering::Relaxed);
        self.on_demand_slots.fetch_add(on_demand as u64, Ordering::Relaxed);
    }

    pub fn events_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.events.load(Ordering::Relaxed) as f64 / secs
        }
    }

    /// One-line status render.
    pub fn render(&self) -> String {
        format!(
            "events={} demand_slots={} reservations={} od_slots={} ticks={} gaps={} rate={:.0}/s",
            self.events.load(Ordering::Relaxed),
            self.demand_slots.load(Ordering::Relaxed),
            self.reservations.load(Ordering::Relaxed),
            self.on_demand_slots.load(Ordering::Relaxed),
            self.analytics_ticks.load(Ordering::Relaxed),
            self.gap_filled_slots.load(Ordering::Relaxed),
            self.events_per_sec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_event(3, 1, 2);
        m.record_event(0, 0, 0);
        assert_eq!(m.events.load(Ordering::Relaxed), 2);
        assert_eq!(m.demand_slots.load(Ordering::Relaxed), 3);
        assert_eq!(m.reservations.load(Ordering::Relaxed), 1);
        assert_eq!(m.on_demand_slots.load(Ordering::Relaxed), 2);
        assert!(m.render().contains("events=2"));
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record_event(1, 0, 1);
                    }
                });
            }
        });
        assert_eq!(m.events.load(Ordering::Relaxed), 4000);
    }
}
