//! # CloudReserve
//!
//! A production-grade reproduction of *"To Reserve or Not to Reserve:
//! Optimal Online Multi-Instance Acquisition in IaaS Clouds"* (Wang, Li,
//! Liang — 2013): online algorithms that combine on-demand and reserved
//! IaaS instances to serve time-varying demand at near-optimal cost,
//! without knowledge of the future.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L3** — this Rust crate: policies, ledger, traces, fleet simulator,
//!   and a multi-tenant brokerage coordinator;
//! * **L2** — a JAX compute graph (batched break-even window scans + AR
//!   demand forecasting), AOT-lowered to HLO text at build time;
//! * **L1** — Pallas kernels inside the L2 graph (see `python/compile/`).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU
//! client and the [`coordinator`] drives them on its analytics hot path.
//! (Offline builds link a stub `xla` backend — see `rust/vendor/xla` — and
//! degrade to the pure-Rust paths.)
//!
//! The [`broker`] module is the shared-portfolio layer on top: it folds a
//! fleet's demand into one aggregate curve, buys a single reservation
//! portfolio with the same online policies, and settles the realized cost
//! back to users bit-exactly (the multiplexing counterpart to the
//! per-user [`coordinator`] path).
//!
//! The evaluation hot path is the batched fleet engine ([`sim::engine`]):
//! zero allocation per slot, monomorphic policy dispatch, columnar trace
//! storage ([`trace::FlatPopulation`]). Its measured baseline and the
//! benchmark methodology live in `PERF.md`; regenerate the tracked
//! `BENCH.json` with `cargo run --release -- bench`.

pub mod algos;
pub mod analysis;
pub mod broker;
pub mod coordinator;
pub mod forecast;
pub mod ledger;
pub mod pricing;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;

pub use algos::{Decision, Policy};
pub use ledger::{CostReport, Ledger};
pub use pricing::{Contract, ContractId, Market, Pricing};
