//! Minimal JSON parser **and serializer** (serde is not in the offline
//! vendor set). Supports the subset the artifact manifest and the
//! `BENCH.json` perf baseline use: objects, arrays, strings (with basic
//! escapes), numbers, booleans, null. Not streaming, not fast — it parses
//! a ~kB manifest once at startup and dumps small reports.

use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with **insertion order preserved** (the artifact
    /// manifest's input order is the HLO parameter order).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj()
            .and_then(|m| m.iter().find(|(k, _)| k == key))
            .map(|(_, v)| v)
            .unwrap_or(&NULL)
    }

    /// Convenience constructor for an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to a compact JSON document. Non-finite numbers (which JSON
    /// cannot represent) serialize as `null`.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation (for committed/diffed files).
    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, level: usize) {
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        // integral values print without a fraction (and round-trip exactly)
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let b = input.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(err(pos, "trailing characters"));
    }
    Ok(v)
}

fn err(offset: usize, msg: &str) -> ParseError {
    ParseError { offset, message: msg.to_string() }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(err(*pos, "unexpected end of input")),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, ParseError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(err(*pos, "invalid literal"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| err(start, "invalid number"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, ParseError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(err(*pos, "unterminated string")),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or_else(|| err(*pos, "bad \\u escape"))?;
                        out.push(char::from_u32(hex).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(err(*pos, "bad escape")),
                }
                *pos += 1;
            }
            Some(&c) => {
                // collect a UTF-8 run
                let start = *pos;
                let len = utf8_len(c);
                *pos += len;
                out.push_str(
                    std::str::from_utf8(&b[start..(*pos).min(b.len())])
                        .map_err(|_| err(start, "invalid utf-8"))?,
                );
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(err(*pos, "expected ',' or ']'")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // {
    let mut out: Vec<(String, Json)> = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(err(*pos, "expected object key"));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(err(*pos, "expected ':'"));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(err(*pos, "expected ',' or '}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"[
          {"name": "fleet_step_b8_w64_k8", "kind": "fleet_step",
           "inputs": {"p": [1], "demand": [8, 64]},
           "outputs": {"counts": [8]},
           "params": {"B": 8, "W": 64, "K": 8}}
        ]"#;
        let v = parse(doc).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let e = &arr[0];
        assert_eq!(e.get("name").as_str(), Some("fleet_step_b8_w64_k8"));
        assert_eq!(e.get("params").get("W").as_usize(), Some(64));
        let dims: Vec<usize> = e
            .get("inputs")
            .get("demand")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![8, 64]);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, {"b": [true, null]}]}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let v = Json::obj(vec![
            ("name", Json::Str("suite \"quoted\"\n".into())),
            ("count", Json::Num(42.0)),
            ("ratio", Json::Num(1.5125)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        for text in [v.dump(), v.dump_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, v, "from {text}");
        }
    }

    #[test]
    fn dump_integers_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(-3.0).dump(), "-3");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn pretty_dump_is_valid_json() {
        let v = Json::obj(vec![(
            "nested",
            Json::Arr(vec![Json::obj(vec![("k", Json::Num(1.0))]), Json::Arr(vec![])]),
        )]);
        let text = v.dump_pretty();
        assert_eq!(parse(&text).unwrap(), v);
        assert!(text.contains('\n'));
    }
}
