//! Minimal byte-level state serialization for checkpoints.
//!
//! The offline vendor set has no serde, so checkpointable types write their
//! state through [`StateWriter`] and read it back through [`StateReader`]:
//! fixed-width little-endian integers, `f64` as raw bits (bit-exact resume
//! is the whole point), and length-prefixed blobs. Readers are fully
//! bounds-checked and return errors instead of panicking, because checkpoint
//! bytes may arrive torn or bit-flipped from disk.

use anyhow::{bail, ensure, Result};

/// FNV-1a 64-bit hash — shared by the chunked trace index and the checkpoint
/// format. Not cryptographic; detects corruption, not adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only byte sink for state snapshots.
#[derive(Debug, Default)]
pub struct StateWriter {
    buf: Vec<u8>,
}

impl StateWriter {
    pub fn new() -> Self {
        StateWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` values travel as u64 so snapshots are portable across widths.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Raw IEEE-754 bits — restores must be bit-identical, so no decimal
    /// round-trip is acceptable.
    pub fn f64_bits(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed byte blob.
    pub fn blob(&mut self, bytes: &[u8]) {
        self.usize(bytes.len());
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.blob(s.as_bytes());
    }
}

/// Bounds-checked cursor over snapshot bytes.
#[derive(Debug)]
pub struct StateReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> StateReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        StateReader { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.at..end];
                self.at = end;
                Ok(s)
            }
            None => bail!(
                "state truncated: need {} bytes at offset {} of {}",
                n,
                self.at,
                self.buf.len()
            ),
        }
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("state value {v} exceeds usize"))
    }

    pub fn f64_bits(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a sequence length whose items occupy at least `bytes_per_item`
    /// bytes each, rejecting counts larger than the remaining payload could
    /// possibly hold. Restore paths size allocations from these counts, so
    /// an unvalidated length in a corrupt checkpoint would otherwise demand
    /// an unbounded allocation before the truncation was ever noticed.
    pub fn seq_len(&mut self, bytes_per_item: usize) -> Result<usize> {
        let n = self.usize()?;
        let cap = self.remaining() / bytes_per_item.max(1);
        ensure!(
            n <= cap,
            "state sequence length {n} exceeds remaining capacity \
             ({} bytes / {} per item)",
            self.remaining(),
            bytes_per_item
        );
        Ok(n)
    }

    pub fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        ensure!(
            n <= self.buf.len().saturating_sub(self.at),
            "state blob length {} exceeds remaining {} bytes",
            n,
            self.buf.len() - self.at
        );
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.blob()?;
        Ok(String::from_utf8(b.to_vec())?)
    }

    /// Remaining unread bytes.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    /// Assert the snapshot was fully consumed — catches schema drift where a
    /// writer and reader disagree about field order.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.at == self.buf.len(),
            "state has {} unread trailing bytes",
            self.buf.len() - self.at
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_field_kinds() {
        let mut w = StateWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.i64(-42);
        w.usize(123_456);
        w.f64_bits(-0.0);
        w.f64_bits(f64::NAN);
        w.blob(b"hello");
        w.str("chunk 3");
        let bytes = w.into_bytes();

        let mut r = StateReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.i64().unwrap(), -42);
        assert_eq!(r.usize().unwrap(), 123_456);
        assert_eq!(r.f64_bits().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64_bits().unwrap().is_nan());
        assert_eq!(r.blob().unwrap(), b"hello");
        assert_eq!(r.str().unwrap(), "chunk 3");
        r.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_instead_of_panicking() {
        let mut w = StateWriter::new();
        w.u64(1);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes[..5]);
        let err = r.u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn seq_len_bounds_by_remaining_payload() {
        let mut w = StateWriter::new();
        w.usize(3);
        w.u64(1);
        w.u64(2);
        w.u64(3);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert_eq!(r.seq_len(8).unwrap(), 3);

        let mut w = StateWriter::new();
        w.usize(4); // claims one item more than the payload holds
        w.u64(1);
        w.u64(2);
        w.u64(3);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        let err = r.seq_len(8).unwrap_err().to_string();
        assert!(err.contains("length"), "{err}");
    }

    #[test]
    fn oversized_blob_length_rejected() {
        let mut w = StateWriter::new();
        w.usize(1 << 40); // claims a blob far larger than the buffer
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert!(r.blob().is_err());
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = StateWriter::new();
        w.u32(1);
        w.u32(2);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        r.u32().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
