//! Process-memory introspection for the fleet-scale bench: peak resident
//! set size, read from the kernel's high-water mark (`VmHWM` in
//! `/proc/self/status`). No syscalls beyond a procfs read, no
//! dependencies; non-Linux platforms report `None`.

/// Peak resident set size of the current process in kibibytes, if the
/// platform exposes it.
///
/// `VmHWM` is a process-lifetime high-water mark: it never decreases, so a
/// grid of runs must execute in ascending memory order for per-run
/// attribution (the fleet-scale bench runs 10³ → 10⁶ users ascending and
/// reads the mark after each cell — a flat mark across cells is exactly
/// the O(chunk) bounded-memory evidence).
#[cfg(target_os = "linux")]
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

#[cfg(not(target_os = "linux"))]
pub fn peak_rss_kb() -> Option<u64> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(target_os = "linux")]
    fn peak_rss_is_positive_and_monotone() {
        let before = peak_rss_kb().expect("VmHWM available on Linux");
        assert!(before > 0);
        // touch a few MB so the mark cannot move backwards
        let v = vec![1u8; 4 << 20];
        std::hint::black_box(&v);
        let after = peak_rss_kb().unwrap();
        assert!(after >= before, "high-water mark went backwards: {before} -> {after}");
    }
}
