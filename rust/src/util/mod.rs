//! In-tree substitutes for crates unavailable in the offline vendor set
//! (rand, clap, criterion, proptest), plus shared statistics helpers.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod mem;
pub mod prop;
pub mod rng;
pub mod state;
pub mod stats;
