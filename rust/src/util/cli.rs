//! Dependency-free command-line parsing (clap is not in the offline vendor
//! set). Supports `subcommand --flag value --bool-flag positional` shapes,
//! with typed accessors and automatic usage text.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, `--key` switches,
/// and bare positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or --switch
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.switches.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }
}

/// Error text for an unknown name-valued option: names the offending value
/// and lists every valid name, so "unknown policy/settlement/…" errors are
/// always actionable (CLI and spec parsers share this).
pub fn expected_one_of(what: &str, got: &str, valid: &[&str]) -> String {
    format!("{what}: unknown name '{got}' (expected one of: {})", valid.join("|"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // NOTE: a bare word after a flag is consumed as that flag's value
        // (`--verbose out.csv` would read as verbose=out.csv), so switches
        // go last or use `--flag=value` — documented parser behaviour.
        let a = Args::parse(argv("simulate out.csv --users 100 --seed 42 --verbose"));
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.usize_or("users", 0), 100);
        assert_eq!(a.u64_or("seed", 0), 42);
        assert!(a.has("verbose"));
        assert_eq!(a.positionals, vec!["out.csv"]);
    }

    #[test]
    fn parses_eq_form() {
        let a = Args::parse(argv("run --alpha=0.49"));
        assert!((a.f64_or("alpha", 0.0) - 0.49).abs() < 1e-12);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(argv("run --check"));
        assert!(a.has("check"));
        assert_eq!(a.get("check"), None);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv("run"));
        assert_eq!(a.f64_or("alpha", 0.5), 0.5);
        assert_eq!(a.str_or("mode", "fast"), "fast");
    }

    #[test]
    fn expected_one_of_lists_names() {
        let msg = expected_one_of("policy", "magic", &["a", "b", "c"]);
        assert!(msg.contains("'magic'"));
        assert!(msg.contains("a|b|c"));
    }

    #[test]
    fn negative_number_as_value() {
        // "--shift -3": -3 does not start with --, so it is consumed as value.
        let a = Args::parse(argv("run --shift -3"));
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
