//! Seedable, fast, dependency-free PRNG.
//!
//! The offline build environment does not ship the `rand` crate, so we carry
//! a small xoshiro256** implementation (public-domain algorithm by Blackman &
//! Vigna) seeded through SplitMix64. Determinism across runs matters for the
//! trace generator and the randomized policy: every experiment records its
//! seed and can be replayed bit-for-bit.

/// xoshiro256** generator. Not cryptographic; statistical quality is more
/// than sufficient for workload synthesis and policy randomization.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step, used for seeding (recommended by the xoshiro authors).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Snapshot the raw xoshiro256** words for checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`state`](Rng::state) snapshot; the restored
    /// stream continues bit-identically from the save point.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent child stream (used to give each user / shard its
    /// own generator while keeping a single experiment-level seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform u64 in [0, n) (Lemire-style rejection-free approximation is
    /// unnecessary here; modulo bias is negligible for our n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Pareto (type I) with scale `xm` and shape `a` — heavy-tailed burst
    /// sizes for the sporadic-workload archetype.
    pub fn pareto(&mut self, xm: f64, a: f64) -> f64 {
        debug_assert!(xm > 0.0 && a > 0.0);
        xm / self.f64().max(f64::MIN_POSITIVE).powf(1.0 / a)
    }

    /// Poisson via inversion for small means, normal approximation above.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            return self.normal_ms(mean, mean.sqrt()).max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // numeric safety valve
            }
        }
    }

    /// Geometric number of failures before first success, success prob `p`.
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        (self.f64().max(f64::MIN_POSITIVE).ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick an index according to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(13);
        for lambda in [0.5, 3.0, 10.0, 50.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.15 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_resumes_identically() {
        let mut a = Rng::new(31);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
