//! Descriptive statistics and empirical-CDF helpers used by the trace
//! classifier (Fig. 4), the cost reports (Fig. 5), and the bench harness.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Coefficient of variation sigma/mu — the paper's demand "fluctuation
    /// level" (Sec. VII-A). Returns +inf for zero-mean, non-degenerate data.
    pub fn cov(&self) -> f64 {
        if self.mean == 0.0 {
            if self.std == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            self.std / self.mean
        }
    }
}

/// Compute summary statistics (population standard deviation).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        if x < min {
            min = x;
        }
        if x > max {
            max = x;
        }
    }
    Summary { n: xs.len(), mean, std: var.sqrt(), min, max }
}

/// Summary over integer demand curves.
pub fn summarize_u32(xs: &[u32]) -> Summary {
    // Stream to avoid allocating a second copy of month-long minute traces.
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    let (mut min, mut max) = (u32::MAX, 0u32);
    for &x in xs {
        let f = x as f64;
        sum += f;
        sumsq += f * f;
        min = min.min(x);
        max = max.max(x);
    }
    let mean = sum / n;
    let var = (sumsq / n - mean * mean).max(0.0);
    Summary { n: xs.len(), mean, std: var.sqrt(), min: min as f64, max: max as f64 }
}

/// Quantile with linear interpolation on a *sorted* slice, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Empirical CDF sampled at `points` fixed x-positions; returns
/// (x, P[X <= x]) pairs — the series plotted in Fig. 5/6/7.
pub fn ecdf(xs: &[f64], points: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|&x| {
            // number of elements <= x via binary search on the sorted copy
            let cnt = sorted.partition_point(|&v| v <= x);
            (x, cnt as f64 / sorted.len().max(1) as f64)
        })
        .collect()
}

/// Evenly spaced grid [lo, hi] with `n` points (n >= 2).
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// Histogram with `bins` equal-width buckets over [lo, hi).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            let b = ((x - lo) / w) as usize;
            h[b.min(bins - 1)] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_u32_matches_f64() {
        let xs: Vec<u32> = vec![0, 5, 5, 10, 100];
        let f: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
        let a = summarize_u32(&xs);
        let b = summarize(&f);
        assert!((a.mean - b.mean).abs() < 1e-9);
        assert!((a.std - b.std).abs() < 1e-6);
    }

    #[test]
    fn cov_of_constant_is_zero() {
        let s = summarize(&[3.0, 3.0, 3.0]);
        assert_eq!(s.cov(), 0.0);
    }

    #[test]
    fn cov_zero_mean() {
        let s = summarize(&[0.0, 0.0]);
        assert_eq!(s.cov(), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 5.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 3.0);
        assert!((quantile_sorted(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone_and_bounded() {
        let xs = [0.5, 0.9, 1.4, 2.0, 2.0, 7.0];
        let grid = linspace(0.0, 10.0, 21);
        let cdf = ecdf(&xs, &grid);
        let mut prev = 0.0;
        for &(_, p) in &cdf {
            assert!(p >= prev && (0.0..=1.0).contains(&p));
            prev = p;
        }
        assert_eq!(cdf.last().unwrap().1, 1.0);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.5], 0.0, 2.0, 2);
        assert_eq!(h, vec![3, 1]);
    }
}
