//! Minimal micro-benchmark harness (criterion is not available in the
//! offline vendor set). Provides warmup, repeated timed runs, and a robust
//! summary (median / p10 / p90 / mean) printed in a fixed, grep-friendly
//! format that the bench binaries under `rust/benches/` share.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration for each sample, **sorted ascending**
    /// (construct through [`BenchResult::new`], which sorts once — the
    /// quantile accessors used to clone + re-sort on every call).
    pub samples_ns: Vec<f64>,
    /// Iterations executed per sample.
    pub iters_per_sample: u64,
}

impl BenchResult {
    /// Build a result, sorting the samples once up front.
    pub fn new(
        name: impl Into<String>,
        mut samples_ns: Vec<f64>,
        iters_per_sample: u64,
    ) -> BenchResult {
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchResult { name: name.into(), samples_ns, iters_per_sample }
    }

    pub fn median_ns(&self) -> f64 {
        self.quantile_ns(0.5)
    }

    pub fn quantile_ns(&self, q: f64) -> f64 {
        debug_assert!(
            self.samples_ns.windows(2).all(|w| w[0] <= w[1]),
            "BenchResult.samples_ns must be sorted (use BenchResult::new)"
        );
        crate::util::stats::quantile_sorted(&self.samples_ns, q)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len().max(1) as f64
    }

    /// JSON form for the tracked perf baseline (`BENCH.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("median_ns", Json::Num(self.median_ns())),
            ("p10_ns", Json::Num(self.quantile_ns(0.10))),
            ("p90_ns", Json::Num(self.quantile_ns(0.90))),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("samples", Json::Num(self.samples_ns.len() as f64)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
        ])
    }

    /// Print the standard one-line report:
    /// `bench <name> median 12.3us p10 11us p90 14us mean 12.5us (20 samples x 100 iters)`
    pub fn report(&self) {
        println!(
            "bench {:<44} median {:>12} p10 {:>12} p90 {:>12} mean {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.quantile_ns(0.10)),
            fmt_ns(self.quantile_ns(0.90)),
            fmt_ns(self.mean_ns()),
            self.samples_ns.len(),
            self.iters_per_sample
        );
    }

    /// Throughput helper: items processed per second given items/iter.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns() * 1e-9)
    }
}

/// Human formatting of nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner with sensible defaults for this repo's workloads.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            samples: 15,
            min_sample_time: Duration::from_millis(50),
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample_time: Duration::from_millis(20),
        }
    }

    /// Run `f` repeatedly and measure. A `black_box`-style sink is applied by
    /// requiring `f` to return a value which we consume volatilely.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: figure out how many iters fit a sample.
        let start = Instant::now();
        let mut iters_done = 0u64;
        while start.elapsed() < self.warmup || iters_done == 0 {
            sink(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let per_iter = start.elapsed().as_secs_f64() / iters_done as f64;
        let iters_per_sample = ((self.min_sample_time.as_secs_f64() / per_iter.max(1e-12))
            as u64)
            .clamp(1, 10_000_000);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                sink(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            samples_ns.push(dt / iters_per_sample as f64);
        }
        BenchResult::new(name, samples_ns, iters_per_sample)
    }
}

/// Prevent the optimizer from deleting the benchmarked computation.
#[inline]
pub fn sink<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            samples: 3,
            min_sample_time: Duration::from_millis(2),
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.samples_ns.len(), 3);
        assert!(r.median_ns() > 0.0);
        assert!(r.throughput(100.0) > 0.0);
    }

    #[test]
    fn new_sorts_samples_and_quantiles_read_directly() {
        let r = BenchResult::new("x", vec![5.0, 1.0, 3.0, 2.0, 4.0], 10);
        assert_eq!(r.samples_ns, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(r.median_ns(), 3.0);
        assert_eq!(r.quantile_ns(0.0), 1.0);
        assert_eq!(r.quantile_ns(1.0), 5.0);
    }

    #[test]
    fn to_json_has_the_tracked_fields() {
        let r = BenchResult::new("suite", vec![10.0, 20.0], 7);
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("suite"));
        assert_eq!(j.get("iters_per_sample").as_f64(), Some(7.0));
        assert!(j.get("median_ns").as_f64().unwrap() > 0.0);
        // serializes to valid JSON
        assert!(crate::util::json::parse(&j.dump()).is_ok());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
