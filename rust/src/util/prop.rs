//! Tiny property-based testing harness (proptest is not available in the
//! offline vendor set). Generates random cases from a seeded [`Rng`], runs a
//! property, and on failure attempts greedy shrinking via a user-provided
//! shrinker before reporting the minimal counterexample and the seed needed
//! to replay it.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed is overridable via env for CI reproduction of failures.
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC10D_5EED);
        Config { cases: 64, seed, max_shrink_steps: 400 }
    }
}

/// Run `prop` against `cases` random inputs produced by `gen`.
/// `shrink` proposes smaller variants of a failing input (return empty to
/// stop). Panics with the minimal counterexample on failure.
pub fn check<T, G, P, S>(cfg: &Config, name: &str, mut gen: G, mut prop: P, mut shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut input = gen(&mut rng);
        if let Err(mut msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&input) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        input = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  {msg}\n  minimal input: {input:?}\n  replay with PROP_SEED={seed}",
                seed = cfg.seed
            );
        }
    }
}

/// Convenience: property check without shrinking.
pub fn check_no_shrink<T, G, P>(cfg: &Config, name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(cfg, name, gen, prop, |_| Vec::new());
}

/// Standard shrinker for demand sequences: try truncations, halving the
/// values, and zeroing single positions.
pub fn shrink_demand(d: &Vec<u32>) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    if d.len() > 1 {
        out.push(d[..d.len() / 2].to_vec());
        out.push(d[..d.len() - 1].to_vec());
        out.push(d[d.len() / 2..].to_vec());
    }
    if d.iter().any(|&x| x > 0) {
        out.push(d.iter().map(|&x| x / 2).collect());
    }
    for i in 0..d.len().min(8) {
        if d[i] > 0 {
            let mut c = d.clone();
            c[i] = 0;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config { cases: 32, seed: 1, max_shrink_steps: 10 };
        check_no_shrink(
            &cfg,
            "sum-nonneg",
            |r| (0..8).map(|_| r.below(10) as u32).collect::<Vec<u32>>(),
            |d| {
                let s: u32 = d.iter().sum();
                if s < u32::MAX {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn failing_property_panics_with_shrunk_input() {
        let cfg = Config { cases: 64, seed: 2, max_shrink_steps: 100 };
        check(
            &cfg,
            "always-small",
            |r| (0..10).map(|_| r.below(100) as u32).collect::<Vec<u32>>(),
            |d| {
                if d.iter().all(|&x| x < 90) {
                    Ok(())
                } else {
                    Err(format!("found value >= 90 in {d:?}"))
                }
            },
            shrink_demand,
        );
    }

    #[test]
    fn shrinker_produces_smaller_candidates() {
        let cands = shrink_demand(&vec![4, 5, 6, 7]);
        assert!(!cands.is_empty());
        assert!(cands.iter().any(|c| c.len() < 4));
    }
}
