//! Deterministic fault injection for crash-recovery testing.
//!
//! A [`FaultPlan`] is consulted at named I/O sites (trace chunk reads,
//! checkpoint writes, post-chunk kill-points). Faults come from two sources
//! that compose:
//!
//! * **scripted** entries — exact `(site, key, attempt)` triggers, used by
//!   tests and the CI crash-recovery smoke to hit one specific boundary;
//! * a **seeded** mode — a hash of `(seed, site, key, attempt)` against
//!   per-site rates, so soak runs can shotgun faults reproducibly from a
//!   single `--fault-seed`.
//!
//! Every injected fault is logged; the run report surfaces the log so no
//! fault is ever silent. The plan itself never performs I/O — callers apply
//! the returned [`Fault`] to their own buffers/files, which keeps injection
//! in one auditable place per site.

use std::sync::Mutex;

/// Well-known failpoint site names.
pub mod site {
    /// Reading one chunk payload from a chunked trace file.
    pub const TRACE_READ: &str = "trace.read_chunk";
    /// Writing a checkpoint snapshot (torn write / bit flip before rename).
    pub const CKPT_WRITE: &str = "checkpoint.write";
    /// Immediately after a chunk (and any due checkpoint) completes.
    pub const FLEET_AFTER_CHUNK: &str = "fleet.after_chunk";
}

/// What to inject at a site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail the read with a transient I/O error (retryable).
    ReadError,
    /// Flip one bit of the payload: byte index (mod len), bit 0..=7.
    BitFlip { byte: u64, bit: u8 },
    /// Truncate the written file to `keep` bytes before it is renamed.
    TornWrite { keep: u64 },
    /// Abort the process at this point (simulated crash).
    Kill,
}

impl Fault {
    fn name(&self) -> &'static str {
        match self {
            Fault::ReadError => "read_error",
            Fault::BitFlip { .. } => "bit_flip",
            Fault::TornWrite { .. } => "torn_write",
            Fault::Kill => "kill",
        }
    }
}

/// One scripted trigger: fires while `attempt <= max_attempt` for the exact
/// `(site, key)` pair. `max_attempt >= 1` lets a transient fault persist for
/// a bounded number of retries and then clear.
#[derive(Debug, Clone)]
struct Scripted {
    site: &'static str,
    key: u64,
    max_attempt: u32,
    fault: Fault,
}

/// Record of a fault that actually fired.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub site: &'static str,
    pub key: u64,
    pub attempt: u32,
    pub kind: &'static str,
}

/// Deterministic fault source. `Sync` so the coordinator thread can hold it
/// across scoped shard threads (checks happen on the coordinator only).
#[derive(Debug, Default)]
pub struct FaultPlan {
    scripted: Vec<Scripted>,
    seed: Option<u64>,
    read_error_rate: f64,
    flip_rate: f64,
    log: Mutex<Vec<InjectedFault>>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Script an exact fault: fires for `(site, key)` while
    /// `attempt <= max_attempt`.
    pub fn script(mut self, site: &'static str, key: u64, max_attempt: u32, fault: Fault) -> Self {
        self.scripted.push(Scripted { site, key, max_attempt, fault });
        self
    }

    /// Enable seeded random faults: independent draws per
    /// `(seed, site, key, attempt)`, so a fault on attempt 0 does not imply
    /// one on the retry.
    pub fn seeded(mut self, seed: u64, read_error_rate: f64, flip_rate: f64) -> Self {
        self.seed = Some(seed);
        self.read_error_rate = read_error_rate.clamp(0.0, 1.0);
        self.flip_rate = flip_rate.clamp(0.0, 1.0);
        self
    }

    /// True if any fault source is configured — callers can skip the
    /// injection path entirely otherwise.
    pub fn is_armed(&self) -> bool {
        !self.scripted.is_empty() || self.seed.is_some()
    }

    /// Consult the plan at `site` for unit-of-work `key` (chunk index,
    /// checkpoint ordinal, …) on retry `attempt` (0 = first try). Fires at
    /// most one fault; scripted entries win over seeded draws.
    pub fn check(&self, site: &'static str, key: u64, attempt: u32) -> Option<Fault> {
        let fault = self.decide(site, key, attempt)?;
        self.log.lock().unwrap().push(InjectedFault {
            site,
            key,
            attempt,
            kind: fault.name(),
        });
        Some(fault)
    }

    fn decide(&self, site: &'static str, key: u64, attempt: u32) -> Option<Fault> {
        for s in &self.scripted {
            if s.site == site && s.key == key && attempt <= s.max_attempt {
                return Some(s.fault);
            }
        }
        let seed = self.seed?;
        let h = mix(seed, site, key, attempt);
        // Map the top 53 bits to [0,1) — same construction as Rng::f64.
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if site == site::TRACE_READ {
            if u < self.read_error_rate {
                return Some(Fault::ReadError);
            }
            if u < self.read_error_rate + self.flip_rate {
                let h2 = mix(seed ^ 0x5bf0_3635, site, key, attempt);
                return Some(Fault::BitFlip { byte: h2 >> 8, bit: (h2 & 7) as u8 });
            }
        }
        None
    }

    /// Faults that have fired so far, in order.
    pub fn injected(&self) -> Vec<InjectedFault> {
        self.log.lock().unwrap().clone()
    }
}

/// SplitMix64-style avalanche over the fault coordinates.
fn mix(seed: u64, site: &str, key: u64, attempt: u32) -> u64 {
    let mut z = seed
        .wrapping_add(crate::util::state::fnv1a64(site.as_bytes()))
        .wrapping_add(key.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(attempt as u64);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Error type for a scripted kill-point: carried up through `anyhow` so the
/// CLI can map a simulated crash to a distinct exit code.
#[derive(Debug, Clone)]
pub struct KillPoint {
    pub site: &'static str,
    pub key: u64,
}

impl std::fmt::Display for KillPoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kill-point triggered at {} (key {})", self.site, self.key)
    }
}

impl std::error::Error for KillPoint {}

/// Exponential backoff delay for retry `attempt` (0-based): `base << attempt`
/// milliseconds, capped to keep tests fast.
pub fn backoff_delay(attempt: u32, base_ms: u64) -> std::time::Duration {
    let ms = base_ms.saturating_mul(1u64 << attempt.min(6)).min(2_000);
    std::time::Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_fault_fires_only_within_attempt_bound() {
        let plan = FaultPlan::new().script(site::TRACE_READ, 3, 1, Fault::ReadError);
        assert_eq!(plan.check(site::TRACE_READ, 3, 0), Some(Fault::ReadError));
        assert_eq!(plan.check(site::TRACE_READ, 3, 1), Some(Fault::ReadError));
        assert_eq!(plan.check(site::TRACE_READ, 3, 2), None);
        assert_eq!(plan.check(site::TRACE_READ, 4, 0), None);
        assert_eq!(plan.check(site::CKPT_WRITE, 3, 0), None);
        assert_eq!(plan.injected().len(), 2);
    }

    #[test]
    fn seeded_faults_are_deterministic() {
        let a = FaultPlan::new().seeded(11, 0.3, 0.1);
        let b = FaultPlan::new().seeded(11, 0.3, 0.1);
        for key in 0..64 {
            for attempt in 0..3 {
                assert_eq!(
                    a.decide(site::TRACE_READ, key, attempt),
                    b.decide(site::TRACE_READ, key, attempt)
                );
            }
        }
    }

    #[test]
    fn seeded_faults_vary_by_attempt() {
        // With a 50% read-error rate, some key must recover on retry —
        // attempts draw independently.
        let plan = FaultPlan::new().seeded(7, 0.5, 0.0);
        let recovered = (0..64).any(|key| {
            plan.decide(site::TRACE_READ, key, 0).is_some()
                && plan.decide(site::TRACE_READ, key, 1).is_none()
        });
        assert!(recovered);
    }

    #[test]
    fn unarmed_plan_is_inert() {
        let plan = FaultPlan::new();
        assert!(!plan.is_armed());
        assert_eq!(plan.check(site::TRACE_READ, 0, 0), None);
        assert!(plan.injected().is_empty());
    }

    #[test]
    fn kill_point_downcasts_through_anyhow_context() {
        use anyhow::Context;
        let err = anyhow::Error::new(KillPoint { site: site::FLEET_AFTER_CHUNK, key: 5 })
            .context("fleet run aborted");
        let kp = err.downcast_ref::<KillPoint>().expect("downcast");
        assert_eq!(kp.key, 5);
    }

    #[test]
    fn backoff_grows_and_caps() {
        assert!(backoff_delay(0, 10) < backoff_delay(3, 10));
        assert!(backoff_delay(40, 1_000).as_millis() <= 2_000);
    }
}
