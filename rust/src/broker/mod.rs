//! Shared-portfolio reservation broker: aggregate a fleet's demand, buy
//! one reservation portfolio for everyone, settle the realized cost back
//! to users.
//!
//! The paper's guarantees (2−α deterministic, e/(e−1+α) randomized) are
//! per-user; a broker that folds many users' curves into one aggregate
//! curve and runs the *same* online policies on it exploits statistical
//! multiplexing — one user's trough absorbs another's burst, so shared
//! reservations stay utilized where per-user reservations would idle
//! (the provider-side counterpart is analyzed in arXiv:1611.07379).
//!
//! Pipeline ([`BrokerRun`]):
//!
//! 1. **Aggregate** ([`aggregate`]) — fold per-user demand streams into
//!    one `u64` curve plus per-user usage totals. Streaming
//!    chunk-at-a-time over v2 traces, so 10⁵+-user fleets stay O(one
//!    chunk) resident; bit-identical to the in-RAM fold.
//! 2. **Portfolio** ([`portfolio`]) — replay any [`PolicySpec`] over the
//!    aggregate curve against a single shared [`Ledger`](crate::Ledger),
//!    recording the per-contract portfolio composition.
//! 3. **Settle** ([`settlement`]) — split the broker's realized cost into
//!    per-user bills through a pluggable [`Settlement`] scheme. Σ bills
//!    reproduces the ledger total **bit-exactly** under plain `f64`
//!    summation in any order (quantized largest-remainder apportionment —
//!    see the module docs), and the `od-capped` scheme guarantees no user
//!    pays more than their standalone all-on-demand cost.
//!
//! The outcome carries the "isolated users" baseline alongside: every
//! user's standalone deterministic cost (the per-user path that
//! `coordinator::broker` / `examples/broker_service.rs` serve), whose sum
//! minus the broker's aggregate cost is the **multiplexing gain**. The
//! offline joint DP on the aggregate curve, when tractable, sandwiches the
//! broker cost from below. `tests/broker_props.rs` pins all three
//! invariants across randomized fleets and menus.

pub mod aggregate;
pub mod portfolio;
pub mod settlement;

pub use aggregate::{AggregateDemand, UserUsage};
pub use portfolio::{run_portfolio, ContractUse, PortfolioOutcome};
pub use settlement::{
    settlement_from_name, OnDemandCapped, ProportionalUsage, Settlement, SettlementError,
    SETTLEMENT_NAMES,
};

use anyhow::{anyhow, ensure, Context, Result};

use crate::algos::offline::{self, OfflineSolution};
use crate::pricing::Market;
use crate::sim::engine::run_fleet_flat;
use crate::sim::fleet::{PolicySpec, UserResult};
use crate::trace::io::ChunkedPopulation;
use crate::trace::FlatPopulation;

/// The standalone per-user baseline every broker run compares against:
/// windowless `A_β` (the paper's deterministic policy), one instance per
/// user — "what the fleet would pay without the broker".
pub const STANDALONE_SPEC: PolicySpec = PolicySpec::Deterministic { z: None, window: 0 };

/// One user's share of the broker outcome.
#[derive(Debug, Clone)]
pub struct UserBill {
    pub user_id: u32,
    /// What the settlement scheme charges this user.
    pub amount: f64,
    /// Total instance-slots the user requested (the proportional weight).
    pub usage_slots: u64,
    /// The user's standalone deterministic cost (isolated-users baseline).
    pub standalone_cost: f64,
    /// The user's standalone all-on-demand cost `p·usage_slots` (the
    /// od-capped scheme's ceiling).
    pub on_demand_cost: f64,
}

/// Everything a broker run produces.
#[derive(Debug, Clone)]
pub struct BrokerOutcome {
    pub users: usize,
    /// Aggregate horizon in slots.
    pub slots: usize,
    pub policy: String,
    pub settlement: String,
    /// The shared portfolio's replay result (the broker's realized cost).
    pub aggregate: PortfolioOutcome,
    /// Σ per-user standalone deterministic costs (sequential sum in user
    /// order — the order the bills conserve in).
    pub standalone_total: f64,
    /// Σ per-user all-on-demand costs.
    pub on_demand_total: f64,
    /// `standalone_total − aggregate cost`: what multiplexing saved.
    pub multiplexing_gain: f64,
    /// Per-user bills, in trace order. Σ amounts == aggregate cost,
    /// bit-exactly.
    pub bills: Vec<UserBill>,
    /// Offline joint DP on the aggregate curve (the sandwich floor), when
    /// requested and tractable.
    pub offline: Option<OfflineSolution>,
}

/// A configured broker run: market + policy + settlement (+ threads for
/// the standalone baseline sweep, + whether to attempt the offline floor).
pub struct BrokerRun<'a> {
    pub market: &'a Market,
    pub policy: PolicySpec,
    pub settlement: &'a dyn Settlement,
    pub threads: usize,
    pub offline: bool,
}

impl BrokerRun<'_> {
    /// Run over an in-RAM columnar population.
    pub fn run_flat(&self, flat: &FlatPopulation) -> Result<BrokerOutcome> {
        let agg = AggregateDemand::from_flat(flat);
        let standalone = run_fleet_flat(flat, self.market, &STANDALONE_SPEC, self.threads);
        self.finish(agg, standalone.per_user)
    }

    /// Run streaming over a chunked v2 trace: only one chunk of demand is
    /// resident at a time; the per-user state kept across the whole run is
    /// O(users) bills/usage, never the demand itself.
    pub fn run_chunked(&self, chunked: &mut ChunkedPopulation) -> Result<BrokerOutcome> {
        let mut agg = AggregateDemand::new();
        let mut standalone: Vec<UserResult> = Vec::with_capacity(chunked.n_users());
        let mut buf = FlatPopulation::default();
        for i in 0..chunked.n_chunks() {
            chunked
                .read_chunk_into(i, &mut buf)
                .with_context(|| format!("reading trace chunk {i}"))?;
            agg.fold_flat(&buf);
            let res = run_fleet_flat(&buf, self.market, &STANDALONE_SPEC, self.threads);
            standalone.extend(res.per_user);
        }
        self.finish(agg, standalone)
    }

    fn finish(
        &self,
        agg: AggregateDemand,
        standalone: Vec<UserResult>,
    ) -> Result<BrokerOutcome> {
        ensure!(agg.n_users() > 0, "broker run needs at least one user");
        ensure!(
            standalone.len() == agg.n_users(),
            "standalone baseline covered {} users, aggregate folded {}",
            standalone.len(),
            agg.n_users()
        );
        // The fleet engine returns results sorted by user id; the usage
        // vector is in trace order. Requiring ascending ids keeps the two
        // positionally aligned without a join.
        for (u, s) in agg.users().iter().zip(&standalone) {
            ensure!(
                u.user_id == s.user_id,
                "broker runs require traces with ascending user ids \
                 (usage order has user {}, baseline order has {})",
                u.user_id,
                s.user_id
            );
        }

        let curve = agg.curve()?;
        let pf = run_portfolio(&curve, self.market, &self.policy)
            .map_err(|e| anyhow!("aggregate portfolio replay: {e}"))?;

        let p = self.market.p();
        let standalone_total: f64 = standalone.iter().map(|u| u.absolute_cost).sum();
        let on_demand_total: f64 =
            agg.users().iter().map(|u| p * u.demand_slots as f64).sum();
        let amounts = self.settlement.settle(pf.report.total, agg.users(), p)?;

        let bills = agg
            .users()
            .iter()
            .zip(&standalone)
            .zip(&amounts)
            .map(|((u, s), &amount)| UserBill {
                user_id: u.user_id,
                amount,
                usage_slots: u.demand_slots,
                standalone_cost: s.absolute_cost,
                on_demand_cost: p * u.demand_slots as f64,
            })
            .collect();

        let offline = if self.offline {
            let terms: Vec<usize> =
                self.market.contracts().iter().map(|c| c.term).collect();
            let d_max = curve.iter().copied().max().unwrap_or(0);
            if offline::dp_joint_tractable(d_max, &terms) {
                offline::optimal_market_joint(&curve, self.market)
            } else {
                None
            }
        } else {
            None
        };

        let multiplexing_gain = standalone_total - pf.report.total;
        Ok(BrokerOutcome {
            users: agg.n_users(),
            slots: agg.horizon(),
            policy: pf.policy.clone(),
            settlement: self.settlement.name().to_string(),
            aggregate: pf,
            standalone_total,
            on_demand_total,
            multiplexing_gain,
            bills,
            offline,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::Contract;

    fn menu() -> Market {
        Market::new(
            0.08,
            vec![
                Contract { upfront: 0.1333, rate: 0.039, term: 4 },
                Contract { upfront: 0.3, rate: 0.031, term: 12 },
            ],
        )
    }

    /// Phase-shifted bursts: each user busy in its own 12-slot window, so
    /// the aggregate is constant 1 — maximal multiplexing.
    fn rotating_fleet(users: usize, burst: usize) -> FlatPopulation {
        let slots = users * burst;
        let mut flat = FlatPopulation::default();
        for u in 0..users {
            let demand: Vec<u32> =
                (0..slots).map(|t| u32::from(t / burst == u)).collect();
            flat.push_user(u as u32, &demand);
        }
        flat
    }

    fn run(flat: &FlatPopulation, settlement: &dyn Settlement) -> BrokerOutcome {
        BrokerRun {
            market: &menu(),
            policy: PolicySpec::Deterministic { z: None, window: 0 },
            settlement,
            threads: 2,
            offline: true,
        }
        .run_flat(flat)
        .unwrap()
    }

    #[test]
    fn multiplexing_gain_on_rotating_bursts() {
        let flat = rotating_fleet(8, 12);
        let out = run(&flat, &ProportionalUsage);
        assert_eq!(out.users, 8);
        assert_eq!(out.slots, 96);
        // aggregate is constant 1: the broker reserves; isolated users see
        // only their own 12-slot burst and pay far more in total
        assert!(out.aggregate.report.reservations >= 1);
        assert!(
            out.multiplexing_gain > 0.0,
            "gain {} (aggregate {} vs standalone {})",
            out.multiplexing_gain,
            out.aggregate.report.total,
            out.standalone_total
        );
        // offline floor sandwiches the broker cost
        let off = out.offline.expect("constant unit curve is joint-DP tractable");
        assert!(off.cost <= out.aggregate.report.total + 1e-9);
    }

    #[test]
    fn bills_conserve_bitwise_and_align_with_users() {
        let flat = rotating_fleet(8, 12);
        for s in [&ProportionalUsage as &dyn Settlement, &OnDemandCapped] {
            let out = run(&flat, s);
            let total: f64 = out.bills.iter().map(|b| b.amount).sum();
            assert_eq!(
                total.to_bits(),
                out.aggregate.report.total.to_bits(),
                "{} drifted",
                s.name()
            );
            for (i, b) in out.bills.iter().enumerate() {
                assert_eq!(b.user_id, i as u32);
                assert_eq!(b.usage_slots, 12);
            }
        }
    }

    #[test]
    fn od_capped_bills_stay_under_the_cap() {
        let flat = rotating_fleet(8, 12);
        let out = run(&flat, &OnDemandCapped);
        for b in &out.bills {
            assert!(b.amount <= b.on_demand_cost, "user {} over cap", b.user_id);
        }
    }

    #[test]
    fn streaming_run_matches_flat_run_bitwise() {
        let flat = rotating_fleet(6, 9);
        let pop = crate::trace::Population {
            users: (0..flat.len())
                .map(|i| crate::trace::UserTrace::new(flat.user_id(i), flat.demand(i).to_vec()))
                .collect(),
        };
        let dir = std::env::temp_dir().join("cldrsv_broker_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.cld2");
        crate::trace::io::write_chunked(&pop, &path, 4).unwrap();
        let mut chunked = ChunkedPopulation::open(&path).unwrap();
        let market = menu();
        let run = BrokerRun {
            market: &market,
            policy: PolicySpec::Deterministic { z: None, window: 0 },
            settlement: &ProportionalUsage,
            threads: 2,
            offline: false,
        };
        let a = run.run_flat(&flat).unwrap();
        let b = run.run_chunked(&mut chunked).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(a.aggregate.report.total.to_bits(), b.aggregate.report.total.to_bits());
        assert_eq!(a.standalone_total.to_bits(), b.standalone_total.to_bits());
        for (x, y) in a.bills.iter().zip(&b.bills) {
            assert_eq!(x.amount.to_bits(), y.amount.to_bits());
            assert_eq!(x.standalone_cost.to_bits(), y.standalone_cost.to_bits());
        }
    }
}
