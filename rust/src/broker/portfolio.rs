//! The shared reservation portfolio: run one online policy over the
//! *aggregate* demand curve, billing every slot through a single
//! [`Ledger`] that owns the broker's whole reservation book.
//!
//! The replay loop is bit-identical to
//! [`run_policy_market`](crate::sim::run_policy_market) (same oracle
//! future-window slices, same typed decisions, same ledger arithmetic) —
//! it is unrolled here only to additionally record the *portfolio
//! composition*: how many reservations of each contract the broker bought
//! and what it spent on their upfront fees, which the broker report
//! surfaces per contract label.

use crate::ledger::{CostReport, Ledger, LedgerError};
use crate::pricing::Market;
use crate::sim::fleet::PolicySpec;

/// How much of the portfolio one contract accounts for.
#[derive(Debug, Clone, PartialEq)]
pub struct ContractUse {
    pub label: String,
    pub reservations: u64,
    pub upfront_spend: f64,
}

/// Outcome of running one policy on the aggregate curve against the shared
/// ledger.
#[derive(Debug, Clone)]
pub struct PortfolioOutcome {
    /// Display name of the policy that drove the portfolio.
    pub policy: String,
    /// The shared ledger's cost report (the broker's realized cost).
    pub report: CostReport,
    /// Purchases broken down by contract, in menu order.
    pub per_contract: Vec<ContractUse>,
}

/// Replay `spec` over the aggregate `curve`, billing through one shared
/// [`Ledger`]. Window policies see oracle futures borrowed from the curve
/// (Sec. VI semantics, exactly as the per-user runners do). Randomized
/// policies draw from the spec seed itself (broker user id 0).
pub fn run_portfolio(
    curve: &[u32],
    market: &Market,
    spec: &PolicySpec,
) -> Result<PortfolioOutcome, LedgerError> {
    let mut policy = spec.build(market, 0);
    let w = policy.window();
    let mut ledger = Ledger::new(market.clone());
    let mut reservations = vec![0u64; market.len()];
    let mut upfront = vec![0f64; market.len()];
    for (t, &d) in curve.iter().enumerate() {
        let fut: &[u32] = if w == 0 {
            &[]
        } else {
            let hi = (t + 1 + w).min(curve.len());
            &curve[(t + 1).min(hi)..hi]
        };
        let dec = policy.decide(d, fut);
        ledger.bill(d, &dec)?;
        for &(cid, n) in dec.reservations {
            reservations[cid] += n as u64;
            upfront[cid] += n as f64 * market.contract(cid).upfront;
        }
    }
    let per_contract = (0..market.len())
        .map(|cid| ContractUse {
            label: market.label(cid).to_string(),
            reservations: reservations[cid],
            upfront_spend: upfront[cid],
        })
        .collect();
    Ok(PortfolioOutcome { policy: spec.name(), report: ledger.report(), per_contract })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::{Contract, Pricing};
    use crate::sim::run_policy_market;

    fn menu() -> Market {
        Market::new(
            0.08,
            vec![
                Contract { upfront: 0.1333, rate: 0.039, term: 4 },
                Contract { upfront: 0.3, rate: 0.031, term: 12 },
            ],
        )
    }

    fn curve() -> Vec<u32> {
        (0..240).map(|t| 1 + ((t / 17) % 3) as u32).collect()
    }

    #[test]
    fn matches_run_policy_market_bitwise() {
        let m = menu();
        let c = curve();
        for spec in [
            PolicySpec::AllOnDemand,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: None, window: 3 },
            PolicySpec::Randomized { window: 0, seed: 42 },
        ] {
            let pf = run_portfolio(&c, &m, &spec).unwrap();
            let mut p = spec.build(&m, 0);
            let reference = run_policy_market(p.as_mut(), &c, &m).unwrap();
            assert_eq!(pf.report.total.to_bits(), reference.total.to_bits(), "{}", spec.name());
            assert_eq!(pf.report, reference);
        }
    }

    #[test]
    fn per_contract_composition_sums_to_the_report() {
        let m = menu();
        let pf =
            run_portfolio(&curve(), &m, &PolicySpec::Deterministic { z: None, window: 0 }).unwrap();
        let total_res: u64 = pf.per_contract.iter().map(|c| c.reservations).sum();
        assert_eq!(total_res, pf.report.reservations);
        let total_fees: f64 = pf.per_contract.iter().map(|c| c.upfront_spend).sum();
        assert!((total_fees - pf.report.reservation_fees).abs() < 1e-9);
        assert_eq!(pf.per_contract.len(), 2);
        assert!(total_res >= 1, "a stable curve must trigger reservations");
    }

    #[test]
    fn single_contract_markets_run_the_classic_policies() {
        let m = Market::single(Pricing::normalized(0.1, 0.5, 10));
        let c: Vec<u32> = vec![2; 60];
        let pf = run_portfolio(&c, &m, &PolicySpec::Deterministic { z: None, window: 0 }).unwrap();
        assert!(pf.report.reservations >= 1);
        assert_eq!(pf.per_contract.len(), 1);
    }
}
