//! Demand aggregation: fold a fleet of per-user demand curves into one
//! aggregate curve the broker buys for, plus the per-user usage totals the
//! settlement schemes split the realized cost over.
//!
//! Aggregation is pure integer addition (`u64` per slot), so the streaming
//! chunk-at-a-time fold over a [`ChunkedPopulation`] is *bit-identical* to
//! the in-RAM [`FlatPopulation`] fold for any chunk size — pinned by
//! `tests/broker_props.rs` across chunk sizes 1/4/23/64. The `u64`
//! accumulator means 10⁵+ users at u32 demand levels cannot overflow; the
//! conversion back to the `u32` curve the policies replay is checked and
//! fails loudly if an aggregate slot exceeds `u32::MAX`.

use anyhow::{ensure, Result};

use crate::trace::io::ChunkedPopulation;
use crate::trace::FlatPopulation;

/// Per-user usage totals collected during aggregation — everything the
/// settlement schemes need: total instance-slots (the proportional weight)
/// and the peak (reported, and a cheap sanity signal for cap schemes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UserUsage {
    pub user_id: u32,
    /// Total instance-slots requested (Σ_t d_t).
    pub demand_slots: u64,
    /// Peak concurrent instances.
    pub peak: u32,
}

/// The aggregate demand curve of a fleet plus per-user usage, built by
/// folding users in one at a time (any source: in-RAM flat populations or
/// streamed trace chunks).
#[derive(Debug, Clone, Default)]
pub struct AggregateDemand {
    /// Aggregate demand per slot, `u64` so no realistic fleet overflows.
    slots: Vec<u64>,
    /// Usage of every folded user, in fold order.
    users: Vec<UserUsage>,
}

impl AggregateDemand {
    pub fn new() -> AggregateDemand {
        AggregateDemand::default()
    }

    /// Fold one user's demand curve into the aggregate.
    pub fn fold_user(&mut self, user_id: u32, demand: &[u32]) {
        if demand.len() > self.slots.len() {
            self.slots.resize(demand.len(), 0);
        }
        let mut total = 0u64;
        let mut peak = 0u32;
        for (slot, &d) in self.slots.iter_mut().zip(demand) {
            *slot += d as u64;
            total += d as u64;
            peak = peak.max(d);
        }
        self.users.push(UserUsage { user_id, demand_slots: total, peak });
    }

    /// Fold a whole columnar population, user by user in store order.
    pub fn fold_flat(&mut self, flat: &FlatPopulation) {
        for i in 0..flat.len() {
            self.fold_user(flat.user_id(i), flat.demand(i));
        }
    }

    /// Build from an in-RAM columnar population.
    pub fn from_flat(flat: &FlatPopulation) -> AggregateDemand {
        let mut agg = AggregateDemand::new();
        agg.fold_flat(flat);
        agg
    }

    /// Build by streaming a chunked v2 trace, one chunk resident at a time.
    /// Bit-identical to [`AggregateDemand::from_flat`] on the same users in
    /// the same order (integer folds commute with chunking).
    pub fn from_chunked(chunked: &mut ChunkedPopulation) -> Result<AggregateDemand> {
        let mut agg = AggregateDemand::new();
        let mut buf = FlatPopulation::default();
        for i in 0..chunked.n_chunks() {
            chunked.read_chunk_into(i, &mut buf)?;
            agg.fold_flat(&buf);
        }
        Ok(agg)
    }

    /// Number of users folded so far.
    pub fn n_users(&self) -> usize {
        self.users.len()
    }

    /// Aggregate horizon in slots (longest user curve).
    pub fn horizon(&self) -> usize {
        self.slots.len()
    }

    /// Per-user usage, in fold order.
    pub fn users(&self) -> &[UserUsage] {
        &self.users
    }

    /// Raw `u64` aggregate curve.
    pub fn slots(&self) -> &[u64] {
        &self.slots
    }

    /// Total instance-slots across the whole fleet.
    pub fn total_demand(&self) -> u64 {
        self.users.iter().map(|u| u.demand_slots).sum()
    }

    /// Peak aggregate demand.
    pub fn peak(&self) -> u64 {
        self.slots.iter().copied().max().unwrap_or(0)
    }

    /// The `u32` curve the online policies replay. Errors if any slot
    /// exceeds `u32::MAX` (rather than silently truncating a fleet).
    pub fn curve(&self) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(self.slots.len());
        for (t, &d) in self.slots.iter().enumerate() {
            ensure!(
                d <= u32::MAX as u64,
                "aggregate demand {d} at slot {t} exceeds u32::MAX; \
                 the policy replay cannot represent this fleet"
            );
            out.push(d as u32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(users: &[(u32, &[u32])]) -> FlatPopulation {
        let mut f = FlatPopulation::default();
        for &(id, d) in users {
            f.push_user(id, d);
        }
        f
    }

    #[test]
    fn folds_ragged_curves_to_the_longest_horizon() {
        let f = flat(&[(0, &[1, 2, 3]), (1, &[4]), (2, &[0, 5])]);
        let agg = AggregateDemand::from_flat(&f);
        assert_eq!(agg.n_users(), 3);
        assert_eq!(agg.horizon(), 3);
        assert_eq!(agg.slots(), &[5, 7, 3]);
        assert_eq!(agg.curve().unwrap(), vec![5, 7, 3]);
        assert_eq!(agg.total_demand(), 15);
        assert_eq!(agg.peak(), 7);
    }

    #[test]
    fn per_user_usage_is_collected_in_fold_order() {
        let f = flat(&[(7, &[2, 0, 1]), (9, &[0, 0, 0]), (11, &[3])]);
        let agg = AggregateDemand::from_flat(&f);
        assert_eq!(
            agg.users(),
            &[
                UserUsage { user_id: 7, demand_slots: 3, peak: 2 },
                UserUsage { user_id: 9, demand_slots: 0, peak: 0 },
                UserUsage { user_id: 11, demand_slots: 3, peak: 3 },
            ]
        );
    }

    #[test]
    fn curve_rejects_u32_overflow() {
        let mut agg = AggregateDemand::new();
        agg.fold_user(0, &[u32::MAX]);
        agg.fold_user(1, &[1]);
        assert_eq!(agg.slots()[0], u32::MAX as u64 + 1);
        let err = agg.curve().unwrap_err().to_string();
        assert!(err.contains("u32::MAX"), "{err}");
    }

    #[test]
    fn empty_aggregate_is_well_formed() {
        let agg = AggregateDemand::new();
        assert_eq!(agg.n_users(), 0);
        assert_eq!(agg.horizon(), 0);
        assert_eq!(agg.peak(), 0);
        assert!(agg.curve().unwrap().is_empty());
    }
}
