//! Settlement: split the broker's realized portfolio cost back into
//! per-user bills, conserving the total **bit-exactly**.
//!
//! Floating-point proportional splits cannot promise `Σ bills == total` to
//! the last bit, so the schemes here never divide money in `f64`. Instead
//! the total is decomposed as `total = m · q` with `m ≤ 2^53` the exact
//! integer mantissa and `q` a power of two (the *quantum*); the `m` quanta
//! are apportioned among users by the largest-remainder method in exact
//! `u128` integer arithmetic over integer usage weights, and user `i`'s
//! bill is `units_i · q`. Every bill and every partial sum of bills is an
//! integer `≤ 2^53` times the same power of two — exactly representable —
//! so plain sequential `f64` summation of the bills, **in any order**,
//! reproduces `total` bit-for-bit. `tests/broker_props.rs` pins this.
//!
//! Two schemes ship (the [`Settlement`] trait is open for more):
//!
//! * [`ProportionalUsage`] — quanta proportional to each user's total
//!   instance-slots.
//! * [`OnDemandCapped`] — the marginal-cost-style scheme: proportional,
//!   but no user pays more than their standalone all-on-demand cost
//!   `p·Σd_t`; surplus quanta water-fill over the uncapped users. If the
//!   broker somehow realizes more than the sum of caps (no settlement can
//!   respect the caps), it fails loudly instead of silently violating them.

use super::aggregate::UserUsage;
use crate::util::cli::expected_one_of;

/// Errors surfaced by settlement (Display/Error hand-written — `thiserror`
/// is not in the offline vendor set).
#[derive(Debug, Clone, PartialEq)]
pub enum SettlementError {
    /// The broker total is not a finite non-negative amount.
    BadTotal { total: f64 },
    /// The caps cannot absorb the broker total (od-capped scheme).
    TotalExceedsCaps { total: f64, cap_total: f64 },
}

impl std::fmt::Display for SettlementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            SettlementError::BadTotal { total } => {
                write!(f, "settlement: broker total {total} is not a finite non-negative cost")
            }
            SettlementError::TotalExceedsCaps { total, cap_total } => write!(
                f,
                "settlement: broker total {total} exceeds the sum of on-demand caps \
                 {cap_total}; no cap-respecting settlement exists"
            ),
        }
    }
}

impl std::error::Error for SettlementError {}

/// A pluggable settlement scheme: split the broker's realized `total`
/// across the users whose usage built the aggregate curve. Returns one
/// bill per user, aligned with `usage`; implementations must conserve the
/// total bit-exactly under plain `f64` summation (see the module docs for
/// the quantization recipe that makes this possible).
pub trait Settlement: Send + Sync {
    fn name(&self) -> &'static str;

    /// `p` is the market's on-demand rate (used by cap schemes for the
    /// standalone all-on-demand cost `p·demand_slots`).
    fn settle(
        &self,
        total: f64,
        usage: &[UserUsage],
        p: f64,
    ) -> Result<Vec<f64>, SettlementError>;
}

/// Valid scheme names for [`settlement_from_name`] (and CLI error text).
pub const SETTLEMENT_NAMES: &[&str] = &["proportional", "od-capped"];

/// Look up a settlement scheme by its spec/CLI name.
pub fn settlement_from_name(name: &str) -> anyhow::Result<Box<dyn Settlement>> {
    match name {
        "proportional" => Ok(Box::new(ProportionalUsage)),
        "od-capped" => Ok(Box::new(OnDemandCapped)),
        other => Err(anyhow::anyhow!(expected_one_of("settlement", other, SETTLEMENT_NAMES))),
    }
}

/// Decompose a positive finite `total` into `(m, q)` with `m ≤ 2^53` an
/// integer, `q` a power of two, and `total == m as f64 * q` exactly. Both
/// the mantissa extraction and the division are exact IEEE operations.
fn quantum(total: f64) -> (u64, f64) {
    debug_assert!(total > 0.0 && total.is_finite());
    let bits = total.to_bits();
    let exp = (bits >> 52) & 0x7ff;
    let frac = bits & ((1u64 << 52) - 1);
    let m = if exp == 0 { frac } else { frac | (1u64 << 52) };
    // m is exactly representable (≤ 2^53) and total / m is a power of two,
    // so the quotient is exact.
    (m, total / m as f64)
}

/// Hamilton / largest-remainder apportionment of `m` quanta over integer
/// `weights`, in exact `u128` arithmetic. `Σ result == m` whenever
/// `Σ weights > 0`; ties go to the lower index (deterministic).
fn apportion(m: u64, weights: &[u128]) -> Vec<u64> {
    let w_total: u128 = weights.iter().sum();
    let mut units = vec![0u64; weights.len()];
    if m == 0 || w_total == 0 {
        return units;
    }
    let mut assigned = 0u64;
    let mut rema: Vec<(u128, usize)> = Vec::with_capacity(weights.len());
    for (i, &w) in weights.iter().enumerate() {
        // m ≤ 2^53 and w ≤ 2^64, so the product fits u128 with headroom.
        let prod = m as u128 * w;
        let floor = (prod / w_total) as u64;
        units[i] = floor;
        assigned += floor;
        rema.push((prod % w_total, i));
    }
    let leftover = (m - assigned) as usize;
    rema.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in rema.iter().take(leftover) {
        units[i] += 1;
    }
    units
}

/// Turn per-user quantum counts into bills. Each bill (and any partial sum
/// of bills) is an integer ≤ 2^53 times the power-of-two quantum `q`, so
/// every `f64` operation here and in downstream summation is exact.
fn bills_from_units(units: &[u64], q: f64) -> Vec<f64> {
    units.iter().map(|&u| u as f64 * q).collect()
}

/// Saturating `f64 → u64` quantum-count conversion, for cap values that
/// may exceed the integer range.
///
/// The boundary deserves spelling out: `u64::MAX as f64` rounds **up** to
/// `2^64` (u64::MAX = 2^64 − 1 is not representable), so the obvious guard
/// `c >= u64::MAX as f64` actually compares against `2^64` — it admits
/// every representable f64 below `2^64`, the largest being
/// `2^64 − 2048`, all of which convert losslessly. Rust's `as` cast has
/// saturated on overflow since 1.45, so the behavior here is belt and
/// braces; the point of the helper is that the boundary is now *named*,
/// documented, and pinned by tests instead of re-derived at each call
/// site. NaN and negative inputs map to 0 (a cap that cannot absorb
/// anything), infinities and `≥ 2^64` to `u64::MAX`.
fn saturating_quanta(c: f64) -> u64 {
    if c.is_nan() || c <= 0.0 {
        0
    } else if c >= 18_446_744_073_709_551_616.0 {
        // 2^64: the rounded value of `u64::MAX as f64`
        u64::MAX
    } else {
        c as u64
    }
}

/// Shared entry guard: zero totals settle to all-zero bills; negative or
/// non-finite totals are rejected.
fn check_total(total: f64, n: usize) -> Result<Option<Vec<f64>>, SettlementError> {
    if !total.is_finite() || total < 0.0 {
        return Err(SettlementError::BadTotal { total });
    }
    if total == 0.0 {
        return Ok(Some(vec![0.0; n]));
    }
    Ok(None)
}

/// Proportional-to-usage settlement: quanta ∝ total instance-slots. Users
/// with zero usage pay nothing (unless *every* user has zero usage, in
/// which case the cost is split evenly — a degenerate fleet should still
/// conserve).
pub struct ProportionalUsage;

impl Settlement for ProportionalUsage {
    fn name(&self) -> &'static str {
        "proportional"
    }

    fn settle(
        &self,
        total: f64,
        usage: &[UserUsage],
        _p: f64,
    ) -> Result<Vec<f64>, SettlementError> {
        if let Some(zeros) = check_total(total, usage.len())? {
            return Ok(zeros);
        }
        let (m, q) = quantum(total);
        let mut weights: Vec<u128> = usage.iter().map(|u| u.demand_slots as u128).collect();
        if weights.iter().all(|&w| w == 0) {
            weights.iter_mut().for_each(|w| *w = 1);
        }
        Ok(bills_from_units(&apportion(m, &weights), q))
    }
}

/// Proportional settlement capped at each user's standalone all-on-demand
/// cost `p·demand_slots`: surplus quanta from capped users water-fill over
/// the remaining users (still usage-proportional) until everything is
/// placed. Guarantees `bill_i ≤ p·d_i` *exactly* (each cap is
/// `⌊od_i / q⌋` quanta, and `q`-divisions are exact), on top of the
/// bit-exact conservation shared by all schemes.
pub struct OnDemandCapped;

impl Settlement for OnDemandCapped {
    fn name(&self) -> &'static str {
        "od-capped"
    }

    fn settle(
        &self,
        total: f64,
        usage: &[UserUsage],
        p: f64,
    ) -> Result<Vec<f64>, SettlementError> {
        if let Some(zeros) = check_total(total, usage.len())? {
            return Ok(zeros);
        }
        let (m, q) = quantum(total);
        let n = usage.len();
        let weights: Vec<u128> = usage.iter().map(|u| u.demand_slots as u128).collect();
        // Cap in quanta: ⌊(p·d_i) / q⌋. The division by a power of two is
        // exact, so the floor never rounds a cap-respecting bill away.
        let caps: Vec<u64> = usage
            .iter()
            .map(|u| {
                let od = p * u.demand_slots as f64;
                saturating_quanta((od / q).floor())
            })
            .collect();
        let cap_total: u128 = caps.iter().map(|&c| c as u128).sum();
        if (m as u128) > cap_total {
            // Report the cap sum the comparison actually used: the exact
            // integer quantum count scaled back to money. A float sum of
            // the per-user `p·d_i` here could overflow to infinity (or
            // round the other way) on extreme fleets and contradict the
            // integer verdict above.
            let cap_sum = cap_total as f64 * q;
            return Err(SettlementError::TotalExceedsCaps { total, cap_total: cap_sum });
        }

        // Water-fill: fix violators at their caps, re-apportion the rest
        // over the uncapped set. Each round either finishes or caps at
        // least one more user, so it terminates in ≤ n rounds.
        let mut units = vec![0u64; n];
        let mut capped = vec![false; n];
        let mut remaining = m;
        loop {
            if remaining == 0 {
                break;
            }
            let mut ws = vec![0u128; n];
            let mut any_weight = false;
            for i in 0..n {
                if !capped[i] {
                    ws[i] = weights[i];
                    any_weight |= weights[i] > 0;
                }
            }
            if !any_weight {
                // only zero-usage users left uncapped: spread by headroom
                for i in 0..n {
                    if !capped[i] {
                        ws[i] = (caps[i] - units[i]) as u128;
                    }
                }
            }
            let share = apportion(remaining, &ws);
            let mut violated = false;
            for i in 0..n {
                if !capped[i] && share[i] > caps[i] {
                    units[i] = caps[i];
                    capped[i] = true;
                    remaining -= caps[i];
                    violated = true;
                }
            }
            if !violated {
                for i in 0..n {
                    if !capped[i] {
                        units[i] = share[i];
                    }
                }
                break;
            }
        }
        Ok(bills_from_units(&units, q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn usage(slots: &[u64]) -> Vec<UserUsage> {
        slots
            .iter()
            .enumerate()
            .map(|(i, &d)| UserUsage { user_id: i as u32, demand_slots: d, peak: 1 })
            .collect()
    }

    fn assert_conserves(bills: &[f64], total: f64) {
        let fwd: f64 = bills.iter().sum();
        let rev: f64 = bills.iter().rev().sum();
        assert_eq!(fwd.to_bits(), total.to_bits(), "forward sum drifted");
        assert_eq!(rev.to_bits(), total.to_bits(), "reverse sum drifted");
    }

    #[test]
    fn quantum_reconstructs_exactly() {
        for &t in &[0.1, 1.0, 3.5, 1e-12, 7.25e9, 0.08 * 41_760.0] {
            let (m, q) = quantum(t);
            assert!(m <= 1u64 << 53);
            assert_eq!((m as f64 * q).to_bits(), t.to_bits(), "total {t}");
        }
    }

    #[test]
    fn apportion_is_exact_and_deterministic() {
        let units = apportion(10, &[1, 1, 1]);
        assert_eq!(units.iter().sum::<u64>(), 10);
        // 10/3 → floors 3,3,3; equal remainders, leftover goes to index 0
        assert_eq!(units, vec![4, 3, 3]);
        assert_eq!(apportion(0, &[5, 5]), vec![0, 0]);
        assert_eq!(apportion(7, &[0, 0]), vec![0, 0]);
    }

    #[test]
    fn proportional_conserves_bitwise() {
        let u = usage(&[100, 33, 0, 67]);
        let total = 12.3456789;
        let bills = ProportionalUsage.settle(total, &u, 0.1).unwrap();
        assert_conserves(&bills, total);
        assert_eq!(bills[2], 0.0, "zero-usage user pays nothing");
        assert!(bills[0] > bills[1]);
    }

    #[test]
    fn proportional_zero_total_and_zero_usage() {
        let u = usage(&[0, 0]);
        assert_eq!(ProportionalUsage.settle(0.0, &u, 0.1).unwrap(), vec![0.0, 0.0]);
        // all-zero usage with positive total still conserves (even split)
        let bills = ProportionalUsage.settle(1.0, &u, 0.1).unwrap();
        assert_conserves(&bills, 1.0);
    }

    #[test]
    fn od_capped_respects_caps_exactly() {
        // user 0 dominates usage but its cap binds; user 1 absorbs surplus
        let u = usage(&[10, 1000]);
        let p = 0.01;
        let total = 5.0; // user 0's cap: 0.1
        let bills = OnDemandCapped.settle(total, &u, p).unwrap();
        assert_conserves(&bills, total);
        for (b, uu) in bills.iter().zip(&u) {
            assert!(*b <= p * uu.demand_slots as f64, "bill {b} above cap");
        }
    }

    #[test]
    fn od_capped_rejects_infeasible_totals() {
        let u = usage(&[1, 1]);
        let err = OnDemandCapped.settle(10.0, &u, 0.1).unwrap_err();
        assert!(matches!(err, SettlementError::TotalExceedsCaps { .. }), "{err}");
    }

    #[test]
    fn saturating_quanta_pins_the_boundary() {
        const TWO_POW_64: f64 = 18_446_744_073_709_551_616.0;
        // the rounding fact the helper documents
        assert_eq!(u64::MAX as f64, TWO_POW_64);
        // largest f64 strictly below 2^64 converts losslessly
        let below = f64::from_bits(TWO_POW_64.to_bits() - 1);
        assert_eq!(below, 18_446_744_073_709_549_568.0); // 2^64 - 2048
        assert_eq!(saturating_quanta(below), 18_446_744_073_709_549_568);
        // at and above 2^64: saturate
        assert_eq!(saturating_quanta(TWO_POW_64), u64::MAX);
        assert_eq!(saturating_quanta(TWO_POW_64 * 2.0), u64::MAX);
        assert_eq!(saturating_quanta(f64::INFINITY), u64::MAX);
        // degenerate inputs absorb nothing
        assert_eq!(saturating_quanta(f64::NAN), 0);
        assert_eq!(saturating_quanta(-1.0), 0);
        assert_eq!(saturating_quanta(0.0), 0);
        assert_eq!(saturating_quanta(0.75), 0);
        assert_eq!(saturating_quanta(3.0), 3);
    }

    #[test]
    fn od_capped_survives_saturated_caps() {
        // A cap near the u64 boundary: q is tiny (total ≈ 1), so od/q for a
        // huge user overflows the quantum range and must saturate rather
        // than wrap. The settlement still conserves and respects caps.
        let u = usage(&[u64::MAX / 2, 4]);
        let p = 1e6;
        let total = 1.0;
        let bills = OnDemandCapped.settle(total, &u, p).unwrap();
        assert_conserves(&bills, total);
        for (b, uu) in bills.iter().zip(&u) {
            assert!(*b <= p * uu.demand_slots as f64, "bill {b} above cap");
        }
    }

    #[test]
    fn od_capped_error_reports_the_exact_cap_sum() {
        // Caps are 10 quanta each of the total's quantum; the reported
        // cap_total must be the integer quantum count scaled by q — i.e.
        // exactly representable and strictly below the rejected total.
        let u = usage(&[1, 1]);
        let p = 0.1;
        let total = 10.0;
        let err = OnDemandCapped.settle(total, &u, p).unwrap_err();
        match err {
            SettlementError::TotalExceedsCaps { total: t, cap_total } => {
                assert_eq!(t.to_bits(), total.to_bits());
                assert!(cap_total < total, "cap_total {cap_total} not below total");
                // consistent with the integer comparison: cap_total is a
                // whole number of quanta
                let (_, q) = quantum(total);
                let units = cap_total / q;
                assert_eq!(units.fract(), 0.0, "cap_total {cap_total} not quantum-aligned");
                // and within one quantum per user of the float cap sum
                let float_sum: f64 = u.iter().map(|x| p * x.demand_slots as f64).sum();
                assert!((float_sum - cap_total).abs() <= q * u.len() as f64);
            }
            other => panic!("expected TotalExceedsCaps, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_finite_totals() {
        let u = usage(&[1]);
        for bad in [f64::NAN, f64::INFINITY, -1.0] {
            assert!(matches!(
                ProportionalUsage.settle(bad, &u, 0.1),
                Err(SettlementError::BadTotal { .. })
            ));
        }
    }

    #[test]
    fn from_name_lists_valid_names_on_error() {
        assert_eq!(settlement_from_name("proportional").unwrap().name(), "proportional");
        assert_eq!(settlement_from_name("od-capped").unwrap().name(), "od-capped");
        let err = settlement_from_name("magic").unwrap_err().to_string();
        assert!(err.contains("proportional") && err.contains("od-capped"), "{err}");
    }
}
