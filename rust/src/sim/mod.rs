//! Simulation engine, v2: replay demand traces through policies over a
//! [`Market`] menu, bill through the [`Ledger`](crate::ledger::Ledger),
//! and aggregate fleet-wide results (the machinery behind Fig. 5-7,
//! Table II, and the declarative [`scenario`] runner).
//!
//! Layers, bottom up:
//!
//! * [`run_policy_market`] / [`run_policy_src`] — one policy, one demand
//!   curve, one `&Market`; decisions are typed
//!   [`Decision`](crate::algos::Decision)s billed per contract.
//!   [`run_policy`] is the single-contract convenience taking a classic
//!   [`Pricing`] through the bit-identical [`Market::single`] embedding.
//! * [`engine`] — the batched zero-allocation fleet engine (monomorphic
//!   dispatch, columnar traces, contiguous shards). Single-contract
//!   markets take the classic policy fast path; multi-contract markets run
//!   the menu policies ([`crate::algos::market`]).
//! * [`fleet`] — policy specs, per-user results, the Sec. VII suite, and
//!   the seed reference runner kept as the parity oracle.
//! * [`scenario`] — declarative JSON scenarios: market menu + trace source
//!   + policy set in a config file, normalized-cost reports out.

pub mod engine;
pub mod fleet;
pub mod scenario;

use crate::algos::Policy;
use crate::ledger::{CostReport, Ledger, LedgerError};
use crate::pricing::{Market, Pricing};

/// A per-slot future-demand provider: `future(t)` yields the predicted
/// demands `d̂_{t+1}, …, d̂_{t+w}` (possibly shorter near the trace tail)
/// as a **borrowed slice** — the replay hot path never allocates.
///
/// Implementors lend from either the actual trace ([`OracleFuture`]) or an
/// internal reusable buffer ([`BufferedFuture`], forecaster adapters).
pub trait FutureSource {
    fn future(&mut self, t: usize) -> &[u32];
}

/// Oracle provider: borrows the future window straight from the actual
/// demand curve (the paper's reliable-prediction assumption, Sec. VI).
/// Zero-copy, zero-allocation.
#[derive(Debug, Clone, Copy)]
pub struct OracleFuture<'a> {
    demands: &'a [u32],
    w: usize,
}

impl<'a> OracleFuture<'a> {
    pub fn new(demands: &'a [u32], w: usize) -> OracleFuture<'a> {
        OracleFuture { demands, w }
    }
}

impl FutureSource for OracleFuture<'_> {
    #[inline]
    fn future(&mut self, t: usize) -> &[u32] {
        let hi = (t + 1 + self.w).min(self.demands.len());
        let lo = (t + 1).min(hi);
        &self.demands[lo..hi]
    }
}

/// Closure-backed provider: the closure **fills a reusable buffer**
/// (cleared before every call), so the compatibility path is also
/// allocation-free in the slot loop once the buffer has grown to the
/// window size — `clear()` keeps capacity.
pub struct BufferedFuture<F: FnMut(usize, &mut Vec<u32>)> {
    f: F,
    buf: Vec<u32>,
}

impl<F: FnMut(usize, &mut Vec<u32>)> BufferedFuture<F> {
    pub fn new(f: F) -> BufferedFuture<F> {
        BufferedFuture { f, buf: Vec::new() }
    }
}

impl<F: FnMut(usize, &mut Vec<u32>)> FutureSource for BufferedFuture<F> {
    fn future(&mut self, t: usize) -> &[u32] {
        self.buf.clear();
        (self.f)(t, &mut self.buf);
        &self.buf
    }
}

/// Run one policy over one demand curve against a classic single-contract
/// [`Pricing`] — the [`Market::single`] fast path, bit-identical to the v1
/// arithmetic. See [`run_policy_market`] for menus.
pub fn run_policy(
    policy: &mut dyn Policy,
    demands: &[u32],
    pricing: Pricing,
) -> Result<CostReport, LedgerError> {
    run_policy_market(policy, demands, &Market::single(pricing))
}

/// Run one policy over one demand curve against a [`Market`], billing
/// every slot through a menu ledger.
///
/// `future` slices are borrowed from the *actual* demand (the paper's
/// assumption that short-term predictions are reliable, Sec. VI); pass a
/// forecaster-backed provider through [`run_policy_with`] (or any
/// [`FutureSource`] through [`run_policy_src`]) to study imperfect
/// predictions.
pub fn run_policy_market(
    policy: &mut dyn Policy,
    demands: &[u32],
    market: &Market,
) -> Result<CostReport, LedgerError> {
    let w = policy.window();
    run_policy_src(policy, demands, market, &mut OracleFuture::new(demands, w))
}

/// Run one policy with a custom future-demand closure that fills the
/// provided buffer with the predicted demands for `t+1..=t+w`.
/// Compatibility wrapper over [`run_policy_src`].
pub fn run_policy_with(
    policy: &mut dyn Policy,
    demands: &[u32],
    pricing: Pricing,
    future: impl FnMut(usize, &mut Vec<u32>),
) -> Result<CostReport, LedgerError> {
    run_policy_src(policy, demands, &Market::single(pricing), &mut BufferedFuture::new(future))
}

/// Core replay loop over any [`FutureSource`]. The provider is only
/// consulted for window policies (`w > 0`).
pub fn run_policy_src(
    policy: &mut dyn Policy,
    demands: &[u32],
    market: &Market,
    future: &mut dyn FutureSource,
) -> Result<CostReport, LedgerError> {
    let mut ledger = Ledger::new(market.clone());
    let w = policy.window();
    for (t, &d) in demands.iter().enumerate() {
        let fut: &[u32] = if w == 0 { &[] } else { future.future(t) };
        let dec = policy.decide(d, fut);
        ledger.bill(d, &dec)?;
    }
    Ok(ledger.report())
}

/// Cost of serving a demand curve entirely on demand (`S = p·Σd_t`) at
/// on-demand rate `p` — the normalization denominator used throughout
/// Sec. VII (pass `pricing.p` or `market.p()`).
pub fn all_on_demand_cost(demands: &[u32], p: f64) -> f64 {
    p * demands.iter().map(|&d| d as u64).sum::<u64>() as f64
}

/// The one per-user seed derivation, shared by every seeded policy
/// construction and reseed site (boxed reference path, batched engine,
/// learned-policy reseed). The formula is **pinned**: golden fixtures and
/// the `gen_golden.py` Python port both encode `base ^ (user_id << 17)`,
/// so changing it breaks reseed-equals-fresh bit-parity everywhere at once
/// — which is exactly why it lives in one place.
pub(crate) fn per_user_seed(base: u64, user_id: u32) -> u64 {
    base ^ ((user_id as u64) << 17)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::baselines::AllOnDemand;
    use crate::algos::deterministic::Deterministic;
    use crate::algos::market::MarketDeterministic;

    #[test]
    fn run_policy_matches_manual_bill() {
        let pricing = Pricing::normalized(0.1, 0.5, 4);
        let demands = [1u32, 2, 0, 3];
        let r = run_policy(&mut AllOnDemand::new(), &demands, pricing).unwrap();
        assert!((r.total - 0.1 * 6.0).abs() < 1e-12);
        assert!((r.total - all_on_demand_cost(&demands, pricing.p)).abs() < 1e-12);
    }

    #[test]
    fn custom_future_provider_is_used() {
        // A window policy fed an all-zero forecast behaves like one that
        // never sees future demand spikes.
        let pricing = Pricing::normalized(0.1, 0.0, 50);
        let demands = vec![1u32; 40];
        let mut with_oracle = Deterministic::with_window(pricing, 10);
        let mut with_zeros = Deterministic::with_window(pricing, 10);
        let r_oracle = run_policy(&mut with_oracle, &demands, pricing).unwrap();
        let r_zeros = run_policy_with(&mut with_zeros, &demands, pricing, |_, buf| {
            buf.resize(10, 0);
        })
        .unwrap();
        // oracle foresees break-even sooner -> fewer on-demand slots
        assert!(r_oracle.on_demand_slots <= r_zeros.on_demand_slots);
    }

    #[test]
    fn oracle_future_matches_closure_provider_bitwise() {
        // The borrowed-slice oracle must reproduce the buffered closure
        // path exactly (bit-identical costs) for a window policy.
        let pricing = Pricing::normalized(0.1, 0.0, 50);
        let demands: Vec<u32> = (0..200).map(|i| ((i / 13) % 3) as u32).collect();
        let w = 10;
        let mut a = Deterministic::with_window(pricing, w);
        let mut b = Deterministic::with_window(pricing, w);
        let r_oracle = run_policy(&mut a, &demands, pricing).unwrap();
        let r_closure = run_policy_with(&mut b, &demands, pricing, |t, buf| {
            let hi = (t + 1 + w).min(demands.len());
            buf.extend_from_slice(&demands[t + 1..hi]);
        })
        .unwrap();
        assert_eq!(r_oracle.total.to_bits(), r_closure.total.to_bits());
        assert_eq!(r_oracle.reservations, r_closure.reservations);
        assert_eq!(r_oracle.on_demand_slots, r_closure.on_demand_slots);
    }

    #[test]
    fn buffered_future_reuses_its_buffer() {
        // the closure sees a cleared buffer each slot and fills it in place
        let mut calls = 0usize;
        let mut src = BufferedFuture::new(|t, buf: &mut Vec<u32>| {
            calls += 1;
            assert!(buf.is_empty());
            buf.extend((0..3).map(|i| (t + i) as u32));
        });
        assert_eq!(src.future(5), &[5, 6, 7]);
        assert_eq!(src.future(9), &[9, 10, 11]);
        drop(src);
        assert_eq!(calls, 2);
    }

    #[test]
    fn oracle_future_tail_shrinks() {
        let demands = [1u32, 2, 3];
        let mut src = OracleFuture::new(&demands, 5);
        assert_eq!(src.future(0), &[2, 3]);
        assert_eq!(src.future(1), &[3]);
        assert_eq!(src.future(2), &[] as &[u32]);
    }

    #[test]
    fn identity_holds_for_policy_runs() {
        let pricing = Pricing::normalized(0.05, 0.4875, 30);
        let demands: Vec<u32> = (0..300).map(|i| ((i / 17) % 4) as u32).collect();
        let mut det = Deterministic::online(pricing);
        let r = run_policy(&mut det, &demands, pricing).unwrap();
        assert!(r.identity_holds(&pricing, 1e-9));
    }

    #[test]
    fn per_user_seed_formula_is_pinned() {
        // The exact bits matter: fixtures and the Python port encode them.
        assert_eq!(per_user_seed(0, 0), 0);
        assert_eq!(per_user_seed(0, 1), 1 << 17);
        assert_eq!(per_user_seed(0xFEED, 3), 0xFEED ^ (3u64 << 17));
        assert_eq!(per_user_seed(u64::MAX, u32::MAX), u64::MAX ^ ((u32::MAX as u64) << 17));
    }

    #[test]
    fn run_policy_market_accepts_menu_policies() {
        let market = crate::pricing::Market::new(
            0.1,
            vec![
                crate::pricing::Contract { upfront: 0.3, rate: 0.02, term: 8 },
                crate::pricing::Contract { upfront: 0.9, rate: 0.01, term: 30 },
            ],
        );
        let demands: Vec<u32> = (0..120).map(|i| ((i / 9) % 3) as u32).collect();
        let mut p = MarketDeterministic::new(market.clone());
        let r = run_policy_market(&mut p, &demands, &market).unwrap();
        assert!(r.total.is_finite());
        assert_eq!(r.demand_slots, demands.iter().map(|&d| d as u64).sum::<u64>());
    }
}
