//! Simulation engine: replay demand traces through policies, bill through
//! the [`Ledger`](crate::ledger::Ledger), and aggregate fleet-wide results
//! (the machinery behind Fig. 5-7 and Table II).

pub mod fleet;

use crate::algos::Policy;
use crate::ledger::{CostReport, Ledger, LedgerError};
use crate::pricing::Pricing;

/// Run one policy over one demand curve, billing every slot.
///
/// `future` slices are taken from the *actual* demand (the paper's
/// assumption that short-term predictions are reliable, Sec. VI); pass a
/// forecaster-backed provider through [`run_policy_with`] to study
/// imperfect predictions.
pub fn run_policy(policy: &mut dyn Policy, demands: &[u32], pricing: Pricing) -> Result<CostReport, LedgerError> {
    let w = policy.window();
    run_policy_with(policy, demands, pricing, |t| {
        let hi = (t + 1 + w).min(demands.len());
        demands[t + 1..hi].to_vec()
    })
}

/// Run one policy with a custom future-demand provider (`t -> predicted
/// demands for t+1..=t+w`).
pub fn run_policy_with(
    policy: &mut dyn Policy,
    demands: &[u32],
    pricing: Pricing,
    mut future: impl FnMut(usize) -> Vec<u32>,
) -> Result<CostReport, LedgerError> {
    let mut ledger = Ledger::new(pricing);
    let w = policy.window();
    for (t, &d) in demands.iter().enumerate() {
        let fut = if w == 0 { Vec::new() } else { future(t) };
        let dec = policy.decide(d, &fut);
        ledger.bill_slot(d, dec.reserve, dec.on_demand)?;
    }
    Ok(ledger.report())
}

/// Cost of serving a demand curve entirely on demand (`S = p·Σd_t`) — the
/// normalization denominator used throughout Sec. VII.
pub fn all_on_demand_cost(demands: &[u32], pricing: &Pricing) -> f64 {
    pricing.p * demands.iter().map(|&d| d as u64).sum::<u64>() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::baselines::AllOnDemand;
    use crate::algos::deterministic::Deterministic;

    #[test]
    fn run_policy_matches_manual_bill() {
        let pricing = Pricing::normalized(0.1, 0.5, 4);
        let demands = [1u32, 2, 0, 3];
        let r = run_policy(&mut AllOnDemand::new(), &demands, pricing).unwrap();
        assert!((r.total - 0.1 * 6.0).abs() < 1e-12);
        assert!((r.total - all_on_demand_cost(&demands, &pricing)).abs() < 1e-12);
    }

    #[test]
    fn custom_future_provider_is_used() {
        // A window policy fed an all-zero forecast behaves like one that
        // never sees future demand spikes.
        let pricing = Pricing::normalized(0.1, 0.0, 50);
        let demands = vec![1u32; 40];
        let mut with_oracle = Deterministic::with_window(pricing, 10);
        let mut with_zeros = Deterministic::with_window(pricing, 10);
        let r_oracle = run_policy(&mut with_oracle, &demands, pricing).unwrap();
        let r_zeros =
            run_policy_with(&mut with_zeros, &demands, pricing, |_| vec![0; 10]).unwrap();
        // oracle foresees break-even sooner -> fewer on-demand slots
        assert!(r_oracle.on_demand_slots <= r_zeros.on_demand_slots);
    }

    #[test]
    fn identity_holds_for_policy_runs() {
        let pricing = Pricing::normalized(0.05, 0.4875, 30);
        let demands: Vec<u32> = (0..300).map(|i| ((i / 17) % 4) as u32).collect();
        let mut det = Deterministic::online(pricing);
        let r = run_policy(&mut det, &demands, pricing).unwrap();
        assert!(r.identity_holds(&pricing, 1e-9));
    }
}
