//! Simulation engine: replay demand traces through policies, bill through
//! the [`Ledger`](crate::ledger::Ledger), and aggregate fleet-wide results
//! (the machinery behind Fig. 5-7 and Table II).

pub mod engine;
pub mod fleet;

use crate::algos::Policy;
use crate::ledger::{CostReport, Ledger, LedgerError};
use crate::pricing::Pricing;

/// A per-slot future-demand provider: `future(t)` yields the predicted
/// demands `d̂_{t+1}, …, d̂_{t+w}` (possibly shorter near the trace tail)
/// as a **borrowed slice** — the replay hot path never allocates.
///
/// Implementors lend from either the actual trace ([`OracleFuture`]) or an
/// internal reusable buffer ([`BufferedFuture`], forecaster adapters).
pub trait FutureSource {
    fn future(&mut self, t: usize) -> &[u32];
}

/// Oracle provider: borrows the future window straight from the actual
/// demand curve (the paper's reliable-prediction assumption, Sec. VI).
/// Zero-copy, zero-allocation.
#[derive(Debug, Clone, Copy)]
pub struct OracleFuture<'a> {
    demands: &'a [u32],
    w: usize,
}

impl<'a> OracleFuture<'a> {
    pub fn new(demands: &'a [u32], w: usize) -> OracleFuture<'a> {
        OracleFuture { demands, w }
    }
}

impl FutureSource for OracleFuture<'_> {
    #[inline]
    fn future(&mut self, t: usize) -> &[u32] {
        let hi = (t + 1 + self.w).min(self.demands.len());
        let lo = (t + 1).min(hi);
        &self.demands[lo..hi]
    }
}

/// Closure-backed provider (the pre-engine API): owns the closure's output
/// so the borrowed-slice contract holds. Allocates whatever the closure
/// allocates — use [`OracleFuture`] or a buffer-reusing source on hot paths.
pub struct BufferedFuture<F: FnMut(usize) -> Vec<u32>> {
    f: F,
    buf: Vec<u32>,
}

impl<F: FnMut(usize) -> Vec<u32>> BufferedFuture<F> {
    pub fn new(f: F) -> BufferedFuture<F> {
        BufferedFuture { f, buf: Vec::new() }
    }
}

impl<F: FnMut(usize) -> Vec<u32>> FutureSource for BufferedFuture<F> {
    fn future(&mut self, t: usize) -> &[u32] {
        self.buf = (self.f)(t);
        &self.buf
    }
}

/// Run one policy over one demand curve, billing every slot.
///
/// `future` slices are borrowed from the *actual* demand (the paper's
/// assumption that short-term predictions are reliable, Sec. VI); pass a
/// forecaster-backed provider through [`run_policy_with`] (or any
/// [`FutureSource`] through [`run_policy_src`]) to study imperfect
/// predictions.
pub fn run_policy(policy: &mut dyn Policy, demands: &[u32], pricing: Pricing) -> Result<CostReport, LedgerError> {
    let w = policy.window();
    run_policy_src(policy, demands, pricing, &mut OracleFuture::new(demands, w))
}

/// Run one policy with a custom future-demand closure (`t -> predicted
/// demands for t+1..=t+w`). Compatibility wrapper over [`run_policy_src`].
pub fn run_policy_with(
    policy: &mut dyn Policy,
    demands: &[u32],
    pricing: Pricing,
    future: impl FnMut(usize) -> Vec<u32>,
) -> Result<CostReport, LedgerError> {
    run_policy_src(policy, demands, pricing, &mut BufferedFuture::new(future))
}

/// Core replay loop over any [`FutureSource`]. The provider is only
/// consulted for window policies (`w > 0`).
pub fn run_policy_src(
    policy: &mut dyn Policy,
    demands: &[u32],
    pricing: Pricing,
    future: &mut dyn FutureSource,
) -> Result<CostReport, LedgerError> {
    let mut ledger = Ledger::new(pricing);
    let w = policy.window();
    for (t, &d) in demands.iter().enumerate() {
        let fut: &[u32] = if w == 0 { &[] } else { future.future(t) };
        let dec = policy.decide(d, fut);
        ledger.bill_slot(d, dec.reserve, dec.on_demand)?;
    }
    Ok(ledger.report())
}

/// Cost of serving a demand curve entirely on demand (`S = p·Σd_t`) — the
/// normalization denominator used throughout Sec. VII.
pub fn all_on_demand_cost(demands: &[u32], pricing: &Pricing) -> f64 {
    pricing.p * demands.iter().map(|&d| d as u64).sum::<u64>() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::baselines::AllOnDemand;
    use crate::algos::deterministic::Deterministic;

    #[test]
    fn run_policy_matches_manual_bill() {
        let pricing = Pricing::normalized(0.1, 0.5, 4);
        let demands = [1u32, 2, 0, 3];
        let r = run_policy(&mut AllOnDemand::new(), &demands, pricing).unwrap();
        assert!((r.total - 0.1 * 6.0).abs() < 1e-12);
        assert!((r.total - all_on_demand_cost(&demands, &pricing)).abs() < 1e-12);
    }

    #[test]
    fn custom_future_provider_is_used() {
        // A window policy fed an all-zero forecast behaves like one that
        // never sees future demand spikes.
        let pricing = Pricing::normalized(0.1, 0.0, 50);
        let demands = vec![1u32; 40];
        let mut with_oracle = Deterministic::with_window(pricing, 10);
        let mut with_zeros = Deterministic::with_window(pricing, 10);
        let r_oracle = run_policy(&mut with_oracle, &demands, pricing).unwrap();
        let r_zeros =
            run_policy_with(&mut with_zeros, &demands, pricing, |_| vec![0; 10]).unwrap();
        // oracle foresees break-even sooner -> fewer on-demand slots
        assert!(r_oracle.on_demand_slots <= r_zeros.on_demand_slots);
    }

    #[test]
    fn oracle_future_matches_closure_provider_bitwise() {
        // The borrowed-slice oracle must reproduce the old to_vec() path
        // exactly (bit-identical costs) for a window policy.
        let pricing = Pricing::normalized(0.1, 0.0, 50);
        let demands: Vec<u32> = (0..200).map(|i| ((i / 13) % 3) as u32).collect();
        let w = 10;
        let mut a = Deterministic::with_window(pricing, w);
        let mut b = Deterministic::with_window(pricing, w);
        let r_oracle = run_policy(&mut a, &demands, pricing).unwrap();
        let r_closure = run_policy_with(&mut b, &demands, pricing, |t| {
            let hi = (t + 1 + w).min(demands.len());
            demands[t + 1..hi].to_vec()
        })
        .unwrap();
        assert_eq!(r_oracle.total.to_bits(), r_closure.total.to_bits());
        assert_eq!(r_oracle.reservations, r_closure.reservations);
        assert_eq!(r_oracle.on_demand_slots, r_closure.on_demand_slots);
    }

    #[test]
    fn oracle_future_tail_shrinks() {
        let demands = [1u32, 2, 3];
        let mut src = OracleFuture::new(&demands, 5);
        assert_eq!(src.future(0), &[2, 3]);
        assert_eq!(src.future(1), &[3]);
        assert_eq!(src.future(2), &[] as &[u32]);
    }

    #[test]
    fn identity_holds_for_policy_runs() {
        let pricing = Pricing::normalized(0.05, 0.4875, 30);
        let demands: Vec<u32> = (0..300).map(|i| ((i / 17) % 4) as u32).collect();
        let mut det = Deterministic::online(pricing);
        let r = run_policy(&mut det, &demands, pricing).unwrap();
        assert!(r.identity_holds(&pricing, 1e-9));
    }
}
