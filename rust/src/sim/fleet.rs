//! Fleet-scale evaluation: run a suite of policies across a whole user
//! population in parallel, producing the per-user normalized costs behind
//! Fig. 5-7 and the per-group means of Table II.
//!
//! [`run_fleet`] drives the batched zero-allocation engine
//! ([`crate::sim::engine`]) over a columnar [`FlatPopulation`]; the seed
//! implementation (strided `mpsc` sharding over `Box<dyn Policy>`) is kept
//! verbatim as [`run_fleet_reference`] — it is the golden model for the
//! engine-parity tests and the baseline the `bench` CLI measures speedups
//! against. Both paths take a [`Market`]; single-contract markets run the
//! classic policies (bit-identical to v1 for [`Market::single`]), menus
//! run the generalized policies of [`crate::algos::market`].

use std::sync::mpsc;
use std::thread;

use crate::algos::learned::{AdaptiveWindow, UcbThreshold};
use crate::algos::market::{MarketDeterministic, MarketRandomized, PinnedSingle};
use crate::algos::{
    baselines, deterministic::Deterministic, randomized::Randomized, Policy, SaveState,
};
use crate::analysis::classify::{classify, Group};
use crate::pricing::Market;
use crate::sim::engine::run_fleet_flat;
use crate::sim::{all_on_demand_cost, per_user_seed, run_policy_market};
use crate::trace::{FlatPopulation, Population};
use crate::util::state::{StateReader, StateWriter};

/// Which policy to instantiate per user (policies carry per-user state, so
/// the fleet runner needs a factory, not an instance).
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    AllOnDemand,
    AllReserved,
    Separate,
    /// `A_z` with optional prediction window; `z = None` means `z = β`.
    /// Custom `z` requires a single-contract market; windows generalize to
    /// menus (`w < min τ`, Sec. VI semantics per contract).
    Deterministic { z: Option<f64>, window: usize },
    /// Algorithm 2/4; the per-user draw is seeded from `seed ^ user_id`.
    /// Windows generalize to menus (`w < min τ`).
    Randomized { window: usize, seed: u64 },
    /// UCB threshold selection over the arm grid
    /// [`crate::algos::learned::ARM_MULTIPLIERS`]; `seed` permutes the
    /// per-user exploration order (derived like the randomized draw).
    Ucb { seed: u64 },
    /// Forecast-driven adaptive prediction window (deterministic; the
    /// synthetic window is manufactured internally, so `window() == 0` to
    /// the driver).
    AdaptiveWindow,
}

impl PolicySpec {
    pub fn name(&self) -> String {
        match self {
            PolicySpec::AllOnDemand => "All-on-demand".into(),
            PolicySpec::AllReserved => "All-reserved".into(),
            PolicySpec::Separate => "Separate".into(),
            PolicySpec::Deterministic { z, window } => match (z, window) {
                (None, 0) => "Deterministic".into(),
                (None, w) => format!("Deterministic(w={w})"),
                (Some(z), 0) => format!("Deterministic(z={z:.3})"),
                (Some(z), w) => format!("Deterministic(z={z:.3},w={w})"),
            },
            PolicySpec::Randomized { window: 0, .. } => "Randomized".into(),
            PolicySpec::Randomized { window, .. } => format!("Randomized(w={window})"),
            PolicySpec::Ucb { .. } => "UCB".into(),
            PolicySpec::AdaptiveWindow => "AdaptiveWindow".into(),
        }
    }

    /// Instantiate for one user. Single-contract markets build the classic
    /// policies against [`Market::contract_pricing`]; menus build the
    /// generalized policies (baselines pinned to the steady-best contract).
    /// Mirrored monomorphically by
    /// [`FleetPolicy::build`](crate::sim::engine::FleetPolicy::build).
    pub fn build(&self, market: &Market, user_id: u32) -> Box<dyn Policy> {
        // The learned policies run the menu machinery on every market
        // (single-contract included) — handle them before the fast-path
        // split so both engine paths construct identical instances.
        match *self {
            PolicySpec::Ucb { seed } => {
                return Box::new(UcbThreshold::new(market.clone(), per_user_seed(seed, user_id)))
            }
            PolicySpec::AdaptiveWindow => return Box::new(AdaptiveWindow::new(market.clone())),
            _ => {}
        }
        if market.is_single() {
            let pricing = market.contract_pricing(0);
            return match *self {
                PolicySpec::AllOnDemand => Box::new(baselines::AllOnDemand::new()),
                PolicySpec::AllReserved => Box::new(baselines::AllReserved::new(pricing)),
                PolicySpec::Separate => Box::new(baselines::Separate::new(pricing)),
                PolicySpec::Deterministic { z, window } => {
                    let z = z.unwrap_or_else(|| pricing.beta());
                    Box::new(Deterministic::new(pricing, z, window))
                }
                PolicySpec::Randomized { window, seed } => {
                    Box::new(Randomized::with_window(pricing, window, per_user_seed(seed, user_id)))
                }
                PolicySpec::Ucb { .. } | PolicySpec::AdaptiveWindow => unreachable!(),
            };
        }
        if market.is_empty() {
            return Box::new(baselines::AllOnDemand::new());
        }
        let pin = market.steady_best().expect("non-empty market has a steady-best contract");
        match *self {
            PolicySpec::AllOnDemand => Box::new(baselines::AllOnDemand::new()),
            PolicySpec::AllReserved => Box::new(PinnedSingle::new(
                baselines::AllReserved::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Separate => Box::new(PinnedSingle::new(
                baselines::Separate::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Deterministic { z: None, window } => {
                Box::new(MarketDeterministic::with_window(market.clone(), window))
            }
            PolicySpec::Deterministic { z: Some(_), .. } => panic!(
                "custom thresholds are single-contract only (menu of {})",
                market.len()
            ),
            PolicySpec::Randomized { window, seed } => Box::new(MarketRandomized::with_window(
                market.clone(),
                window,
                per_user_seed(seed, user_id),
            )),
            PolicySpec::Ucb { .. } | PolicySpec::AdaptiveWindow => unreachable!(),
        }
    }
}

/// Per-user outcome for one policy.
#[derive(Debug, Clone)]
pub struct UserResult {
    pub user_id: u32,
    pub group: Group,
    /// Cost normalized to All-on-demand (the Sec. VII normalization).
    /// Users with zero demand are reported as 1.0 (no cost either way).
    pub normalized_cost: f64,
    pub absolute_cost: f64,
    pub reservations: u64,
}

/// Fleet-wide outcome of one policy.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub policy: String,
    pub per_user: Vec<UserResult>,
}

impl FleetResult {
    /// Normalized costs of users in a group (or all).
    pub fn normalized(&self, group: Option<Group>) -> Vec<f64> {
        self.per_user
            .iter()
            .filter(|u| group.map(|g| u.group == g).unwrap_or(true))
            .map(|u| u.normalized_cost)
            .collect()
    }

    /// Mean normalized cost — a Table II cell.
    pub fn mean_normalized(&self, group: Option<Group>) -> f64 {
        let v = self.normalized(group);
        if v.is_empty() {
            f64::NAN
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    }

    /// Total absolute cost across the fleet (market currency).
    pub fn total_cost(&self) -> f64 {
        self.per_user.iter().map(|u| u.absolute_cost).sum()
    }

    /// Total reservations across the fleet.
    pub fn total_reservations(&self) -> u64 {
        self.per_user.iter().map(|u| u.reservations).sum()
    }

    /// Table II row: [all, g1, g2, g3].
    pub fn table2_row(&self) -> [f64; 4] {
        [
            self.mean_normalized(None),
            self.mean_normalized(Some(Group::G1Sporadic)),
            self.mean_normalized(Some(Group::G2Medium)),
            self.mean_normalized(Some(Group::G3Stable)),
        ]
    }
}

/// Streaming accumulator for fleet replays too large to hold a per-user
/// result vector: O(1) state fed one [`UserResult`] at a time (the sink
/// for [`crate::sim::engine::for_each_user_chunked`]). Means match
/// [`FleetResult`]'s when fed in the same order (same summation order).
#[derive(Debug, Clone, Default)]
pub struct FleetAggregate {
    users: u64,
    sum_normalized: f64,
    group_users: [u64; 3],
    group_sum_normalized: [f64; 3],
    total_cost: f64,
    total_reservations: u64,
}

impl FleetAggregate {
    pub fn new() -> FleetAggregate {
        FleetAggregate::default()
    }

    fn group_idx(g: Group) -> usize {
        match g {
            Group::G1Sporadic => 0,
            Group::G2Medium => 1,
            Group::G3Stable => 2,
        }
    }

    /// Fold one user's result into the aggregate.
    pub fn merge(&mut self, u: &UserResult) {
        self.users += 1;
        self.sum_normalized += u.normalized_cost;
        let gi = FleetAggregate::group_idx(u.group);
        self.group_users[gi] += 1;
        self.group_sum_normalized[gi] += u.normalized_cost;
        self.total_cost += u.absolute_cost;
        self.total_reservations += u.reservations;
    }

    pub fn users(&self) -> u64 {
        self.users
    }

    /// Mean normalized cost across all users folded so far.
    pub fn mean_normalized(&self) -> f64 {
        if self.users == 0 {
            f64::NAN
        } else {
            self.sum_normalized / self.users as f64
        }
    }

    /// Mean normalized cost of one σ/μ group.
    pub fn group_mean_normalized(&self, g: Group) -> f64 {
        let gi = FleetAggregate::group_idx(g);
        if self.group_users[gi] == 0 {
            f64::NAN
        } else {
            self.group_sum_normalized[gi] / self.group_users[gi] as f64
        }
    }

    pub fn total_cost(&self) -> f64 {
        self.total_cost
    }

    pub fn total_reservations(&self) -> u64 {
        self.total_reservations
    }

    /// Table II row: [all, g1, g2, g3].
    pub fn table2_row(&self) -> [f64; 4] {
        [
            self.mean_normalized(),
            self.group_mean_normalized(Group::G1Sporadic),
            self.group_mean_normalized(Group::G2Medium),
            self.group_mean_normalized(Group::G3Stable),
        ]
    }
}

impl SaveState for FleetAggregate {
    /// The sums are sequential f64 additions in user order, so restoring
    /// their exact bits and continuing in the same order yields an aggregate
    /// bit-identical to the uninterrupted run.
    fn save_state(&self, w: &mut StateWriter) {
        w.u64(self.users);
        w.f64_bits(self.sum_normalized);
        for &g in &self.group_users {
            w.u64(g);
        }
        for &s in &self.group_sum_normalized {
            w.f64_bits(s);
        }
        w.f64_bits(self.total_cost);
        w.u64(self.total_reservations);
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        self.users = r.u64()?;
        self.sum_normalized = r.f64_bits()?;
        for g in &mut self.group_users {
            *g = r.u64()?;
        }
        for s in &mut self.group_sum_normalized {
            *s = r.f64_bits()?;
        }
        self.total_cost = r.f64_bits()?;
        self.total_reservations = r.u64()?;
        Ok(())
    }
}

/// Run one policy spec across the population, sharded over `threads`.
///
/// Flattens the population and drives the batched engine; when running
/// several specs over the same population, flatten once and call
/// [`run_fleet_flat`] (or [`run_benchmark_suite`], which does) to avoid
/// rebuilding the columnar store per policy.
pub fn run_fleet(
    pop: &Population,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> FleetResult {
    run_fleet_flat(&pop.flatten(), market, spec, threads)
}

/// The seed fleet runner, kept as the golden reference for the batched
/// engine: strided sharding over an `mpsc` channel with `Box<dyn Policy>`
/// dispatch. Slower by design — use [`run_fleet`] everywhere except parity
/// tests and the `bench` baseline measurement.
pub fn run_fleet_reference(
    pop: &Population,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> FleetResult {
    let threads = threads.max(1).min(pop.users.len().max(1));
    let (tx, rx) = mpsc::channel::<Vec<UserResult>>();
    thread::scope(|scope| {
        for shard in 0..threads {
            let tx = tx.clone();
            let spec = spec.clone();
            let users = &pop.users;
            scope.spawn(move || {
                let mut out = Vec::new();
                let mut idx = shard;
                while idx < users.len() {
                    let u = &users[idx];
                    let mut policy = spec.build(market, u.user_id);
                    let report = run_policy_market(policy.as_mut(), &u.demand, market)
                        .unwrap_or_else(|e| panic!("user {}: infeasible decision: {e}", u.user_id));
                    let denom = all_on_demand_cost(&u.demand, market.p());
                    let normalized = if denom > 0.0 { report.total / denom } else { 1.0 };
                    out.push(UserResult {
                        user_id: u.user_id,
                        group: classify(&u.summary()),
                        normalized_cost: normalized,
                        absolute_cost: report.total,
                        reservations: report.reservations,
                    });
                    idx += threads;
                }
                tx.send(out).expect("fleet collector alive");
            });
        }
        drop(tx);
        let mut per_user: Vec<UserResult> = rx.iter().flatten().collect();
        per_user.sort_by_key(|u| u.user_id);
        FleetResult { policy: spec.name(), per_user }
    })
}

/// The Sec. VII policy suite, in the paper's order.
pub fn suite_specs(seed: u64) -> [PolicySpec; 5] {
    [
        PolicySpec::AllOnDemand,
        PolicySpec::AllReserved,
        PolicySpec::Separate,
        PolicySpec::Deterministic { z: None, window: 0 },
        PolicySpec::Randomized { window: 0, seed },
    ]
}

/// The learned-policy extension pack (ROADMAP learning-augmented family).
/// Not part of the paper's Sec. VII suite — scenario reports and benches
/// account for these separately, with regret vs the joint DP.
pub fn learned_specs(seed: u64) -> [PolicySpec; 2] {
    [PolicySpec::Ucb { seed }, PolicySpec::AdaptiveWindow]
}

/// Run the full Sec. VII suite (5 policies) across the population,
/// flattening to the columnar store once.
pub fn run_benchmark_suite(
    pop: &Population,
    market: &Market,
    seed: u64,
    threads: usize,
) -> Vec<FleetResult> {
    let flat = FlatPopulation::from(pop);
    suite_specs(seed)
        .iter()
        .map(|spec| run_fleet_flat(&flat, market, spec, threads))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::Pricing;
    use crate::trace::synth::{generate, SynthConfig};

    fn small_pop() -> Population {
        generate(&SynthConfig { users: 24, slots: 3000, seed: 5, ..Default::default() })
    }

    fn market() -> Market {
        // compressed EC2 small but with tau that fits the short test trace
        Market::single(Pricing::normalized(0.08 / 69.0, 0.4875, 1000))
    }

    #[test]
    fn all_on_demand_normalizes_to_one() {
        let pop = small_pop();
        let r = run_fleet(&pop, &market(), &PolicySpec::AllOnDemand, 4);
        for u in &r.per_user {
            assert!((u.normalized_cost - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sharding_is_deterministic() {
        let pop = small_pop();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        let a = run_fleet(&pop, &market(), &spec, 1);
        let b = run_fleet(&pop, &market(), &spec, 7);
        for (x, y) in a.per_user.iter().zip(&b.per_user) {
            assert_eq!(x.user_id, y.user_id);
            assert!((x.normalized_cost - y.normalized_cost).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic_beats_all_on_demand_overall() {
        let pop = small_pop();
        let det = run_fleet(&pop, &market(), &PolicySpec::Deterministic { z: None, window: 0 }, 4);
        // mean normalized cost must be <= 1 + epsilon: A_beta never pays
        // more than (2-alpha) OPT <= (2-alpha) * AllOnDemand, and on mixed
        // populations it should actually save.
        let mean = det.mean_normalized(None);
        assert!(mean <= 1.05, "mean normalized {mean}");
    }

    #[test]
    fn randomized_seed_gives_reproducible_fleet() {
        let pop = small_pop();
        let spec = PolicySpec::Randomized { window: 0, seed: 99 };
        let a = run_fleet(&pop, &market(), &spec, 3);
        let b = run_fleet(&pop, &market(), &spec, 5);
        for (x, y) in a.per_user.iter().zip(&b.per_user) {
            assert!((x.normalized_cost - y.normalized_cost).abs() < 1e-12);
        }
    }

    #[test]
    fn learned_policies_give_reproducible_fleets() {
        let pop = small_pop();
        for spec in learned_specs(99) {
            let a = run_fleet(&pop, &market(), &spec, 3);
            let b = run_fleet(&pop, &market(), &spec, 5);
            for (x, y) in a.per_user.iter().zip(&b.per_user) {
                assert_eq!(x.user_id, y.user_id);
                assert_eq!(
                    x.normalized_cost.to_bits(),
                    y.normalized_cost.to_bits(),
                    "{} user {}",
                    spec.name(),
                    x.user_id
                );
            }
        }
    }

    #[test]
    fn suite_runs_all_five() {
        let pop = small_pop();
        let results = run_benchmark_suite(&pop, &market(), 1, 4);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.per_user.len(), pop.users.len());
        }
    }

    #[test]
    fn engine_matches_reference_runner() {
        // Full parity coverage lives in tests/engine_parity.rs; this is the
        // fast in-tree smoke check.
        let pop = small_pop();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        let new = run_fleet(&pop, &market(), &spec, 4);
        let old = run_fleet_reference(&pop, &market(), &spec, 4);
        assert_eq!(new.per_user.len(), old.per_user.len());
        for (a, b) in new.per_user.iter().zip(&old.per_user) {
            assert_eq!(a.user_id, b.user_id);
            assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
            assert_eq!(a.reservations, b.reservations);
        }
    }

    #[test]
    fn aggregate_matches_fleet_result_means() {
        let pop = small_pop();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        let r = run_fleet(&pop, &market(), &spec, 4);
        let mut agg = FleetAggregate::new();
        for u in &r.per_user {
            agg.merge(u);
        }
        assert_eq!(agg.users(), r.per_user.len() as u64);
        // fed in the same order, the sums are bit-identical
        assert_eq!(agg.mean_normalized().to_bits(), r.mean_normalized(None).to_bits());
        assert_eq!(agg.total_cost().to_bits(), r.total_cost().to_bits());
        assert_eq!(agg.total_reservations(), r.total_reservations());
        let a = agg.table2_row();
        let b = r.table2_row();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()));
        }
    }

    #[test]
    fn table2_row_shape() {
        let pop = small_pop();
        let r = run_fleet(&pop, &market(), &PolicySpec::AllOnDemand, 2);
        let row = r.table2_row();
        assert!((row[0] - 1.0).abs() < 1e-9);
    }
}
