//! Declarative scenario runner: a JSON spec in, a comparable
//! normalized-cost report out — new workloads become a config file rather
//! than a code change (ROADMAP scenario-diversity north star).
//!
//! # Spec schema (`cloudreserve-scenario` spec, parsed via [`crate::util::json`])
//!
//! ```json
//! {
//!   "name": "table1-two-term-compressed",
//!   "description": "optional free text",
//!   "market": {
//!     "on_demand": 0.08,
//!     "contracts": [
//!       {"label": "1yr-light", "upfront": 0.1333, "rate": 0.039, "term": 4},
//!       {"label": "3yr-light", "upfront": 0.3,    "rate": 0.031, "term": 12}
//!     ]
//!   },
//!   "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 120},
//!   "policies": ["all-on-demand", "all-reserved", "separate",
//!                "deterministic", "randomized"],
//!   "window": 0,
//!   "seed": 1,
//!   "offline": true
//! }
//! ```
//!
//! * `market.on_demand` — on-demand rate per slot (market currency);
//!   `contracts[*]` — upfront fee, discounted per-slot rate, term in
//!   slots. The menu is validated, sorted, and dominance-pruned by
//!   [`Market::with_labels`]; the report records how many contracts the
//!   pruning removed.
//! * `trace.kind` — `"constant"` (`users`, `level`, `slots`),
//!   `"synthetic"` (`users`, `slots`, `seed` — the Google-like generator),
//!   `"inline"` (`demands`: array of per-user demand arrays), `"file"`
//!   (`path` to a `gen-traces` CSV/BIN, optional `slots` for CSV), or
//!   `"regime"` (`regime`: `"stationary" | "drifting" | "adversarial"`,
//!   plus `users`, `slots`, `seed`, `term_hint` — the learned-policy
//!   harness generator).
//! * `policies` — strings as above (plus the learned policies `"ucb"` and
//!   `"adaptive_window"`), or objects
//!   `{"policy": "deterministic", "z": 0.4, "window": 60}`. Custom `z` is
//!   single-contract-market only; prediction windows work on any menu as
//!   long as `w < min τ` (Sec. VI semantics per contract). Fields a policy
//!   ignores (`z` on anything but deterministic, `window` on anything but
//!   deterministic/randomized) are rejected, naming the offending policy.
//! * `window` — default prediction window applied to deterministic /
//!   randomized entries.
//! * `offline` — when true and the trace has exactly one user, solve the
//!   offline comparator: the joint multi-contract DP
//!   ([`offline::optimal_market_joint`]) when tractable, with the
//!   per-contract restricted DP ([`offline::optimal_market`]) as the
//!   upper-bound cross-check; the deterministic policies' cost ratios are
//!   reported against it, next to the `2 − α_max` comparison bound.
//!
//! Reports render as text ([`ScenarioReport::render`]) and serialize as
//! `cloudreserve-scenario/v2` JSON ([`ScenarioReport::to_json`]) for CI
//! trajectory tracking (v2 adds `offline.joint`, `offline.restricted_cost`
//! and `deterministic_window_ratio` to v1; when the offline comparator is
//! solved, every policy entry additionally carries additive
//! `regret_vs_joint` / `per_slot_regret` fields — total and per-slot excess
//! cost over the offline optimum).
//!
//! # Broker mode (`"mode": "broker"`)
//!
//! The same `market` + `trace` sections, but instead of a `policies` list
//! a single `broker` object selects the policy that buys the *shared*
//! reservation portfolio over the fleet's aggregate demand and the
//! settlement scheme that splits the realized cost back to users
//! ([`crate::broker`]):
//!
//! ```json
//! {
//!   "name": "broker-rotating-bursts",
//!   "mode": "broker",
//!   "market": { "...": "as above" },
//!   "trace": { "...": "as above" },
//!   "broker": {"policy": "deterministic", "window": 0,
//!              "settlement": "proportional"},
//!   "offline": true
//! }
//! ```
//!
//! `broker.settlement` is `"proportional"` or `"od-capped"`; `offline`
//! solves the joint DP on the *aggregate* curve when tractable (the
//! sandwich floor under the broker's cost). Reports serialize as
//! `cloudreserve-broker/v1` ([`BrokerReport::to_json`]): aggregate cost,
//! Σ standalone deterministic costs, the multiplexing gain, and the
//! per-user bill vector — bit-exact fields carry `*_bits` hex-f64 twins.
//! [`parse_scenario`] dispatches a spec document to its mode.

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::algos::offline;
use crate::broker::{settlement_from_name, BrokerOutcome, BrokerRun};
use crate::pricing::{Contract, Market};
use crate::sim::engine::run_fleet_flat;
use crate::sim::fleet::{FleetResult, PolicySpec};
use crate::trace::{FlatPopulation, Population, UserTrace};
use crate::util::cli::expected_one_of;
use crate::util::json::Json;

/// Valid policy names for spec/CLI parsing (and their error text).
pub const POLICY_NAMES: &[&str] = &[
    "all-on-demand",
    "all-reserved",
    "separate",
    "deterministic",
    "randomized",
    "ucb",
    "adaptive_window",
];

/// Policy names that accept a per-entry `window` field.
const WINDOWED_POLICY_NAMES: &[&str] = &["deterministic", "randomized"];

/// Policy names that accept a per-entry `z` field.
const THRESHOLD_POLICY_NAMES: &[&str] = &["deterministic"];

/// Where the demand trace comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSpec {
    /// The Google-like synthetic population generator.
    Synthetic { users: usize, slots: usize, seed: u64 },
    /// Every user at a constant demand level.
    Constant { users: usize, level: u32, slots: usize },
    /// Demands spelled out in the spec (one array per user).
    Inline { demands: Vec<Vec<u32>> },
    /// A `gen-traces` CSV/BIN file; `slots` bounds CSV parsing.
    File { path: String, slots: usize },
    /// A statistical regime for the learned-policy harness
    /// ([`crate::trace::synth::Regime`]): stationary / drifting /
    /// adversarial, with `term_hint` anchoring the adversarial burst
    /// length.
    Regime {
        users: usize,
        slots: usize,
        seed: u64,
        regime: crate::trace::synth::Regime,
        term_hint: usize,
    },
}

impl TraceSpec {
    fn build(&self) -> Result<Population> {
        match self {
            TraceSpec::Synthetic { users, slots, seed } => {
                Ok(crate::trace::synth::generate(&crate::trace::synth::SynthConfig {
                    users: *users,
                    slots: *slots,
                    seed: *seed,
                    ..Default::default()
                }))
            }
            TraceSpec::Constant { users, level, slots } => Ok(Population {
                users: (0..*users)
                    .map(|u| UserTrace::new(u as u32, vec![*level; *slots]))
                    .collect(),
            }),
            TraceSpec::Inline { demands } => Ok(Population {
                users: demands
                    .iter()
                    .enumerate()
                    .map(|(u, d)| UserTrace::new(u as u32, d.clone()))
                    .collect(),
            }),
            TraceSpec::File { path, slots } => {
                let p = std::path::Path::new(path);
                if p.extension().map(|e| e == "csv").unwrap_or(false) {
                    crate::trace::io::read_csv(p, *slots)
                } else {
                    crate::trace::io::read_bin(p)
                }
            }
            TraceSpec::Regime { users, slots, seed, regime, term_hint } => {
                Ok(crate::trace::synth::generate_regime(&crate::trace::synth::RegimeConfig {
                    users: *users,
                    slots: *slots,
                    seed: *seed,
                    regime: *regime,
                    term_hint: *term_hint,
                }))
            }
        }
    }
}

/// Parse and validate `doc.market` into a pruned [`Market`]; returns how
/// many contracts dominance pruning removed. Shared by both scenario
/// modes.
fn parse_market(doc: &Json) -> Result<(Market, usize)> {
    let mj = doc.get("market");
    let p = mj
        .get("on_demand")
        .as_f64()
        .ok_or_else(|| anyhow!("market: missing number 'on_demand'"))?;
    ensure!(p > 0.0, "market.on_demand must be positive");
    let cj = mj
        .get("contracts")
        .as_arr()
        .ok_or_else(|| anyhow!("market: missing array 'contracts'"))?;
    let mut entries = Vec::with_capacity(cj.len());
    for (i, c) in cj.iter().enumerate() {
        let label = c
            .get("label")
            .as_str()
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("c{i}"));
        let upfront = c
            .get("upfront")
            .as_f64()
            .ok_or_else(|| anyhow!("contract '{label}': missing number 'upfront'"))?;
        let rate = c
            .get("rate")
            .as_f64()
            .ok_or_else(|| anyhow!("contract '{label}': missing number 'rate'"))?;
        let term = c
            .get("term")
            .as_usize()
            .filter(|&t| t >= 1)
            .ok_or_else(|| anyhow!("contract '{label}': missing positive integer 'term'"))?;
        ensure!(upfront > 0.0, "contract '{label}': upfront must be positive");
        ensure!(rate >= 0.0, "contract '{label}': rate must be non-negative");
        ensure!(rate <= p, "contract '{label}': rate {rate} exceeds on-demand rate {p}");
        entries.push((label, Contract { upfront, rate, term }));
    }
    let n_input = entries.len();
    let market = Market::with_labels(p, entries);
    let pruned = n_input - market.len();
    Ok((market, pruned))
}

/// Parse `doc.trace` into a [`TraceSpec`]. Shared by both scenario modes.
fn parse_trace(doc: &Json) -> Result<TraceSpec> {
    let tj = doc.get("trace");
    let kind = tj.get("kind").as_str().unwrap_or("synthetic");
    match kind {
        "synthetic" => Ok(TraceSpec::Synthetic {
            users: tj.get("users").as_usize().unwrap_or(50),
            slots: tj.get("slots").as_usize().unwrap_or(5000),
            seed: tj.get("seed").as_f64().unwrap_or(2013.0) as u64,
        }),
        "constant" => Ok(TraceSpec::Constant {
            users: tj.get("users").as_usize().unwrap_or(1),
            level: tj.get("level").as_usize().unwrap_or(1) as u32,
            slots: tj
                .get("slots")
                .as_usize()
                .ok_or_else(|| anyhow!("trace(constant): missing integer 'slots'"))?,
        }),
        "inline" => {
            let rows = tj
                .get("demands")
                .as_arr()
                .ok_or_else(|| anyhow!("trace(inline): missing array 'demands'"))?;
            let mut demands = Vec::with_capacity(rows.len());
            for (u, row) in rows.iter().enumerate() {
                let row = row
                    .as_arr()
                    .ok_or_else(|| anyhow!("trace(inline): demands[{u}] is not an array"))?;
                demands.push(
                    row.iter()
                        .map(|d| {
                            d.as_f64()
                                .filter(|x| *x >= 0.0)
                                .map(|x| x as u32)
                                .ok_or_else(|| anyhow!("trace(inline): bad demand in row {u}"))
                        })
                        .collect::<Result<Vec<u32>>>()?,
                );
            }
            ensure!(!demands.is_empty(), "trace(inline): at least one user row required");
            Ok(TraceSpec::Inline { demands })
        }
        "file" => Ok(TraceSpec::File {
            path: tj
                .get("path")
                .as_str()
                .ok_or_else(|| anyhow!("trace(file): missing string 'path'"))?
                .to_string(),
            slots: tj.get("slots").as_usize().unwrap_or(crate::trace::TRACE_SLOTS),
        }),
        "regime" => Ok(TraceSpec::Regime {
            users: tj.get("users").as_usize().unwrap_or(20),
            slots: tj.get("slots").as_usize().unwrap_or(4000),
            seed: tj.get("seed").as_f64().unwrap_or(2013.0) as u64,
            regime: crate::trace::synth::Regime::from_name(
                tj.get("regime")
                    .as_str()
                    .ok_or_else(|| anyhow!("trace(regime): missing string 'regime'"))?,
            )?,
            term_hint: tj.get("term_hint").as_usize().unwrap_or(64),
        }),
        other => bail!(expected_one_of(
            "trace.kind",
            other,
            &["synthetic", "constant", "inline", "file", "regime"]
        )),
    }
}

/// Parse one policy entry — a bare name string, or an object with
/// `policy` (+ optional `z`, `window`). Shared by the `policies` list and
/// the broker section.
fn parse_policy_entry(item: &Json, default_window: usize, seed: u64) -> Result<PolicySpec> {
    let (kind, z, w) = match (item.as_str(), item.as_obj()) {
        (Some(s), _) => (s.to_string(), None, None),
        (None, Some(_)) => (
            item.get("policy")
                .as_str()
                .ok_or_else(|| anyhow!("policies: object needs 'policy'"))?
                .to_string(),
            item.get("z").as_f64(),
            item.get("window").as_usize(),
        ),
        _ => bail!("policies: entries must be strings or objects"),
    };
    if !POLICY_NAMES.contains(&kind.as_str()) {
        bail!(expected_one_of("policies: policy", &kind, POLICY_NAMES));
    }
    // Fields a policy ignores are spec bugs, not silent defaults: reject
    // them naming the offending policy and the policies that do take the
    // field (same shape as [`expected_one_of`] errors).
    if z.is_some() && !THRESHOLD_POLICY_NAMES.contains(&kind.as_str()) {
        bail!(
            "policy '{kind}': field 'z' is ignored by this policy \
             (accepted by: {})",
            THRESHOLD_POLICY_NAMES.join("|")
        );
    }
    if w.is_some() && !WINDOWED_POLICY_NAMES.contains(&kind.as_str()) {
        bail!(
            "policy '{kind}': field 'window' is ignored by this policy \
             (accepted by: {})",
            WINDOWED_POLICY_NAMES.join("|")
        );
    }
    match kind.as_str() {
        "all-on-demand" => Ok(PolicySpec::AllOnDemand),
        "all-reserved" => Ok(PolicySpec::AllReserved),
        "separate" => Ok(PolicySpec::Separate),
        "deterministic" => Ok(PolicySpec::Deterministic { z, window: w.unwrap_or(default_window) }),
        "randomized" => Ok(PolicySpec::Randomized { window: w.unwrap_or(default_window), seed }),
        "ucb" => Ok(PolicySpec::Ucb { seed }),
        "adaptive_window" => Ok(PolicySpec::AdaptiveWindow),
        other => unreachable!("policy '{other}' passed the POLICY_NAMES membership check"),
    }
}

/// Market-dependent validation shared by both modes: prediction windows
/// are a feature path on any menu (Sec. VI semantics per contract); only
/// `w ≥ min τ` is rejected, since no contract's check window could hold
/// it. Custom thresholds remain single-contract (one `z` does not map
/// onto a menu).
fn validate_policy(market: &Market, spec: &PolicySpec) -> Result<()> {
    if !market.is_single() {
        ensure!(
            !matches!(spec, PolicySpec::Deterministic { z: Some(_), .. }),
            "policy '{}': custom z needs a single-contract market",
            spec.name()
        );
    }
    let w = match spec {
        PolicySpec::Deterministic { window, .. } => *window,
        PolicySpec::Randomized { window, .. } => *window,
        _ => 0,
    };
    if w > 0 {
        if let Some(tau) = market.contracts().iter().map(|c| c.term).min() {
            ensure!(
                w < tau,
                "policy '{}': prediction window {w} must be shorter than the shortest \
                 term on the menu ({tau})",
                spec.name()
            );
        }
    }
    Ok(())
}

/// A parsed, validated scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: Option<String>,
    pub market: Market,
    /// Contracts removed by dominance pruning at parse time.
    pub pruned_contracts: usize,
    pub trace: TraceSpec,
    pub policies: Vec<PolicySpec>,
    pub offline: bool,
}

impl ScenarioSpec {
    /// Parse and validate a spec document (see the module docs for the
    /// schema). Errors are actionable (`field: problem`).
    pub fn from_json(doc: &Json) -> Result<ScenarioSpec> {
        let name = doc
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("spec: missing string field 'name'"))?
            .to_string();
        let description = doc.get("description").as_str().map(|s| s.to_string());
        let (market, pruned_contracts) = parse_market(doc)?;
        let trace = parse_trace(doc)?;

        // --- policies ---
        let seed = doc.get("seed").as_f64().unwrap_or(1.0) as u64;
        let window = doc.get("window").as_usize().unwrap_or(0);
        let pj = doc.get("policies");
        let mut policies = Vec::new();
        match pj.as_arr() {
            None => {
                for spec in crate::sim::fleet::suite_specs(seed) {
                    policies.push(spec);
                }
            }
            Some(items) => {
                for item in items {
                    policies.push(parse_policy_entry(item, window, seed)?);
                }
            }
        }
        ensure!(!policies.is_empty(), "policies: at least one policy required");
        for spec in &policies {
            validate_policy(&market, spec)?;
        }

        let offline = matches!(*doc.get("offline"), Json::Bool(true));
        Ok(ScenarioSpec {
            name,
            description,
            market,
            pruned_contracts,
            trace,
            policies,
            offline,
        })
    }
}

/// A parsed broker-mode scenario (`"mode": "broker"`): one policy drives
/// the shared portfolio over the fleet's aggregate demand, one settlement
/// scheme splits the realized cost back into per-user bills.
#[derive(Debug, Clone)]
pub struct BrokerScenarioSpec {
    pub name: String,
    pub description: Option<String>,
    pub market: Market,
    pub pruned_contracts: usize,
    pub trace: TraceSpec,
    /// The policy driving the shared portfolio.
    pub policy: PolicySpec,
    /// Settlement scheme name (validated at parse time; see
    /// [`crate::broker::SETTLEMENT_NAMES`]).
    pub settlement: String,
    pub offline: bool,
}

impl BrokerScenarioSpec {
    /// Parse a broker-mode spec: `market` and `trace` as in policy mode,
    /// plus a `broker` object — `{"policy": "deterministic", "window": 0,
    /// "settlement": "proportional"}` (policy defaults to deterministic,
    /// settlement to proportional).
    pub fn from_json(doc: &Json) -> Result<BrokerScenarioSpec> {
        let name = doc
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("spec: missing string field 'name'"))?
            .to_string();
        let description = doc.get("description").as_str().map(|s| s.to_string());
        let (market, pruned_contracts) = parse_market(doc)?;
        let trace = parse_trace(doc)?;

        let seed = doc.get("seed").as_f64().unwrap_or(1.0) as u64;
        let window = doc.get("window").as_usize().unwrap_or(0);
        let bj = doc.get("broker");
        ensure!(bj.as_obj().is_some(), "broker mode: missing object 'broker'");
        // The broker object *is* a policy entry (`policy` + optional
        // `z`/`window`), so the policies-list parser handles it directly.
        let policy = if matches!(*bj.get("policy"), Json::Null) {
            PolicySpec::Deterministic { z: None, window }
        } else {
            parse_policy_entry(bj, window, seed)?
        };
        validate_policy(&market, &policy)?;
        let settlement = bj.get("settlement").as_str().unwrap_or("proportional").to_string();
        settlement_from_name(&settlement)?; // validate the name at parse time

        let offline = matches!(*doc.get("offline"), Json::Bool(true));
        Ok(BrokerScenarioSpec {
            name,
            description,
            market,
            pruned_contracts,
            trace,
            policy,
            settlement,
            offline,
        })
    }
}

/// A spec document of either mode, dispatched on its `mode` field.
#[derive(Debug, Clone)]
pub enum ParsedScenario {
    Policies(ScenarioSpec),
    Broker(BrokerScenarioSpec),
}

/// Parse a spec of either mode (`"mode": "policies"` — the default — or
/// `"mode": "broker"`).
pub fn parse_scenario(doc: &Json) -> Result<ParsedScenario> {
    match doc.get("mode").as_str().unwrap_or("policies") {
        "policies" => Ok(ParsedScenario::Policies(ScenarioSpec::from_json(doc)?)),
        "broker" => Ok(ParsedScenario::Broker(BrokerScenarioSpec::from_json(doc)?)),
        other => bail!(expected_one_of("mode", other, &["policies", "broker"])),
    }
}

/// The complete broker scenario result: the broker outcome plus the
/// market header fields every report carries.
#[derive(Debug, Clone)]
pub struct BrokerReport {
    pub name: String,
    pub market_contracts: usize,
    pub pruned_contracts: usize,
    pub alpha_max: f64,
    pub outcome: BrokerOutcome,
}

impl BrokerReport {
    /// Machine-readable report (`cloudreserve-broker/v1`). Costs that feed
    /// bit-exact invariants carry `*_bits` hex-f64 twins so downstream
    /// validation does not depend on decimal round-tripping.
    pub fn to_json(&self) -> Json {
        let hex = |v: f64| Json::Str(format!("{:#018x}", v.to_bits()));
        let o = &self.outcome;
        let r = &o.aggregate.report;
        let per_contract = o
            .aggregate
            .per_contract
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("label", Json::Str(c.label.clone())),
                    ("reservations", Json::Num(c.reservations as f64)),
                    ("upfront_spend", Json::Num(c.upfront_spend)),
                ])
            })
            .collect();
        let bills = o
            .bills
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("user_id", Json::Num(b.user_id as f64)),
                    ("amount", Json::Num(b.amount)),
                    ("amount_bits", hex(b.amount)),
                    ("usage_slots", Json::Num(b.usage_slots as f64)),
                    ("standalone_cost", Json::Num(b.standalone_cost)),
                    ("on_demand_cost", Json::Num(b.on_demand_cost)),
                ])
            })
            .collect();
        // plain sequential sum — conserved bit-exactly by construction
        let bills_total: f64 = o.bills.iter().map(|b| b.amount).sum();
        let offline = match &o.offline {
            None => Json::Null,
            Some(s) => Json::obj(vec![
                ("cost", Json::Num(s.cost)),
                ("cost_bits", hex(s.cost)),
                ("reservations", Json::Num(s.reservations as f64)),
            ]),
        };
        let gain_fraction = if o.standalone_total > 0.0 {
            o.multiplexing_gain / o.standalone_total
        } else {
            0.0
        };
        Json::obj(vec![
            ("schema", Json::Str("cloudreserve-broker/v1".into())),
            ("name", Json::Str(self.name.clone())),
            ("users", Json::Num(o.users as f64)),
            ("slots", Json::Num(o.slots as f64)),
            ("market_contracts", Json::Num(self.market_contracts as f64)),
            ("pruned_contracts", Json::Num(self.pruned_contracts as f64)),
            ("alpha_max", Json::Num(self.alpha_max)),
            ("policy", Json::Str(o.policy.clone())),
            ("settlement", Json::Str(o.settlement.clone())),
            ("aggregate_cost", Json::Num(r.total)),
            ("aggregate_cost_bits", hex(r.total)),
            (
                "aggregate",
                Json::obj(vec![
                    ("reservations", Json::Num(r.reservations as f64)),
                    ("peak_active", Json::Num(r.peak_active as f64)),
                    ("reservation_fees", Json::Num(r.reservation_fees)),
                    ("on_demand_cost", Json::Num(r.on_demand_cost)),
                    ("reserved_usage_cost", Json::Num(r.reserved_usage_cost)),
                    ("per_contract", Json::Arr(per_contract)),
                ]),
            ),
            ("standalone_total", Json::Num(o.standalone_total)),
            ("standalone_total_bits", hex(o.standalone_total)),
            ("on_demand_total", Json::Num(o.on_demand_total)),
            ("multiplexing_gain", Json::Num(o.multiplexing_gain)),
            ("multiplexing_gain_bits", hex(o.multiplexing_gain)),
            ("gain_fraction", Json::Num(gain_fraction)),
            ("offline", offline),
            ("bills_total_bits", hex(bills_total)),
            ("bills", Json::Arr(bills)),
        ])
    }

    /// Human-readable report (bills elided past the first dozen users).
    pub fn render(&self) -> String {
        let o = &self.outcome;
        let r = &o.aggregate.report;
        let mut out = String::new();
        out.push_str(&format!(
            "broker '{}': {} users x {} slots, menu of {} contract(s) ({} pruned), alpha_max {:.4}\n",
            self.name, o.users, o.slots, self.market_contracts, self.pruned_contracts, self.alpha_max
        ));
        out.push_str(&format!(
            "policy {} + settlement {}\n",
            o.policy, o.settlement
        ));
        out.push_str(&format!(
            "aggregate portfolio: cost {:.4} ({} reservations, peak {} active)\n",
            r.total, r.reservations, r.peak_active
        ));
        for c in &o.aggregate.per_contract {
            out.push_str(&format!(
                "  contract {:<12} {:>6} reservations, upfront spend {:.4}\n",
                c.label, c.reservations, c.upfront_spend
            ));
        }
        out.push_str(&format!(
            "isolated users (standalone deterministic): {:.4}; all-on-demand: {:.4}\n",
            o.standalone_total, o.on_demand_total
        ));
        out.push_str(&format!(
            "multiplexing gain: {:.4} ({:.2}% of standalone)\n",
            o.multiplexing_gain,
            if o.standalone_total > 0.0 {
                100.0 * o.multiplexing_gain / o.standalone_total
            } else {
                0.0
            }
        ));
        if let Some(s) = &o.offline {
            out.push_str(&format!(
                "offline joint DP on the aggregate: {:.4} ({} reservations)\n",
                s.cost, s.reservations
            ));
        }
        out.push_str(&format!(
            "{:<10} {:>12} {:>12} {:>14} {:>14}\n",
            "user", "bill", "usage", "standalone", "on-demand cap"
        ));
        for b in o.bills.iter().take(12) {
            out.push_str(&format!(
                "{:<10} {:>12.4} {:>12} {:>14.4} {:>14.4}\n",
                b.user_id, b.amount, b.usage_slots, b.standalone_cost, b.on_demand_cost
            ));
        }
        if o.bills.len() > 12 {
            out.push_str(&format!("... {} more users\n", o.bills.len() - 12));
        }
        out
    }
}

/// Run a broker scenario: build the trace, aggregate it, buy the shared
/// portfolio, settle, and compare against the isolated-users baseline.
pub fn run_broker(spec: &BrokerScenarioSpec, threads: usize) -> Result<BrokerReport> {
    let pop = spec.trace.build().context("building scenario trace")?;
    ensure!(!pop.users.is_empty(), "scenario trace has no users");
    let flat = FlatPopulation::from(&pop);
    let settlement = settlement_from_name(&spec.settlement)?;
    let outcome = BrokerRun {
        market: &spec.market,
        policy: spec.policy.clone(),
        settlement: settlement.as_ref(),
        threads,
        offline: spec.offline,
    }
    .run_flat(&flat)?;
    Ok(BrokerReport {
        name: spec.name.clone(),
        market_contracts: spec.market.len(),
        pruned_contracts: spec.pruned_contracts,
        alpha_max: spec.market.alpha_max(),
        outcome,
    })
}

/// One policy's scenario-level outcome.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub name: String,
    pub mean_normalized: f64,
    pub total_cost: f64,
    pub reservations: u64,
    /// `total_cost − offline cost` when the offline comparator is solved
    /// (the joint multi-contract DP when tractable, else the best
    /// restricted schedule — see [`OfflineOutcome::joint`]). The regret of
    /// an online policy against hindsight; can be negative only by float
    /// noise.
    pub regret_vs_joint: Option<f64>,
    /// `regret_vs_joint / slots` — the per-slot regret the learned-policy
    /// differential tests track across horizon doublings.
    pub per_slot_regret: Option<f64>,
}

/// Offline comparator (single-user traces only).
#[derive(Debug, Clone)]
pub struct OfflineOutcome {
    /// Tightest available offline cost: the joint multi-contract DP
    /// ([`offline::optimal_market_joint`]) when tractable, otherwise the
    /// best restricted single-contract schedule.
    pub cost: f64,
    pub reservations: u64,
    /// Whether `cost` comes from the joint DP.
    pub joint: bool,
    /// Best restricted (single-contract ∪ on-demand) cost — the
    /// upper-bound cross-check on the joint DP.
    pub restricted_cost: f64,
    /// Which contract the best restricted schedule commits to
    /// (`None` = pure on-demand).
    pub contract: Option<usize>,
    /// Contracts skipped by the restricted DP as intractable.
    pub skipped: usize,
}

/// The complete scenario result.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    pub users: usize,
    pub slots: usize,
    pub market_contracts: usize,
    pub pruned_contracts: usize,
    pub alpha_max: f64,
    /// `2 − α_max`: the empirical comparison bound reported next to the
    /// deterministic ratio.
    pub ratio_bound: f64,
    pub policies: Vec<PolicyOutcome>,
    pub offline: Option<OfflineOutcome>,
    /// Deterministic-policy cost / offline cost, when both are present
    /// (the windowless `z = β` entry).
    pub deterministic_ratio: Option<f64>,
    /// Same ratio for the first prediction-window deterministic entry
    /// (Sec. VI), when the suite has one.
    pub deterministic_window_ratio: Option<f64>,
}

impl ScenarioReport {
    /// Machine-readable report (`cloudreserve-scenario/v2`).
    pub fn to_json(&self) -> Json {
        let policies = self
            .policies
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    ("mean_normalized", Json::Num(p.mean_normalized)),
                    ("total_cost", Json::Num(p.total_cost)),
                    ("reservations", Json::Num(p.reservations as f64)),
                    (
                        "regret_vs_joint",
                        p.regret_vs_joint.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    (
                        "per_slot_regret",
                        p.per_slot_regret.map(Json::Num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        let offline = match &self.offline {
            None => Json::Null,
            Some(o) => Json::obj(vec![
                ("cost", Json::Num(o.cost)),
                ("reservations", Json::Num(o.reservations as f64)),
                ("joint", Json::Bool(o.joint)),
                ("restricted_cost", Json::Num(o.restricted_cost)),
                (
                    "contract",
                    o.contract.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null),
                ),
                ("skipped", Json::Num(o.skipped as f64)),
            ]),
        };
        Json::obj(vec![
            ("schema", Json::Str("cloudreserve-scenario/v2".into())),
            ("name", Json::Str(self.name.clone())),
            ("users", Json::Num(self.users as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("market_contracts", Json::Num(self.market_contracts as f64)),
            ("pruned_contracts", Json::Num(self.pruned_contracts as f64)),
            ("alpha_max", Json::Num(self.alpha_max)),
            ("ratio_bound", Json::Num(self.ratio_bound)),
            ("policies", Json::Arr(policies)),
            ("offline", offline),
            (
                "deterministic_ratio",
                self.deterministic_ratio.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "deterministic_window_ratio",
                self.deterministic_window_ratio.map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "scenario '{}': {} users x {} slots, menu of {} contract(s) ({} pruned), alpha_max {:.4}\n",
            self.name,
            self.users,
            self.slots,
            self.market_contracts,
            self.pruned_contracts,
            self.alpha_max
        ));
        out.push_str(&format!(
            "{:<28} {:>16} {:>14} {:>14}\n",
            "policy", "mean normalized", "total cost", "reservations"
        ));
        for p in &self.policies {
            out.push_str(&format!(
                "{:<28} {:>16.4} {:>14.4} {:>14}\n",
                p.name, p.mean_normalized, p.total_cost, p.reservations
            ));
        }
        if let Some(o) = &self.offline {
            out.push_str(&format!(
                "offline ({}): cost {:.4}, {} reservations{}{}\n",
                if o.joint { "joint multi-contract DP" } else { "best single contract" },
                o.cost,
                o.reservations,
                match o.contract {
                    Some(c) => {
                        format!(", restricted best: contract {c} ({:.4})", o.restricted_cost)
                    }
                    None => {
                        format!(", restricted best: pure on-demand ({:.4})", o.restricted_cost)
                    }
                },
                if o.skipped > 0 {
                    format!(" ({} contract(s) DP-intractable, skipped)", o.skipped)
                } else {
                    String::new()
                }
            ));
        }
        if let Some(r) = self.deterministic_ratio {
            out.push_str(&format!(
                "deterministic / offline ratio: {:.4} (comparison bound 2 - alpha_max = {:.4})\n",
                r, self.ratio_bound
            ));
        }
        if let Some(r) = self.deterministic_window_ratio {
            out.push_str(&format!(
                "deterministic(window) / offline ratio: {:.4} (comparison bound {:.4})\n",
                r, self.ratio_bound
            ));
        }
        if self.policies.iter().any(|p| p.regret_vs_joint.is_some()) {
            out.push_str("per-policy regret vs offline (total / per-slot):\n");
            for p in &self.policies {
                if let (Some(r), Some(ps)) = (p.regret_vs_joint, p.per_slot_regret) {
                    out.push_str(&format!("  {:<28} {:>14.4} / {:.6}\n", p.name, r, ps));
                }
            }
        }
        out
    }
}

/// Run a scenario: build the trace, replay every policy through the
/// batched engine, optionally solve the offline comparator.
pub fn run(spec: &ScenarioSpec, threads: usize) -> Result<ScenarioReport> {
    let pop = spec.trace.build().context("building scenario trace")?;
    ensure!(!pop.users.is_empty(), "scenario trace has no users");
    let slots = pop.users.iter().map(|u| u.demand.len()).max().unwrap_or(0);
    let flat = FlatPopulation::from(&pop);

    let mut outcomes = Vec::with_capacity(spec.policies.len());
    let mut det_total: Option<f64> = None;
    let mut det_window_total: Option<f64> = None;
    for pspec in &spec.policies {
        let res: FleetResult = run_fleet_flat(&flat, &spec.market, pspec, threads);
        match pspec {
            PolicySpec::Deterministic { z: None, window: 0 } if det_total.is_none() => {
                det_total = Some(res.total_cost());
            }
            PolicySpec::Deterministic { z: None, window: 1.. } if det_window_total.is_none() => {
                det_window_total = Some(res.total_cost());
            }
            _ => {}
        }
        outcomes.push(PolicyOutcome {
            name: res.policy.clone(),
            mean_normalized: res.mean_normalized(None),
            total_cost: res.total_cost(),
            reservations: res.total_reservations(),
            regret_vs_joint: None,
            per_slot_regret: None,
        });
    }

    let offline_outcome = if spec.offline && pop.users.len() == 1 {
        let demand = &pop.users[0].demand;
        let restricted = offline::optimal_market(demand, &spec.market);
        let joint = offline::optimal_market_joint(demand, &spec.market);
        match (joint, restricted.best) {
            // The joint DP is tractable only when every per-contract DP is,
            // so a solved joint always comes with a restricted cross-check.
            (Some(j), Some((contract, r))) => Some(OfflineOutcome {
                cost: j.cost,
                reservations: j.reservations,
                joint: true,
                restricted_cost: r.cost,
                contract,
                skipped: restricted.skipped.len(),
            }),
            (None, Some((contract, r))) => Some(OfflineOutcome {
                cost: r.cost,
                reservations: r.reservations,
                joint: false,
                restricted_cost: r.cost,
                contract,
                skipped: restricted.skipped.len(),
            }),
            (_, None) => None,
        }
    } else {
        None
    };

    // Regret accounting: every policy's excess cost over the offline
    // comparator, total and per slot. Additive v2 fields — absent (null)
    // whenever the offline DP did not run.
    if let Some(o) = &offline_outcome {
        for p in &mut outcomes {
            let regret = p.total_cost - o.cost;
            p.regret_vs_joint = Some(regret);
            p.per_slot_regret = Some(regret / slots.max(1) as f64);
        }
    }

    let ratio_against_offline = |total: Option<f64>| match (&offline_outcome, total) {
        (Some(o), Some(t)) if o.cost > 0.0 => Some(t / o.cost),
        _ => None,
    };
    let deterministic_ratio = ratio_against_offline(det_total);
    let deterministic_window_ratio = ratio_against_offline(det_window_total);

    let alpha_max = spec.market.alpha_max();
    Ok(ScenarioReport {
        name: spec.name.clone(),
        users: pop.users.len(),
        slots,
        market_contracts: spec.market.len(),
        pruned_contracts: spec.pruned_contracts,
        alpha_max,
        ratio_bound: 2.0 - alpha_max,
        policies: outcomes,
        offline: offline_outcome,
        deterministic_ratio,
        deterministic_window_ratio,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn two_term_spec_text() -> &'static str {
        r#"{
          "name": "unit-two-term",
          "market": {
            "on_demand": 0.08,
            "contracts": [
              {"label": "1yr", "upfront": 0.1333, "rate": 0.039, "term": 4},
              {"label": "3yr", "upfront": 0.3, "rate": 0.031, "term": 12}
            ]
          },
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 120},
          "policies": ["all-on-demand", "deterministic", "randomized"],
          "seed": 1,
          "offline": true
        }"#
    }

    #[test]
    fn parses_and_runs_two_term_scenario() {
        let spec = ScenarioSpec::from_json(&parse(two_term_spec_text()).unwrap()).unwrap();
        assert_eq!(spec.market.len(), 2);
        assert_eq!(spec.pruned_contracts, 0);
        assert!((spec.market.alpha_max() - 0.4875).abs() < 1e-12);
        let report = run(&spec, 2).unwrap();
        assert_eq!(report.users, 1);
        assert_eq!(report.slots, 120);
        assert_eq!(report.policies.len(), 3);
        // all-on-demand normalizes to exactly 1
        assert!((report.policies[0].mean_normalized - 1.0).abs() < 1e-9);
        // offline solved (joint DP on this compressed menu), deterministic
        // committed at least once, and the ratio respects the 2 - alpha_max
        // comparison bound
        let off = report.offline.as_ref().expect("offline DP ran");
        assert!(off.cost > 0.0);
        assert!(off.joint, "terms 4 + 12 at unit demand are joint-DP tractable");
        assert!(off.cost <= off.restricted_cost + 1e-9);
        assert!(report.policies[1].reservations >= 1);
        let ratio = report.deterministic_ratio.expect("ratio computed");
        assert!(
            ratio <= report.ratio_bound + 1e-9,
            "ratio {ratio} exceeds bound {}",
            report.ratio_bound
        );
        // JSON report round-trips through the parser
        let text = report.to_json().dump_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("schema").as_str(), Some("cloudreserve-scenario/v2"));
        assert_eq!(back.get("policies").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn accepts_windows_on_multi_contract_markets() {
        let text = r#"{
          "name": "windowed-menu",
          "market": {"on_demand": 0.08, "contracts": [
            {"upfront": 0.2, "rate": 0.039, "term": 6},
            {"upfront": 0.45, "rate": 0.031, "term": 18}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 40},
          "policies": ["all-on-demand", "deterministic", "randomized"],
          "window": 4
        }"#;
        let spec = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(spec.market.len(), 2);
        let report = run(&spec, 1).unwrap();
        assert_eq!(report.policies.len(), 3);
        assert!(report.policies[1].name.contains("w=4"));
        // no offline comparator requested -> no ratios
        assert!(report.deterministic_ratio.is_none());
        assert!(report.deterministic_window_ratio.is_none());
    }

    #[test]
    fn rejects_windows_reaching_the_shortest_term() {
        let text = r#"{
          "name": "bad",
          "market": {"on_demand": 0.08, "contracts": [
            {"upfront": 0.2, "rate": 0.039, "term": 6},
            {"upfront": 0.45, "rate": 0.031, "term": 18}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 10},
          "policies": ["deterministic"],
          "window": 6
        }"#;
        let err = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("shortest"));
    }

    #[test]
    fn rejects_custom_z_on_multi_contract_markets() {
        let text = r#"{
          "name": "bad",
          "market": {"on_demand": 0.08, "contracts": [
            {"upfront": 0.2, "rate": 0.039, "term": 6},
            {"upfront": 0.45, "rate": 0.031, "term": 18}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 10},
          "policies": [{"policy": "deterministic", "z": 0.4}]
        }"#;
        let err = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("single-contract"));
    }

    #[test]
    fn rejects_window_on_policies_that_ignore_it() {
        for policy in ["all-on-demand", "all-reserved", "separate", "ucb", "adaptive_window"] {
            let text = format!(
                r#"{{
              "name": "bad",
              "market": {{"on_demand": 0.1, "contracts": [
                {{"upfront": 0.5, "rate": 0.01, "term": 10}}
              ]}},
              "trace": {{"kind": "constant", "users": 1, "level": 1, "slots": 10}},
              "policies": [{{"policy": "{policy}", "window": 4}}]
            }}"#
            );
            let err =
                format!("{:#}", ScenarioSpec::from_json(&parse(&text).unwrap()).unwrap_err());
            assert!(
                err.contains(&format!("policy '{policy}'")) && err.contains("'window'"),
                "error must name the offending policy: {err}"
            );
            assert!(
                err.contains("deterministic|randomized"),
                "error must list the policies that take 'window': {err}"
            );
        }
    }

    #[test]
    fn rejects_z_on_non_threshold_policies() {
        for policy in ["randomized", "ucb", "adaptive_window", "separate"] {
            let text = format!(
                r#"{{
              "name": "bad",
              "market": {{"on_demand": 0.1, "contracts": [
                {{"upfront": 0.5, "rate": 0.01, "term": 10}}
              ]}},
              "trace": {{"kind": "constant", "users": 1, "level": 1, "slots": 10}},
              "policies": [{{"policy": "{policy}", "z": 0.4}}]
            }}"#
            );
            let err =
                format!("{:#}", ScenarioSpec::from_json(&parse(&text).unwrap()).unwrap_err());
            assert!(
                err.contains(&format!("policy '{policy}'")) && err.contains("'z'"),
                "error must name the offending policy: {err}"
            );
        }
    }

    #[test]
    fn unknown_policy_wins_over_stray_field_errors() {
        let text = r#"{
          "name": "bad",
          "market": {"on_demand": 0.1, "contracts": [
            {"upfront": 0.5, "rate": 0.01, "term": 10}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 10},
          "policies": [{"policy": "magic", "window": 4}]
        }"#;
        let err = format!("{:#}", ScenarioSpec::from_json(&parse(text).unwrap()).unwrap_err());
        assert!(err.contains("unknown name 'magic'"), "{err}");
        assert!(err.contains("ucb") && err.contains("adaptive_window"), "{err}");
    }

    #[test]
    fn learned_policies_run_and_report_regret() {
        let text = r#"{
          "name": "learned-unit",
          "market": {"on_demand": 0.08, "contracts": [
            {"label": "1yr", "upfront": 0.1333, "rate": 0.039, "term": 4},
            {"label": "3yr", "upfront": 0.3, "rate": 0.031, "term": 12}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 120},
          "policies": ["all-on-demand", "deterministic", "ucb", "adaptive_window"],
          "seed": 7,
          "offline": true
        }"#;
        let spec = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(spec.policies.len(), 4);
        let report = run(&spec, 2).unwrap();
        assert_eq!(report.policies.len(), 4);
        assert!(report.policies.iter().any(|p| p.name.contains("UCB")));
        assert!(report.policies.iter().any(|p| p.name.contains("AdaptiveWindow")));
        let off = report.offline.as_ref().expect("offline DP ran");
        for p in &report.policies {
            // joint ≤ online for every policy, learned included
            let regret = p.regret_vs_joint.expect("regret filled when offline solved");
            assert!(regret >= -1e-9, "policy {} beat the offline DP: {regret}", p.name);
            assert!(
                (p.total_cost - off.cost - regret).abs() < 1e-12,
                "regret must be total_cost - offline cost"
            );
            let ps = p.per_slot_regret.expect("per-slot regret filled");
            assert!((ps - regret / 120.0).abs() < 1e-12);
        }
        // additive v2 fields round-trip through the JSON parser
        let back = parse(&report.to_json().dump_pretty()).unwrap();
        let arr = back.get("policies").as_arr().unwrap();
        assert!(arr.iter().all(|p| p.get("regret_vs_joint").as_f64().is_some()));
        assert!(report.render().contains("per-policy regret"));
    }

    #[test]
    fn rejects_unknown_policy() {
        let text = r#"{
          "name": "bad",
          "market": {"on_demand": 0.1, "contracts": [
            {"upfront": 0.5, "rate": 0.01, "term": 10}
          ]},
          "trace": {"kind": "constant", "users": 1, "level": 1, "slots": 10},
          "policies": ["magic"]
        }"#;
        assert!(ScenarioSpec::from_json(&parse(text).unwrap()).is_err());
    }

    fn broker_spec_text(settlement: &str) -> String {
        format!(
            r#"{{
          "name": "unit-broker",
          "mode": "broker",
          "market": {{
            "on_demand": 0.08,
            "contracts": [
              {{"label": "1yr", "upfront": 0.1333, "rate": 0.039, "term": 4}},
              {{"label": "3yr", "upfront": 0.3, "rate": 0.031, "term": 12}}
            ]
          }},
          "trace": {{"kind": "inline", "demands": [
            [1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0],
            [0, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0],
            [0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1]
          ]}},
          "broker": {{"policy": "deterministic", "settlement": "{settlement}"}},
          "offline": true
        }}"#
        )
    }

    #[test]
    fn broker_mode_parses_runs_and_serializes() {
        let doc = parse(&broker_spec_text("proportional")).unwrap();
        let spec = match parse_scenario(&doc).unwrap() {
            ParsedScenario::Broker(s) => s,
            other => panic!("expected broker mode, got {other:?}"),
        };
        assert_eq!(spec.settlement, "proportional");
        let report = run_broker(&spec, 2).unwrap();
        let o = &report.outcome;
        assert_eq!(o.users, 3);
        assert_eq!(o.slots, 12);
        // the aggregate is constant 1 -> the shared portfolio reserves
        assert!(o.aggregate.report.reservations >= 1);
        // bills conserve the aggregate cost bit-exactly
        let total: f64 = o.bills.iter().map(|b| b.amount).sum();
        assert_eq!(total.to_bits(), o.aggregate.report.total.to_bits());
        // offline joint DP on the aggregate sandwiches the broker cost
        let off = o.offline.as_ref().expect("unit aggregate is tractable");
        assert!(off.cost <= o.aggregate.report.total + 1e-9);
        // JSON round-trips and pins the bit-exact conservation
        let text = report.to_json().dump_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("schema").as_str(), Some("cloudreserve-broker/v1"));
        assert_eq!(
            back.get("bills_total_bits").as_str(),
            back.get("aggregate_cost_bits").as_str()
        );
        assert_eq!(back.get("bills").as_arr().unwrap().len(), 3);
        assert!(report.render().contains("multiplexing gain"));
    }

    #[test]
    fn broker_mode_od_capped_respects_caps() {
        let doc = parse(&broker_spec_text("od-capped")).unwrap();
        let spec = BrokerScenarioSpec::from_json(&doc).unwrap();
        let report = run_broker(&spec, 1).unwrap();
        for b in &report.outcome.bills {
            assert!(b.amount <= b.on_demand_cost, "user {} over cap", b.user_id);
        }
    }

    #[test]
    fn broker_mode_rejects_unknown_settlement_with_names() {
        let doc = parse(&broker_spec_text("magic")).unwrap();
        let err = format!("{:#}", BrokerScenarioSpec::from_json(&doc).unwrap_err());
        assert!(err.contains("proportional") && err.contains("od-capped"), "{err}");
    }

    #[test]
    fn unknown_mode_lists_valid_modes() {
        let mut text = broker_spec_text("proportional");
        text = text.replace("\"mode\": \"broker\"", "\"mode\": \"auction\"");
        let err = format!("{:#}", parse_scenario(&parse(&text).unwrap()).unwrap_err());
        assert!(err.contains("policies") && err.contains("broker"), "{err}");
    }

    #[test]
    fn default_mode_is_policies() {
        let spec = parse_scenario(&parse(two_term_spec_text()).unwrap()).unwrap();
        assert!(matches!(spec, ParsedScenario::Policies(_)));
    }

    #[test]
    fn regime_trace_parses_and_runs() {
        let text = r#"{
          "name": "regime-unit",
          "market": {"on_demand": 0.1, "contracts": [
            {"upfront": 0.4, "rate": 0.02, "term": 8}
          ]},
          "trace": {"kind": "regime", "regime": "adversarial",
                    "users": 3, "slots": 200, "seed": 5, "term_hint": 8},
          "policies": ["all-on-demand", "deterministic", "ucb"]
        }"#;
        let spec = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap();
        assert!(matches!(
            spec.trace,
            TraceSpec::Regime { users: 3, slots: 200, term_hint: 8, .. }
        ));
        let report = run(&spec, 1).unwrap();
        assert_eq!(report.users, 3);
        assert_eq!(report.slots, 200);
        assert_eq!(report.policies.len(), 3);

        let bad = text.replace("\"adversarial\"", "\"chaotic\"");
        let err = format!("{:#}", ScenarioSpec::from_json(&parse(&bad).unwrap()).unwrap_err());
        assert!(err.contains("stationary") && err.contains("drifting"), "{err}");
    }

    #[test]
    fn inline_trace_and_default_policies() {
        let text = r#"{
          "name": "inline",
          "market": {"on_demand": 0.1, "contracts": [
            {"upfront": 0.4, "rate": 0.02, "term": 8}
          ]},
          "trace": {"kind": "inline", "demands": [[1, 2, 0, 1], [0, 0, 1, 1]]}
        }"#;
        let spec = ScenarioSpec::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(spec.policies.len(), 5);
        let report = run(&spec, 1).unwrap();
        assert_eq!(report.users, 2);
        assert_eq!(report.slots, 4);
        assert!(report.offline.is_none());
    }
}
