//! The batched fleet replay engine: zero-allocation per slot, monomorphic
//! policy dispatch, contiguous-memory traversal — now over a [`Market`]
//! menu.
//!
//! The seed fleet runner walked 933 heap-scattered `Vec<u32>` curves
//! through `Box<dyn Policy>` with a per-slot `to_vec()` of the future
//! window, sharded by striding (`idx += threads`) over an `mpsc` channel.
//! This engine replaces all three costs:
//!
//! * **dispatch** — [`FleetPolicy`] is an enum over the Sec. VII policies
//!   plus their menu generalizations; the per-slot `decide` is a direct
//!   `match`, so each arm monomorphizes and inlines
//!   ([`crate::algos::Policy`] stays as the extensibility trait — anything
//!   exotic still runs through the boxed reference path in
//!   [`super::fleet::run_fleet_reference`]);
//! * **allocation** — future windows are borrowed sub-slices of the demand
//!   curve (see [`crate::sim::OracleFuture`] for the single-user form) and
//!   typed decisions borrow each policy's reusable reservation buffer;
//!   nothing allocates inside the slot loop;
//! * **locality** — shards replay contiguous *chunks* of the columnar
//!   [`FlatPopulation`] store, streaming one flat buffer front to back
//!   instead of pointer-chasing per-user vectors, and results come back in
//!   order without a channel.
//!
//! Market routing: a **single-contract** market takes the classic policy
//! fast path through [`Market::contract_pricing`] — for markets built with
//! [`Market::single`] that path performs the exact same arithmetic in the
//! exact same order as the v1 `Pricing` code, so results are
//! **bit-identical** to the reference path — enforced by
//! `rust/tests/engine_parity.rs`. Multi-contract markets dispatch to the
//! menu policies ([`crate::algos::market`]), identically in both the
//! engine and the reference runner.

use crate::algos::baselines::{AllOnDemand, AllReserved, Separate};
use crate::algos::deterministic::Deterministic;
use crate::algos::market::{MarketDeterministic, MarketRandomized, PinnedSingle};
use crate::algos::randomized::Randomized;
use crate::algos::{Decision, Policy, Reset};
use crate::analysis::classify::classify;
use crate::ledger::Ledger;
use crate::pricing::Market;
use crate::sim::all_on_demand_cost;
use crate::sim::fleet::{FleetResult, PolicySpec, UserResult};
use crate::trace::io::ChunkedPopulation;
use crate::trace::FlatPopulation;
use crate::util::stats::summarize_u32;

/// Statically dispatched per-user policy state for the fleet hot path.
/// Construction mirrors [`PolicySpec::build`] exactly (including the
/// per-user randomized seed and the single-vs-menu market routing) so both
/// paths replay identical decision sequences.
pub enum FleetPolicy {
    AllOnDemand(AllOnDemand),
    AllReserved(AllReserved),
    Separate(Separate),
    Deterministic(Deterministic),
    Randomized(Randomized),
    MarketDeterministic(MarketDeterministic),
    MarketRandomized(MarketRandomized),
    PinnedAllReserved(PinnedSingle<AllReserved>),
    PinnedSeparate(PinnedSingle<Separate>),
}

impl FleetPolicy {
    /// Instantiate for one user (the monomorphic mirror of
    /// [`PolicySpec::build`]).
    pub fn build(spec: &PolicySpec, market: &Market, user_id: u32) -> FleetPolicy {
        if market.is_single() {
            let pricing = market.contract_pricing(0);
            return match *spec {
                PolicySpec::AllOnDemand => FleetPolicy::AllOnDemand(AllOnDemand::new()),
                PolicySpec::AllReserved => FleetPolicy::AllReserved(AllReserved::new(pricing)),
                PolicySpec::Separate => FleetPolicy::Separate(Separate::new(pricing)),
                PolicySpec::Deterministic { z, window } => {
                    let z = z.unwrap_or_else(|| pricing.beta());
                    FleetPolicy::Deterministic(Deterministic::new(pricing, z, window))
                }
                PolicySpec::Randomized { window, seed } => FleetPolicy::Randomized(
                    Randomized::with_window(pricing, window, seed ^ ((user_id as u64) << 17)),
                ),
            };
        }
        if market.is_empty() {
            // reserving never helps: every policy degrades to on-demand
            return FleetPolicy::AllOnDemand(AllOnDemand::new());
        }
        let pin = market.steady_best().expect("non-empty market has a steady-best contract");
        match *spec {
            PolicySpec::AllOnDemand => FleetPolicy::AllOnDemand(AllOnDemand::new()),
            PolicySpec::AllReserved => FleetPolicy::PinnedAllReserved(PinnedSingle::new(
                AllReserved::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Separate => FleetPolicy::PinnedSeparate(PinnedSingle::new(
                Separate::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Deterministic { z: None, window } => FleetPolicy::MarketDeterministic(
                MarketDeterministic::with_window(market.clone(), window),
            ),
            PolicySpec::Deterministic { z: Some(_), .. } => panic!(
                "custom thresholds are single-contract only (menu of {})",
                market.len()
            ),
            PolicySpec::Randomized { window, seed } => {
                let seed = seed ^ ((user_id as u64) << 17);
                FleetPolicy::MarketRandomized(MarketRandomized::with_window(
                    market.clone(),
                    window,
                    seed,
                ))
            }
        }
    }

    /// Per-slot decision — a direct match, no vtable.
    #[inline]
    pub fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        match self {
            FleetPolicy::AllOnDemand(p) => p.decide(demand, future),
            FleetPolicy::AllReserved(p) => p.decide(demand, future),
            FleetPolicy::Separate(p) => p.decide(demand, future),
            FleetPolicy::Deterministic(p) => p.decide(demand, future),
            FleetPolicy::Randomized(p) => p.decide(demand, future),
            FleetPolicy::MarketDeterministic(p) => p.decide(demand, future),
            FleetPolicy::MarketRandomized(p) => p.decide(demand, future),
            FleetPolicy::PinnedAllReserved(p) => p.decide(demand, future),
            FleetPolicy::PinnedSeparate(p) => p.decide(demand, future),
        }
    }

    /// Prediction window the policy wants (0 for purely online).
    pub fn window(&self) -> usize {
        match self {
            FleetPolicy::AllOnDemand(p) => p.window(),
            FleetPolicy::AllReserved(p) => p.window(),
            FleetPolicy::Separate(p) => p.window(),
            FleetPolicy::Deterministic(p) => p.window(),
            FleetPolicy::Randomized(p) => p.window(),
            FleetPolicy::MarketDeterministic(p) => p.window(),
            FleetPolicy::MarketRandomized(p) => p.window(),
            FleetPolicy::PinnedAllReserved(p) => p.window(),
            FleetPolicy::PinnedSeparate(p) => p.window(),
        }
    }
}

/// One shard's reusable replay state: a single [`FleetPolicy`] and a
/// single [`Ledger`], rewound per user instead of rebuilt. The seed path
/// constructed both per user — two `Market` clones and ~10 heap
/// allocations per user, which dominates at fleet scale where each user's
/// replay is short. Deterministic policies `reset()`; randomized ones
/// `reseed()` with the per-user seed, reproducing `FleetPolicy::build`'s
/// draws bit-for-bit (pinned by the reset/reseed unit tests and by
/// `tests/engine_parity.rs` against the build-per-user reference runner).
pub struct ShardRunner {
    policy: FleetPolicy,
    ledger: Ledger,
    p: f64,
    /// Base seed of a `Randomized`/`MarketRandomized` spec (unused
    /// otherwise); the per-user seed is `base ^ (user_id << 17)`.
    base_seed: u64,
    w: usize,
}

impl ShardRunner {
    pub fn new(spec: &PolicySpec, market: &Market) -> ShardRunner {
        let policy = FleetPolicy::build(spec, market, 0);
        let w = policy.window();
        let base_seed = match *spec {
            PolicySpec::Randomized { seed, .. } => seed,
            _ => 0,
        };
        ShardRunner { policy, ledger: Ledger::new(market.clone()), p: market.p(), base_seed, w }
    }

    /// Rewind policy + ledger to the fresh state for `user_id`.
    fn prepare(&mut self, user_id: u32) {
        match &mut self.policy {
            FleetPolicy::AllOnDemand(p) => p.reset(),
            FleetPolicy::AllReserved(p) => p.reset(),
            FleetPolicy::Separate(p) => p.reset(),
            FleetPolicy::Deterministic(p) => p.reset(),
            FleetPolicy::Randomized(p) => p.reseed(self.base_seed ^ ((user_id as u64) << 17)),
            FleetPolicy::MarketDeterministic(p) => p.reset(),
            FleetPolicy::MarketRandomized(p) => {
                p.reseed(self.base_seed ^ ((user_id as u64) << 17))
            }
            FleetPolicy::PinnedAllReserved(p) => p.reset(),
            FleetPolicy::PinnedSeparate(p) => p.reset(),
        }
        self.ledger.reset();
    }

    /// Replay one user's demand curve: the allocation-free inner loop of
    /// the batched engine.
    pub fn replay(&mut self, demand: &[u32], user_id: u32) -> UserResult {
        self.prepare(user_id);
        let w = self.w;
        let len = demand.len();
        for (t, &d) in demand.iter().enumerate() {
            let fut: &[u32] = if w == 0 {
                &[]
            } else {
                // Borrowed future window [t+1, t+w] (shrinking at the tail).
                &demand[t + 1..(t + 1 + w).min(len)]
            };
            let dec = self.policy.decide(d, fut);
            self.ledger
                .bill(d, &dec)
                .unwrap_or_else(|e| panic!("user {user_id}: infeasible decision: {e}"));
        }
        let report = self.ledger.report();
        let denom = all_on_demand_cost(demand, self.p);
        let normalized = if denom > 0.0 { report.total / denom } else { 1.0 };
        UserResult {
            user_id,
            group: classify(&summarize_u32(demand)),
            normalized_cost: normalized,
            absolute_cost: report.total,
            reservations: report.reservations,
        }
    }
}

/// Replay one user's demand curve through one policy (one-off form; shard
/// loops should hold a [`ShardRunner`] and call `replay` repeatedly).
pub fn replay_user(demand: &[u32], user_id: u32, market: &Market, spec: &PolicySpec) -> UserResult {
    ShardRunner::new(spec, market).replay(demand, user_id)
}

/// Shard `flat` into contiguous chunks across `threads` std threads and
/// append every user's result to `out` in input order. Per-user results
/// are independent of the sharding, so output is deterministic and
/// thread-count-invariant.
fn run_shards_into(
    flat: &FlatPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
    out: &mut Vec<UserResult>,
) {
    let n = flat.len();
    let threads = threads.max(1).min(n.max(1));
    let chunk = if n == 0 { 0 } else { n.div_ceil(threads) };
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for shard in 0..threads {
            let lo = shard * chunk;
            let hi = ((shard + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            handles.push(scope.spawn(move || {
                let mut runner = ShardRunner::new(spec, market);
                (lo..hi)
                    .map(|i| runner.replay(flat.demand(i), flat.user_id(i)))
                    .collect::<Vec<UserResult>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("fleet shard panicked"));
        }
    });
}

/// Run one policy spec over a columnar population, sharded into contiguous
/// chunks across `threads` std threads. Results are deterministic and
/// independent of the thread count.
pub fn run_fleet_flat(
    flat: &FlatPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> FleetResult {
    let mut per_user: Vec<UserResult> = Vec::with_capacity(flat.len());
    run_shards_into(flat, market, spec, threads, &mut per_user);
    // Chunking already preserves input order; sort by user id to keep the
    // reference path's output contract for arbitrarily ordered populations.
    per_user.sort_by_key(|u| u.user_id);
    FleetResult { policy: spec.name(), per_user }
}

/// Stream a chunked trace file through the engine, feeding each user's
/// result to `sink` in file order. Resident memory is O(one chunk): the
/// chunk buffer and the per-chunk result vector are reused across chunks,
/// so a 10⁶-user fleet replays in the footprint of `chunk_users` users.
/// Per-user results are bit-identical to [`run_fleet_flat`] over the same
/// fleet (sharding never crosses a user).
pub fn for_each_user_chunked(
    chunked: &mut ChunkedPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
    mut sink: impl FnMut(&UserResult),
) -> anyhow::Result<()> {
    let mut buf = FlatPopulation::default();
    let mut chunk_results: Vec<UserResult> = Vec::new();
    for c in 0..chunked.n_chunks() {
        chunked.read_chunk_into(c, &mut buf)?;
        chunk_results.clear();
        run_shards_into(&buf, market, spec, threads, &mut chunk_results);
        for u in &chunk_results {
            sink(u);
        }
    }
    Ok(())
}

/// Run one policy spec over a chunked trace file, collecting the full
/// per-user result vector (bit-identical to [`run_fleet_flat`] on the
/// equivalent in-RAM population). For fleets too large to hold even the
/// results in memory, use [`for_each_user_chunked`] with a streaming sink
/// such as [`crate::sim::fleet::FleetAggregate`].
pub fn run_fleet_chunked(
    chunked: &mut ChunkedPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> anyhow::Result<FleetResult> {
    let mut per_user: Vec<UserResult> = Vec::with_capacity(chunked.n_users());
    for_each_user_chunked(chunked, market, spec, threads, |u| per_user.push(u.clone()))?;
    per_user.sort_by_key(|u| u.user_id);
    Ok(FleetResult { policy: spec.name(), per_user })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::{Contract, Pricing};
    use crate::trace::synth::{generate, SynthConfig};

    fn market() -> Market {
        Market::single(Pricing::normalized(0.08 / 69.0, 0.4875, 1000))
    }

    fn menu_market() -> Market {
        // break-evens (167 / 188 violation-slots) fit the short test traces
        // so the menu policies actually commit; both contracts survive
        // dominance pruning.
        let m = Market::new(
            0.01,
            vec![
                Contract { upfront: 1.0, rate: 0.004, term: 600 },
                Contract { upfront: 1.5, rate: 0.002, term: 1800 },
            ],
        );
        assert_eq!(m.len(), 2);
        m
    }

    fn specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::AllOnDemand,
            PolicySpec::AllReserved,
            PolicySpec::Separate,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: Some(0.4), window: 40 },
            PolicySpec::Randomized { window: 0, seed: 11 },
        ]
    }

    /// Specs valid for multi-contract menus (no custom z; windows are a
    /// feature path now, `w < min τ`).
    fn menu_specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::AllOnDemand,
            PolicySpec::AllReserved,
            PolicySpec::Separate,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: None, window: 40 },
            PolicySpec::Randomized { window: 0, seed: 11 },
            PolicySpec::Randomized { window: 25, seed: 11 },
        ]
    }

    #[test]
    fn fleet_policy_matches_boxed_dispatch() {
        // The enum's decide must reproduce the trait-object path exactly —
        // on both the single-contract fast path and the menu path.
        let pop = generate(&SynthConfig { users: 6, slots: 1200, seed: 3, ..Default::default() });
        for (mkt, specs) in [(market(), specs()), (menu_market(), menu_specs())] {
            for spec in specs {
                for u in &pop.users {
                    let mut fast = FleetPolicy::build(&spec, &mkt, u.user_id);
                    let mut slow = spec.build(&mkt, u.user_id);
                    assert_eq!(fast.window(), slow.window());
                    let w = fast.window();
                    for (t, &d) in u.demand.iter().enumerate() {
                        let hi = (t + 1 + w).min(u.demand.len());
                        let fut = &u.demand[t + 1..hi];
                        let fut = if w == 0 { &[] as &[u32] } else { fut };
                        assert_eq!(
                            fast.decide(d, fut),
                            slow.decide(d, fut),
                            "{} user {} slot {t} (menu k={})",
                            spec.name(),
                            u.user_id,
                            mkt.len()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_sharding_is_thread_count_invariant() {
        let pop = generate(&SynthConfig { users: 17, slots: 1500, seed: 9, ..Default::default() });
        let flat = pop.flatten();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        for mkt in [market(), menu_market()] {
            let one = run_fleet_flat(&flat, &mkt, &spec, 1);
            for threads in [2usize, 3, 8, 64] {
                let many = run_fleet_flat(&flat, &mkt, &spec, threads);
                assert_eq!(one.per_user.len(), many.per_user.len());
                for (a, b) in one.per_user.iter().zip(&many.per_user) {
                    assert_eq!(a.user_id, b.user_id);
                    assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
                    assert_eq!(a.absolute_cost.to_bits(), b.absolute_cost.to_bits());
                    assert_eq!(a.reservations, b.reservations);
                }
            }
        }
    }

    #[test]
    fn empty_population_yields_empty_result() {
        let flat = FlatPopulation::default();
        let r = run_fleet_flat(&flat, &market(), &PolicySpec::AllOnDemand, 4);
        assert!(r.per_user.is_empty());
    }

    #[test]
    fn chunked_replay_matches_in_ram_engine() {
        // Full policy x chunk-size x thread-count coverage lives in
        // tests/engine_parity.rs; this is the in-tree smoke check.
        let pop = generate(&SynthConfig { users: 13, slots: 900, seed: 4, ..Default::default() });
        let flat = pop.flatten();
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("engine_chunked_{}", std::process::id()));
        crate::trace::io::write_chunked(&pop, &path, 4).unwrap();
        let spec = PolicySpec::Randomized { window: 0, seed: 11 };
        for mkt in [market(), menu_market()] {
            let in_ram = run_fleet_flat(&flat, &mkt, &spec, 3);
            let mut chunked = ChunkedPopulation::open(&path).unwrap();
            let streamed = run_fleet_chunked(&mut chunked, &mkt, &spec, 3).unwrap();
            assert_eq!(in_ram.per_user.len(), streamed.per_user.len());
            for (a, b) in in_ram.per_user.iter().zip(&streamed.per_user) {
                assert_eq!(a.user_id, b.user_id);
                assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
                assert_eq!(a.absolute_cost.to_bits(), b.absolute_cost.to_bits());
                assert_eq!(a.reservations, b.reservations);
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "single-contract only")]
    fn menu_rejects_custom_thresholds() {
        FleetPolicy::build(
            &PolicySpec::Deterministic { z: Some(0.4), window: 0 },
            &menu_market(),
            0,
        );
    }

    #[test]
    #[should_panic(expected = "shorter than every term")]
    fn menu_rejects_windows_at_least_min_term() {
        // min term on the menu is 600
        FleetPolicy::build(
            &PolicySpec::Deterministic { z: None, window: 600 },
            &menu_market(),
            0,
        );
    }

    #[test]
    fn menu_windows_take_the_market_policy_path() {
        let mut p = FleetPolicy::build(
            &PolicySpec::Deterministic { z: None, window: 10 },
            &menu_market(),
            0,
        );
        assert_eq!(p.window(), 10);
        let fut = [1u32; 10];
        let dec = p.decide(1, &fut);
        assert!(dec.on_demand <= 1);
    }
}
