//! The batched fleet replay engine: zero-allocation per slot, monomorphic
//! policy dispatch, contiguous-memory traversal — now over a [`Market`]
//! menu.
//!
//! The seed fleet runner walked 933 heap-scattered `Vec<u32>` curves
//! through `Box<dyn Policy>` with a per-slot `to_vec()` of the future
//! window, sharded by striding (`idx += threads`) over an `mpsc` channel.
//! This engine replaces all three costs:
//!
//! * **dispatch** — [`FleetPolicy`] is an enum over the Sec. VII policies
//!   plus their menu generalizations; the per-slot `decide` is a direct
//!   `match`, so each arm monomorphizes and inlines
//!   ([`crate::algos::Policy`] stays as the extensibility trait — anything
//!   exotic still runs through the boxed reference path in
//!   [`super::fleet::run_fleet_reference`]);
//! * **allocation** — future windows are borrowed sub-slices of the demand
//!   curve (see [`crate::sim::OracleFuture`] for the single-user form) and
//!   typed decisions borrow each policy's reusable reservation buffer;
//!   nothing allocates inside the slot loop;
//! * **locality** — shards replay contiguous *chunks* of the columnar
//!   [`FlatPopulation`] store, streaming one flat buffer front to back
//!   instead of pointer-chasing per-user vectors, and results come back in
//!   order without a channel.
//!
//! Market routing: a **single-contract** market takes the classic policy
//! fast path through [`Market::contract_pricing`] — for markets built with
//! [`Market::single`] that path performs the exact same arithmetic in the
//! exact same order as the v1 `Pricing` code, so results are
//! **bit-identical** to the reference path — enforced by
//! `rust/tests/engine_parity.rs`. Multi-contract markets dispatch to the
//! menu policies ([`crate::algos::market`]), identically in both the
//! engine and the reference runner.

use crate::algos::baselines::{AllOnDemand, AllReserved, Separate};
use crate::algos::deterministic::Deterministic;
use crate::algos::learned::{AdaptiveWindow, UcbThreshold};
use crate::algos::market::{MarketDeterministic, MarketRandomized, PinnedSingle};
use crate::algos::randomized::Randomized;
use std::path::Path;

use anyhow::Context;

use crate::algos::{Decision, Policy, Reset, SaveState};
use crate::analysis::classify::classify;
use crate::ledger::Ledger;
use crate::pricing::Market;
use crate::runtime::checkpoint::{
    market_fingerprint, spec_fingerprint, Checkpoint, QuarantinedChunk,
};
use crate::sim::fleet::{FleetAggregate, FleetResult, PolicySpec, UserResult};
use crate::sim::{all_on_demand_cost, per_user_seed};
use crate::trace::io::{ChunkCorrupt, ChunkedPopulation};
use crate::trace::FlatPopulation;
use crate::util::faults::{backoff_delay, site, Fault, FaultPlan, KillPoint};
use crate::util::state::{StateReader, StateWriter};
use crate::util::stats::summarize_u32;

/// Statically dispatched per-user policy state for the fleet hot path.
/// Construction mirrors [`PolicySpec::build`] exactly (including the
/// per-user randomized seed and the single-vs-menu market routing) so both
/// paths replay identical decision sequences.
pub enum FleetPolicy {
    AllOnDemand(AllOnDemand),
    AllReserved(AllReserved),
    Separate(Separate),
    Deterministic(Deterministic),
    Randomized(Randomized),
    MarketDeterministic(MarketDeterministic),
    MarketRandomized(MarketRandomized),
    PinnedAllReserved(PinnedSingle<AllReserved>),
    PinnedSeparate(PinnedSingle<Separate>),
    Ucb(UcbThreshold),
    AdaptiveWindow(AdaptiveWindow),
}

impl FleetPolicy {
    /// Instantiate for one user (the monomorphic mirror of
    /// [`PolicySpec::build`]).
    pub fn build(spec: &PolicySpec, market: &Market, user_id: u32) -> FleetPolicy {
        // Learned policies run the menu machinery on every market — handle
        // them before the single/empty routing so both engine paths build
        // identical instances (mirrors `PolicySpec::build`).
        match *spec {
            PolicySpec::Ucb { seed } => {
                return FleetPolicy::Ucb(UcbThreshold::new(
                    market.clone(),
                    per_user_seed(seed, user_id),
                ))
            }
            PolicySpec::AdaptiveWindow => {
                return FleetPolicy::AdaptiveWindow(AdaptiveWindow::new(market.clone()))
            }
            _ => {}
        }
        if market.is_single() {
            let pricing = market.contract_pricing(0);
            return match *spec {
                PolicySpec::AllOnDemand => FleetPolicy::AllOnDemand(AllOnDemand::new()),
                PolicySpec::AllReserved => FleetPolicy::AllReserved(AllReserved::new(pricing)),
                PolicySpec::Separate => FleetPolicy::Separate(Separate::new(pricing)),
                PolicySpec::Deterministic { z, window } => {
                    let z = z.unwrap_or_else(|| pricing.beta());
                    FleetPolicy::Deterministic(Deterministic::new(pricing, z, window))
                }
                PolicySpec::Randomized { window, seed } => FleetPolicy::Randomized(
                    Randomized::with_window(pricing, window, per_user_seed(seed, user_id)),
                ),
                PolicySpec::Ucb { .. } | PolicySpec::AdaptiveWindow => unreachable!(),
            };
        }
        if market.is_empty() {
            // reserving never helps: every policy degrades to on-demand
            return FleetPolicy::AllOnDemand(AllOnDemand::new());
        }
        let pin = market.steady_best().expect("non-empty market has a steady-best contract");
        match *spec {
            PolicySpec::AllOnDemand => FleetPolicy::AllOnDemand(AllOnDemand::new()),
            PolicySpec::AllReserved => FleetPolicy::PinnedAllReserved(PinnedSingle::new(
                AllReserved::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Separate => FleetPolicy::PinnedSeparate(PinnedSingle::new(
                Separate::new(market.contract_pricing(pin)),
                pin,
            )),
            PolicySpec::Deterministic { z: None, window } => FleetPolicy::MarketDeterministic(
                MarketDeterministic::with_window(market.clone(), window),
            ),
            PolicySpec::Deterministic { z: Some(_), .. } => panic!(
                "custom thresholds are single-contract only (menu of {})",
                market.len()
            ),
            PolicySpec::Randomized { window, seed } => {
                FleetPolicy::MarketRandomized(MarketRandomized::with_window(
                    market.clone(),
                    window,
                    per_user_seed(seed, user_id),
                ))
            }
            PolicySpec::Ucb { .. } | PolicySpec::AdaptiveWindow => unreachable!(),
        }
    }

    /// Per-slot decision — a direct match, no vtable.
    #[inline]
    pub fn decide(&mut self, demand: u32, future: &[u32]) -> Decision<'_> {
        match self {
            FleetPolicy::AllOnDemand(p) => p.decide(demand, future),
            FleetPolicy::AllReserved(p) => p.decide(demand, future),
            FleetPolicy::Separate(p) => p.decide(demand, future),
            FleetPolicy::Deterministic(p) => p.decide(demand, future),
            FleetPolicy::Randomized(p) => p.decide(demand, future),
            FleetPolicy::MarketDeterministic(p) => p.decide(demand, future),
            FleetPolicy::MarketRandomized(p) => p.decide(demand, future),
            FleetPolicy::PinnedAllReserved(p) => p.decide(demand, future),
            FleetPolicy::PinnedSeparate(p) => p.decide(demand, future),
            FleetPolicy::Ucb(p) => p.decide(demand, future),
            FleetPolicy::AdaptiveWindow(p) => p.decide(demand, future),
        }
    }

    /// Prediction window the policy wants (0 for purely online).
    pub fn window(&self) -> usize {
        match self {
            FleetPolicy::AllOnDemand(p) => p.window(),
            FleetPolicy::AllReserved(p) => p.window(),
            FleetPolicy::Separate(p) => p.window(),
            FleetPolicy::Deterministic(p) => p.window(),
            FleetPolicy::Randomized(p) => p.window(),
            FleetPolicy::MarketDeterministic(p) => p.window(),
            FleetPolicy::MarketRandomized(p) => p.window(),
            FleetPolicy::PinnedAllReserved(p) => p.window(),
            FleetPolicy::PinnedSeparate(p) => p.window(),
            FleetPolicy::Ucb(p) => p.window(),
            FleetPolicy::AdaptiveWindow(p) => p.window(),
        }
    }

    /// Checkpoint tag of the active variant — restores must target the same
    /// variant (same spec + market routing), never transmute across arms.
    fn tag(&self) -> u8 {
        match self {
            FleetPolicy::AllOnDemand(_) => 0,
            FleetPolicy::AllReserved(_) => 1,
            FleetPolicy::Separate(_) => 2,
            FleetPolicy::Deterministic(_) => 3,
            FleetPolicy::Randomized(_) => 4,
            FleetPolicy::MarketDeterministic(_) => 5,
            FleetPolicy::MarketRandomized(_) => 6,
            FleetPolicy::PinnedAllReserved(_) => 7,
            FleetPolicy::PinnedSeparate(_) => 8,
            FleetPolicy::Ucb(_) => 9,
            FleetPolicy::AdaptiveWindow(_) => 10,
        }
    }
}

impl SaveState for FleetPolicy {
    fn save_state(&self, w: &mut StateWriter) {
        w.u8(self.tag());
        match self {
            FleetPolicy::AllOnDemand(p) => p.save_state(w),
            FleetPolicy::AllReserved(p) => p.save_state(w),
            FleetPolicy::Separate(p) => p.save_state(w),
            FleetPolicy::Deterministic(p) => p.save_state(w),
            FleetPolicy::Randomized(p) => p.save_state(w),
            FleetPolicy::MarketDeterministic(p) => p.save_state(w),
            FleetPolicy::MarketRandomized(p) => p.save_state(w),
            FleetPolicy::PinnedAllReserved(p) => p.save_state(w),
            FleetPolicy::PinnedSeparate(p) => p.save_state(w),
            FleetPolicy::Ucb(p) => p.save_state(w),
            FleetPolicy::AdaptiveWindow(p) => p.save_state(w),
        }
    }

    fn restore_state(&mut self, r: &mut StateReader<'_>) -> anyhow::Result<()> {
        let tag = r.u8()?;
        anyhow::ensure!(
            tag == self.tag(),
            "checkpointed policy variant (tag {tag}) does not match the \
             running policy (tag {})",
            self.tag()
        );
        match self {
            FleetPolicy::AllOnDemand(p) => p.restore_state(r),
            FleetPolicy::AllReserved(p) => p.restore_state(r),
            FleetPolicy::Separate(p) => p.restore_state(r),
            FleetPolicy::Deterministic(p) => p.restore_state(r),
            FleetPolicy::Randomized(p) => p.restore_state(r),
            FleetPolicy::MarketDeterministic(p) => p.restore_state(r),
            FleetPolicy::MarketRandomized(p) => p.restore_state(r),
            FleetPolicy::PinnedAllReserved(p) => p.restore_state(r),
            FleetPolicy::PinnedSeparate(p) => p.restore_state(r),
            FleetPolicy::Ucb(p) => p.restore_state(r),
            FleetPolicy::AdaptiveWindow(p) => p.restore_state(r),
        }
    }
}

/// One shard's reusable replay state: a single [`FleetPolicy`] and a
/// single [`Ledger`], rewound per user instead of rebuilt. The seed path
/// constructed both per user — two `Market` clones and ~10 heap
/// allocations per user, which dominates at fleet scale where each user's
/// replay is short. Deterministic policies `reset()`; randomized ones
/// `reseed()` with the per-user seed, reproducing `FleetPolicy::build`'s
/// draws bit-for-bit (pinned by the reset/reseed unit tests and by
/// `tests/engine_parity.rs` against the build-per-user reference runner).
pub struct ShardRunner {
    policy: FleetPolicy,
    ledger: Ledger,
    p: f64,
    /// Base seed of a seeded spec (`Randomized`/`MarketRandomized`/`Ucb`;
    /// unused otherwise); the per-user seed is
    /// [`per_user_seed`]`(base, user_id)`.
    base_seed: u64,
    w: usize,
}

impl ShardRunner {
    pub fn new(spec: &PolicySpec, market: &Market) -> ShardRunner {
        let policy = FleetPolicy::build(spec, market, 0);
        let w = policy.window();
        let base_seed = match *spec {
            PolicySpec::Randomized { seed, .. } | PolicySpec::Ucb { seed } => seed,
            _ => 0,
        };
        ShardRunner { policy, ledger: Ledger::new(market.clone()), p: market.p(), base_seed, w }
    }

    /// Rewind policy + ledger to the fresh state for `user_id`.
    fn prepare(&mut self, user_id: u32) {
        match &mut self.policy {
            FleetPolicy::AllOnDemand(p) => p.reset(),
            FleetPolicy::AllReserved(p) => p.reset(),
            FleetPolicy::Separate(p) => p.reset(),
            FleetPolicy::Deterministic(p) => p.reset(),
            FleetPolicy::Randomized(p) => p.reseed(per_user_seed(self.base_seed, user_id)),
            FleetPolicy::MarketDeterministic(p) => p.reset(),
            FleetPolicy::MarketRandomized(p) => p.reseed(per_user_seed(self.base_seed, user_id)),
            FleetPolicy::PinnedAllReserved(p) => p.reset(),
            FleetPolicy::PinnedSeparate(p) => p.reset(),
            FleetPolicy::Ucb(p) => p.reseed(per_user_seed(self.base_seed, user_id)),
            FleetPolicy::AdaptiveWindow(p) => p.reset(),
        }
        self.ledger.reset();
    }

    /// Replay one user's demand curve: the allocation-free inner loop of
    /// the batched engine.
    pub fn replay(&mut self, demand: &[u32], user_id: u32) -> UserResult {
        self.prepare(user_id);
        let w = self.w;
        let len = demand.len();
        for (t, &d) in demand.iter().enumerate() {
            let fut: &[u32] = if w == 0 {
                &[]
            } else {
                // Borrowed future window [t+1, t+w] (shrinking at the tail).
                &demand[t + 1..(t + 1 + w).min(len)]
            };
            let dec = self.policy.decide(d, fut);
            self.ledger
                .bill(d, &dec)
                .unwrap_or_else(|e| panic!("user {user_id}: infeasible decision: {e}"));
        }
        let report = self.ledger.report();
        let denom = all_on_demand_cost(demand, self.p);
        let normalized = if denom > 0.0 { report.total / denom } else { 1.0 };
        UserResult {
            user_id,
            group: classify(&summarize_u32(demand)),
            normalized_cost: normalized,
            absolute_cost: report.total,
            reservations: report.reservations,
        }
    }

    /// Serialize the runner's dynamic state (policy + ledger) for a
    /// checkpoint. `replay` rewinds everything per user, so restoring this
    /// state is about snapshot fidelity — results never depend on runner
    /// state carried across users — but it means a resumed process is
    /// byte-for-byte in the state the killed one checkpointed, RNG words
    /// and expiry queues included.
    pub fn save_state_bytes(&self) -> Vec<u8> {
        let mut w = StateWriter::new();
        self.policy.save_state(&mut w);
        self.ledger.save_state(&mut w);
        w.into_bytes()
    }

    /// Restore state serialized by
    /// [`save_state_bytes`](ShardRunner::save_state_bytes). The runner must
    /// have been built from the same spec + market.
    pub fn restore_state_bytes(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = StateReader::new(bytes);
        self.policy.restore_state(&mut r).context("restore policy state")?;
        self.ledger.restore_state(&mut r).context("restore ledger state")?;
        r.finish()
    }
}

/// Replay one user's demand curve through one policy (one-off form; shard
/// loops should hold a [`ShardRunner`] and call `replay` repeatedly).
pub fn replay_user(demand: &[u32], user_id: u32, market: &Market, spec: &PolicySpec) -> UserResult {
    ShardRunner::new(spec, market).replay(demand, user_id)
}

/// Shard `flat` into contiguous chunks across `threads` std threads and
/// append every user's result to `out` in input order. Per-user results
/// are independent of the sharding, so output is deterministic and
/// thread-count-invariant.
fn run_shards_into(
    flat: &FlatPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
    out: &mut Vec<UserResult>,
) {
    let n = flat.len();
    let threads = threads.max(1).min(n.max(1));
    let mut runners: Vec<ShardRunner> =
        (0..threads).map(|_| ShardRunner::new(spec, market)).collect();
    run_shards_over(&mut runners, flat, out);
}

/// Shard `flat` over a set of persistent [`ShardRunner`]s (at most
/// `runners.len()` threads, fewer when the population is smaller) and append
/// results to `out` in input order. The checkpointed chunk loop owns the
/// runners across chunks so their state can be snapshotted between chunks;
/// [`run_shards_into`] builds throwaway runners and delegates here.
fn run_shards_over(runners: &mut [ShardRunner], flat: &FlatPopulation, out: &mut Vec<UserResult>) {
    let n = flat.len();
    let threads = runners.len().max(1).min(n.max(1));
    let chunk = if n == 0 { 0 } else { n.div_ceil(threads) };
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for (shard, runner) in runners.iter_mut().enumerate().take(threads) {
            let lo = shard * chunk;
            let hi = ((shard + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            handles.push(scope.spawn(move || {
                (lo..hi)
                    .map(|i| runner.replay(flat.demand(i), flat.user_id(i)))
                    .collect::<Vec<UserResult>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("fleet shard panicked"));
        }
    });
}

/// Run one policy spec over a columnar population, sharded into contiguous
/// chunks across `threads` std threads. Results are deterministic and
/// independent of the thread count.
pub fn run_fleet_flat(
    flat: &FlatPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> FleetResult {
    let mut per_user: Vec<UserResult> = Vec::with_capacity(flat.len());
    run_shards_into(flat, market, spec, threads, &mut per_user);
    // Chunking already preserves input order; sort by user id to keep the
    // reference path's output contract for arbitrarily ordered populations.
    per_user.sort_by_key(|u| u.user_id);
    FleetResult { policy: spec.name(), per_user }
}

/// What to do when a chunk fails its checksum (or decodes corrupt) and
/// retries are exhausted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnCorrupt {
    /// Abort the run with the chunk's typed error (the default).
    #[default]
    Fail,
    /// Skip the chunk, record a [`QuarantinedChunk`], and keep replaying.
    Skip,
}

/// Knobs for the crash-recoverable chunked replay path. The default is
/// exactly the old behavior: no checkpointing, no fault injection, fail on
/// the first corrupt chunk (transient I/O errors still get a short retry).
#[derive(Debug, Clone, Copy)]
pub struct RecoveryOptions<'a> {
    /// Where to write checkpoints (and read them from on resume); `None`
    /// disables checkpointing entirely.
    pub checkpoint_path: Option<&'a Path>,
    /// Checkpoint every N completed chunks (a final checkpoint is always
    /// written when a path is set); `0` means final-only.
    pub checkpoint_every: usize,
    /// Load `checkpoint_path` (or its `.prev` fallback) and resume from its
    /// `next_chunk` instead of starting at chunk 0.
    pub resume: bool,
    pub on_corrupt: OnCorrupt,
    /// Bounded retries for *transient* read errors (I/O). Checksum and
    /// decode failures are deterministic and never retried.
    pub max_read_retries: u32,
    /// Base backoff in milliseconds (doubles per retry, capped).
    pub retry_base_ms: u64,
    /// Deterministic failpoint plan; `None` or an unarmed plan is inert.
    pub faults: Option<&'a FaultPlan>,
}

impl Default for RecoveryOptions<'_> {
    fn default() -> Self {
        RecoveryOptions {
            checkpoint_path: None,
            checkpoint_every: 0,
            resume: false,
            on_corrupt: OnCorrupt::Fail,
            max_read_retries: 2,
            retry_base_ms: 10,
            faults: None,
        }
    }
}

/// What a recoverable chunked run did, beyond the per-user sink calls.
#[derive(Debug, Clone)]
pub struct ChunkedRunOutcome {
    /// Aggregate over every user folded so far — including users replayed
    /// by the checkpointed predecessor run when resuming.
    pub aggregate: FleetAggregate,
    /// Chunks skipped under [`OnCorrupt::Skip`], in order (carried forward
    /// across resumes).
    pub quarantined: Vec<QuarantinedChunk>,
    /// First chunk this process replayed, when resumed from a checkpoint.
    pub resumed_from_chunk: Option<u64>,
    /// True when the newest checkpoint was unusable and `.prev` was loaded.
    pub used_fallback_checkpoint: bool,
    pub checkpoints_written: u64,
    /// Chunks replayed by THIS process (excludes checkpointed + skipped).
    pub chunks_replayed: u64,
}

/// Read chunk `c` with bounded retry-with-backoff for transient I/O errors.
/// Injected faults (when armed) fire per attempt: `ReadError` manufactures
/// a retryable I/O error, `BitFlip` corrupts the payload before checksum
/// verification (deterministic, so it is *not* retried — the same flip
/// would fire again — and surfaces as [`ChunkCorrupt`]).
fn read_chunk_with_retry(
    chunked: &mut ChunkedPopulation,
    c: usize,
    buf: &mut FlatPopulation,
    opts: &RecoveryOptions<'_>,
) -> anyhow::Result<()> {
    let mut attempt: u32 = 0;
    loop {
        let injected = opts.faults.and_then(|p| p.check(site::TRACE_READ, c as u64, attempt));
        let result = match injected {
            Some(Fault::ReadError) => Err(anyhow::Error::new(std::io::Error::other(format!(
                "injected transient read error (chunk {c}, attempt {attempt})"
            )))),
            Some(Fault::BitFlip { byte, bit }) => {
                chunked.read_chunk_into_with(c, buf, Some((byte, bit)))
            }
            // Kill/TornWrite don't apply to the read site; read normally.
            Some(Fault::Kill) | Some(Fault::TornWrite { .. }) | None => {
                chunked.read_chunk_into(c, buf)
            }
        };
        let err = match result {
            Ok(()) => return Ok(()),
            Err(e) => e,
        };
        let transient = err.downcast_ref::<std::io::Error>().is_some()
            && err.downcast_ref::<ChunkCorrupt>().is_none();
        if transient && attempt < opts.max_read_retries {
            std::thread::sleep(backoff_delay(attempt, opts.retry_base_ms));
            attempt += 1;
            continue;
        }
        return Err(err);
    }
}

/// The crash-recoverable chunk loop behind [`for_each_user_chunked`]:
/// streams chunks through persistent shard runners, folds every user into a
/// [`FleetAggregate`] (and `sink`), checkpoints at chunk boundaries, and —
/// on resume — picks up bit-identically where the checkpoint left off
/// (per-user results are sharding-independent, and the aggregate's
/// sequential f64 sums restore their exact bits).
///
/// On resume, users already folded into the checkpointed aggregate are NOT
/// re-fed to `sink`; the returned aggregate covers the whole fleet.
pub fn for_each_user_chunked_recoverable(
    chunked: &mut ChunkedPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
    opts: &RecoveryOptions<'_>,
    mut sink: impl FnMut(&UserResult),
) -> anyhow::Result<ChunkedRunOutcome> {
    let trace_fp = chunked.fingerprint64();
    let market_fp = market_fingerprint(market);
    let spec_fp = spec_fingerprint(spec);
    let n_chunks = chunked.n_chunks() as u64;

    let threads = threads.max(1);
    let mut runners: Vec<ShardRunner> =
        (0..threads).map(|_| ShardRunner::new(spec, market)).collect();
    let mut aggregate = FleetAggregate::new();
    let mut quarantined: Vec<QuarantinedChunk> = Vec::new();
    let mut start_chunk = 0u64;
    let mut resumed_from_chunk = None;
    let mut used_fallback_checkpoint = false;

    if opts.resume {
        let path = opts
            .checkpoint_path
            .ok_or_else(|| anyhow::anyhow!("resume requested without a checkpoint path"))?;
        let (ckpt, used_fallback) = Checkpoint::load(path)?;
        ckpt.ensure_matches(trace_fp, market_fp, spec_fp, n_chunks)
            .with_context(|| format!("checkpoint {path:?} does not match this run"))?;
        // Same shard count: restore each runner to its checkpointed state
        // (RNG words, queues, ledger). A different count is harmless —
        // per-user results never depend on state carried across users — so
        // fresh runners are used instead.
        if ckpt.runners.len() == runners.len() {
            for (runner, blob) in runners.iter_mut().zip(&ckpt.runners) {
                runner
                    .restore_state_bytes(blob)
                    .with_context(|| format!("restore shard runner from {path:?}"))?;
            }
        }
        aggregate = ckpt.aggregate;
        quarantined = ckpt.quarantined;
        start_chunk = ckpt.next_chunk;
        resumed_from_chunk = Some(start_chunk);
        used_fallback_checkpoint = used_fallback;
    }

    let every = if opts.checkpoint_every == 0 { u64::MAX } else { opts.checkpoint_every as u64 };
    let mut buf = FlatPopulation::default();
    let mut chunk_results: Vec<UserResult> = Vec::new();
    let mut checkpoints_written = 0u64;
    let mut chunks_replayed = 0u64;

    for c in (start_chunk as usize)..chunked.n_chunks() {
        match read_chunk_with_retry(chunked, c, &mut buf, opts) {
            Ok(()) => {
                chunk_results.clear();
                run_shards_over(&mut runners, &buf, &mut chunk_results);
                for u in &chunk_results {
                    aggregate.merge(u);
                    sink(u);
                }
                chunks_replayed += 1;
            }
            Err(e) => match opts.on_corrupt {
                OnCorrupt::Fail => {
                    return Err(e.context(format!("chunk {c}: unrecoverable, aborting run")))
                }
                OnCorrupt::Skip => {
                    let m = chunked.chunk_meta(c);
                    quarantined.push(QuarantinedChunk {
                        chunk: c,
                        offset: m.offset,
                        byte_len: m.byte_len,
                        users_skipped: m.users_in_chunk,
                        error: format!("{e:#}"),
                    });
                }
            },
        }
        let done = c as u64 + 1;
        if let Some(path) = opts.checkpoint_path {
            if done % every == 0 || done == n_chunks {
                let ckpt = Checkpoint {
                    trace_fp,
                    market_fp,
                    spec_fp,
                    n_chunks,
                    next_chunk: done,
                    aggregate: aggregate.clone(),
                    quarantined: quarantined.clone(),
                    runners: runners.iter().map(ShardRunner::save_state_bytes).collect(),
                };
                ckpt.write_atomic(path, opts.faults)
                    .with_context(|| format!("write checkpoint after chunk {c}"))?;
                checkpoints_written += 1;
            }
        }
        // Kill-point AFTER the checkpoint write: a resume from this crash
        // restarts at `done`, never replaying a chunk twice.
        if let Some(plan) = opts.faults {
            if matches!(plan.check(site::FLEET_AFTER_CHUNK, c as u64, 0), Some(Fault::Kill)) {
                return Err(anyhow::Error::new(KillPoint {
                    site: site::FLEET_AFTER_CHUNK,
                    key: c as u64,
                }));
            }
        }
    }

    Ok(ChunkedRunOutcome {
        aggregate,
        quarantined,
        resumed_from_chunk,
        used_fallback_checkpoint,
        checkpoints_written,
        chunks_replayed,
    })
}

/// Stream a chunked trace file through the engine, feeding each user's
/// result to `sink` in file order. Resident memory is O(one chunk): the
/// chunk buffer and the per-chunk result vector are reused across chunks,
/// so a 10⁶-user fleet replays in the footprint of `chunk_users` users.
/// Per-user results are bit-identical to [`run_fleet_flat`] over the same
/// fleet (sharding never crosses a user).
///
/// This is the no-recovery convenience form of
/// [`for_each_user_chunked_recoverable`] (default [`RecoveryOptions`]: no
/// checkpoints, no faults, fail on corruption).
pub fn for_each_user_chunked(
    chunked: &mut ChunkedPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
    mut sink: impl FnMut(&UserResult),
) -> anyhow::Result<()> {
    for_each_user_chunked_recoverable(
        chunked,
        market,
        spec,
        threads,
        &RecoveryOptions::default(),
        |u| sink(u),
    )
    .map(|_| ())
}

/// Run one policy spec over a chunked trace file, collecting the full
/// per-user result vector (bit-identical to [`run_fleet_flat`] on the
/// equivalent in-RAM population). For fleets too large to hold even the
/// results in memory, use [`for_each_user_chunked`] with a streaming sink
/// such as [`crate::sim::fleet::FleetAggregate`].
pub fn run_fleet_chunked(
    chunked: &mut ChunkedPopulation,
    market: &Market,
    spec: &PolicySpec,
    threads: usize,
) -> anyhow::Result<FleetResult> {
    let mut per_user: Vec<UserResult> = Vec::with_capacity(chunked.n_users());
    for_each_user_chunked(chunked, market, spec, threads, |u| per_user.push(u.clone()))?;
    per_user.sort_by_key(|u| u.user_id);
    Ok(FleetResult { policy: spec.name(), per_user })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::{Contract, Pricing};
    use crate::trace::synth::{generate, SynthConfig};

    fn market() -> Market {
        Market::single(Pricing::normalized(0.08 / 69.0, 0.4875, 1000))
    }

    /// Borrowed future window `[t+1, t+w]` (empty for purely online).
    fn fut_at(demand: &[u32], w: usize, t: usize) -> &[u32] {
        if w == 0 {
            &[]
        } else {
            &demand[t + 1..(t + 1 + w).min(demand.len())]
        }
    }

    fn menu_market() -> Market {
        // break-evens (167 / 188 violation-slots) fit the short test traces
        // so the menu policies actually commit; both contracts survive
        // dominance pruning.
        let m = Market::new(
            0.01,
            vec![
                Contract { upfront: 1.0, rate: 0.004, term: 600 },
                Contract { upfront: 1.5, rate: 0.002, term: 1800 },
            ],
        );
        assert_eq!(m.len(), 2);
        m
    }

    fn specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::AllOnDemand,
            PolicySpec::AllReserved,
            PolicySpec::Separate,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: Some(0.4), window: 40 },
            PolicySpec::Randomized { window: 0, seed: 11 },
            PolicySpec::Ucb { seed: 11 },
            PolicySpec::AdaptiveWindow,
        ]
    }

    /// Specs valid for multi-contract menus (no custom z; windows are a
    /// feature path now, `w < min τ`).
    fn menu_specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::AllOnDemand,
            PolicySpec::AllReserved,
            PolicySpec::Separate,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: None, window: 40 },
            PolicySpec::Randomized { window: 0, seed: 11 },
            PolicySpec::Randomized { window: 25, seed: 11 },
            PolicySpec::Ucb { seed: 11 },
            PolicySpec::AdaptiveWindow,
        ]
    }

    #[test]
    fn fleet_policy_matches_boxed_dispatch() {
        // The enum's decide must reproduce the trait-object path exactly —
        // on both the single-contract fast path and the menu path.
        let pop = generate(&SynthConfig { users: 6, slots: 1200, seed: 3, ..Default::default() });
        for (mkt, specs) in [(market(), specs()), (menu_market(), menu_specs())] {
            for spec in specs {
                for u in &pop.users {
                    let mut fast = FleetPolicy::build(&spec, &mkt, u.user_id);
                    let mut slow = spec.build(&mkt, u.user_id);
                    assert_eq!(fast.window(), slow.window());
                    let w = fast.window();
                    for (t, &d) in u.demand.iter().enumerate() {
                        let hi = (t + 1 + w).min(u.demand.len());
                        let fut = &u.demand[t + 1..hi];
                        let fut = if w == 0 { &[] as &[u32] } else { fut };
                        assert_eq!(
                            fast.decide(d, fut),
                            slow.decide(d, fut),
                            "{} user {} slot {t} (menu k={})",
                            spec.name(),
                            u.user_id,
                            mkt.len()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fleet_policy_save_restore_resumes_mid_user() {
        // Snapshot every policy variant mid-replay and restore into an
        // instance built for a DIFFERENT user (different per-user RNG seed):
        // the continued decision streams must match exactly, proving the
        // snapshot captures all dynamic state including the random draw.
        let pop = generate(&SynthConfig { users: 2, slots: 800, seed: 6, ..Default::default() });
        let u = &pop.users[0];
        for (mkt, specs) in [(market(), specs()), (menu_market(), menu_specs())] {
            for spec in specs {
                let mut original = FleetPolicy::build(&spec, &mkt, u.user_id);
                let w = original.window();
                let cut = 300;
                for (t, &d) in u.demand.iter().enumerate().take(cut) {
                    original.decide(d, fut_at(&u.demand, w, t));
                }
                let mut sw = StateWriter::new();
                original.save_state(&mut sw);
                let bytes = sw.into_bytes();
                let mut restored = FleetPolicy::build(&spec, &mkt, u.user_id ^ 1);
                let mut sr = StateReader::new(&bytes);
                restored.restore_state(&mut sr).unwrap();
                sr.finish().unwrap();
                for (t, &d) in u.demand.iter().enumerate().skip(cut) {
                    assert_eq!(
                        original.decide(d, fut_at(&u.demand, w, t)),
                        restored.decide(d, fut_at(&u.demand, w, t)),
                        "{} slot {t} (menu k={})",
                        spec.name(),
                        mkt.len()
                    );
                }
            }
        }
    }

    #[test]
    fn shard_runner_state_bytes_round_trip() {
        let pop = generate(&SynthConfig { users: 3, slots: 700, seed: 8, ..Default::default() });
        for (mkt, spec) in [
            (market(), PolicySpec::Randomized { window: 0, seed: 11 }),
            (menu_market(), PolicySpec::Deterministic { z: None, window: 0 }),
        ] {
            let mut a = ShardRunner::new(&spec, &mkt);
            a.replay(&pop.users[0].demand, pop.users[0].user_id);
            let blob = a.save_state_bytes();
            let mut b = ShardRunner::new(&spec, &mkt);
            b.restore_state_bytes(&blob).unwrap();
            // both runners continue identically from the snapshot
            for u in &pop.users[1..] {
                let ra = a.replay(&u.demand, u.user_id);
                let rb = b.replay(&u.demand, u.user_id);
                assert_eq!(ra.normalized_cost.to_bits(), rb.normalized_cost.to_bits());
                assert_eq!(ra.absolute_cost.to_bits(), rb.absolute_cost.to_bits());
                assert_eq!(ra.reservations, rb.reservations);
            }
        }
    }

    #[test]
    fn restore_rejects_cross_variant_blobs() {
        let mkt = market();
        let det = ShardRunner::new(&PolicySpec::Deterministic { z: None, window: 0 }, &mkt);
        let blob = det.save_state_bytes();
        let mut rand = ShardRunner::new(&PolicySpec::Randomized { window: 0, seed: 1 }, &mkt);
        let err = rand.restore_state_bytes(&blob).unwrap_err();
        assert!(format!("{err:#}").contains("variant"), "unexpected: {err:#}");
    }

    #[test]
    fn chunked_sharding_is_thread_count_invariant() {
        let pop = generate(&SynthConfig { users: 17, slots: 1500, seed: 9, ..Default::default() });
        let flat = pop.flatten();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        for mkt in [market(), menu_market()] {
            let one = run_fleet_flat(&flat, &mkt, &spec, 1);
            for threads in [2usize, 3, 8, 64] {
                let many = run_fleet_flat(&flat, &mkt, &spec, threads);
                assert_eq!(one.per_user.len(), many.per_user.len());
                for (a, b) in one.per_user.iter().zip(&many.per_user) {
                    assert_eq!(a.user_id, b.user_id);
                    assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
                    assert_eq!(a.absolute_cost.to_bits(), b.absolute_cost.to_bits());
                    assert_eq!(a.reservations, b.reservations);
                }
            }
        }
    }

    #[test]
    fn empty_population_yields_empty_result() {
        let flat = FlatPopulation::default();
        let r = run_fleet_flat(&flat, &market(), &PolicySpec::AllOnDemand, 4);
        assert!(r.per_user.is_empty());
    }

    #[test]
    fn chunked_replay_matches_in_ram_engine() {
        // Full policy x chunk-size x thread-count coverage lives in
        // tests/engine_parity.rs; this is the in-tree smoke check.
        let pop = generate(&SynthConfig { users: 13, slots: 900, seed: 4, ..Default::default() });
        let flat = pop.flatten();
        let dir = std::env::temp_dir().join("cloudreserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("engine_chunked_{}", std::process::id()));
        crate::trace::io::write_chunked(&pop, &path, 4).unwrap();
        let spec = PolicySpec::Randomized { window: 0, seed: 11 };
        for mkt in [market(), menu_market()] {
            let in_ram = run_fleet_flat(&flat, &mkt, &spec, 3);
            let mut chunked = ChunkedPopulation::open(&path).unwrap();
            let streamed = run_fleet_chunked(&mut chunked, &mkt, &spec, 3).unwrap();
            assert_eq!(in_ram.per_user.len(), streamed.per_user.len());
            for (a, b) in in_ram.per_user.iter().zip(&streamed.per_user) {
                assert_eq!(a.user_id, b.user_id);
                assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
                assert_eq!(a.absolute_cost.to_bits(), b.absolute_cost.to_bits());
                assert_eq!(a.reservations, b.reservations);
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "single-contract only")]
    fn menu_rejects_custom_thresholds() {
        FleetPolicy::build(
            &PolicySpec::Deterministic { z: Some(0.4), window: 0 },
            &menu_market(),
            0,
        );
    }

    #[test]
    #[should_panic(expected = "shorter than every term")]
    fn menu_rejects_windows_at_least_min_term() {
        // min term on the menu is 600
        FleetPolicy::build(
            &PolicySpec::Deterministic { z: None, window: 600 },
            &menu_market(),
            0,
        );
    }

    #[test]
    fn menu_windows_take_the_market_policy_path() {
        let mut p = FleetPolicy::build(
            &PolicySpec::Deterministic { z: None, window: 10 },
            &menu_market(),
            0,
        );
        assert_eq!(p.window(), 10);
        let fut = [1u32; 10];
        let dec = p.decide(1, &fut);
        assert!(dec.on_demand <= 1);
    }
}
