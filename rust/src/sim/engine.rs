//! The batched fleet replay engine: zero-allocation per slot, monomorphic
//! policy dispatch, contiguous-memory traversal.
//!
//! The seed fleet runner walked 933 heap-scattered `Vec<u32>` curves
//! through `Box<dyn Policy>` with a per-slot `to_vec()` of the future
//! window, sharded by striding (`idx += threads`) over an `mpsc` channel.
//! This engine replaces all three costs:
//!
//! * **dispatch** — [`FleetPolicy`] is an enum over the five Sec. VII
//!   policies; the per-slot `decide` is a direct `match`, so each arm
//!   monomorphizes and inlines ([`crate::algos::Policy`] stays as the
//!   extensibility trait — anything exotic still runs through the boxed
//!   reference path in [`super::fleet::run_fleet_reference`]);
//! * **allocation** — future windows are borrowed sub-slices of the demand
//!   curve (see [`crate::sim::OracleFuture`] for the single-user form);
//!   nothing allocates inside the slot loop;
//! * **locality** — shards replay contiguous *chunks* of the columnar
//!   [`FlatPopulation`] store, streaming one flat buffer front to back
//!   instead of pointer-chasing per-user vectors, and results come back in
//!   order without a channel.
//!
//! Numerical contract: for every policy the engine performs the exact same
//! arithmetic in the exact same order as [`crate::sim::run_policy`], so
//! results are **bit-identical** to the reference path — enforced by
//! `rust/tests/engine_parity.rs`.

use crate::algos::baselines::{AllOnDemand, AllReserved, Separate};
use crate::algos::deterministic::Deterministic;
use crate::algos::randomized::Randomized;
use crate::algos::{Decision, Policy};
use crate::analysis::classify::classify;
use crate::ledger::Ledger;
use crate::pricing::Pricing;
use crate::sim::all_on_demand_cost;
use crate::sim::fleet::{FleetResult, PolicySpec, UserResult};
use crate::trace::FlatPopulation;
use crate::util::stats::summarize_u32;

/// Statically dispatched per-user policy state for the fleet hot path.
/// One variant per Sec. VII policy; construction mirrors
/// [`PolicySpec::build`] exactly (including the per-user randomized seed)
/// so both paths replay identical decision sequences.
pub enum FleetPolicy {
    AllOnDemand(AllOnDemand),
    AllReserved(AllReserved),
    Separate(Separate),
    Deterministic(Deterministic),
    Randomized(Randomized),
}

impl FleetPolicy {
    /// Instantiate for one user (the monomorphic mirror of
    /// [`PolicySpec::build`]).
    pub fn build(spec: &PolicySpec, pricing: Pricing, user_id: u32) -> FleetPolicy {
        match *spec {
            PolicySpec::AllOnDemand => FleetPolicy::AllOnDemand(AllOnDemand::new()),
            PolicySpec::AllReserved => FleetPolicy::AllReserved(AllReserved::new(pricing)),
            PolicySpec::Separate => FleetPolicy::Separate(Separate::new(pricing)),
            PolicySpec::Deterministic { z, window } => {
                let z = z.unwrap_or_else(|| pricing.beta());
                FleetPolicy::Deterministic(Deterministic::new(pricing, z, window))
            }
            PolicySpec::Randomized { window, seed } => FleetPolicy::Randomized(
                Randomized::with_window(pricing, window, seed ^ ((user_id as u64) << 17)),
            ),
        }
    }

    /// Per-slot decision — a direct match, no vtable.
    #[inline]
    pub fn decide(&mut self, demand: u32, future: &[u32]) -> Decision {
        match self {
            FleetPolicy::AllOnDemand(p) => p.decide(demand, future),
            FleetPolicy::AllReserved(p) => p.decide(demand, future),
            FleetPolicy::Separate(p) => p.decide(demand, future),
            FleetPolicy::Deterministic(p) => p.decide(demand, future),
            FleetPolicy::Randomized(p) => p.decide(demand, future),
        }
    }

    /// Prediction window the policy wants (0 for purely online).
    pub fn window(&self) -> usize {
        match self {
            FleetPolicy::AllOnDemand(p) => p.window(),
            FleetPolicy::AllReserved(p) => p.window(),
            FleetPolicy::Separate(p) => p.window(),
            FleetPolicy::Deterministic(p) => p.window(),
            FleetPolicy::Randomized(p) => p.window(),
        }
    }
}

/// Replay one user's demand curve through one policy: the allocation-free
/// inner loop of the batched engine.
pub fn replay_user(demand: &[u32], user_id: u32, pricing: Pricing, spec: &PolicySpec) -> UserResult {
    let mut policy = FleetPolicy::build(spec, pricing, user_id);
    let w = policy.window();
    let len = demand.len();
    let mut ledger = Ledger::new(pricing);
    for (t, &d) in demand.iter().enumerate() {
        let fut: &[u32] = if w == 0 {
            &[]
        } else {
            // Borrowed future window [t+1, t+w] (shrinking at the tail).
            &demand[t + 1..(t + 1 + w).min(len)]
        };
        let dec = policy.decide(d, fut);
        ledger
            .bill_slot(d, dec.reserve, dec.on_demand)
            .unwrap_or_else(|e| panic!("user {user_id}: infeasible decision: {e}"));
    }
    let report = ledger.report();
    let denom = all_on_demand_cost(demand, &pricing);
    let normalized = if denom > 0.0 { report.total / denom } else { 1.0 };
    UserResult {
        user_id,
        group: classify(&summarize_u32(demand)),
        normalized_cost: normalized,
        absolute_cost: report.total,
        reservations: report.reservations,
    }
}

/// Run one policy spec over a columnar population, sharded into contiguous
/// chunks across `threads` std threads. Results are deterministic and
/// independent of the thread count.
pub fn run_fleet_flat(
    flat: &FlatPopulation,
    pricing: Pricing,
    spec: &PolicySpec,
    threads: usize,
) -> FleetResult {
    let n = flat.len();
    let threads = threads.max(1).min(n.max(1));
    let chunk = if n == 0 { 0 } else { (n + threads - 1) / threads };
    let mut per_user: Vec<UserResult> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for shard in 0..threads {
            let lo = shard * chunk;
            let hi = ((shard + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            handles.push(scope.spawn(move || {
                (lo..hi)
                    .map(|i| replay_user(flat.demand(i), flat.user_id(i), pricing, spec))
                    .collect::<Vec<UserResult>>()
            }));
        }
        for h in handles {
            per_user.extend(h.join().expect("fleet shard panicked"));
        }
    });
    // Chunking already preserves input order; sort by user id to keep the
    // reference path's output contract for arbitrarily ordered populations.
    per_user.sort_by_key(|u| u.user_id);
    FleetResult { policy: spec.name(), per_user }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{generate, SynthConfig};

    fn pricing() -> Pricing {
        Pricing::normalized(0.08 / 69.0, 0.4875, 1000)
    }

    fn specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::AllOnDemand,
            PolicySpec::AllReserved,
            PolicySpec::Separate,
            PolicySpec::Deterministic { z: None, window: 0 },
            PolicySpec::Deterministic { z: Some(0.4), window: 40 },
            PolicySpec::Randomized { window: 0, seed: 11 },
        ]
    }

    #[test]
    fn fleet_policy_matches_boxed_dispatch() {
        // The enum's decide must reproduce the trait-object path exactly.
        let pop = generate(&SynthConfig { users: 6, slots: 1200, seed: 3, ..Default::default() });
        for spec in specs() {
            for u in &pop.users {
                let mut fast = FleetPolicy::build(&spec, pricing(), u.user_id);
                let mut slow = spec.build(pricing(), u.user_id);
                assert_eq!(fast.window(), slow.window());
                let w = fast.window();
                for (t, &d) in u.demand.iter().enumerate() {
                    let hi = (t + 1 + w).min(u.demand.len());
                    let fut = &u.demand[t + 1..hi];
                    let fut = if w == 0 { &[] as &[u32] } else { fut };
                    assert_eq!(
                        fast.decide(d, fut),
                        slow.decide(d, fut),
                        "{} user {} slot {t}",
                        spec.name(),
                        u.user_id
                    );
                }
            }
        }
    }

    #[test]
    fn chunked_sharding_is_thread_count_invariant() {
        let pop = generate(&SynthConfig { users: 17, slots: 1500, seed: 9, ..Default::default() });
        let flat = pop.flatten();
        let spec = PolicySpec::Deterministic { z: None, window: 0 };
        let one = run_fleet_flat(&flat, pricing(), &spec, 1);
        for threads in [2usize, 3, 8, 64] {
            let many = run_fleet_flat(&flat, pricing(), &spec, threads);
            assert_eq!(one.per_user.len(), many.per_user.len());
            for (a, b) in one.per_user.iter().zip(&many.per_user) {
                assert_eq!(a.user_id, b.user_id);
                assert_eq!(a.normalized_cost.to_bits(), b.normalized_cost.to_bits());
                assert_eq!(a.absolute_cost.to_bits(), b.absolute_cost.to_bits());
                assert_eq!(a.reservations, b.reservations);
            }
        }
    }

    #[test]
    fn empty_population_yields_empty_result() {
        let flat = FlatPopulation::default();
        let r = run_fleet_flat(&flat, pricing(), &PolicySpec::AllOnDemand, 4);
        assert!(r.per_user.is_empty());
    }
}
